"""Check that relative markdown links in README/docs resolve to real files.

Scans every tracked ``*.md`` at the repo root and under ``docs/`` for
``[text](target)`` links; external targets (http/https/mailto) are
skipped, ``#anchors`` are stripped, and the remaining path must exist
relative to the file that references it. Exit 1 on any dangling link.

  python tools/check_doc_links.py
"""
from __future__ import annotations

import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def check(root: str = ".") -> int:
    files = sorted(glob.glob(os.path.join(root, "*.md")) +
                   glob.glob(os.path.join(root, "docs", "*.md")))
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    bad = 0
    for path in files:
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        for target in LINK_RE.findall(text):
            if target.startswith(SKIP_PREFIXES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                print(f"DANGLING {path}: ({target}) -> {resolved}")
                bad += 1
    print(f"checked {len(files)} files: "
          f"{'OK' if not bad else f'{bad} dangling link(s)'}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(check())
