"""CI perf gate: fail on serve-path regressions vs the committed baseline.

Compares a freshly collected serve artifact (``benchmarks.run --json
--quick`` or ``benchmarks.measured``) against the committed one and
fails when a tracked metric regresses by more than ``--tolerance``
(default 20%):

- ``decode_tokens_per_s``       lower is worse
- ``ttft_s``                    higher is worse
- ``spec_tokens_per_s``         lower is worse (when both files carry it)
- ``moe_tokens_per_s``          lower is worse (when both files carry it)
- ``kv_tokens_per_s``           lower is worse (when both files carry it)
- ``p50_ttft_s``                higher is worse (replayed traffic)
- ``p99_ttft_s``                higher is worse (replayed traffic)
- ``goodput_tokens_per_s``      lower is worse (replayed traffic)

Artifacts are per-platform: a blob carrying a ``platform`` key is only
gated against a committed artifact of the SAME platform. The committed
side resolves in order: ``--artifact`` (explicit), then
``BENCH_serve.<platform>.json`` next to ``--baseline`` when the new blob
names its platform and that file exists, then ``--baseline`` itself.
When both sides carry a platform and they differ, the gate prints a
notice and exits 0 — a TPU trajectory must never fail a CPU runner.

Wall-clock metrics vary across machines, so the gate is a guard against
step-function regressions (a retrace on the decode path, a lost launch
fusion), not a micro-benchmark. Usage::

    python -m benchmarks.run --json /tmp/bench_new.json --quick
    python tools/perf_gate.py /tmp/bench_new.json [--baseline BENCH_serve.json]
    python tools/perf_gate.py /tmp/bench_measured.json \
        --artifact BENCH_serve.cpu.json --tolerance 0.5
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Tuple

# metric -> direction; +1 means higher-is-better, -1 means lower-is-better
METRICS = {
    "decode_tokens_per_s": +1,
    "ttft_s": -1,
    "spec_tokens_per_s": +1,
    "moe_tokens_per_s": +1,
    "kv_tokens_per_s": +1,
    "p50_ttft_s": -1,
    "p99_ttft_s": -1,
    "goodput_tokens_per_s": +1,
}


def check(new: dict, base: dict,
          tolerance: float) -> Tuple[List[str], List[str]]:
    """Gate ``new`` against ``base``; returns ``(failures, compared)`` —
    the regressed metric names and every metric present in BOTH blobs
    (the caller reports the comparison surface so a silently shrunk
    artifact is visible in the log)."""
    failures, compared = [], []
    for name, sign in METRICS.items():
        if name not in base or name not in new:
            continue            # metric added after the baseline landed
        b, n = float(base[name]), float(new[name])
        if b <= 0:
            continue
        compared.append(name)
        ratio = n / b if sign > 0 else b / n if n > 0 else 0.0
        verdict = "ok" if ratio >= 1.0 - tolerance else "FAIL"
        print(f"{name}: baseline={b:.4g} new={n:.4g} "
              f"ratio={ratio:.3f} {verdict}")
        if verdict == "FAIL":
            failures.append(name)
    return failures, compared


def resolve_baseline(new: dict, baseline: str,
                     artifact: Optional[str]) -> str:
    """The committed artifact to gate against: explicit ``--artifact``
    wins; else the per-platform ``BENCH_serve.<platform>.json`` sibling
    of ``--baseline`` when the new blob is from the MEASURED suite,
    names its platform, and the file exists; else ``--baseline``.

    The suite guard keeps the two artifact families apart: per-platform
    siblings are written by ``benchmarks.measured`` (tiny fixed kernels),
    while ``BENCH_serve.json`` is written by ``benchmarks.run`` (engine
    fixtures) — their metrics share names but not magnitudes, so a
    ``run`` blob must never auto-upgrade onto a ``measured`` sibling."""
    if artifact:
        return artifact
    plat = new.get("platform")
    if plat and new.get("suite") == "measured":
        sibling = os.path.join(os.path.dirname(baseline) or ".",
                               f"BENCH_serve.{plat}.json")
        if os.path.exists(sibling):
            return sibling
    return baseline


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly collected serve artifact")
    ap.add_argument("--baseline", default="BENCH_serve.json")
    ap.add_argument("--artifact", default=None,
                    help="explicit committed per-platform artifact "
                         "(overrides --baseline and auto-selection)")
    ap.add_argument("--tolerance", type=float, default=0.20)
    args = ap.parse_args()
    with open(args.new) as fh:
        new = json.load(fh)
    base_path = resolve_baseline(new, args.baseline, args.artifact)
    with open(base_path) as fh:
        base = json.load(fh)
    new_plat, base_plat = new.get("platform"), base.get("platform")
    if new_plat and base_plat and new_plat != base_plat:
        print(f"perf gate SKIPPED: committed artifact {base_path} is for "
              f"platform {base_plat!r}, this run is {new_plat!r} — "
              f"no matching trajectory to gate against")
        return 0
    new_suite, base_suite = new.get("suite"), base.get("suite")
    if new_suite and base_suite and new_suite != base_suite:
        print(f"perf gate SKIPPED: committed artifact {base_path} is the "
              f"{base_suite!r} suite, this run is {new_suite!r} — "
              f"same-named metrics are not comparable across suites")
        return 0
    failures, compared = check(new, base, args.tolerance)
    print(f"compared {len(compared)} metric(s) vs {base_path}: "
          f"{', '.join(compared) if compared else '(none)'}")
    if failures:
        print(f"perf gate FAILED: {', '.join(failures)} regressed "
              f">{args.tolerance:.0%} vs {base_path}")
        return 1
    print("perf gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
