"""CI perf gate: fail on serve-path regressions vs the committed baseline.

Compares a freshly collected ``BENCH_serve.json`` (``benchmarks.run
--json --quick``) against the committed one and fails when a tracked
metric regresses by more than ``--tolerance`` (default 20%):

- ``decode_tokens_per_s``       lower is worse
- ``ttft_s``                    higher is worse
- ``spec_tokens_per_s``         lower is worse (when both files carry it)
- ``moe_tokens_per_s``          lower is worse (when both files carry it)
- ``kv_tokens_per_s``           lower is worse (when both files carry it)
- ``p50_ttft_s``                higher is worse (replayed traffic)
- ``p99_ttft_s``                higher is worse (replayed traffic)
- ``goodput_tokens_per_s``      lower is worse (replayed traffic)

Wall-clock metrics vary across machines, so the gate is a guard against
step-function regressions (a retrace on the decode path, a lost launch
fusion), not a micro-benchmark. Usage::

    python -m benchmarks.run --json /tmp/bench_new.json --quick
    python tools/perf_gate.py /tmp/bench_new.json [--baseline BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import sys

# metric -> direction; +1 means higher-is-better, -1 means lower-is-better
METRICS = {
    "decode_tokens_per_s": +1,
    "ttft_s": -1,
    "spec_tokens_per_s": +1,
    "moe_tokens_per_s": +1,
    "kv_tokens_per_s": +1,
    "p50_ttft_s": -1,
    "p99_ttft_s": -1,
    "goodput_tokens_per_s": +1,
}


def check(new: dict, base: dict, tolerance: float) -> list:
    failures = []
    for name, sign in METRICS.items():
        if name not in base or name not in new:
            continue            # metric added after the baseline landed
        b, n = float(base[name]), float(new[name])
        if b <= 0:
            continue
        ratio = n / b if sign > 0 else b / n if n > 0 else 0.0
        verdict = "ok" if ratio >= 1.0 - tolerance else "FAIL"
        print(f"{name}: baseline={b:.4g} new={n:.4g} "
              f"ratio={ratio:.3f} {verdict}")
        if verdict == "FAIL":
            failures.append(name)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly collected BENCH_serve.json")
    ap.add_argument("--baseline", default="BENCH_serve.json")
    ap.add_argument("--tolerance", type=float, default=0.20)
    args = ap.parse_args()
    with open(args.new) as fh:
        new = json.load(fh)
    with open(args.baseline) as fh:
        base = json.load(fh)
    failures = check(new, base, args.tolerance)
    if failures:
        print(f"perf gate FAILED: {', '.join(failures)} regressed "
              f">{args.tolerance:.0%} vs {args.baseline}")
        return 1
    print("perf gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
