"""Runtime model adaptation under a fluctuating QoS budget (paper Fig. 1).

Sweeps system utilization over time; the planner adapts the target
precision per tick; the engine realizes it. Prints a text timeline.

  PYTHONPATH=src python examples/qos_adaptation.py
"""
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np


def main():
    from benchmarks.common import built_model
    from repro.serving import LatencyModel, QoSPlanner, ServingEngine

    cfg, params, model = built_model(targets=(3.25, 3.5, 4.0, 4.5, 4.75))
    engine = ServingEngine(cfg, params, model)
    # latency model parameterized at llama3-8b scale so the planner has a
    # real trade-off to make; the in-container tiny model then *realizes*
    # whatever target it picks.
    bytes_per_bit_8b = 7.0e9 / 8            # ~7B linear params
    planner = QoSPlanner(
        list(model.adaptations),
        LatencyModel(bytes_per_bit=bytes_per_bit_8b, overhead_s=2e-4),
        chips=1)

    rng = np.random.default_rng(1)
    tpot_budget = 6.0e-3
    print("tick | utilization | planned precision | realized eff bits")
    util = 0.1
    for tick in range(8):
        util = float(np.clip(util + rng.normal(0, 0.25), 0.0, 0.9))
        target = planner.plan(tpot_budget, util)
        prompt = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
        _, ebits = engine.generate(prompt, 8, target)
        bar = "#" * int(util * 20)
        print(f"{tick:4d} | {util:4.2f} {bar:<20s} | {target:5.2f}b"
              f"            | {np.mean(ebits):.2f}b")
    print("\nhigh load -> lower precision -> lower latency; "
          "slack -> higher precision. Runtime adaptation, one model.")


if __name__ == "__main__":
    main()
