"""Programmatic multi-pod dry-run for a single cell (the API the full
sweep in repro.launch.dryrun drives).

  PYTHONPATH=src python examples/multipod_dryrun.py --arch llama3-8b \
      --shape decode_32k --mesh multi
"""
import sys
sys.path.insert(0, "src")

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="multi", choices=["single", "multi"])
    args = ap.parse_args()

    # dryrun sets XLA_FLAGS before importing jax — import it first
    from repro.launch import dryrun
    rec = dryrun.run_cell(args.arch, args.shape, args.mesh)
    print("\nrecord:")
    for k, v in rec.items():
        if k != "trace":
            print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
