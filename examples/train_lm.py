"""Train a byte-level LM on the stdlib corpus, then build its DP-LLM
adaptation set — the artifacts the serving examples consume.

Default is the ~6M bench-lm (a few minutes on CPU); pass --arch train-100m
for the ~100M config on real hardware.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import sys
sys.path.insert(0, "src")

import argparse
import os
import pickle

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bench-lm")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="experiments/ckpt_example")
    ap.add_argument("--out", default="experiments/artifacts/example_lm.pkl")
    args = ap.parse_args()

    from repro.launch.train import train
    from repro.configs import get_config
    from repro.core import build_multiscale_model
    from benchmarks.common import calibration_batches

    print(f"training {args.arch} for {args.steps} steps "
          f"(checkpoints -> {args.ckpt_dir})")
    state, losses = train(args.arch, steps=args.steps, seq_len=256,
                          global_batch=8, lr=2e-3, ckpt_dir=args.ckpt_dir,
                          save_every=100)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    cfg = get_config(args.arch)
    from repro.models.stacked import group_size
    params = dict(state["glob"])
    g = group_size(cfg)
    for rel, arr in state["stack"].items():
        r, rest = rel.split(".", 1)
        for c in range(arr.shape[0]):
            params[f"layers.{int(r) + c * g}.{rest}"] = arr[c]

    print("building DP-LLM adaptation set (phases 1-3 + estimators)...")
    model = build_multiscale_model(
        cfg, params, calibration_batches(cfg), targets=[3.5, 4.0, 4.5],
        finetune_epochs=2, baselines=("llm_mq",))
    for t, aset in model.adaptations.items():
        print(f"  target {t}: avg_p={aset.avg_p:.3f} "
              f"census={aset.estimator_census()} "
              f"est_overhead={aset.estimator_overhead_bytes()/1e6:.2f}MB")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "wb") as fh:
        pickle.dump({"params": {k: np.asarray(v)
                                for k, v in params.items()},
                     "model": model}, fh)
    print(f"artifacts -> {args.out}")


if __name__ == "__main__":
    main()
