"""END-TO-END DRIVER: serve a small trained LM with continuous batching
and dynamic layer-wise precision (the paper's deployment scenario).

Loads the artifacts from examples/train_lm.py (or trains a fresh model),
then serves a stream of queries with per-query TPOT budgets through the
QoS planner -> slot scheduler -> DP-LLM engine: every admitted request
decodes in one shared compiled step with its own target index, and the
per-request effective bits feed the QoS tracker.

  PYTHONPATH=src python examples/serve_dynamic_precision.py
  PYTHONPATH=src python examples/serve_dynamic_precision.py --mesh local
(``--mesh local`` runs the same serve path mesh-native: slots shard over
the 'data' axis, weights/overlays over 'model' — one compiled tick.)
"""
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")

import argparse
import os
import pickle

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts",
                    default="experiments/artifacts/example_lm.pkl")
    ap.add_argument("--queries", type=int, default=5)
    ap.add_argument("--gen-len", type=int, default=48)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--mesh", default="none", choices=["none", "local"])
    ap.add_argument("--model-parallel", type=int, default=None,
                    help="default: devices/slots so slots shard over "
                         "'data'")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="token rows per batched prefill launch at "
                         "admission (0 = legacy tick-by-tick prefill)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="speculative window size: draft k-1 tokens at "
                         "the 2-bit floor, verify all k in one batched "
                         "launch (needs --prefill-chunk > 0)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import load_corpus, decode as bdecode
    from repro.serving import (LatencyModel, QoSPlanner, QueryBitTracker,
                               Request, ServingEngine, SlotScheduler)

    if os.path.exists(args.artifacts):
        with open(args.artifacts, "rb") as fh:
            blob = pickle.load(fh)
        params, model = blob["params"], blob["model"]
        import jax.numpy as jnp
        params = {k: jnp.asarray(v) for k, v in params.items()}
        cfg = get_config(model.arch)
    else:
        print("no artifacts found; building from benchmarks cache...")
        from benchmarks.common import built_model
        cfg, params, model = built_model(targets=(3.5, 4.0, 4.5))

    mesh, chips = None, 1
    if args.mesh == "local":
        from repro.launch.mesh import make_serve_mesh, serve_chips
        mesh = make_serve_mesh(args.slots, args.model_parallel)
        chips = serve_chips(mesh)
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"({chips} chip(s)/request)")
    engine = ServingEngine(cfg, params, model, mesh=mesh,
                           prefill_chunk=args.prefill_chunk)
    planner = QoSPlanner(
        list(model.adaptations),
        LatencyModel(bytes_per_bit=engine.overlay_bytes() / 5),
        chips=chips, spec_k=args.spec_k)
    tracker = QueryBitTracker()
    scheduler = SlotScheduler(engine, planner, slots=args.slots,
                              max_prompt=32, max_new=args.gen_len,
                              tracker=tracker, spec_k=args.spec_k)

    corpus = load_corpus("eval", 500_000)
    rng = np.random.default_rng(0)
    print(f"serving {args.queries} queries on {args.slots} slots "
          f"(targets available: {sorted(model.adaptations)})\n")
    requests = []
    for qi in range(args.queries):
        s = int(rng.integers(0, len(corpus) - 64))
        requests.append(Request(
            rid=qi, prompt=corpus[s:s + 32].astype(np.int32),
            max_new=args.gen_len,
            tpot_budget_s=float(rng.uniform(0.4e-3, 4e-3))))
    completed = scheduler.run(requests)
    for r in completed:
        completion = bdecode(r.tokens[32:])
        ttft = f", TTFT {r.ttft_s*1e3:.0f}ms" if r.ttft_s else ""
        print(f"query {r.rid}: TPOT budget {r.tpot_budget_s*1e3:.2f}ms "
              f"-> target {r.target}b, realized "
              f"{np.mean(r.effective_bits):.2f}b{ttft}")
        print(f"  prompt: {bdecode(r.tokens[:32])!r}")
        print(f"  completion: {completion!r}\n")
    if args.spec_k and args.spec_k > 1 and scheduler.spec_windows:
        w, a = scheduler.spec_windows, scheduler.spec_accepted
        print(f"speculative k={args.spec_k}: {w:.0f} windows, {a:.0f} "
              f"accepted (acceptance {a / (w * (args.spec_k - 1)):.2f}, "
              f"{w / (w + a):.2f} launches/token)")
    print("QoS summary:", {k: round(v, 4)
                           for k, v in tracker.summary().items()})


if __name__ == "__main__":
    main()
