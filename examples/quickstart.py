"""Quickstart: the DP-LLM mechanism in ~60 lines.

1. quantize a weight once into a bit-plane overlay (Any-Precision storage),
2. materialize any precision from the same bytes,
3. run the dynamic-precision linear: per-input precision selection via the
   relative-error threshold.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delta_weight, materialize, quantize_linear
from repro.kernels.bitserial import bitserial_matmul

key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (256, 512)) * 0.1          # one linear layer

# --- 1. one overlay, every precision -------------------------------------
ql = quantize_linear(w, bits=6)
print(f"overlay: {ql}  (stores 6 planes = "
      f"{ql.planes.size * 4 / w.size:.2f} B/param)")
for b in (3, 4, 6):
    err = float(jnp.abs(materialize(ql, b) - w).mean())
    print(f"  {b}-bit reconstruction: mean |err| = {err:.5f}")

# --- 2. the relative-error mechanism --------------------------------------
l, h = 3, 4
dw = delta_weight(ql, l, h)                            # ΔW = W_h − W_l
xs = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
rel_err = jnp.linalg.norm(xs @ dw, axis=-1)            # ‖x·ΔW‖ per input
T = float(jnp.quantile(rel_err, 0.8))                  # p=3.2 -> r=0.8
print(f"\nthreshold T (80th pct of calibration ‖ΔW·x‖): {T:.4f}")

# --- 3. dynamic selection per decode step ----------------------------------
hits = 0
for i in range(8):
    x = xs[i:i + 1]
    est = float(jnp.linalg.norm(x @ dw))               # (exact) estimate
    bits = h if est > T else l
    hits += bits == h
    y = bitserial_matmul(x, ql, bits)                  # reads `bits` planes
    ref = x @ materialize(ql, bits)
    assert np.allclose(y, ref, atol=1e-3)
    print(f"step {i}: est={est:8.4f} -> {bits}-bit  "
          f"(‖y‖={float(jnp.linalg.norm(y)):.3f})")
print(f"\n{hits}/8 steps upgraded to {h}-bit — precision follows the input,"
      " not the layer. That's DP-LLM.")
