"""Paper Table 3 analog: exact relative-error selector vs hybrid estimator.

The exact selector computes ‖ΔW·x‖ with no approximation (impractical at
runtime — an extra GEMV per unit) and upper-bounds the approximation.
"""
from __future__ import annotations

from benchmarks.common import QUICK_TARGETS, built_model, emit, eval_ppl, \
    eval_sequences
from repro.serving import ServingEngine


def main(quick: bool = False) -> dict:
    cfg, params, model = built_model()
    engine = ServingEngine(cfg, params, model)
    toks = eval_sequences(cfg, n=1)
    results = {}
    for t in QUICK_TARGETS:
        ppl_a, _, us_a = eval_ppl(engine, toks, t, "dynamic")
        ppl_e, _, us_e = eval_ppl(engine, toks, t, "exact")
        emit(f"exact_vs_approx/approx/t{t}", us_a, f"ppl={ppl_a:.3f}")
        emit(f"exact_vs_approx/exact/t{t}", us_e, f"ppl={ppl_e:.3f}")
        emit(f"exact_vs_approx/gap/t{t}", 0,
             f"approx-exact={ppl_a - ppl_e:+.3f}")
        results[t] = (ppl_e, ppl_a)
    return results


if __name__ == "__main__":
    main()
