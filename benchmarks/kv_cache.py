"""Dynamic-precision KV cache: bit-serial plane-read benchmark.

The overlay KV cache stores every token as a full ``bits``-deep bitplane
stack; each tick the planner assigns a per-layer READ precision, and the
bit-serial decode-attention kernel fetches exactly ``kv_b[s]`` planes
per cache tile for slot ``s`` (idle slots fetch none). This benchmark
reports, per slot-precision mix and context length:

- modeled HBM plane traffic (``kv_plane_fetches`` — the kernel's
  index_map walked in grid order, property-tested against the closed
  form ``n_tiles * sum(kv_b) + idle_runs``) vs the generic-batching
  model where every slot pays the full stack, with bytes saved;
- storage bytes: dense fp32 rows vs the plane stack + scale/zero rows
  (the ``ServingEngine.kv_bytes_saved`` closed form at the op level);
- CPU wall time of the mixed-precision plane read (jnp oracle — the CPU
  CI backend) vs the same read pinned to the full stack (the cost
  without dynamic read precision), and — with ``--interpret`` — the
  actual Pallas kernel body in interpret mode (slow; correctness smoke).

Self-contained (no trained model); run from the repo root:
    PYTHONPATH=src python benchmarks/kv_cache.py --quick
    PYTHONPATH=src python benchmarks/kv_cache.py --smoke   # CI variant
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.kv_attention import (kv_decode_attention,
                                        kv_plane_fetches)
from repro.models.attention import encode_kv_rows
from repro.kernels.tuning import time_us


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _time(fn, *args, reps: int = 20) -> float:
    """Median microseconds per call via the shared harness
    (``repro.kernels.tuning``): warmup + per-rep block_until_ready."""
    return time_us(fn, *args, warmup=1, reps=reps)


def _caches(s: int, t: int, hkv: int, dh: int, bits: int):
    kv = jax.random.normal(jax.random.PRNGKey(0), (2, s, t, hkv, dh),
                           dtype=jnp.float32)
    kp, ks, kz = encode_kv_rows(kv[0], bits)
    vp, vs, vz = encode_kv_rows(kv[1], bits)
    return kp, ks, kz, vp, vs, vz


def storage_bytes(t: int, hkv: int, dh: int, bits: int):
    """Per-slot K+V storage: dense fp32 rows vs plane stack + scale/zero
    rows — the op-level twin of ``ServingEngine.kv_bytes_saved``."""
    dense = 2 * t * hkv * dh * 4
    dw = -(-dh // 32)
    overlay = 2 * (bits * t * hkv * dw * 4 + 2 * t * hkv * 4)
    return dense, overlay


def measure(quick: bool = False, interpret: bool = False,
            reps: int = 20) -> dict:
    bits, hkv, hq, dh, m = 8, 2, 4, 64, 1
    contexts = (128, 512) if quick else (256, 1024)
    tile_t = 128
    mixes = {
        "hetero": [8, 4, 0, 6, 2, 0, 3, 8],
        "uniform4": [4] * 8,
        "half-idle": [8, 0, 8, 0, 8, 0, 8, 0],
    }
    if quick:
        mixes = {k: v[:4] for k, v in mixes.items()}

    results = {}
    for t in contexts:
        n_tiles = t // tile_t
        dense_b, overlay_b = storage_bytes(t, hkv, dh, bits)
        dw = -(-dh // 32)
        # one K-or-V plane block, as the kernel tiles it
        block_bytes = tile_t * hkv * dw * 4
        for mix, b_list in mixes.items():
            s = len(b_list)
            kp, ks, kz, vp, vs, vz = _caches(s, t, hkv, dh, bits)
            q = jax.random.normal(jax.random.PRNGKey(1), (s, m, hq, dh),
                                  dtype=jnp.float32)
            lens = jnp.full((s, m), t, jnp.int32)
            kv_b = jnp.asarray(b_list, jnp.int32)
            full_b = jnp.full((s,), bits, jnp.int32)

            plane = jax.jit(lambda qq, bb: kv_decode_attention(
                qq, kp, ks, kz, vp, vs, vz, lens, bb, bits=bits,
                backend="ref"))
            t_plane = _time(plane, q, kv_b, reps=reps)
            t_full = _time(plane, q, full_b, reps=reps)

            # traffic model: ONE stream (K); V doubles it
            fetches = 2 * kv_plane_fetches(b_list, n_tiles, bits)
            generic = 2 * s * n_tiles * bits      # all slots, all planes
            saved_mb = (generic - fetches) * block_bytes / 1e6

            if interpret:
                y_int = kv_decode_attention(
                    q, kp, ks, kz, vp, vs, vz, lens, kv_b, bits=bits,
                    backend="interpret")
                y_ref = plane(q, kv_b)
                np.testing.assert_allclose(y_int, y_ref, rtol=1e-5,
                                           atol=1e-5)

            emit(f"kv_cache/t{t}/{mix}", t_plane,
                 f"blocks={fetches};generic={generic};"
                 f"saved_mb={saved_mb:.3f};full_read_us={t_full:.1f};"
                 f"store_dense_b={dense_b};store_overlay_b={overlay_b}")
            results[(t, mix)] = {
                "fetches": fetches, "generic": generic,
                "us_plane": t_plane, "us_full_read": t_full,
                "store_dense_bytes": dense_b,
                "store_overlay_bytes": overlay_b,
            }
            assert fetches <= generic
    return results


def smoke() -> dict:
    """CI variant: one tiny mix, interpret-mode kernel check included."""
    out = measure(quick=True, interpret=True, reps=3)
    print("# kv_cache smoke ok")
    return out


def main(quick: bool = False, interpret: bool = False) -> dict:
    return measure(quick=quick, interpret=interpret)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + interpret-mode kernel parity — "
                         "the CI smoke variant")
    ap.add_argument("--interpret", action="store_true",
                    help="also run the Pallas kernel body in interpret "
                         "mode")
    args = ap.parse_args()
    smoke() if args.smoke else main(quick=args.quick,
                                    interpret=args.interpret)
