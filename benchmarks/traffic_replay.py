"""Replayed-traffic serving benchmark: paged-KV fleet under load.

The tentpole measurement for the paged bitplane-KV pool + prefill-worker
fleet: replay a synthetic but realistically shaped request trace —
heavy-tailed prompt lengths (lognormal) and diurnal arrivals (thinned
Poisson whose rate swings sinusoidally over the horizon) — through the
:class:`SlotScheduler` with the :class:`AdmissionRouter` in front, and
report the latency distribution the SLOs care about:

- ``p50_ttft_s`` / ``p99_ttft_s``  submit -> first generated token,
  queue wait included (the router's queue-depth pricing exists exactly
  because the p99 lives in the burst);
- ``goodput_tokens_per_s``  generated tokens of requests that MET their
  class TTFT SLO, per wall second — tokens delivered late count toward
  throughput but not goodput;
- ``slo_attainment``  fraction of completed requests inside their SLO.

Two legs:

1. **Parity** (deterministic, virtual time): the same trace through a
   bucketed scheduler and a paged scheduler with 4x the slots on the
   SAME KV budget (pool sized to what the bucketed slot count spends on
   worst-case buckets). Tokens and per-token effective bits must match
   BITWISE — page indirection, trims, and preemption restarts are
   mechanically invisible.
2. **Replay** (wall clock): arrivals fire at their trace offsets against
   the paged fleet; TTFT percentiles and goodput come from here.

Smoke variant (``--smoke`` / ``quick=True``) shrinks the trace for CI.
"""
from __future__ import annotations

import argparse
import sys
import time
from collections import deque
from typing import List, Optional, Tuple

import numpy as np

from benchmarks.common import built_model, emit
from repro.serving import (AdmissionRouter, LatencyModel, PriorityClass,
                           QoSPlanner, Request, ServingEngine,
                           SlotScheduler, pages_for_rows)

# per-class (ttft_slo_s, tpot_slo_s) BEFORE scaling: interactive /
# standard / batch. ``slo_scale`` stretches them to the host's speed
# (the CPU CI box is orders slower than a v5e) — the *relative* class
# structure is what the router and the goodput split exercise.
CLASS_SLOS = ((0.25, 0.03), (1.0, 0.10), (10.0, 1.00))


def make_classes(slo_scale: float) -> Tuple[PriorityClass, ...]:
    names = ("interactive", "standard", "batch")
    return tuple(PriorityClass(n, i, ttft * slo_scale, tpot * slo_scale)
                 for i, (n, (ttft, tpot)) in
                 enumerate(zip(names, CLASS_SLOS)))


def make_trace(vocab: int, n: int, max_prompt: int, max_new: int,
               slo_scale: float, horizon_s: float, seed: int = 0
               ) -> List[Tuple[float, Request]]:
    """``[(arrival_s, Request)]`` sorted by arrival.

    Prompt lengths are heavy-tailed (lognormal around max_prompt/4,
    clipped to [1, max_prompt]); arrivals are a thinned Poisson process
    whose rate swings +-80% sinusoidally across the horizon (the diurnal
    shape: the p99 TTFT lives in the crest, the pool drains in the
    trough); classes mix 50/30/20 interactive/standard/batch.
    """
    rng = np.random.default_rng(seed)
    plens = np.clip(rng.lognormal(np.log(max(2, max_prompt // 4)), 0.8,
                                  size=n).astype(int), 1, max_prompt)
    base = n / horizon_s
    lam_max = 1.8 * base
    ts, t = [], 0.0
    while len(ts) < n:
        t += rng.exponential(1.0 / lam_max)
        lam = base * (1.0 + 0.8 * np.sin(2 * np.pi * t / horizon_s))
        if rng.uniform() * lam_max < lam:
            ts.append(t)
    cls = rng.choice(3, size=n, p=(0.5, 0.3, 0.2))
    out = []
    for i in range(n):
        ttft_slo, tpot_slo = CLASS_SLOS[cls[i]]
        out.append((float(ts[i]), Request(
            rid=i,
            prompt=rng.integers(1, vocab, (plens[i],)).astype(np.int32),
            max_new=1 + int(rng.integers(1, max_new)),
            tpot_budget_s=tpot_slo * slo_scale,
            ttft_budget_s=ttft_slo * slo_scale)))
    return out


def _busy(sched: SlotScheduler) -> bool:
    return any(s.request is not None for s in sched._slots)


def replay(sched: SlotScheduler, trace) -> float:
    """Wall-clock replay: submit each request at its arrival offset,
    drive admission + chunks in between. Returns the wall seconds."""
    t0 = time.monotonic()
    pend = deque(trace)
    while pend or sched._pending() or _busy(sched):
        now = time.monotonic() - t0
        while pend and pend[0][0] <= now:
            sched.submit(pend.popleft()[1])
        if sched._pending() or _busy(sched):
            sched._admit_ready()
            sched._run_chunk()
        elif pend:
            time.sleep(min(0.002, max(0.0, pend[0][0] - now)))
    return time.monotonic() - t0


def _fresh(trace) -> List[Request]:
    """Clone the trace's requests (a Request is mutated by a run)."""
    return [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                    tpot_budget_s=r.tpot_budget_s,
                    ttft_budget_s=r.ttft_budget_s) for _, r in trace]


def measure(quick: bool = False, slo_scale: float = 400.0,
            seed: int = 0) -> dict:
    cfg, params, model = built_model()
    engine = ServingEngine(cfg, params, model, kv_overlay=True)
    s_bucketed = 1 if quick else 2          # the fixed-HBM reference
    mult = 4                                # the slot multiplier claim
    slots = mult * s_bucketed
    max_prompt, max_new = (12, 6) if quick else (24, 12)
    chunk, page_len = (3, 4) if quick else (4, 8)
    max_len = max_prompt + max_new + 1
    pages_per_slot = pages_for_rows(max_len, page_len)
    # the pool gets EXACTLY the bucketed slot count's KV budget: 4x the
    # slots share pages that worst-case buckets for s_bucketed would
    # have spent — live tokens, not bucket reservations, bound HBM
    n_pages = s_bucketed * pages_per_slot + 1
    hbm = engine.paged_bytes_report(slots, max_len, page_len,
                                    n_pages=n_pages)

    def sched(paged: bool) -> SlotScheduler:
        planner = QoSPlanner(sorted(model.adaptations),
                             LatencyModel(bytes_per_bit=1e6))
        router = AdmissionRouter(classes=make_classes(slo_scale),
                                 prefill_workers=2)
        kw = dict(slots=slots, max_prompt=max_prompt, max_new=max_new,
                  chunk=chunk, router=router)
        if paged:
            kw.update(paged=True, page_len=page_len, n_pages=n_pages)
        return SlotScheduler(engine, planner, **kw)

    n_req = 8 if quick else 32
    horizon = n_req * (0.15 if quick else 0.25)
    trace = make_trace(cfg.vocab_size, n_req, max_prompt, max_new,
                       slo_scale, horizon, seed=seed)

    # -- leg 1: fixed-HBM parity (virtual time, deterministic) ----------
    ref = sched(False)
    done_ref = {r.rid: r for r in ref.run(_fresh(trace))}
    paged_sched = sched(True)
    done_paged = {r.rid: r for r in paged_sched.run(_fresh(trace))}
    tok_ok = all(np.array_equal(done_ref[i].tokens, done_paged[i].tokens)
                 for i in done_ref)
    bit_ok = all(np.array_equal(done_ref[i].effective_bits,
                                done_paged[i].effective_bits)
                 for i in done_ref)
    parity_stats = paged_sched.paged_stats()

    # -- leg 2: wall-clock replay on the paged fleet --------------------
    live = sched(True)
    wall = replay(live, [(t, r) for (t, _), r in
                         zip(trace, _fresh(trace))])
    done = live.completed
    ttfts = np.asarray([r.ttft_s for r in done if r.ttft_s is not None])
    ok_tokens = sum(r.max_new for r in done
                    if r.ttft_s is not None
                    and r.ttft_s <= r.ttft_budget_s)
    met = sum(1 for r in done if r.ttft_s is not None
              and r.ttft_s <= r.ttft_budget_s)
    stats = live.paged_stats()
    return {
        "n_requests": n_req,
        "p50_ttft_s": float(np.percentile(ttfts, 50)),
        "p99_ttft_s": float(np.percentile(ttfts, 99)),
        "goodput_tokens_per_s": ok_tokens / wall,
        "slo_attainment": met / max(1, len(done)),
        "replay_wall_s": wall,
        "paged_tokens_match": bool(tok_ok),
        "paged_bits_match": bool(bit_ok),
        "paged_slot_multiplier": mult,
        "paged_preemptions": int(parity_stats["preemptions"]
                                 + stats["preemptions"]),
        "paged_hwm_pages": int(max(parity_stats["high_watermark_pages"],
                                   stats["high_watermark_pages"])),
        "paged_pool_bytes": hbm["paged"],
        "bucketed_bytes_same_slots": hbm["bucketed"],
        "paged_kv_saved": hbm["saved"],
    }


def main(quick: bool = False) -> dict:
    r = measure(quick=quick)
    assert r["paged_tokens_match"] and r["paged_bits_match"], \
        "paged scheduler diverged from bucketed reference"
    emit("traffic_replay/p50_ttft", r["p50_ttft_s"] * 1e6,
         f"p99={r['p99_ttft_s']:.3f}s")
    emit("traffic_replay/goodput", 0,
         f"{r['goodput_tokens_per_s']:.1f}tok/s;"
         f"slo={r['slo_attainment']:.2f}")
    emit("traffic_replay/paged", 0,
         f"{r['paged_slot_multiplier']}x_slots;"
         f"saved={r['paged_kv_saved']}B;"
         f"preempt={r['paged_preemptions']};"
         f"bitexact={r['paged_tokens_match'] and r['paged_bits_match']}")
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (same shape, smaller)")
    args = ap.parse_args()
    out = main(quick=args.smoke)
    sys.exit(0 if out["paged_tokens_match"] else 1)
