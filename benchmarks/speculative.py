"""Self-speculative decoding: tokens/s vs baseline, acceptance, launches.

The any-precision overlay is its own draft model: pinning every unit to
the 2-bit plane prefix (``core.decision.draft_floor_bits``) makes a draft
tick that streams a fraction of the overlay with ZERO planner launches,
and one batched k-row verify launch (the PR-5 prefill cells on the PR-3
slot-batched kernel) re-scores the whole window at planner-assigned bits.
Greedy longest-prefix accept keeps the output token- and bits-identical
to baseline decode, so the sweep below is a pure latency experiment.

Reports, per k in the sweep:
- spec tokens/s vs the baseline decode tokens/s (same engine, same
  prompt, same target);
- acceptance rate (accepted drafts / offered drafts) from the engine's
  on-device counters;
- verify launches per emitted token, ASSERTED against the closed form
  ``windows / (windows + accepted)`` — the invariant that makes the
  speedup mechanical: any acceptance at all pushes it below 1.

Uses the cached bench-lm build; run from the repo root:
    PYTHONPATH=src python -m benchmarks.speculative --quick
``--smoke`` is the CI variant: a fresh tiny-dense build (no trained
bench-lm / artifact cache needed), same asserts.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit
from repro.kernels.tuning import measure as harness_measure


def _decode_wall(engine, prompt, max_new: int, target: float,
                 spec_k=None) -> tuple:
    """(wall seconds, tokens, effective bits) for one generate call —
    a single fenced shot through the shared harness, whose ``out``
    carries the (tokens, bits) pair back for the parity asserts."""
    kw = {} if spec_k is None else {"spec_k": spec_k}
    r = harness_measure(
        lambda: engine.generate(prompt, max_new, target, **kw),
        warmup=0, reps=1)
    out, ebits = r.out
    return r.seconds, out, ebits


def measure(engine, prompt, max_new: int, target: float,
            ks=(2, 4, 8)) -> dict:
    """Spec-vs-baseline sweep on one engine; asserts parity + invariant."""
    _decode_wall(engine, prompt, max_new, target)          # warm baseline
    wall_b, out_b, eb_b = _decode_wall(engine, prompt, max_new, target)
    res = {"baseline_tokens_per_s": max_new / wall_b, "rows": []}
    for k in ks:
        _decode_wall(engine, prompt, max_new, target, spec_k=k)  # warm
        wall, out_s, eb_s = _decode_wall(engine, prompt, max_new, target,
                                         spec_k=k)
        # greedy verification is exact: same tokens, same emitted bits
        assert np.array_equal(out_b, out_s), f"spec k={k} changed tokens"
        np.testing.assert_allclose(eb_b, eb_s, atol=1e-5,
                                   err_msg=f"spec k={k} changed bits")
        s = dict(engine.last_spec)
        w, a = s["windows"], s["accepted"]
        # closed-form launch invariant: every window is exactly ONE
        # verify launch and emits 1 + (its accepted drafts) tokens
        assert s["verify_launches"] == w, s
        assert s["emitted_raw"] == w + a, s
        assert abs(s["launches_per_token"] - w / (w + a)) < 1e-9, s
        if a > 0:
            assert s["launches_per_token"] < 1.0, s
        row = {"k": k, "tokens_per_s": max_new / wall,
               "acceptance_rate": s["acceptance_rate"],
               "verify_launches": w,
               "launches_per_token": s["launches_per_token"]}
        res["rows"].append(row)
        emit(f"spec_k{k}", wall / max_new * 1e6,
             f"{row['acceptance_rate']:.3f}_acc_"
             f"{row['launches_per_token']:.3f}_lpt")
    emit("spec_baseline", wall_b / max_new * 1e6,
         f"{res['baseline_tokens_per_s']:.1f}_tok_per_s")
    return res


def _run(cfg, params, model, engine, max_new: int, ks) -> dict:
    target = sorted(model.adaptations)[0]
    prompt = np.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 8)),
        np.int32)
    return measure(engine, prompt, max_new, target, ks=ks)


def main(quick: bool = False) -> dict:
    from benchmarks.common import built_model
    from repro.serving import ServingEngine

    cfg, params, model = built_model()
    engine = ServingEngine(cfg, params, model)
    return _run(cfg, params, model, engine,
                max_new=24 if quick else 64,
                ks=(2, 4) if quick else (2, 4, 8))


def smoke() -> dict:
    """Self-contained CI gate: fresh tiny-dense build, same asserts."""
    import jax

    from repro.configs import get_config
    from repro.core import build_multiscale_model
    from repro.models import init_model_params
    from repro.serving import ServingEngine

    cfg = get_config("tiny-dense")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [(rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32),
                rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32))]
    model = build_multiscale_model(cfg, params, batches,
                                   targets=[3.5, 4.5], finetune_epochs=1,
                                   baselines=())
    engine = ServingEngine(cfg, params, model)
    return _run(cfg, params, model, engine, max_new=12, ks=(2, 4))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fresh tiny-dense gate (no artifact cache) — "
                         "the CI smoke variant")
    args = ap.parse_args()
    smoke() if args.smoke else main(quick=args.quick)
