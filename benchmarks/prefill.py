"""Prefill/decode disaggregation: TTFT and launch-count benchmark.

The legacy serve path teacher-forced every prompt token through the M=1
decode tick — O(prompt_len) launches before the first generated token, each
streaming the full overlay for ONE token of work. The batched prefill stage
(``ServingEngine(prefill_chunk=C)``) runs the prompt as
``ceil(prompt_len / C)`` M-row fused launches with per-row precision
decisions, bit-identical tokens/effective-bits, and hands the KV block +
decision carry to the decode stage.

Reports, per prompt length:
- launches to the first token: staged ``ceil(p/C)`` vs legacy
  ``1 + ceil((p-1)/decode_chunk)`` (counted from the engines'
  ``call_counts`` instrumentation, not modeled);
- measured TTFT — wall clock until the first generated token is computed,
  i.e. the prompt ticks ONLY (driven through the engine's tick runner with
  zero generation ticks, blocked on the emitted tokens; no trailing decode
  chunk pollutes the number) — and prefill tokens/s for both engines;
- parity check: identical first token and prompt-tick effective bits.

Uses the cached bench-lm build; run from the repo root:
    PYTHONPATH=src python -m benchmarks.prefill --quick
``--smoke`` is the CI variant: a fresh tiny-dense build (no trained
bench-lm / artifact cache needed), same asserts.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit
from repro.kernels.tuning import median_time_s


def _first_token_wall(engine, prompt, target: float) -> float:
    """Wall seconds until the first generated token exists on device.

    Drives exactly the prompt ticks (all teacher-forced, no generation
    ticks): the first generated token is the last prompt tick's argmax,
    so this measures the prefill stage alone for a staged engine and the
    boot-tick + teacher-forced chunks for a legacy one.
    """
    import jax.numpy as jnp

    p = prompt.shape[1]
    t_idx = jnp.int32(engine.artifacts.target_index(target))
    # single fenced call through the shared harness (TTFT is a one-shot
    # latency, not a throughput median; the caller warms separately)
    return median_time_s(
        lambda: engine._run_chunks(
            "dynamic", np.asarray(prompt, np.int32), np.ones((p,), bool),
            np.zeros(prompt.shape, np.int32), t_idx, want_nll=False)[0],
        warmup=0, reps=1)


def measure(engine_staged, engine_legacy, prompt, target: float) -> dict:
    p = prompt.shape[1]
    out = {}
    for name, eng in (("staged", engine_staged), ("legacy", engine_legacy)):
        _first_token_wall(eng, prompt, target)     # warm the compiles
        eng.call_counts.clear()
        wall = _first_token_wall(eng, prompt, target)
        calls = dict(eng.call_counts)
        out[f"{name}_ttft_s"] = wall
        out[f"{name}_prefill_tokens_per_s"] = p / wall
        out[f"{name}_launches"] = calls.get("prefill", 0) + \
            calls.get("boot", 0) + calls.get("chunk", 0)
    out["prompt_len"] = p
    # parity: the stage split may not change the query's output
    out_s, bits_s = engine_staged.generate(prompt, 1, target)
    out_l, bits_l = engine_legacy.generate(prompt, 1, target)
    assert np.array_equal(out_s, out_l), "prefill changed the first token"
    np.testing.assert_allclose(bits_s, bits_l, atol=1e-5)
    return out


def _run(cfg, params, model, lens, chunk: int) -> dict:
    from repro.serving import ServingEngine
    from repro.serving.kv_cache import n_prefill_chunks

    staged = ServingEngine(cfg, params, model, prefill_chunk=chunk)
    legacy = ServingEngine(cfg, params, model, prefill_chunk=0)
    target = sorted(model.adaptations)[0]
    toks = np.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size,
                                          (1, max(lens))), np.int32)
    out = {"prefill_chunk": chunk, "rows": []}
    for p in lens:
        row = measure(staged, legacy, toks[:, :p], target)
        out["rows"].append(row)
        emit(f"prefill_p{p}_staged", row["staged_ttft_s"] * 1e6,
             f"{row['staged_launches']}_launches")
        emit(f"prefill_p{p}_legacy", row["legacy_ttft_s"] * 1e6,
             f"{row['legacy_launches']}_launches")
        assert row["staged_launches"] == n_prefill_chunks(p, chunk), row
        assert row["legacy_launches"] >= row["staged_launches"], row
    return out


def main(quick: bool = False) -> dict:
    from benchmarks.common import built_model

    cfg, params, model = built_model()
    return _run(cfg, params, model, (8, 32) if quick else (8, 32, 96),
                chunk=16)


def smoke() -> dict:
    """Self-contained CI gate: a fresh tiny-dense build (no trained
    bench-lm, no artifact cache) — asserts launch counts and first-token
    parity without paying for the 300-step benchmark training run."""
    import jax

    from repro.configs import get_config
    from repro.core import build_multiscale_model
    from repro.models import init_model_params

    cfg = get_config("tiny-dense")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [(rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32),
                rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32))]
    model = build_multiscale_model(cfg, params, batches,
                                   targets=[3.5, 4.5], finetune_epochs=1,
                                   baselines=())
    return _run(cfg, params, model, (4, 12), chunk=8)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fresh tiny-dense gate (no artifact cache) — "
                         "the CI smoke variant")
    args = ap.parse_args()
    smoke() if args.smoke else main(quick=args.quick)
