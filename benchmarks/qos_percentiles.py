"""Paper Table 7 analog: per-query effective-bitwidth distribution.

DP-LLM matches the target precision on a best-effort, per-query basis;
this measures how far individual queries deviate (90th/99th percentile
increase over the mean) across a batch of held-out prompts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import built_model, emit
from repro.data import load_corpus
from repro.serving import QueryBitTracker, ServingEngine


def main(quick: bool = False) -> dict:
    cfg, params, model = built_model()
    engine = ServingEngine(cfg, params, model)
    data = load_corpus("eval", 1_000_000)
    rng = np.random.default_rng(7)
    n_queries = 8 if quick else 24
    results = {}
    for t in (3.5, 4.0, 4.5):
        if t not in model.adaptations:
            continue
        tracker = QueryBitTracker()
        for _ in range(n_queries):
            s = int(rng.integers(0, len(data) - 64))
            prompt = data[s:s + 16][None, :].astype(np.int32)
            _, ebits = engine.generate(prompt, 16, t)
            tracker.record_query(ebits)
        s = tracker.summary()
        if not s:            # empty tracker (no queries recorded)
            emit(f"qos/t{t}", 0, "no-queries")
            continue
        emit(f"qos/t{t}", 0,
             f"mean={s['mean']:.3f};p90=+{s['p90_increase']*100:.2f}%;"
             f"p99=+{s['p99_increase']*100:.2f}%")
        results[t] = s
    return results


if __name__ == "__main__":
    main()
