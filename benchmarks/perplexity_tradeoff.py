"""Paper Tables 1 / 10 / 11 analog: perplexity vs target precision.

DP-LLM (dynamic layer-wise) vs LLM-MQ / HAWQ-V2 (static layer-wise) vs
uniform, on the trained byte-LM, teacher-forced per-step decoding exactly as
the paper evaluates perplexity.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (QUICK_TARGETS, TARGETS, built_model, emit,
                               eval_ppl, eval_sequences)
from repro.models import linear_units
from repro.serving import ServingEngine


def main(quick: bool = False) -> dict:
    targets = QUICK_TARGETS if quick else TARGETS
    cfg, params, model = built_model(targets)
    engine = ServingEngine(cfg, params, model)
    toks = eval_sequences(cfg, n=1 if quick else 2)

    units = linear_units(cfg)
    model.static_tables["uniform"] = {}
    for t in targets:
        b = int(round(t))
        model.static_tables["uniform"][t] = {u.path: b for u in units}

    results = {}
    for t in targets:
        row = {}
        ppl, eb, us = eval_ppl(engine, toks, t, "dynamic")
        emit(f"ppl/dp_llm/t{t}", us, f"ppl={ppl:.3f};eff_bits={eb:.2f}")
        row["dp_llm"] = ppl
        for method in ("llm_mq", "hawq_v2", "uniform"):
            ppl, eb, us = eval_ppl(engine, toks, t, f"static:{method}")
            emit(f"ppl/{method}/t{t}", us,
                 f"ppl={ppl:.3f};eff_bits={eb:.2f}")
            row[method] = ppl
        results[t] = row

    wins = sum(1 for t in targets
               if results[t]["dp_llm"] <= min(results[t]["llm_mq"],
                                              results[t]["hawq_v2"]) + 0.02)
    emit("ppl/dp_llm_wins", 0, f"{wins}/{len(targets)} targets")
    return results


if __name__ == "__main__":
    main()
