"""§Roofline: three-term analysis per (arch × shape × mesh).

Terms (seconds, per chip):
    compute    = FLOPs / (chips × 197e12)
    memory     = HBM bytes / (chips × 819e9)
    collective = ICI bytes per chip / 50e9

FLOPs/bytes/collectives are ANALYTIC closed forms of the architecture and
sharding (formulas below) — XLA's ``cost_analysis`` counts ``while`` bodies
once (verified in-container: scan length does not change reported flops), so
compiled numbers structurally undercount scanned programs. The dry-run
remains the *shardability + memory-fit + collective inventory* proof; this
module is the performance model. MODEL_FLOPS / analytic-FLOPs exposes
remat/bit-serial redundancy, per the assignment.

Conventions:
- decode weight traffic uses each unit's h-bit plane prefix (the serving
  upper bound; the Pallas kernel's DMA elision reaches the effective-bits
  value reported alongside);
- ring collectives cost 2×payload (reduce+broadcast halves), all-gather /
  reduce-scatter 1×payload, per participating chip.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from benchmarks import hw
from repro.configs import SHAPES, get_config
from repro.configs.base import DECODE, PREFILL, TRAIN, ModelConfig
from repro.models import linear_units
from repro.models.ssm import ssm_dims

DRYRUN_DIR = "experiments/dryrun"
SERVE_H = 5              # serving stores 5-bit overlays (input_specs)
EFF_BITS = 4.5           # target precision of the synthesized serve tables


@dataclass
class MeshShape:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model


MESHES = {"single": MeshShape(1, 16, 16), "multi": MeshShape(2, 16, 16)}


# ---------------------------------------------------------------------------
# Analytic cost model
# ---------------------------------------------------------------------------
def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.num_layers)
               if cfg.layer_kind(i) == "attn")


def _ssm_layers(cfg: ModelConfig) -> int:
    return cfg.num_layers - _attn_layers(cfg)


def _linear_param_bytes(cfg: ModelConfig, bits: float) -> float:
    """bytes of all linear-unit weights at `bits` (bit-plane storage)."""
    total = 0
    for u in linear_units(cfg):
        n_mats = cfg.num_experts if u.kind.startswith("expert_") else 1
        total += n_mats * u.k * u.n * bits / 8
    return total


def _unit_macs(cfg: ModelConfig, active_only: bool = True) -> float:
    """MACs per token through the linear units (top-k experts only)."""
    total = 0
    for u in linear_units(cfg):
        if u.kind.startswith("expert_"):
            total += cfg.experts_per_token * u.k * u.n
        else:
            total += u.k * u.n
    return total


def analytic_decode(cfg: ModelConfig, shape, mesh: MeshShape) -> Dict:
    b = shape.global_batch
    s = shape.seq_len
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    chips = mesh.chips

    # --- FLOPs: bit-serial plane matmuls (h planes worth of MACs), attention
    # over the cache, SSM state update, estimators, lm head --------------------
    plane_factor = SERVE_H
    lin_flops = 2 * _unit_macs(cfg) * b * plane_factor
    attn_flops = _attn_layers(cfg) * 2 * b * s * cfg.num_heads * hd * 2
    ssm_flops = _ssm_layers(cfg) * 2 * b * (
        ssm_dims(cfg)["d_inner"] * cfg.ssm_state * 3 if cfg.ssm_state else 0)
    est_flops = sum(2 * 64 * u.k for u in linear_units(cfg)
                    if u.async_eligible) * b
    head_flops = 2 * b * d * cfg.padded_vocab_size
    flops = lin_flops + attn_flops + ssm_flops + est_flops + head_flops

    # --- HBM bytes: h-bit plane prefix once per step (weights dominate),
    # full KV cache read + one-slot write, states, G matrices ------------------
    w_bytes = _linear_param_bytes(cfg, SERVE_H)
    kv_bytes = _attn_layers(cfg) * 2 * b * s * cfg.num_kv_heads * hd * 2
    ssm_bytes = _ssm_layers(cfg) * b * (
        (ssm_dims(cfg)["nheads"] * cfg.ssm_state *
         ssm_dims(cfg)["d_inner"] // max(ssm_dims(cfg)["nheads"], 1)) * 4 * 2
        if cfg.ssm_state else 0)
    g_bytes = sum(64 * u.k * 4 for u in linear_units(cfg)
                  if u.async_eligible) / 2      # half the units are JL
    head_bytes = d * cfg.padded_vocab_size * 2
    hbm = w_bytes + kv_bytes + ssm_bytes + g_bytes + head_bytes

    # effective-bits traffic (what the Pallas kernel's DMA elision achieves)
    hbm_eff = (_linear_param_bytes(cfg, EFF_BITS) + kv_bytes + ssm_bytes +
               g_bytes + head_bytes)

    # --- collectives: TP all-reduce of (b,1,d) after o/down per layer (ring
    # 2x), tiny estimator psum, logits all-gather over vocab shards ------------
    ar_per_layer = 2 if cfg.d_ff > 0 else 1
    coll = cfg.num_layers * ar_per_layer * 2 * (b / mesh.data) * d * 2
    coll += (b / mesh.data) * cfg.padded_vocab_size * 2  # logits gather
    if mesh.pod > 1:
        coll *= 1.0   # decode replicates over pods; no cross-pod traffic
    return dict(flops=flops / chips, hbm=hbm / chips,
                hbm_eff=hbm_eff / chips, coll=coll / mesh.model,
                model_flops=2 * cfg.param_count(active_only=True) * b /
                chips)


def analytic_prefill(cfg: ModelConfig, shape, mesh: MeshShape) -> Dict:
    tokens = shape.global_batch * shape.seq_len
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    chips = mesh.chips
    # prefill uses the dequant-fused kernel: tile-wise plane unpack on the
    # VPU (cheap), ONE bf16 MXU matmul — unlike decode's plane-serial path
    # (§Perf iter 8). Unpack cost ~ K*N per tile reuse; negligible vs MACs.
    lin_flops = 2 * _unit_macs(cfg) * tokens
    attn_flops = _attn_layers(cfg) * 2 * tokens * shape.seq_len * \
        cfg.num_heads * hd * 2 / 2        # causal half
    head_flops = 2 * tokens * d * cfg.padded_vocab_size
    flops = lin_flops + attn_flops + head_flops
    w_bytes = _linear_param_bytes(cfg, SERVE_H)
    act_bytes = tokens * d * 2 * cfg.num_layers * 6
    hbm = w_bytes + act_bytes
    coll = cfg.num_layers * 2 * 2 * (tokens / mesh.data / mesh.pod) * d * 2
    return dict(flops=flops / chips, hbm=hbm / chips, hbm_eff=hbm / chips,
                coll=coll / mesh.model,
                model_flops=2 * cfg.param_count(active_only=True) *
                tokens / chips)


def analytic_train(cfg: ModelConfig, shape, mesh: MeshShape) -> Dict:
    tokens = shape.global_batch * shape.seq_len
    n_active = cfg.param_count(active_only=True)
    n_total = cfg.param_count()
    chips = mesh.chips
    d = cfg.d_model
    # fwd 2ND + bwd 4ND + remat re-forward 2ND = 8ND (full remat)
    flops = 8.0 * n_active * tokens
    hd = cfg.resolved_head_dim
    attn_flops = _attn_layers(cfg) * 2 * tokens * shape.seq_len * \
        cfg.num_heads * hd * 2 / 2 * 3   # fwd+bwd+remat, causal half
    flops += attn_flops
    micro = max(1, {True: 16, False: 1}[n_total > 100e9] if True else 1)
    from repro.launch.steps import pick_microbatches
    micro = pick_microbatches(cfg, shape.global_batch)
    # params re-read per microbatch fwd+bwd (bf16) + optimizer f32 traffic
    param_traffic = micro * 3 * n_total * 2 + n_total * (8 + 8)
    act_traffic = tokens * d * cfg.num_layers * 2 * 8   # saved+recomputed io
    hbm = param_traffic + act_traffic
    # collectives: FSDP all-gather params (fwd+bwd, bf16) over data axis,
    # grad reduce-scatter f32, done per microbatch for the gathers
    fsdp = mesh.data * mesh.pod > 1
    shard_n = n_total / mesh.model    # per model-shard parameter count
    coll = 0.0
    if fsdp:
        coll += micro * 2 * shard_n * 2          # AG params bf16, fwd+bwd
        coll += shard_n * 4                      # RS grads f32
    # TP activation all-reduces: 2 per layer fwd + 2 bwd, ring 2x
    tok_local = tokens / (mesh.data * mesh.pod) / micro
    coll += micro * cfg.num_layers * 4 * 2 * tok_local * d * 2
    if mesh.pod > 1:
        coll += shard_n * 4 / mesh.data          # cross-pod grad reduce
    return dict(flops=flops / chips, hbm=hbm / chips, hbm_eff=hbm / chips,
                coll=coll / (mesh.data * mesh.model),
                model_flops=6.0 * n_active * tokens / chips)


def analytic_cell(arch: str, shape_name: str, mesh_kind: str) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = MESHES[mesh_kind]
    if shape.kind == TRAIN:
        return analytic_train(cfg, shape, mesh)
    if shape.kind == PREFILL:
        return analytic_prefill(cfg, shape, mesh)
    return analytic_decode(cfg, shape, mesh)


# ---------------------------------------------------------------------------
# Table assembly
# ---------------------------------------------------------------------------
def three_terms(cell: Dict) -> Dict:
    t_c = cell["flops"] / hw.PEAK_FLOPS_BF16
    t_m = cell["hbm"] / hw.HBM_BW
    t_x = cell["coll"] / hw.ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    bound = max(t_c, t_m, t_x)
    return dict(
        compute_s=t_c, memory_s=t_m, collective_s=t_x,
        dominant=dom[0],
        roofline_frac=bound / (t_c + t_m + t_x) if (t_c + t_m + t_x) else 0,
        step_bound_s=bound,
        useful_ratio=cell["model_flops"] / max(cell["flops"], 1e-30),
        memory_eff_s=cell.get("hbm_eff", cell["hbm"]) / hw.HBM_BW,
    )


def load_dryrun(arch: str, shape: str, mesh: str) -> Optional[Dict]:
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def build_table(mesh_kind: str = "single"):
    from repro.configs import ASSIGNED_ARCHS, SHAPE_ORDER
    rows = []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPE_ORDER:
            rec = load_dryrun(arch, shape, mesh_kind)
            if rec is None:
                continue
            if rec.get("status") == "SKIP":
                rows.append({"arch": arch, "shape": shape,
                             "status": "SKIP", "note": rec["reason"][:40]})
                continue
            if rec.get("status") != "OK":
                rows.append({"arch": arch, "shape": shape,
                             "status": "FAIL",
                             "note": rec.get("error", "?")[:60]})
                continue
            cell = analytic_cell(arch, shape, mesh_kind)
            terms = three_terms(cell)
            resident = rec["memory"]["argument_bytes"]
            hbm_fit = resident + rec["memory"]["temp_bytes"]
            rows.append({
                "arch": arch, "shape": shape, "status": "OK",
                **{k: terms[k] for k in
                   ("compute_s", "memory_s", "collective_s", "dominant",
                    "useful_ratio", "memory_eff_s")},
                "hbm_bytes_per_dev": hbm_fit,
                "resident_bytes_per_dev": resident,
                "fits_16g": hbm_fit <= hw.CHIP_HBM_BYTES,
                "resident_fits": resident <= hw.CHIP_HBM_BYTES,
                "hlo_collectives": sum(rec["collective_counts"].values()),
                "compile_s": rec["compile_s"],
            })
    return rows


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | mem(eff-bits) s | resident GB/dev | "
           "lowered GB/dev | fits 16G | note |")
    sep = "|" + "---|" * 12
    lines = [hdr, sep]
    for r in rows:
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | — | — | — | {r['note']} |")
            continue
        fit = "Y" if r["fits_16g"] else (
            "res" if r["resident_fits"] else "N")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['memory_eff_s']:.2e} | "
            f"{r['resident_bytes_per_dev']/1e9:.2f} | "
            f"{r['hbm_bytes_per_dev']/1e9:.2f} | {fit} | |")
    return "\n".join(lines)


def main(quick: bool = False):
    from benchmarks.common import emit
    os.makedirs("experiments", exist_ok=True)
    for mesh_kind in ("single", "multi"):
        rows = build_table(mesh_kind)
        ok = [r for r in rows if r["status"] == "OK"]
        md = render_markdown(rows)
        with open(f"experiments/roofline_{mesh_kind}.md", "w") as fh:
            fh.write(md + "\n")
        with open(f"experiments/roofline_{mesh_kind}.json", "w") as fh:
            json.dump(rows, fh, indent=1, default=str)
        for r in ok:
            emit(f"roofline/{r['arch']}/{r['shape']}",
                 r["memory_s"] * 1e6,
                 f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        emit("roofline/summary", 0,
             f"cells={len(ok)};dominants={doms}")
    return rows


if __name__ == "__main__":
    main()
