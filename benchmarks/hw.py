"""TPU v5e hardware model (assignment-given constants)."""
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
CHIP_HBM_BYTES = 16e9         # v5e HBM capacity
DMA_ISSUE_S = 1e-6            # fixed cost per HBM->VMEM block DMA issue
                              # (the tile-size lever the autotuner prunes on:
                              # small tiles -> more issues, large tiles ->
                              # VMEM pressure; order-of-magnitude figure)
