"""Shared benchmark infrastructure.

Everything in-container runs on the real trained ``bench-lm`` (a ~6M-param
byte-level LM trained on the Python-stdlib corpus — real text, offline) so
perplexity/accuracy differences between precision-assignment schemes are
meaningful. Expensive artifacts (trained weights, built multiscale models)
are cached under experiments/artifacts/.
"""
from __future__ import annotations

import os
import pickle
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import build_multiscale_model
from repro.data import DataConfig, ShardedBatchIterator, load_corpus
from repro.models import init_model_params
from repro.serving import ServingEngine

ART_DIR = "experiments/artifacts"
TARGETS = (3.25, 3.5, 4.0, 4.5, 4.75)
QUICK_TARGETS = (3.5, 4.5)

# in-process memo over the pickle caches: every benchmark module calls
# trained_bench_lm()/built_model(), and one `run.py` invocation drives a
# dozen modules — without this each module re-reads and re-deserializes
# the same multi-hundred-MB blobs (and device_puts the params again),
# which dominated the quick-bench wall time
_MEMO: Dict[str, tuple] = {}


def _path(name: str) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    return os.path.join(ART_DIR, name)


def trained_bench_lm(steps: int = 300, force: bool = False):
    """Train (or load) the byte-level bench LM on stdlib source."""
    from repro.launch.train import train
    cfg = get_config("bench-lm")
    cache = _path(f"bench_lm_{steps}.pkl")
    if cache in _MEMO and not force:
        return _MEMO[cache]
    if os.path.exists(cache) and not force:
        with open(cache, "rb") as fh:
            blob = pickle.load(fh)
        out = cfg, {k: jnp.asarray(v) for k, v in blob["params"].items()}, \
            blob["final_loss"]
        _MEMO[cache] = out
        return out
    state, losses = train("bench-lm", steps=steps, seq_len=256,
                          global_batch=8, lr=2e-3,
                          log=lambda *a, **k: None)
    from repro.models.stacked import group_size, num_scan_steps
    # un-stack back to loop layout for the core pipeline
    params = dict(state["glob"])
    g = group_size(cfg)
    for rel, arr in state["stack"].items():
        r, rest = rel.split(".", 1)
        for c in range(arr.shape[0]):
            params[f"layers.{int(r) + c * g}.{rest}"] = arr[c]
    with open(cache, "wb") as fh:
        pickle.dump({"params": {k: np.asarray(v)
                                for k, v in params.items()},
                     "final_loss": losses[-1]}, fh)
    _MEMO[cache] = (cfg, params, losses[-1])
    return _MEMO[cache]


def calibration_batches(cfg, n: int = 6, seq: int = 192,
                        split: str = "calibration", seed: int = 0):
    data = load_corpus(split, 2_000_000)
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        starts = rng.integers(0, len(data) - seq - 1, size=2)
        seqs = np.stack([data[s:s + seq + 1] for s in starts])
        out.append((seqs[:, :-1].astype(np.int32),
                    seqs[:, 1:].astype(np.int32)))
    return out


def built_model(targets: Sequence[float] = TARGETS, *,
                budget: float = 5.0, calib_split: str = "calibration",
                steps: int = 300, tag: str = "", force: bool = False):
    """Trained bench-lm + built MultiScaleModel (cached)."""
    cfg, params, _ = trained_bench_lm(steps)
    # the key must cover EVERY build argument: a key that dropped
    # `steps` once served a 300-step model to a 50-step caller (same
    # targets/budget), silently mixing weight checkpoints across runs
    key = f"msm_{budget}b_{steps}s_{'_'.join(str(t) for t in targets)}" \
          f"_{calib_split}{tag}.pkl"
    cache = _path(key)
    if cache in _MEMO and not force:
        return _MEMO[cache]
    if os.path.exists(cache) and not force:
        with open(cache, "rb") as fh:
            model = pickle.load(fh)
        _MEMO[cache] = (cfg, params, model)
        return _MEMO[cache]
    batches = calibration_batches(cfg, split=calib_split)
    model = build_multiscale_model(
        cfg, params, batches, targets=list(targets),
        memory_budget_bits=budget, finetune_epochs=2,
        baselines=("llm_mq", "hawq_v2"))
    with open(cache, "wb") as fh:
        pickle.dump(model, fh)
    _MEMO[cache] = (cfg, params, model)
    return _MEMO[cache]


def eval_sequences(cfg, n: int = 2, seq: int = 160, seed: int = 1):
    data = load_corpus("eval", 1_000_000)
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(data) - seq - 1, size=n)
    return np.stack([data[s:s + seq] for s in starts]).astype(np.int32)


def eval_ppl(engine: ServingEngine, tokens: np.ndarray, target: float,
             mode: str = "dynamic") -> Tuple[float, float, float]:
    """Returns (ppl, mean effective bits, µs per decode step)."""
    t0 = time.monotonic()
    nlls, ebits, steps = [], [], 0
    for row in tokens:
        nll, eb = engine.teacher_forced_nll(row[None, :], target, mode=mode,
                                            prime_len=8)
        nlls.append(nll)
        ebits.extend(eb)
        steps += len(eb)
    wall = time.monotonic() - t0
    return (float(np.exp(np.mean(nlls))), float(np.mean(ebits)),
            wall / max(steps, 1) * 1e6)


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
