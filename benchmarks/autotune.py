"""Roofline-seeded tile autotuner: measure the kernel families' tile
knobs and persist winners to a versioned ``tuning_cache.json``.

Four families, one knob each, all measured through the PUBLIC dispatch
entry points with a candidate cache installed — the tuner times exactly
the resolve/pad/thread path serving pays, not a bare kernel launch:

    bitserial      tile_n   (plain/slots/grouped share the knob)
    jl_plan        u_tile   (planner units per x DMA)
    kv_attention   tile_t   (bucketed cache seq tile)
    kv_paged       page_len (pool page granularity == kernel tile_t)

Candidate enumeration is seeded and PRUNED by the roofline model
(``benchmarks/hw.py``): each candidate's modeled memory term is its
plane-block traffic — the host-side index_map walks the kernels already
export (``plane_block_fetches`` etc.) — over ``HBM_BW`` plus a fixed
``DMA_ISSUE_S`` per block fetch. The DEFAULT candidate is measured first
unconditionally (pruning can never discard it — the fallback the ops
layer dispatches on a cache miss must always have a measurement), then
non-default candidates run in modeled order and are skipped when their
modeled floor already exceeds the best measured time.

The timer is injectable (``--help``-level determinism for tests: a fake
timer yields a reproducible winner); the real one is the shared harness
in ``repro.kernels.tuning`` (warmup + block_until_ready + median).

Self-contained (no trained model); run from the repo root:
    PYTHONPATH=src python benchmarks/autotune.py --smoke --out tuning_cache.json
"""
from __future__ import annotations

import argparse
import math
import os
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import hw
from repro.kernels import tuning
from repro.kernels.bitserial.kernel import plane_block_fetches
from repro.kernels.bitserial.ops import bitserial_matmul
from repro.kernels.jl_estimator.kernel import g_block_fetches
from repro.kernels.jl_estimator.ops import plan_bits
from repro.kernels.kv_attention.kernel import kv_plane_fetches
from repro.kernels.kv_attention.ops import kv_decode_attention
from repro.kernels.kv_attention.paged import (kv_decode_attention_paged,
                                              kv_plane_fetches_paged)
from repro.core.bitplane import quantize_linear
from repro.kernels.tuning import TuningCache, measure


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def default_timer(fn: Callable[[], object]) -> float:
    return measure(fn, warmup=1, reps=3).seconds


def _mem_seconds(block_fetches: int, block_bytes: int) -> float:
    """Roofline memory term of a candidate: streamed bytes over HBM_BW
    plus the per-DMA issue cost — the two levers tile size moves."""
    return (block_fetches * block_bytes / hw.HBM_BW +
            block_fetches * hw.DMA_ISSUE_S)


# ---------------------------------------------------------------------------
# Winner selection (deterministic, default-first, roofline-pruned)
# ---------------------------------------------------------------------------
def pick_winner(candidates: List[int], modeled_s: Callable[[int], float],
                make_runner: Callable[[int], Callable[[], object]],
                timer: Callable[[Callable[[], object]], float]):
    """``candidates[0]`` is the DEFAULT: measured first, never pruned.
    Remaining candidates run in ascending modeled order and are skipped
    when their modeled memory floor exceeds the best measured time.
    Winner is the strict minimum (ties keep the earlier — i.e. the
    default, then the better-modeled — candidate): deterministic for a
    deterministic timer. Returns (winner, measured{c: s}, pruned[c])."""
    measured: Dict[int, float] = {}
    pruned: List[int] = []
    best_c, best_s = None, math.inf
    rest = sorted(candidates[1:], key=lambda c: (modeled_s(c), c))
    for i, c in enumerate([candidates[0]] + rest):
        if i > 0 and modeled_s(c) > best_s:
            pruned.append(c)
            continue
        s = timer(make_runner(c))
        measured[c] = s
        if s < best_s:
            best_c, best_s = c, s
    return best_c, measured, pruned


def _cand_cache(kernel: str, n: int, bits: int, tile: int) -> TuningCache:
    cache = TuningCache()
    cache.put(tuning.platform_name(), kernel, n, bits, tile)
    return cache


def tune_family(out_cache: TuningCache, *, kernel: str, n: int, bits: int,
                candidates: List[int], modeled_s, make_runner, timer,
                force: bool = False) -> Optional[int]:
    """Tune one (kernel, shape-bucket, bits) entry into ``out_cache``.
    Already-keyed entries are kept (CI cache reuse) unless ``force``."""
    plat = tuning.platform_name()
    if not force and out_cache.lookup(plat, kernel, n, bits):
        emit(f"autotune/{kernel}", 0.0,
             f"cached={out_cache.lookup(plat, kernel, n, bits)};skipped=1")
        return out_cache.lookup(plat, kernel, n, bits)
    prev = tuning.active_cache()
    try:
        winner, measured, pruned = pick_winner(candidates, modeled_s,
                                               make_runner, timer)
    finally:
        tuning.use_cache(prev)
    key = out_cache.put(plat, kernel, n, bits, winner)
    default = candidates[0]
    emit(f"autotune/{kernel}",
         measured[winner] * 1e6,
         f"winner={winner};default={default};"
         f"default_us={measured[default] * 1e6:.1f};"
         f"measured={len(measured)};pruned={len(pruned)};key={key}")
    return winner


# ---------------------------------------------------------------------------
# Family builders: inputs + candidate runners + roofline models
# ---------------------------------------------------------------------------
def build_bitserial(smoke: bool, backend: str):
    k, n, bits, s = (128, 256, 4, 4) if smoke else (512, 1024, 8, 8)
    b_list = ([3, 1, 0, 2] if smoke else [4, 2, 0, 6, 1, 0, 3, 2])
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.2
    ql = quantize_linear(w, bits=bits)
    x = jax.random.normal(jax.random.PRNGKey(1), (s, 1, k), jnp.float32)
    b_sel = jnp.asarray(b_list, jnp.int32)
    kw = ql.planes.shape[1]
    candidates = [c for c in (256, 512, 128, 64) if n % c == 0]

    def modeled_s(tile):
        fetches = plane_block_fetches(b_list, n // tile, bits)
        return _mem_seconds(fetches, kw * tile * 4)

    def make_runner(tile):
        def run():
            tuning.use_cache(_cand_cache("bitserial", n, bits, tile))
            # the scheduler's slot vmap: collapses via custom_vmap into
            # ONE slot-kernel launch at the candidate tile
            return jax.vmap(
                lambda xs, bs: bitserial_matmul(xs, ql, bs,
                                                backend=backend))(x, b_sel)
        return run

    return dict(kernel="bitserial", n=n, bits=bits, candidates=candidates,
                modeled_s=modeled_s, make_runner=make_runner)


def build_jl_plan(smoke: bool, backend: str):
    u, m, k, kproj, t = (8, 1, 128, 16, 2) if smoke else (24, 2, 256, 16, 3)
    rng = np.random.default_rng(0)
    tables = {
        "l": jnp.asarray(rng.integers(2, 4, (u, t)), jnp.int32),
        "h": jnp.asarray(rng.integers(5, 7, (u, t)), jnp.int32),
        "kind": jnp.asarray(rng.integers(0, 3, (u, t)), jnp.int32),
        "threshold": jnp.asarray(
            rng.uniform(0.1, 3.0, (u, t)).astype(np.float32)),
        "a": jnp.asarray(rng.uniform(0, 0.2, (u, t)).astype(np.float32)),
        "b": jnp.asarray(rng.uniform(0, 0.2, (u, t)).astype(np.float32)),
        "gamma": jnp.asarray(
            rng.uniform(0.5, 1.5, (u, t)).astype(np.float32)),
    }
    kinds = np.asarray(tables["kind"])
    g_rows = [np.zeros((kproj, k), np.float32)]
    g_row = np.zeros((u, t), np.int32)
    prev = np.zeros((t,), np.int32)
    for ui in range(u):
        for ti in range(t):
            if kinds[ui, ti] == 2:                        # KIND_JL
                g_row[ui, ti] = len(g_rows)
                g_rows.append(rng.normal(size=(kproj, k))
                              .astype(np.float32) / np.sqrt(kproj))
            else:
                g_row[ui, ti] = prev[ti]
        prev = g_row[ui]
    tables["g"] = jnp.asarray(np.stack(g_rows))
    tables["g_row"] = jnp.asarray(g_row)
    x = jnp.asarray(rng.normal(size=(u, m, k)).astype(np.float32))
    g_fetches = g_block_fetches(g_row[:, 0])
    candidates = [c for c in (1, 2, 4, 8) if u % c == 0]

    def modeled_s(u_tile):
        g_s = _mem_seconds(g_fetches, kproj * k * 4)
        x_s = _mem_seconds(u // u_tile, u_tile * m * k * 4)
        return g_s + x_s

    def make_runner(u_tile):
        def run():
            tuning.use_cache(_cand_cache("jl_plan", u, 0, u_tile))
            return plan_bits(x, tables, 0, backend=backend)
        return run

    return dict(kernel="jl_plan", n=u, bits=0, candidates=candidates,
                modeled_s=modeled_s, make_runner=make_runner)


def _rand_kv_stream(key, s, bits, t_rows, hkv, dw):
    kp = jax.random.randint(key, (s, bits, t_rows, hkv, dw), 0,
                            jnp.iinfo(jnp.int32).max, jnp.int32)
    sc = jax.random.uniform(key, (s, t_rows, hkv, 1), jnp.float32,
                            0.01, 0.1)
    zr = jax.random.uniform(key, (s, t_rows, hkv, 1), jnp.float32,
                            0.0, 1.0)
    return kp, sc, zr


def build_kv_attention(smoke: bool, backend: str):
    s, bits, t_rows, hkv, dh = (2, 4, 64, 1, 128) if smoke else \
        (4, 6, 256, 2, 128)
    dw = dh // 32
    kv_b = jnp.asarray([2, bits] + [3] * (s - 2), jnp.int32)[:s]
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, t_rows, (s, 1)), jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(2), (s, 1, hkv, dh),
                          jnp.float32)
    kp, ks, kz = _rand_kv_stream(jax.random.PRNGKey(3), s, bits, t_rows,
                                 hkv, dw)
    vp, vs, vz = _rand_kv_stream(jax.random.PRNGKey(4), s, bits, t_rows,
                                 hkv, dw)
    from repro.kernels.kv_attention.ops import _pick_tile_t
    default = _pick_tile_t(t_rows)[0]
    rest = [c for c in (128, 64, 32, 16, 8)
            if c != default and t_rows % c == 0]
    candidates = [default] + rest

    def modeled_s(tile):
        fetches = 2 * kv_plane_fetches(
            [int(v) for v in kv_b], t_rows // tile, bits)
        return _mem_seconds(fetches, tile * hkv * dw * 4)

    def make_runner(tile):
        def run():
            tuning.use_cache(_cand_cache("kv_attention", t_rows, bits,
                                         tile))
            return kv_decode_attention(q, kp, ks, kz, vp, vs, vz, lens,
                                       kv_b, bits=bits, backend=backend)
        return run

    return dict(kernel="kv_attention", n=t_rows, bits=bits,
                candidates=candidates, modeled_s=modeled_s,
                make_runner=make_runner)


def build_kv_paged(smoke: bool, backend: str):
    s, bits, t_rows, hkv, dh = (2, 4, 64, 1, 128) if smoke else \
        (4, 6, 256, 2, 128)
    dw = dh // 32
    kv_b = jnp.asarray([2, bits] + [3] * (s - 2), jnp.int32)[:s]
    lens = jnp.asarray(
        np.random.default_rng(1).integers(1, t_rows, (s, 1)), jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(5), (s, 1, hkv, dh),
                          jnp.float32)
    candidates = [c for c in (16, 32, 64) if t_rows % c == 0]

    def _pool(page_len):
        pages_per_slot = t_rows // page_len
        n_pages = s * pages_per_slot + 1          # +1: trash page 0
        kk = jax.random.PRNGKey(6)
        kp = jax.random.randint(kk, (n_pages, bits, page_len, hkv, dw), 0,
                                jnp.iinfo(jnp.int32).max, jnp.int32)
        sc = jax.random.uniform(kk, (n_pages, page_len, hkv, 1),
                                jnp.float32, 0.01, 0.1)
        zr = jax.random.uniform(kk, (n_pages, page_len, hkv, 1),
                                jnp.float32, 0.0, 1.0)
        pt = jnp.asarray(
            1 + np.arange(s * pages_per_slot).reshape(s, pages_per_slot),
            jnp.int32)
        return kp, sc, zr, pt

    def modeled_s(page_len):
        pages_per_slot = t_rows // page_len
        pt = 1 + np.arange(s * pages_per_slot).reshape(s, pages_per_slot)
        fetches = 2 * kv_plane_fetches_paged(
            pt, np.asarray(lens), [int(v) for v in kv_b],
            page_len=page_len, bits=bits)
        return _mem_seconds(fetches, page_len * hkv * dw * 4)

    def make_runner(page_len):
        kp, sc, zr, pt = _pool(page_len)

        def run():
            return kv_decode_attention_paged(
                q, kp, sc, zr, kp, sc, zr, pt, lens, kv_b, bits=bits,
                backend=backend)
        return run

    return dict(kernel="kv_paged", n=t_rows, bits=0,
                candidates=candidates, modeled_s=modeled_s,
                make_runner=make_runner)


BUILDERS = {
    "bitserial": build_bitserial,
    "jl_plan": build_jl_plan,
    "kv_attention": build_kv_attention,
    "kv_paged": build_kv_paged,
}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def run_autotune(out: str = "tuning_cache.json", smoke: bool = False,
                 backend: Optional[str] = None,
                 families: Optional[List[str]] = None,
                 timer: Callable = default_timer,
                 force: bool = False) -> TuningCache:
    backend = tuning.kernel_backend(backend)
    cache = TuningCache.load(out) if os.path.exists(out) else TuningCache()
    cache.meta.update(backend=backend, smoke=bool(smoke),
                      platform=tuning.platform_name())
    for name in families or list(BUILDERS):
        fam = BUILDERS[name](smoke, backend)
        tune_family(cache, timer=timer, force=force, **fam)
    cache.save(out)
    emit("autotune/saved", 0.0,
         f"path={out};entries={len(cache.entries)};backend={backend}")
    return cache


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="tuning_cache.json",
                    help="cache file to create/extend")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI shard)")
    ap.add_argument("--backend", default=None,
                    choices=("pallas", "interpret"),
                    help="kernel backend (default: pallas on TPU, "
                         "interpret elsewhere)")
    ap.add_argument("--families", default=None,
                    help="comma-separated subset of "
                         f"{','.join(BUILDERS)}")
    ap.add_argument("--force", action="store_true",
                    help="re-tune entries already in the cache")
    args = ap.parse_args()
    run_autotune(out=args.out, smoke=args.smoke, backend=args.backend,
                 families=args.families.split(",") if args.families
                 else None, force=args.force)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
