"""Batched-slot bit-serial kernel: per-slot DMA elision benchmark.

Continuous batching vmaps the decode tick over S slots with heterogeneous
runtime precisions. Generic batching makes every slot pay for the most
expensive slot's planes (and idle slots pay full price); the slot-batched
kernel (kernels/bitserial) clamps the plane index_map per slot against a
scalar-prefetched b_sel vector, so slot s fetches exactly b_sel[s] plane
blocks per tile and idle slots fetch none.

Reports, per slot-precision mix:
- modeled HBM plane-block traffic (the kernel's index_map walked in grid
  order — the asserted elision contract) vs. the generic-batching and
  worst-slot models, with bytes saved;
- CPU wall time of the slot-batched oracle vs. the per-slot python loop
  (the pre-batching dispatch), and — with ``--interpret`` — the actual
  Pallas kernel body in interpret mode (slow; correctness smoke, not perf).

Self-contained (no trained model); run from the repo root:
    PYTHONPATH=src python benchmarks/slot_kernel.py --quick
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitplane import quantize_linear
from repro.kernels.bitserial import (bitserial_matmul,
                                     bitserial_matmul_slots_pallas,
                                     bitserial_matmul_slots_ref,
                                     plane_block_fetches)
from repro.kernels.tuning import time_us


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _time(fn, *args, reps: int = 20) -> float:
    """Median microseconds per call via the shared harness
    (``repro.kernels.tuning``): warmup + per-rep block_until_ready."""
    return time_us(fn, *args, warmup=1, reps=reps)


def main(quick: bool = False, interpret: bool = False) -> dict:
    k, n, bits, m = (128, 256, 6, 1) if quick else (512, 1024, 8, 1)
    tile_n = 128 if quick else 256
    n_tiles = n // tile_n
    mixes = {
        "hetero": [4, 2, 0, 6, 1, 0, 3, 2],
        "uniform4": [4] * 8,
        "half-idle": [5, 0, 5, 0, 5, 0, 5, 0],
    }
    if quick:
        mixes = {k_: v[:4] for k_, v in mixes.items()}

    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.2
    ql = quantize_linear(w, bits=bits)
    scale, zero = ql.scale[None, :], ql.zero[None, :]
    block_bytes = ql.planes.shape[1] * tile_n * 4

    slots_ref = jax.jit(lambda x, b: bitserial_matmul_slots_ref(
        x, ql.planes, scale, zero, b, bits=bits))

    def per_slot_loop(x, b):                      # pre-batching dispatch
        return jnp.stack([bitserial_matmul(x[s], ql, b[s], backend="ref")
                          for s in range(x.shape[0])])

    per_slot_loop = jax.jit(per_slot_loop)

    results = {}
    for mix, b_list in mixes.items():
        s = len(b_list)
        b_sel = jnp.asarray(b_list, jnp.int32)
        x = jax.random.normal(jax.random.PRNGKey(1), (s, m, k),
                              dtype=jnp.float32)

        fetches = plane_block_fetches(b_list, n_tiles, bits)
        naive = s * n_tiles * bits                # generic: all planes
        worst = s * n_tiles * max(b_list)         # all pay the worst slot
        saved_mb = (naive - fetches) * block_bytes / 1e6

        t_batched = _time(slots_ref, x, b_sel)
        t_loop = _time(per_slot_loop, x, b_sel)

        y_ref = slots_ref(x, b_sel)
        if interpret:                             # actual kernel body
            y_int = bitserial_matmul_slots_pallas(
                x, ql.planes, scale, zero, b_sel, bits=bits, tile_n=tile_n,
                interpret=True)
            y_int = jnp.where((b_sel > 0)[:, None, None], y_int, 0.0)
            np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)

        emit(f"slot_kernel/{mix}", t_batched,
             f"blocks={fetches};generic={naive};worst_slot={worst};"
             f"saved_mb={saved_mb:.2f};loop_us={t_loop:.1f}")
        results[mix] = {"fetches": fetches, "naive": naive, "worst": worst,
                        "us_batched": t_batched, "us_loop": t_loop}
        assert fetches <= worst <= naive
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--interpret", action="store_true",
                    help="also run the Pallas kernel body in interpret mode")
    args = ap.parse_args()
    main(quick=args.quick, interpret=args.interpret)
