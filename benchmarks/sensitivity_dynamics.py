"""Paper Figure 3 analog: layer sensitivity changes per decoding step.

(a) churn of the top-20% most-sensitive units across decoding steps
    (Jaccard overlap between consecutive steps — low overlap = dynamic);
(b) ppl of the *oracle* dynamic scheme (exact per-step errors) vs the
    static assignment — the headroom that motivates DP-LLM.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import built_model, emit, eval_ppl, eval_sequences
from repro.serving import ServingEngine


def main(quick: bool = False) -> dict:
    cfg, params, model = built_model()
    engine = ServingEngine(cfg, params, model)
    toks = eval_sequences(cfg, n=1, seq=64 if quick else 96)

    # (a) per-step churn of high-error units, via the exact selector:
    # record which units chose h-bit at each step
    aset = model.adaptations[3.5]
    step = engine.get_step(3.5, "exact")
    from repro.serving.kv_cache import make_decode_state
    import jax.numpy as jnp
    state = make_decode_state(cfg, 1, toks.shape[1] + 1, dtype=jnp.float32)
    prev_top = None
    overlaps = []
    ebits_series = []
    t = jnp.asarray(toks[:1])
    for i in range(toks.shape[1] - 1):
        logits, state, eb = step(state, t[:, i:i + 1])
        ebits_series.append(float(eb))
    # effective-bit variation across steps is the dynamism signal
    var = float(np.std(ebits_series))
    distinct = len(set(np.round(ebits_series, 3)))
    emit("dynamics/effbits_std", 0,
         f"std={var:.4f};distinct={distinct}/{len(ebits_series)}")

    # (b) oracle(exact) vs static headroom
    ppl_static, _, _ = eval_ppl(engine, toks, 3.5, "static:hawq_v2")
    ppl_oracle, _, _ = eval_ppl(engine, toks, 3.5, "exact")
    ppl_dp, _, _ = eval_ppl(engine, toks, 3.5, "dynamic")
    emit("dynamics/static_ppl", 0, f"{ppl_static:.3f}")
    emit("dynamics/dp_llm_ppl", 0, f"{ppl_dp:.3f}")
    emit("dynamics/oracle_ppl", 0, f"{ppl_oracle:.3f}")
    return {"std": var, "static": ppl_static, "oracle": ppl_oracle,
            "dp": ppl_dp}


if __name__ == "__main__":
    main()
