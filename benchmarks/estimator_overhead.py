"""Paper Tables 4 / 5 / 6 analog: precision-selector overhead.

Three views (no TPU in-container):
- fused planner vs per-unit inline decisions: traced ops dispatched on
  the decode critical path (O(1) vs O(U) — the PR-4 pipeline's tested
  invariant) and decide-phase wall clock, decisions bit-identical;
- measured CPU wall-clock per decode step: static baseline vs DP-LLM
  dynamic (pipelined planner) vs inline-sync, and the Table-6 ablation
  (RP-only vs hybrid vs hybrid+async);
- the analytic TPU v5e model: selector FLOPs/bytes vs the decode GEMV
  traffic at each effective bitwidth (the paper's Table 5 latency scaling).
"""
from __future__ import annotations

import numpy as np

from benchmarks import hw
from benchmarks.common import built_model, emit, eval_ppl, eval_sequences
from repro.kernels.tuning import time_us
from repro.models import linear_units
from repro.serving import ServingEngine


def fused_vs_inline(engine: ServingEngine, quick: bool = False) -> dict:
    """Fused one-launch planner vs the legacy per-unit inline selector.

    Both consume the SAME (U, M, K_max) captured-activation buffer and
    must produce identical decisions; what differs is the dispatch
    shape: one fused kernel/einsum vs ~5 scattered jnp ops per unit.
    Returns {n_units, inline_eqns, fused_eqns, inline_dots, fused_dots,
    inline_us, fused_us, identical}.
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels.common import count_jaxpr_primitives

    art = engine.artifacts
    bundle = art.decision
    planner = engine.planner("dynamic")
    serve_params = {"raw": {}, "overlays": {}, "est": engine.est}
    rng = np.random.default_rng(0)
    # honor the capture contract: each unit's row is zero beyond its true
    # estimator width (the applier zero-pads to K_max)
    raw = rng.normal(size=(bundle.n_units, 1, bundle.k_pad))
    raw *= (np.arange(bundle.k_pad)[None, None, :] <
            bundle.k_actual[:, None, None])
    acts = jnp.asarray(raw.astype(np.float32))

    def inline_decide(acts, t):
        return planner.inline_reference(acts, t, serve_params, art.table)

    def fused_decide(acts, t):
        return planner.plan(acts, t)

    t0 = jnp.int32(0)
    jx_i = jax.make_jaxpr(inline_decide)(acts, t0)
    jx_f = jax.make_jaxpr(fused_decide)(acts, t0)
    inline_fn = jax.jit(inline_decide)
    fused_fn = jax.jit(fused_decide)
    same = bool(np.array_equal(np.asarray(inline_fn(acts, t0)),
                               np.asarray(fused_fn(acts, t0))))

    def wall(fn, reps):
        # shared harness: warmup + per-rep fence + median
        return time_us(fn, acts, t0, warmup=1, reps=reps)

    reps = 20 if quick else 200
    res = {
        "n_units": bundle.n_units,
        "inline_eqns": count_jaxpr_primitives(jx_i.jaxpr),
        "fused_eqns": count_jaxpr_primitives(jx_f.jaxpr),
        "inline_dots": count_jaxpr_primitives(jx_i.jaxpr, "dot_general"),
        "fused_dots": count_jaxpr_primitives(jx_f.jaxpr, "dot_general"),
        "inline_us": wall(inline_fn, reps),
        "fused_us": wall(fused_fn, reps),
        "identical": same,
    }
    emit("planner/inline", res["inline_us"],
         f"eqns={res['inline_eqns']} dots={res['inline_dots']} "
         f"units={res['n_units']}")
    emit("planner/fused", res["fused_us"],
         f"eqns={res['fused_eqns']} dots={res['fused_dots']} "
         f"identical={same} speedup={res['inline_us'] / res['fused_us']:.2f}x")
    return res


def analytic_tpot(cfg, model, target: float, include_selector: bool):
    """v5e decode latency model: weight traffic + selector traffic."""
    aset = model.adaptations[target]
    wbytes = sum(u.size * u.p / 8 for u in aset.units.values())
    sel_bytes = sel_flops = 0.0
    if include_selector:
        for u in aset.units.values():
            if u.est is None or u.l == u.h:
                continue
            if u.est.kind == "jl":
                k, n = u.est.g.shape
                sel_bytes += k * n * 4
                sel_flops += 2 * k * n
    t = wbytes / hw.HBM_BW + sel_bytes / hw.HBM_BW \
        + sel_flops / hw.PEAK_FLOPS_BF16
    return t, wbytes, sel_bytes


def main(quick: bool = False) -> dict:
    cfg, params, model = built_model()
    toks = eval_sequences(cfg, n=1, seq=96 if quick else 128)
    results = {}

    # --- fused planner vs inline selector (the PR-4 decision pipeline) -----
    results["planner"] = fused_vs_inline(ServingEngine(cfg, params, model),
                                         quick=quick)

    # --- measured wall-clock (Table 4 / 6 analog) ---------------------------
    for t in (3.5, 4.5):
        engine = ServingEngine(cfg, params, model)
        _, _, us_static = eval_ppl(engine, toks, t, "static:llm_mq")
        _, _, us_dyn = eval_ppl(engine, toks, t, "dynamic")
        eng_sync = ServingEngine(cfg, params, model, use_async=False)
        _, _, us_sync = eval_ppl(eng_sync, toks, t, "dynamic")
        ovh = (us_dyn - us_static) / us_static * 100
        ovh_sync = (us_sync - us_static) / us_static * 100
        emit(f"overhead/static/t{t}", us_static, "baseline")
        emit(f"overhead/hybrid_async/t{t}", us_dyn,
             f"overhead={ovh:+.1f}%")
        emit(f"overhead/hybrid_sync/t{t}", us_sync,
             f"overhead={ovh_sync:+.1f}%")
        results[t] = {"static_us": us_static, "dyn_us": us_dyn}

    # --- RP-only ablation (Table 6): force every linear unit onto the JL
    # path by refitting with an impossible R² gate --------------------------
    import copy
    from repro.core.estimators import EstimatorFit, make_g, sample_projection
    import jax
    from repro.core.thresholds import delta_weight_of
    model_rp = copy.deepcopy(model)
    key = jax.random.PRNGKey(11)
    for t_, aset in model_rp.adaptations.items():
        for u in aset.units.values():
            if u.est is not None and u.est.kind == "linear":
                dw = delta_weight_of(model.overlays[u.path], u.l, u.h)
                key, sub = jax.random.split(key)
                g = make_g(sample_projection(sub, 64, dw.shape[1]), dw)
                u.est = EstimatorFit(kind="jl", r2=u.est.r2, gamma=1.0,
                                     g=np.asarray(g))
    eng_rp = ServingEngine(cfg, params, model_rp)
    _, _, us_rp = eval_ppl(eng_rp, toks, 3.5, "dynamic")
    base = results[3.5]["static_us"]
    emit("overhead/rp_only/t3.5", us_rp,
         f"overhead={(us_rp - base) / base * 100:+.1f}%")

    # --- analytic TPU model (Table 5 analog) --------------------------------
    # NOTE: on the 6M bench-lm the selector G matrices are comparable to the
    # weights, so overhead % is inflated; the paper's regime appears at full
    # scale, computed below from the configs alone.
    for t in sorted(model.adaptations):
        t_static, wb, _ = analytic_tpot(cfg, model, t, False)
        t_dyn, _, sb = analytic_tpot(cfg, model, t, True)
        emit(f"tpot_v5e/static/t{t}", t_static * 1e6,
             f"weight_bytes={wb:.3e}")
        emit(f"tpot_v5e/dp_llm/t{t}", t_dyn * 1e6,
             f"selector_overhead={(t_dyn - t_static) / t_static * 100:.2f}%")

    # --- full-scale analytic overhead (paper's Table 4 regime) --------------
    from repro.configs import get_config
    for arch in ("llama3-8b", "phi3-medium"):
        fcfg = get_config(arch)
        units = linear_units(fcfg)
        for t in (3.5, 4.0, 4.5):
            wbytes = sum(u.k * u.n for u in units) * t / 8
            # half the units JL (paper Table 8): G (64, K) f32 read/step
            sel_bytes = sum(64 * u.k * 4 for u in units) / 2
            sel_flops = 2 * sel_bytes / 4
            t_s = wbytes / hw.HBM_BW
            t_d = t_s + sel_bytes / hw.HBM_BW + sel_flops / hw.PEAK_FLOPS_BF16
            emit(f"tpot_v5e_fullscale/{arch}/t{t}", t_d * 1e6,
                 f"selector_overhead={(t_d - t_s) / t_s * 100:.2f}%")
    return results


def planner_smoke() -> dict:
    """Self-contained fused-vs-inline gate for CI: a fresh tiny-dense
    build (no trained bench-lm / artifact cache needed), asserting the
    decide/apply invariants — identical decisions, one estimator GEMM."""
    import jax

    from repro.configs import get_config
    from repro.core import build_multiscale_model
    from repro.models import init_model_params

    cfg = get_config("tiny-dense")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [(rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32),
                rng.integers(0, cfg.vocab_size, (2, 32)).astype(np.int32))]
    model = build_multiscale_model(cfg, params, batches,
                                   targets=[3.5, 4.5], finetune_epochs=1,
                                   baselines=())
    res = fused_vs_inline(ServingEngine(cfg, params, model), quick=True)
    assert res["identical"], "fused planner diverged from inline selector"
    assert res["fused_dots"] == 1, res
    assert res["inline_dots"] > res["fused_dots"], res
    return res


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter eval sequences, fewer timing reps")
    ap.add_argument("--smoke", action="store_true",
                    help="fused-vs-inline planner gate only (tiny model, "
                         "no artifact cache) — the CI smoke variant")
    args = ap.parse_args()
    if args.smoke:
        planner_smoke()
    else:
        main(quick=args.quick)
