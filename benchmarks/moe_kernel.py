"""Grouped MoE expert bit-serial kernel: per-expert DMA elision benchmark.

The dense MoE serving path materializes every expert's dequantized stack
— ``(E, K, N)`` per tick, and ``(M, E, K, N)`` for per-row prefill
decisions (the memory cliff noted in ``core/dynamic_linear.weights_rows``).
The grouped kernel (kernels/bitserial) instead streams packed bit-planes
per (expert, token-group) with the router's assignment table scalar-
prefetched, so empty experts and idle groups fetch no plane blocks and
peak MoE-stage bytes stay independent of the row count M.

Reports, per routing mix:
- modeled HBM plane-block traffic (``expert_plane_fetches`` walking the
  kernel's real index_map in grid order) vs. the generic model where
  every group streams every plane, with bytes saved;
- CPU wall time of the grouped MoE forward (oracle backend) vs. the
  dense materialize-and-einsum path, and tokens/s of the grouped path;
- traced peak intermediate bytes of the per-row prefill MoE at two row
  counts — grouped must be M-independent, dense must not be (asserted).

Self-contained (no trained model); run from the repo root:
    PYTHONPATH=src python benchmarks/moe_kernel.py --quick
``--smoke`` is the CI gate: quick shapes + grouped/dense parity asserts.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitplane import materialize_stacked, quantize_stacked
from repro.kernels.bitserial import expert_plane_fetches
from repro.kernels.common import max_eqn_aval_elems
from repro.models.moe import moe_decode_forward, moe_decode_rows
from repro.kernels.tuning import time_us


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _time(fn, *args, reps: int = 10) -> float:
    """Median microseconds per call via the shared harness
    (``repro.kernels.tuning``): warmup + per-rep block_until_ready."""
    return time_us(fn, *args, warmup=1, reps=reps)


def _layer(e: int, d: int, f: int, bits: int):
    key = jax.random.PRNGKey(0)
    kg, ku, kd, kr = jax.random.split(key, 4)
    ovs = {
        "moe.w_gate": quantize_stacked(
            jax.random.normal(kg, (e, d, f)) * 0.2, bits=bits),
        "moe.w_up": quantize_stacked(
            jax.random.normal(ku, (e, d, f)) * 0.2, bits=bits),
        "moe.w_down": quantize_stacked(
            jax.random.normal(kd, (e, f, d)) * 0.2, bits=bits),
    }
    router = jax.random.normal(kr, (d, e)) * 0.3
    return ovs, router


class _DenseLin:
    """Materialize-and-einsum MoE applier (the legacy serving path)."""

    def __init__(self, ovs, router, bits, backend="ref"):
        self._ovs, self._router, self._bits = ovs, router, bits
        self.backend = backend

    def __call__(self, path, x, **kw):
        return jnp.einsum("...k,kn->...n", x, self._router)

    def weights(self, path, x, **kw):
        b = self._bits if jnp.ndim(self._bits) == 0 else self._bits[0]
        return materialize_stacked(self._ovs[path], b)

    def weights_rows(self, path, x, **kw):
        if jnp.ndim(self._bits) == 0:
            return materialize_stacked(self._ovs[path], self._bits)
        return jax.vmap(
            lambda b: materialize_stacked(self._ovs[path], b))(self._bits)


class _GroupedLin(_DenseLin):
    """Same decisions, applied through the grouped bit-serial kernel."""

    def weights(self, path, x, **kw):
        raise AssertionError("grouped path must not materialize")

    weights_rows = weights

    def grouped_weights(self, path, x, **kw):
        return self._ovs[path], self._bits


def _peak_bytes(fn, *args) -> int:
    return max_eqn_aval_elems(jax.make_jaxpr(fn)(*args).jaxpr) * 4


def measure(quick: bool = False, smoke: bool = False) -> dict:
    e, d, f, bits = (4, 32, 48, 6) if quick else (8, 64, 96, 8)
    b, s, top_k = 2, 8, 2
    ovs, router = _layer(e, d, f, bits)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d),
                          dtype=jnp.float32)

    def fwd(lin, xs):
        y, _ = moe_decode_forward("swiglu", lin, {}, "moe", xs,
                                  num_experts=e, top_k=top_k)
        return y

    grouped = jax.jit(lambda xs: fwd(_GroupedLin(ovs, router,
                                                 jnp.int32(bits)), xs))
    dense = jax.jit(lambda xs: fwd(_DenseLin(ovs, router,
                                             jnp.int32(bits)), xs))
    if smoke:
        np.testing.assert_allclose(grouped(x), dense(x),
                                   rtol=1e-4, atol=1e-4)

    us_grouped = _time(grouped, x)
    us_dense = _time(dense, x)
    tokens_per_s = b * s / (us_grouped / 1e6)

    # per-row prefill peak: grouped stays flat in M, dense scales with
    # it. Captured on the kernel dispatch (interpret backend — the
    # pallas_call stays one opaque eqn, exactly like the TPU lowering);
    # the pure-jnp oracle backend materializes per-plane unpacks and is
    # NOT the deployment path this invariant describes.
    m = 8 if quick else 16

    def rows(lin_cls, xm, bits_m, backend):
        y, _ = moe_decode_rows("swiglu",
                               lin_cls(ovs, router, bits_m, backend), {},
                               "moe", xm, num_experts=e, top_k=top_k)
        return y

    def peaks(mm):
        xm = jnp.zeros((b, mm, d), jnp.float32)
        bits_m = jnp.full((mm,), bits, jnp.int32)
        return (_peak_bytes(lambda a, bm: rows(_GroupedLin, a, bm,
                                               "interpret"), xm, bits_m),
                _peak_bytes(lambda a, bm: rows(_DenseLin, a, bm, "ref"),
                            xm, bits_m))
    g1, d1 = peaks(m)
    g2, d2 = peaks(2 * m)

    def stack_bytes(mm):            # the (M, E, K, N) per-row weight stack
        return 4 * mm * max(ov.planes.shape[0] * ov.k * ov.planes.shape[-1]
                            for ov in ovs.values())
    # grouped: no eqn ever reaches the per-row weight stack, and the peak
    # is activations only (exactly linear in M — no M x weights term)
    assert g1 < stack_bytes(m) and g2 < stack_bytes(2 * m), (g1, g2)
    assert g2 == 2 * g1, (g1, g2)
    # dense: the vmapped materialization binds the full stack
    assert d1 >= stack_bytes(m) and d2 >= stack_bytes(2 * m), (d1, d2)

    # modeled plane-block traffic over routing mixes (one token group)
    kw_blocks = ovs["moe.w_up"].planes.shape[2]
    tile_n = 128 if f % 128 == 0 else f
    n_tiles = max(1, f // tile_n)
    block_bytes = kw_blocks * tile_n * 4
    expert_of = list(range(e))
    mixes = {
        "balanced": ([bits] * e, [s * top_k // e] * e),
        "skewed": ([bits] * e, [s * top_k - (e - 1)] + [1] * (e - 1)),
        "empty-experts": ([bits] * e, [s * top_k // 2, s * top_k // 2]
                          + [0] * (e - 2)),
        "low-bit": ([max(1, bits // 2)] * e, [s * top_k // e] * e),
    }
    traffic = {}
    for mix, (b_sel, counts) in mixes.items():
        fetches = expert_plane_fetches(expert_of, b_sel, counts,
                                       n_tiles, bits)
        naive = e * n_tiles * bits
        traffic[mix] = {"fetches": fetches, "naive": naive}
        emit(f"moe_kernel/{mix}", us_grouped,
             f"blocks={fetches};generic={naive};"
             f"saved_mb={(naive - fetches) * block_bytes / 1e6:.3f};"
             f"dense_us={us_dense:.1f}")
        assert fetches <= naive

    return {
        "moe_tokens_per_s": tokens_per_s,
        "moe_peak_bytes": g1,
        "moe_dense_peak_bytes": d1,
        "moe_us_grouped": us_grouped,
        "moe_us_dense": us_dense,
        "traffic": traffic,
    }


def main(quick: bool = False, smoke: bool = False) -> dict:
    out = measure(quick=quick or smoke, smoke=smoke)
    emit("moe_kernel/summary", out["moe_us_grouped"],
         f"tokens_per_s={out['moe_tokens_per_s']:.1f};"
         f"peak_bytes={out['moe_peak_bytes']};"
         f"dense_peak_bytes={out['moe_dense_peak_bytes']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small shapes (CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="quick shapes + grouped/dense parity asserts")
    args = ap.parse_args()
    main(quick=args.quick, smoke=args.smoke)
