"""Paper Table 13 analog: perplexity under forced (l, h) candidate pairs.

The paper finds neighbouring precisions around the target work best; we
force all units to fixed pairs at target 4.5 and compare.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (built_model, calibration_batches, emit,
                               eval_ppl, eval_sequences)
from repro.core.adaptation import AdaptationSet, UnitAdaptation
from repro.core.estimators import fit_estimator
from repro.core.thresholds import collect_calibration
from repro.models import linear_units
from repro.serving import ServingEngine

TARGET = 4.5
PAIRS = [(4, 5), (3, 5), (3, 6), (4, 6)]


def forced_pair_adaptation(cfg, params, model, batches, l, h):
    units = linear_units(cfg)
    frac_h = (TARGET - l) / (h - l)          # fraction of steps at h-bit
    p_eff = l + frac_h * (h - l)             # == TARGET
    pairs = {u.path: (l, h) for u in units}
    records = collect_calibration(
        cfg, params, model.overlays, units,
        {u.path: p_eff for u in units}, batches,
        b_min=model.b_min, max_bits={u.path: max(h, model.max_bits[u.path])
                                     for u in units},
        key=jax.random.PRNGKey(1), pairs=pairs)
    aset = AdaptationSet(target_precision=TARGET, b_min=model.b_min,
                         memory_budget_bits=model.memory_budget_bits)
    for u in units:
        size = int(np.prod(params[u.path].shape))
        ua = UnitAdaptation(path=u.path, kind=u.kind, size=size, p=p_eff,
                            l=l, h=h, max_bits=h,
                            async_eligible=u.async_eligible)
        if u.path in records:
            rec = records[u.path]
            ua.threshold = float(np.quantile(rec.err, 1.0 - frac_h))
            ua.est = fit_estimator(rec.err, rec.xnorm, rec.jl_raw, rec.g)
        else:
            ua.l = ua.h = int(round(TARGET))
        aset.units[u.path] = ua
    return aset


def main(quick: bool = False) -> dict:
    cfg, params, model = built_model()
    batches = calibration_batches(cfg, n=2 if quick else 4)
    toks = eval_sequences(cfg, n=1, seq=96 if quick else 128)
    results = {}
    pairs = PAIRS[:2] if quick else PAIRS
    for (l, h) in pairs:
        aset = forced_pair_adaptation(cfg, params, model, batches, l, h)
        import copy
        m2 = copy.copy(model)
        m2.adaptations = dict(model.adaptations)
        m2.adaptations[TARGET] = aset
        engine = ServingEngine(cfg, params, m2)
        ppl, eb, us = eval_ppl(engine, toks, TARGET)
        emit(f"hl_ablation/l{l}h{h}", us,
             f"ppl={ppl:.3f};eff_bits={eb:.2f}")
        results[(l, h)] = ppl
    return results


if __name__ == "__main__":
    main()
