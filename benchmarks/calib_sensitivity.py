"""Paper Table 14 analog: sensitivity to the calibration corpus.

Builds the adaptation set from two different calibration splits and
compares held-out perplexity (no-overfit check).
"""
from __future__ import annotations

from benchmarks.common import built_model, emit, eval_ppl, eval_sequences
from repro.serving import ServingEngine


def main(quick: bool = False) -> dict:
    results = {}
    toks = None
    for split in ("calibration", "train"):
        cfg, params, model = built_model(
            targets=(3.5, 4.5), calib_split=split, tag=f"_{split}")
        if toks is None:
            toks = eval_sequences(cfg, n=1)
        engine = ServingEngine(cfg, params, model)
        for t in (3.5, 4.5):
            ppl, _, us = eval_ppl(engine, toks, t)
            emit(f"calib_sensitivity/{split}/t{t}", us, f"ppl={ppl:.3f}")
            results[(split, t)] = ppl
    return results


if __name__ == "__main__":
    main()
