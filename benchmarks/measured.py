"""Measured (not modeled) DMA-elision contracts + per-platform artifact.

Every other stage in ``benchmarks/`` reports *modeled* plane traffic
(host-side index_map walks). This stage measures the contract with a
wall clock: uniform ``b_sel`` / ``kv_b`` sweeps through the slot and KV
kernels — fewer planes must cost less *time*, not just fewer modeled
blocks — and tuned-vs-default tokens/s through the public dispatch with
the tuning cache installed and removed.

Platform rules (the artifact is per-platform by construction):

* the artifact is named ``BENCH_serve.<platform>.json`` and carries a
  ``platform`` key; ``tools/perf_gate.py`` only gates artifacts whose
  platforms match, so a TPU trajectory never gates a CPU run;
* sweeps run the kernel body (compiled on TPU/GPU, interpret on CPU);
  the monotone-in-bits assertion is enforced on real backends ONLY —
  interpret-mode wall time doesn't model DMA, so on CPU the sweep is
  recorded for trajectory, not asserted;
* tokens/s metrics on CPU use the jnp oracle (interpret wall time is
  noise); on TPU/GPU they use the compiled kernel.

Self-contained (no trained model); run from the repo root:
    PYTHONPATH=src python benchmarks/measured.py --smoke
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import quantize_linear
from repro.kernels import tuning
from repro.kernels.bitserial.kernel import bitserial_matmul_slots_pallas
from repro.kernels.bitserial.ops import bitserial_matmul
from repro.kernels.bitserial.ref import bitserial_matmul_slots_ref
from repro.kernels.kv_attention.ops import kv_decode_attention
from repro.kernels.tuning import measure

#: monotonicity slack per sweep step on real backends — clock jitter,
#: not a license for a lower-bits step to cost more
MONOTONE_SLACK = 0.05


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _real_backend(platform: str) -> bool:
    return platform in ("tpu", "gpu")


def _monotone(sweep: Dict[int, float]) -> bool:
    ts = [sweep[b] for b in sorted(sweep)]
    return all(ts[i + 1] >= ts[i] * (1.0 - MONOTONE_SLACK)
               for i in range(len(ts) - 1))


# ---------------------------------------------------------------------------
# b_sel sweep: slot kernel wall time vs uniform precision
# ---------------------------------------------------------------------------
def slot_sweep(smoke: bool, platform: str, reps: int) -> Dict[int, float]:
    k, n, bits, s = (128, 256, 4, 4) if smoke else (512, 1024, 8, 8)
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.2
    ql = quantize_linear(w, bits=bits)
    scale, zero = ql.scale[None, :], ql.zero[None, :]
    x = jax.random.normal(jax.random.PRNGKey(1), (s, 1, k), jnp.float32)
    interpret = not _real_backend(platform)
    tile_n = 128 if smoke else 256
    sweep = {}
    for b in range(1, bits + 1):
        b_sel = jnp.full((s,), b, jnp.int32)
        r = measure(
            lambda: bitserial_matmul_slots_pallas(
                x, ql.planes, scale, zero, b_sel, bits=bits,
                tile_n=tile_n, interpret=interpret),
            warmup=1, reps=reps)
        sweep[b] = r.seconds
        emit(f"measured/slot_sweep/b{b}", r.seconds * 1e6,
             f"bits={bits};tile_n={tile_n};interpret={int(interpret)}")
    return sweep


def kv_sweep(smoke: bool, platform: str, reps: int) -> Dict[int, float]:
    s, bits, t_rows, hkv, dh = (2, 4, 64, 1, 128) if smoke else \
        (4, 6, 256, 2, 128)
    dw = dh // 32
    backend = "pallas" if _real_backend(platform) else "interpret"
    lens = jnp.full((s, 1), t_rows, jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(2), (s, 1, hkv, dh),
                          jnp.float32)

    def stream(seed):
        kk = jax.random.PRNGKey(seed)
        kp = jax.random.randint(kk, (s, bits, t_rows, hkv, dw), 0,
                                jnp.iinfo(jnp.int32).max, jnp.int32)
        sc = jax.random.uniform(kk, (s, t_rows, hkv, 1), jnp.float32,
                                0.01, 0.1)
        zr = jax.random.uniform(kk, (s, t_rows, hkv, 1), jnp.float32,
                                0.0, 1.0)
        return kp, sc, zr

    kp, ks, kz = stream(3)
    vp, vs, vz = stream(4)
    sweep = {}
    for b in range(1, bits + 1):
        kv_b = jnp.full((s,), b, jnp.int32)
        r = measure(
            lambda: kv_decode_attention(q, kp, ks, kz, vp, vs, vz, lens,
                                        kv_b, bits=bits, backend=backend),
            warmup=1, reps=reps)
        sweep[b] = r.seconds
        emit(f"measured/kv_sweep/b{b}", r.seconds * 1e6,
             f"bits={bits};backend={backend}")
    return sweep


# ---------------------------------------------------------------------------
# Tuned-vs-default tokens/s through the public dispatch
# ---------------------------------------------------------------------------
def decode_rates(smoke: bool, platform: str, reps: int,
                 cache: Optional[tuning.TuningCache]) -> Dict[str, float]:
    k, n, bits, s = (128, 256, 4, 4) if smoke else (512, 1024, 8, 8)
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.2
    ql = quantize_linear(w, bits=bits)
    x = jax.random.normal(jax.random.PRNGKey(1), (s, 1, k), jnp.float32)
    b_sel = jnp.asarray([bits - 1] * s, jnp.int32)
    if _real_backend(platform):
        backend = "pallas"
        call = lambda: jax.vmap(
            lambda xs, bs: bitserial_matmul(xs, ql, bs,
                                            backend=backend))(x, b_sel)
    else:
        # CPU tokens/s must be gate-stable: the oracle, not interpret
        scale, zero = ql.scale[None, :], ql.zero[None, :]
        call = lambda: bitserial_matmul_slots_ref(
            x, ql.planes, scale, zero, b_sel, bits=bits)

    prev = tuning.active_cache()
    try:
        tuning.use_cache(None)
        t_default = measure(call, warmup=1, reps=reps).seconds
        tuning.use_cache(cache)
        t_tuned = measure(call, warmup=1, reps=reps).seconds
    finally:
        tuning.use_cache(prev)
    tuned_rate = s / max(t_tuned, 1e-12)
    default_rate = s / max(t_default, 1e-12)
    emit("measured/decode_tokens_per_s", t_tuned * 1e6,
         f"tuned={tuned_rate:.1f};default={default_rate:.1f}")
    return {"decode_tokens_per_s": tuned_rate,
            "decode_tokens_per_s_default": default_rate}


def kv_rate(smoke: bool, platform: str, reps: int,
            sweep: Dict[int, float]) -> float:
    # tokens/s of the mid-precision KV read from the sweep already run
    s = 2 if smoke else 4
    b = max(1, max(sweep) // 2)
    return s / max(sweep[b], 1e-12)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def collect(smoke: bool = False,
            cache_path: Optional[str] = None) -> dict:
    platform = tuning.platform_name()
    reps = 3 if smoke else 5
    cache = tuning.TuningCache.load(cache_path) if cache_path else \
        tuning.active_cache()
    real = _real_backend(platform)

    sweep_s = slot_sweep(smoke, platform, reps)
    sweep_k = kv_sweep(smoke, platform, reps)
    mono_s, mono_k = _monotone(sweep_s), _monotone(sweep_k)
    if real and not (mono_s and mono_k):
        raise SystemExit(
            f"measured-time slope not monotone in bits on {platform}: "
            f"slot={sweep_s} kv={sweep_k}")

    blob = {
        "platform": platform,
        "suite": "measured",
        "backend": "pallas" if real else "interpret",
        "quick": bool(smoke),
        "slot_sweep_s": {str(b): t for b, t in sweep_s.items()},
        "kv_sweep_s": {str(b): t for b, t in sweep_k.items()},
        "monotone_slot": mono_s,
        "monotone_kv": mono_k,
        "monotone_enforced": real,
        "tuning_entries": len(cache.entries) if cache else 0,
        "kv_tokens_per_s": kv_rate(smoke, platform, reps, sweep_k),
    }
    blob.update(decode_rates(smoke, platform, reps, cache))
    emit("measured/summary", 0.0,
         f"platform={platform};monotone_slot={int(mono_s)};"
         f"monotone_kv={int(mono_k)};enforced={int(real)}")
    return blob


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI shard)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default "
                         "BENCH_serve.<platform>.json)")
    ap.add_argument("--cache", default=None,
                    help="tuning cache to install (default: the active "
                         "cache / $REPRO_TUNING_CACHE)")
    args = ap.parse_args()
    blob = collect(smoke=args.smoke, cache_path=args.cache)
    out = args.out or f"BENCH_serve.{blob['platform']}.json"
    with open(out, "w") as fh:
        json.dump(blob, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
