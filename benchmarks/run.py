"""Benchmark driver — one module per paper table (DESIGN.md §7 index).

Prints ``name,us_per_call,derived`` CSV rows per the harness convention.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only MODULE]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("perplexity_tradeoff", "Tables 1/10/11: ppl vs target precision"),
    ("downstream_proxy", "Table 2: greedy-decode task accuracy"),
    ("exact_vs_approx", "Table 3: exact vs estimated relative error"),
    ("estimator_overhead", "Tables 4/5/6: selector overhead + ablation"),
    ("qos_percentiles", "Table 7: per-query effective-bit percentiles"),
    ("hl_ablation", "Table 13: forced (l,h) candidate pairs"),
    ("calib_sensitivity", "Table 14: calibration-set swap"),
    ("sensitivity_dynamics", "Figure 3: per-step sensitivity dynamics"),
    ("slot_kernel", "Batched-slot kernel: per-slot DMA elision"),
    ("roofline", "§Roofline: 3-term analysis from the dry-run"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = 0
    for name, desc in MODULES:
        if args.only and args.only != name:
            continue
        print(f"# === {name}: {desc} ===", flush=True)
        t0 = time.monotonic()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(quick=args.quick)
        except Exception as e:
            failures += 1
            print(f"# FAIL {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
        print(f"# === {name} done in {time.monotonic() - t0:.1f}s ===",
              flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
