"""Benchmark driver — one module per paper table (DESIGN.md §7 index).

Prints ``name,us_per_call,derived`` CSV rows per the harness convention.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only MODULE]
  PYTHONPATH=src python -m benchmarks.run --json [PATH]

``--json`` runs the serve-path collection alone and writes a
machine-readable ``BENCH_serve.json`` (decode tokens/s, mean effective
bits, fused-planner overhead) so the perf trajectory is tracked across
PRs; combine with ``--quick`` for the CI smoke variant.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    ("perplexity_tradeoff", "Tables 1/10/11: ppl vs target precision"),
    ("downstream_proxy", "Table 2: greedy-decode task accuracy"),
    ("exact_vs_approx", "Table 3: exact vs estimated relative error"),
    ("estimator_overhead", "Tables 4/5/6: selector overhead + ablation"),
    ("qos_percentiles", "Table 7: per-query effective-bit percentiles"),
    ("hl_ablation", "Table 13: forced (l,h) candidate pairs"),
    ("calib_sensitivity", "Table 14: calibration-set swap"),
    ("sensitivity_dynamics", "Figure 3: per-step sensitivity dynamics"),
    ("slot_kernel", "Batched-slot kernel: per-slot DMA elision"),
    ("moe_kernel", "Grouped MoE kernel: per-expert DMA elision"),
    ("kv_cache", "Dynamic-precision KV: plane-read traffic + storage"),
    ("prefill", "Prefill/decode disaggregation: TTFT + launch counts"),
    ("speculative", "Self-speculative decode: draft/verify speedup sweep"),
    ("traffic_replay", "Paged-KV fleet under replayed traffic: TTFT/goodput"),
    ("roofline", "§Roofline: 3-term analysis from the dry-run"),
]


def collect_serve_json(quick: bool) -> dict:
    """The tracked serve-path numbers: decode throughput, effective bits,
    TTFT / prefill throughput of the disaggregated prefill stage, and the
    fused-planner-vs-inline decision overhead."""
    import jax

    from benchmarks.common import built_model, eval_ppl, eval_sequences
    from benchmarks.estimator_overhead import fused_vs_inline
    from repro.kernels.tuning import measure
    from benchmarks.moe_kernel import measure as moe_measure
    from benchmarks.prefill import measure as prefill_measure
    from benchmarks.speculative import measure as spec_measure
    from repro.serving import ServingEngine

    cfg, params, model = built_model()
    engine = ServingEngine(cfg, params, model)
    toks = eval_sequences(cfg, n=1, seq=64 if quick else 128)
    target = 4.0
    prompt, max_new = toks[:, :8], (24 if quick else 64)
    r = measure(lambda: engine.generate(prompt, max_new, target),
                warmup=1, reps=1)
    gen_wall, gen_bits = r.seconds, r.out[1]
    engine.teacher_forced_nll(toks[:1], target)         # compile
    ppl, eff_bits, us_step = eval_ppl(engine, toks, target)
    planner = fused_vs_inline(engine, quick=quick)
    legacy = ServingEngine(cfg, params, model, prefill_chunk=0)
    p_len = 32 if quick else 64
    prefill = prefill_measure(engine, legacy, toks[:, :p_len], target)
    spec_k = 4
    spec = spec_measure(engine, prompt, max_new, target, ks=(spec_k,))
    spec_row = spec["rows"][0]
    moe = moe_measure(quick=quick)
    # dynamic-precision KV cache: planner-assigned per-layer read bits
    kv_engine = ServingEngine(cfg, params, model, kv_overlay=True)
    kv_wall = measure(lambda: kv_engine.generate(prompt, max_new, target),
                      warmup=1, reps=1).seconds
    # paged bitplane-KV pool + prefill fleet under replayed traffic
    from benchmarks.traffic_replay import measure as replay_measure
    replay = replay_measure(quick=quick)
    assert replay["paged_tokens_match"] and replay["paged_bits_match"]
    return {
        "p50_ttft_s": replay["p50_ttft_s"],
        "p99_ttft_s": replay["p99_ttft_s"],
        "goodput_tokens_per_s": replay["goodput_tokens_per_s"],
        "slo_attainment": replay["slo_attainment"],
        "paged_slot_multiplier": replay["paged_slot_multiplier"],
        "paged_kv_saved": replay["paged_kv_saved"],
        "paged_preemptions": replay["paged_preemptions"],
        "kv_tokens_per_s": max_new / kv_wall,
        "kv_bytes_saved": kv_engine.kv_bytes_saved(
            1, kv_engine.kv_bucket),
        "moe_tokens_per_s": moe["moe_tokens_per_s"],
        "moe_peak_bytes": moe["moe_peak_bytes"],
        "moe_dense_peak_bytes": moe["moe_dense_peak_bytes"],
        "spec_k": spec_k,
        "spec_tokens_per_s": spec_row["tokens_per_s"],
        "spec_acceptance_rate": spec_row["acceptance_rate"],
        "spec_launches_per_token": spec_row["launches_per_token"],
        "target": target,
        "decode_tokens_per_s": max_new / gen_wall,
        "teacher_forced_us_per_step": us_step,
        "perplexity": ppl,
        "effective_bits": eff_bits,
        "generate_effective_bits": float(sum(gen_bits) / len(gen_bits)),
        "planner": planner,
        "ttft_s": prefill["staged_ttft_s"],
        "ttft_legacy_s": prefill["legacy_ttft_s"],
        "prefill_tokens_per_s": prefill["staged_prefill_tokens_per_s"],
        "prefill_launches": prefill["staged_launches"],
        "prefill_prompt_len": p_len,
        "platform": jax.default_backend(),
        "suite": "serve",
        "quick": quick,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="write the serve-path metrics to PATH and exit")
    args = ap.parse_args()

    if args.json:
        # wall_s (total collection time) is deliberately NOT recorded:
        # it tracked machine load, not the serve path, and the perf gate
        # never compared it
        blob = collect_serve_json(args.quick)
        with open(args.json, "w") as fh:
            json.dump(blob, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}: "
              f"{blob['decode_tokens_per_s']:.1f} tok/s, "
              f"eff_bits={blob['effective_bits']:.3f}, planner fused "
              f"{blob['planner']['fused_eqns']} eqns vs inline "
              f"{blob['planner']['inline_eqns']}")
        return 0

    failures = 0
    for name, desc in MODULES:
        if args.only and args.only != name:
            continue
        print(f"# === {name}: {desc} ===", flush=True)
        t0 = time.monotonic()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main(quick=args.quick)
        except Exception as e:
            failures += 1
            print(f"# FAIL {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
        print(f"# === {name} done in {time.monotonic() - t0:.1f}s ===",
              flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
