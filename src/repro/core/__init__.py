"""DP-LLM core: the paper's contribution as a composable JAX module."""
from repro.core.adaptation import (AdaptationSet, DecisionBundle,
                                   MultiScaleModel, ServeArtifacts,
                                   UnitAdaptation, UnitStatic,
                                   export_decision_bundle,
                                   export_serve_arrays,
                                   export_static_arrays)
from repro.core.allocator import allocate_precisions, uniform_allocation
from repro.core.decision import PrecisionPlanner, draft_floor_bits
from repro.core.bitplane import (QuantizedLinear, QuantizedStacked,
                                 bitserial_matmul_ref, delta_weight,
                                 materialize, materialize_stacked,
                                 quantize_linear, quantize_stacked)
from repro.core.dynamic_linear import DynamicLinearApplier
from repro.core.estimators import EstimatorFit, estimate, fit_estimator
from repro.core.pipeline import (build_multiscale_model, quantize_units,
                                 static_allocation)
from repro.core.quantizer import dequantize, quantize_channelwise

__all__ = [
    "AdaptationSet", "DecisionBundle", "DynamicLinearApplier",
    "EstimatorFit", "MultiScaleModel", "PrecisionPlanner",
    "QuantizedLinear", "QuantizedStacked",
    "ServeArtifacts", "UnitAdaptation", "UnitStatic",
    "allocate_precisions", "bitserial_matmul_ref",
    "build_multiscale_model", "delta_weight", "dequantize",
    "draft_floor_bits", "estimate",
    "export_decision_bundle", "export_serve_arrays",
    "export_static_arrays", "fit_estimator",
    "materialize", "materialize_stacked", "quantize_channelwise",
    "quantize_linear", "quantize_stacked", "quantize_units",
    "static_allocation", "uniform_allocation",
]
