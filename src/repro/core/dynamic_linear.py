"""Runtime dynamic-precision linear applier — the DP-LLM serving path.

THE single precision-selection implementation: the serving engine, the
launch/dry-run lowering path, and the continuous-batching scheduler all
build on this class. Every adaptation artifact (candidate l/h pairs,
thresholds, estimator a/b/γ and G matrices) is a *traced array* stacked
over target precisions (see :func:`repro.core.adaptation.export_serve_arrays`),
and the active target is a traced index — so one compiled step serves all
targets without retracing, and the production mesh can shard the artifacts
like any other weight.

Decide/apply split (the serving hot path): when constructed with
``planned_bits`` — the ``(U,)`` decision vector a
:class:`repro.core.decision.PrecisionPlanner` computed in one fused
launch (normally at the END of the *previous* tick: the paper's async
pipelining) — this class shrinks to **lookup-and-apply**: each unit's
bits come from a static-row index into the planned vector, zero
estimator ops run between the matmuls. Without ``planned_bits`` the
legacy inline path runs (~5 jnp ops per unit): the sync fallback for
tick 0, ``use_async=False``, and the lowering builders. With
``capture=True`` the applier additionally records every unit's
estimator input row so the planner can decide the NEXT tick
(:meth:`planner_inputs`).

Implements the ``lin(path, x, async_input=...)`` protocol of the model zoo:
for each quantized unit it estimates the relative error (linear / JL /
exact), compares against the unit's threshold at the selected target, and
runs the bit-serial matmul at the selected precision. Non-unit paths fall
through to the raw parameters. ``weights(path, x)`` materializes stacked
MoE expert weights at the selected precision. Per-step **effective
bitwidth** (paper §6.3 QoS analysis) is a vectorized ``(U,)`` reduction
over the decision vector when a bundle is attached (bit-compatible with
the historical per-call records list, which remains only for
bundle-less builders).

Array-layout contract (shared with the mesh sharding rules)
-----------------------------------------------------------
``serve_params`` carries exactly three trees, whose shapes this class and
``distributed/sharding.SERVE_RULES`` jointly rely on (T targets, K the
padded reduction dim, N the output dim, B the plane budget):

    raw[path]            weight-shaped arrays for non-unit paths
    overlays[path]       QuantizedLinear   planes (B, K/32, N) int32,
                                           scale/zero (N,) f32
                         QuantizedStacked  planes (E, B, K/32, N), scale/
                                           zero (E, N) — MoE expert stacks
    est[path]            l/h/kind/threshold (T,), a/b (T,), gamma (T,),
                         g (T, k_proj, K), delta (T, K, N) (exact mode)

``target_idx`` indexes the leading T axis of every ``est`` array — it is
traced (and per-slot under ``vmap``), so the T axis must stay replicated
on the mesh, while K/N axes shard like the weight they gate and the
plane axis is never split (a precision is a *prefix* of planes). See
``core/adaptation.serve_array_axes`` for the canonical axis names, and
``core/adaptation.DecisionBundle`` for the unit-stacked row order that
``planned_bits`` / :meth:`planner_inputs` follow.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptation import (DecisionBundle, KIND_LINEAR, KIND_PINNED,
                                   UnitStatic)
from repro.core.bitplane import (QuantizedStacked, materialize,
                                 materialize_stacked)


def _bitserial_matmul(*args, **kw):
    # deferred: repro.kernels.bitserial's oracle imports core.bitplane,
    # so a module-level import here cycles when the kernels package is
    # imported first (e.g. `import repro.kernels.jl_estimator`)
    from repro.kernels.bitserial import bitserial_matmul
    return bitserial_matmul(*args, **kw)


def _row_view(x: jax.Array) -> jax.Array:
    """(..., K) -> (R, K) float32 rows for estimation."""
    return x.reshape((-1, x.shape[-1])).astype(jnp.float32)


def _match_width(xf: jax.Array, k: int) -> jax.Array:
    """Zero-pad estimation rows up to an artifact's (padded) K width."""
    if xf.shape[-1] < k:
        xf = jnp.pad(xf, ((0, 0), (0, k - xf.shape[-1])))
    return xf


class StaticDraftLinear:
    """Dense ``lin`` protocol for the speculative DRAFT path.

    The draft plan is STATIC — every unit pinned to the overlay's bit
    floor — so the plane prefix can be materialized ONCE per engine into
    plain dense weights and a draft tick becomes one GEMV per unit: no
    per-plane ops, no estimator, no decision accounting.
    ``x @ materialize(ov, floor)`` is the bit-serial closed form at
    ``floor`` bits up to float association, and draft numerics only
    steer ACCEPTANCE — the verify launch re-derives every emitted token
    — so this is a pure fast path. The engine uses it where the
    bit-serial matmul would run the jnp oracle (whose plane loop costs
    full-``B`` compute regardless of ``b_sel``); the Pallas backend
    keeps the plane-prefix kernel draft, where fetching two planes IS
    the cheap path. See :func:`materialize_draft_weights`.

    Single-token drafts only: linear units via ``__call__``, stacked
    (MoE) units via ``weights`` — the prefill-only ``weights_rows``
    entry point is deliberately absent.
    """

    def __init__(self, raw: Dict, dense: Dict):
        self.raw = raw
        self.dense = dense

    def __call__(self, path: str, x: jax.Array, *,
                 async_input=None) -> jax.Array:
        w = self.dense.get(path)
        if w is None:
            w = self.raw[path]
        return jnp.einsum("...k,kn->...n", x, w).astype(x.dtype)

    def weights(self, path: str, x: jax.Array, *,
                async_input=None) -> jax.Array:
        w = self.dense.get(path)
        return self.raw[path] if w is None else w.astype(x.dtype)


def materialize_draft_weights(overlays: Dict, floor_bits,
                              row_of: Dict) -> Dict:
    """``path -> dense floor-bit weights`` for :class:`StaticDraftLinear`.

    ``floor_bits`` is the static ``(U,)`` draft plan
    (:func:`repro.core.decision.draft_floor_bits`, host-readable);
    ``row_of`` maps unit paths into it. Built once per engine — the
    weights are as static as the overlays they were unpacked from.
    """
    floor = jax.device_get(floor_bits)
    dense = {}
    for path, ov in overlays.items():
        b = int(floor[row_of[path]])
        if isinstance(ov, QuantizedStacked):
            dense[path] = materialize_stacked(ov, b)
        else:
            dense[path] = materialize(ov, b)
    return dense


class DynamicLinearApplier:
    """One instance per traced step; collect ``effective_bits()`` after.

    Parameters
    ----------
    table: trace-time :class:`UnitStatic` constants per unit path.
    serve_params: ``{"raw", "overlays", "est"}`` — raw params for non-unit
        paths, bit-plane overlays, and the target-stacked estimator arrays.
        ``est`` entries may additionally carry ``delta`` — (T, K, N) exact
        ΔW stacks — to enable ``mode="exact"``.
    target_idx: traced int32 scalar selecting the target precision. Under
        ``jax.vmap`` (the scheduler's slot axis) this becomes per-slot.
    mode: ``dynamic | static | max | exact``. ``static`` requires
        ``static_bits``: per-path (T,) int32 arrays (traced).
    grouped: let MoE layers stream stacked (expert) units through the
        grouped bit-serial kernel via :meth:`grouped_weights` instead of
        materializing dense expert stacks. ``False`` forces the legacy
        ``weights``/``weights_rows`` dense path (the parity oracle).
    active: optional traced bool — ``False`` gates every precision decision
        to 0 bits. Under the scheduler's slot vmap this is the per-slot
        running mask: idle/retired slots select ``b_sel = 0``, which the
        batched bit-serial kernel treats as "fetch no planes, output
        zeros" — empty slots stop burning HBM bandwidth and MXU cycles on
        every bit-serial linear unit. Stacked (MoE) units zero their
        materialized weights for consistency, but their dense vmapped
        build has no per-slot elision (a batched stacked kernel is future
        work). ``None`` (the engine's dense path) means always active.
    bundle: optional :class:`DecisionBundle` — enables the vectorized
        effective-bits reduction, ``planned_bits`` lookups, and
        activation capture. The serving engine/scheduler always attach
        it; bundle-less construction keeps the legacy records path for
        the lowering builders.
    planned_bits: optional ``(U,)`` int32 decision vector (the planner's
        output for THIS tick). When given, ``_select_bits`` is a pure
        row lookup — no estimator ops on the critical path. The
        ``active`` gate still applies at use time (planned bits were
        gated with the PREVIOUS tick's mask).
    capture: record each unit's estimator input row (async-eligible
        units: the pre-norm residual via ``async_input`` when
        ``use_async``; otherwise the unit's own input) for
        :meth:`planner_inputs`.
    rows: prefill mode — the number M of token rows per call. Every
        unit call sees ``(b, M, K)`` inputs; decisions are made PER ROW
        (vectorized over M, reducing over the batch axis like the
        legacy per-tick max), the bit-serial matmul applies per-row
        precision through the slot-batched kernel (rows ride the slot
        axis — each row fetches exactly its own planes), and
        :meth:`effective_bits` returns an ``(M,)`` vector. Under
        ``use_async`` row m applies the decision derived from row m-1
        (the pipelined one-tick-stale contract): row 0 applies
        ``carry_bits`` (the previous chunk's last-row decision) or its
        own same-tick decision when ``carry_bits is None`` (the boot
        chunk) — so a prefill launch reproduces M sequential ticks'
        decisions exactly. :meth:`planned_rows` exposes the per-row
        decision matrix for the carry handoff to the decode stage.
    carry_bits: optional ``(U,)`` int32 — the decision vector the
        previous prefill chunk's last row planned (rows mode only).
    """

    def __init__(
        self,
        table: Dict[str, UnitStatic],
        serve_params: Dict[str, Dict],
        *,
        target_idx=0,
        mode: str = "dynamic",
        static_bits: Optional[Dict[str, jax.Array]] = None,
        use_async: bool = True,
        backend: Optional[str] = None,
        grouped: bool = True,
        active=None,
        bundle: Optional[DecisionBundle] = None,
        planned_bits: Optional[jax.Array] = None,
        capture: bool = False,
        rows: Optional[int] = None,
        carry_bits: Optional[jax.Array] = None,
    ):
        if planned_bits is not None and bundle is None:
            raise ValueError("planned_bits needs the decision bundle's "
                             "unit⇄row table")
        if capture and bundle is None:
            raise ValueError("capture=True needs the decision bundle's "
                             "row order and K padding")
        if rows is not None:
            if bundle is None:
                raise ValueError("rows mode needs the decision bundle's "
                                 "unit⇄row table")
            if planned_bits is not None or capture:
                raise ValueError("rows mode is the prefill/verify stage: "
                                 "no planned_bits/capture")
        elif carry_bits is not None:
            raise ValueError("carry_bits only applies in rows mode")
        self.table = table
        self.raw = serve_params["raw"]
        self.overlays = serve_params["overlays"]
        self.est = serve_params.get("est") or {}
        self.target_idx = jnp.asarray(target_idx, jnp.int32)
        self.mode = mode
        self.static_bits = static_bits or {}
        self.use_async = use_async
        self.backend = backend
        self.grouped = grouped
        self.active = active
        self.bundle = bundle
        self.planned_bits = planned_bits
        self.capture = capture
        self.rows = rows
        self.carry_bits = carry_bits
        self.records: List[Tuple[jax.Array, float]] = []
        n_u = bundle.n_units if bundle is not None else 0
        self._bits_rows: List[Optional[jax.Array]] = [None] * n_u
        self._act_rows: List[Optional[jax.Array]] = [None] * n_u
        self._dec_rows: List[Optional[jax.Array]] = [None] * n_u

    # -- precision selection ---------------------------------------------------
    def _select_bits(self, u: UnitStatic, x: jax.Array,
                     async_input) -> jax.Array:
        if self.rows is not None:
            bits = self._select_bits_rows(u, x, async_input)
        elif self.planned_bits is not None:
            bits = self.planned_bits[self.bundle.row_of[u.path]]
        else:
            bits = self._select_bits_active(u, x, async_input)
        if self.active is not None:
            # idle slot: 0 bits — the batched kernel elides every plane
            # DMA. Rows mode (the scheduler's gated VERIFY launch)
            # broadcasts the scalar mask over the (M,) row vector.
            bits = jnp.where(self.active, bits, jnp.int32(0))
        return bits

    def _select_bits_rows(self, u: UnitStatic, x: jax.Array,
                          async_input) -> jax.Array:
        """Prefill: the (M,) bits vector row m's matmul actually runs at.

        ``_decide_rows`` is the per-row decision (row m decided FROM row
        m's activations); under ``use_async`` the applied vector is that
        decision shifted one row late — exactly the pipelined carry the
        sequential path threads tick to tick — with row 0 applying the
        chunk's ``carry_bits`` (or its own sync decision when booting).
        """
        dec = self._decide_rows(u, x, async_input)
        row = self.bundle.row_of[u.path]
        self._dec_rows[row] = dec
        if not self.use_async:
            return dec
        first = dec[:1] if self.carry_bits is None else \
            self.carry_bits[row][None].astype(dec.dtype)
        return jnp.concatenate([first, dec[:-1]])

    def _decide_rows(self, u: UnitStatic, x: jax.Array,
                     async_input) -> jax.Array:
        """Vectorized per-row inline decision, (M,) int32 — row m's value
        is exactly what :meth:`_select_bits_active` computes for the
        sequential tick that consumed row m (estimates reduce over the
        batch axis per row, matching the per-tick row max)."""
        m = self.rows
        t = self.target_idx
        if self.mode == "max":
            return jnp.full((m,), u.h, jnp.int32)
        if self.mode == "static":
            return jnp.broadcast_to(self.static_bits[u.path][t],
                                    (m,)).astype(jnp.int32)
        e = self.est.get(u.path)
        if e is None or u.est_kind == "pinned":
            if e is not None:
                return jnp.broadcast_to(e["l"][t], (m,)).astype(jnp.int32)
            return jnp.full((m,), u.l, jnp.int32)
        l, h = e["l"][t], e["h"][t]
        inp = self._est_input(u, x, async_input)
        xf = inp.reshape((-1, m, inp.shape[-1])).astype(jnp.float32)
        if self.mode == "exact" and "delta" in e:
            d = e["delta"][t]
            est = jnp.max(jnp.linalg.norm(
                xf[..., :d.shape[-2]] @ d, axis=-1), axis=0)
        else:
            est = self._approx_estimate_rows(e, xf, t)
        dynamic = e["kind"][t] != KIND_PINNED
        return jnp.where(dynamic & (est > e["threshold"][t]),
                         h, l).astype(jnp.int32)

    def _approx_estimate_rows(self, e: Dict, xf: jax.Array, t) -> jax.Array:
        """(b, M, K) rows -> (M,) estimates (max over the batch axis)."""
        est_lin = est_jl = None
        if "a" in e:
            xn = jnp.linalg.norm(xf, axis=-1)               # (b, M)
            est_lin = jnp.max(e["a"][t] * xn + e["b"][t], axis=0)
        if "g" in e:
            g = e["g"][t]                                   # (k_proj, K)
            proj = _match_width(xf.reshape((-1, xf.shape[-1])),
                                g.shape[-1]) @ g.T
            proj = proj.reshape(xf.shape[:-1] + (g.shape[0],))
            est_jl = e["gamma"][t] * jnp.max(
                jnp.linalg.norm(proj, axis=-1), axis=0)
        if est_lin is None:
            return est_jl
        if est_jl is None:
            return est_lin
        return jnp.where(e["kind"][t] == KIND_LINEAR, est_lin, est_jl)

    def _select_bits_active(self, u: UnitStatic, x: jax.Array,
                            async_input) -> jax.Array:
        """Legacy inline per-unit decision — the planner's reference
        semantics (tested bit-identical) and the sync fallback."""
        t = self.target_idx
        if self.mode == "max":
            return jnp.int32(u.h)
        if self.mode == "static":
            return self.static_bits[u.path][t]
        e = self.est.get(u.path)
        if e is None or u.est_kind == "pinned":
            if e is not None:
                return e["l"][t]
            return jnp.int32(u.l)
        l, h = e["l"][t], e["h"][t]
        xf = _row_view(self._est_input(u, x, async_input))
        if self.mode == "exact" and "delta" in e:
            est = jnp.max(jnp.linalg.norm(xf @ e["delta"][t], axis=-1))
        else:
            est = self._approx_estimate(e, xf, t)
        dynamic = e["kind"][t] != KIND_PINNED
        return jnp.where(dynamic & (est > e["threshold"][t]), h, l)

    def _est_input(self, u: UnitStatic, x: jax.Array, async_input):
        """The unit's estimator input: pre-norm residual for async-eligible
        units under ``use_async``, the unit's own input otherwise."""
        if self.use_async and u.async_eligible and async_input is not None:
            return async_input
        return x

    def _approx_estimate(self, e: Dict, xf: jax.Array, t) -> jax.Array:
        est_lin = est_jl = None
        if "a" in e:
            xn = jnp.linalg.norm(xf, axis=-1)
            est_lin = jnp.max(e["a"][t] * xn + e["b"][t])
        if "g" in e:
            g = e["g"][t]                       # (k_proj, K)
            proj = _match_width(xf, g.shape[-1]) @ g.T
            est_jl = e["gamma"][t] * jnp.max(
                jnp.linalg.norm(proj, axis=-1))
        if est_lin is None:
            return est_jl
        if est_jl is None:
            return est_lin
        return jnp.where(e["kind"][t] == KIND_LINEAR, est_lin, est_jl)

    # -- decision/activation bookkeeping ----------------------------------------
    def _account(self, u: UnitStatic, bits: jax.Array, size: float,
                 x: jax.Array, async_input) -> None:
        if self.bundle is None:
            self.records.append((bits, size))
            return
        row = self.bundle.row_of[u.path]
        self._bits_rows[row] = bits
        if self.capture:
            xf = _row_view(self._est_input(u, x, async_input))
            self._act_rows[row] = _match_width(xf, self.bundle.k_pad)

    def planner_inputs(self) -> jax.Array:
        """The tick's captured estimator rows, unit-stacked (U, M, K_max)
        in bundle row order — the fused planner's input for the NEXT
        tick's decisions.

        Units a decode tick statically never applies (e.g. enc-dec
        cross-attention K/V projections, computed once at session start)
        contribute zero rows — their planned bits are never looked up,
        and zero rows cost nothing beyond the fixed (U, M, K) buffer.
        """
        applied = [a for a in self._act_rows if a is not None]
        if not applied:
            raise RuntimeError("no unit was applied this tick")
        zero = jnp.zeros_like(applied[0])
        return jnp.stack([a if a is not None else zero
                          for a in self._act_rows])

    # -- lin protocol ------------------------------------------------------------
    def __call__(self, path: str, x: jax.Array, *,
                 async_input=None) -> jax.Array:
        ov = self.overlays.get(path)
        if ov is None or isinstance(ov, QuantizedStacked):
            if ov is not None:
                raise ValueError(
                    f"stacked unit {path} must use .weights(), not lin()")
            return jnp.einsum("...k,kn->...n", x,
                              self.raw[path]).astype(x.dtype)
        u = self.table[path]
        bits = self._select_bits(u, x, async_input)
        self._account(u, bits, float(ov.k * ov.planes.shape[-1]), x,
                      async_input)
        if self.rows is not None:
            # per-row precision through the slot-batched kernel: the M
            # row axis rides the kernel's slot axis (custom_vmap), so
            # row m fetches exactly bits[m] planes — per-row DMA elision
            y = jax.vmap(
                lambda xr, br: _bitserial_matmul(xr, ov, br,
                                                 backend=self.backend),
                in_axes=(1, 0), out_axes=1)(x, bits)
        else:
            y = _bitserial_matmul(x, ov, bits, backend=self.backend)
        return y.astype(x.dtype)

    def weights(self, path: str, x: jax.Array, *,
                async_input=None) -> jax.Array:
        """Materialized weights for stacked (MoE) units at selected bits."""
        ov = self.overlays.get(path)
        if ov is None:
            return self.raw[path]
        u = self.table[path]
        bits = self._select_bits(u, x, async_input)
        e, _, _, n = ov.planes.shape
        self._account(u, bits, float(e * ov.k * n), x, async_input)
        w = materialize_stacked(ov, bits).astype(x.dtype)
        if self.active is not None:
            # idle contract for stacked units: zero weights (bits = 0
            # alone leaves the non-zero midpoint residue). The dense
            # vmapped materialization has no per-slot elision — only the
            # bit-serial linear path skips the idle slot's HBM/MXU work.
            w = jnp.where(self.active, w, jnp.zeros_like(w))
        return w

    def weights_rows(self, path: str, x: jax.Array, *,
                     async_input=None) -> jax.Array:
        """Per-row stacked (MoE) weights for the prefill stage.

        Row-invariant decisions (pinned units, static/max modes — the
        common case) materialize ONE ``(E, K, N)`` stack; genuinely
        per-row decisions (dynamic expert up/gate units) vmap the
        materialization into ``(M, E, K, N)`` so each prefill row
        applies exactly the bits the sequential tick would have.

        MEMORY NOTE: the per-row branch holds M dequantized expert
        stacks live at once — M× the legacy tick's peak for that layer.
        Fine for the eval-scale MoE configs this path serves today;
        production-scale MoE prefill wants the batched stacked kernel
        (ROADMAP) or a smaller ``prefill_chunk`` when expert units are
        dynamic.
        """
        ov = self.overlays.get(path)
        if ov is None:
            return self.raw[path]
        u = self.table[path]
        bits = self._select_bits(u, x, async_input)            # (M,)
        e, _, _, n = ov.planes.shape
        self._account(u, bits, float(e * ov.k * n), x, async_input)
        e_tab = self.est.get(path)
        invariant = (self.mode in ("static", "max") or e_tab is None
                     or u.est_kind == "pinned")
        if invariant:
            w = materialize_stacked(ov, bits[0])
        else:
            w = jax.vmap(lambda b: materialize_stacked(ov, b))(bits)
        if self.active is not None:
            # idle contract mirrors .weights(): bits = 0 alone leaves the
            # non-zero midpoint residue, so zero the materialized stack
            w = jnp.where(self.active, w, jnp.zeros_like(w))
        return w.astype(x.dtype)

    def grouped_weights(self, path: str, x: jax.Array, *,
                        async_input=None):
        """Decision handle for the grouped MoE expert kernel: the overlay
        plus this tick's selected bits, WITHOUT materializing anything.

        The MoE layers probe this before :meth:`weights` /
        :meth:`weights_rows`: a non-``None`` return means "stream the
        expert stacks through ``bitserial_matmul_grouped`` at these
        bits" — the dense ``(E, K, N)`` (or per-row ``(M, E, K, N)``)
        dequantized stack never exists, and idle experts / idle slots
        (``bits == 0`` after the ``active`` gate) elide their plane DMAs
        inside the kernel instead of multiplying by a zeroed stack.
        Accounting (decision vector, effective bits, capture) is
        identical to the dense entry points — only the APPLY changes.

        Returns ``(overlay, bits)`` — bits a scalar (tick mode) or
        ``(M,)`` (rows mode) — or ``None`` when the path has no stacked
        overlay or grouped dispatch is disabled, in which case the
        caller falls back to the dense weights path.
        """
        ov = self.overlays.get(path)
        if ov is None or not self.grouped:
            return None
        u = self.table[path]
        bits = self._select_bits(u, x, async_input)
        e, _, _, n = ov.planes.shape
        self._account(u, bits, float(e * ov.k * n), x, async_input)
        return ov, bits

    # -- accounting ----------------------------------------------------------------
    def decision_vector(self) -> jax.Array:
        """The tick's applied decisions as a (U,) int32 vector (bundle
        row order) — what actually ran, post ``active`` gating. In rows
        mode this is the (U, M) per-row applied matrix. Rows of
        statically-unapplied units are 0 (see :meth:`effective_bits` for
        how they are excluded from accounting)."""
        zero = jnp.int32(0) if self.rows is None else \
            jnp.zeros((self.rows,), jnp.int32)
        return jnp.stack([b if b is not None else zero
                          for b in self._bits_rows]).astype(jnp.int32)

    def planned_rows(self) -> jax.Array:
        """Rows mode: the (U, M) per-row DECISION matrix (row m's value
        was decided FROM row m's activations — what the fused planner
        would have planned for tick m+1). Column ``n_valid - 1`` is the
        carry the decode stage's first pipelined tick applies; rows of
        units the trace never applied are 0 (their planned bits are
        never looked up, exactly like the planner's zero-row capture)."""
        if self.rows is None:
            raise RuntimeError("planned_rows() is prefill (rows mode) only")
        zero = jnp.zeros((self.rows,), jnp.int32)
        return jnp.stack([d if d is not None else zero
                          for d in self._dec_rows]).astype(jnp.int32)

    def effective_bits(self) -> jax.Array:
        """Parameter-weighted mean of this step's precision decisions.

        With a bundle attached this is the vectorized (U,) reduction
        over the decision vector (sizes = the bundle's per-unit k·n
        counts — identical weights to the legacy per-call records).
        Units the traced step never applied are masked out of both the
        numerator and the denominator, matching the legacy records
        semantics (applied-ness is a trace-time constant). Rows mode
        returns the (M,) per-row vector — one entry per prefill row,
        bit-compatible with M sequential ticks' scalars."""
        if self.bundle is not None:
            applied = [b is not None for b in self._bits_rows]
            if not any(applied):           # no quantized unit in the trace
                return jnp.float32(0.0)    # (matches the records path)
            mask = jnp.asarray(applied, jnp.float32)
            sizes = jnp.asarray(self.bundle.sizes, jnp.float32) * mask
            bits = self.decision_vector().astype(jnp.float32)
            if self.rows is not None:
                return jnp.sum(bits * sizes[:, None], axis=0) / \
                    jnp.sum(sizes)
            return jnp.sum(bits * sizes) / jnp.sum(sizes)
        if not self.records:
            return jnp.float32(0.0)
        num = sum(b.astype(jnp.float32) * s for b, s in self.records)
        den = sum(s for _, s in self.records)
        return num / den
