"""Runtime dynamic-precision linear applier — the DP-LLM serving path.

Implements the ``lin(path, x, async_input=...)`` protocol of the model zoo:
for each quantized unit it estimates the relative error (linear / JL /
exact), compares against the unit's threshold, and runs the bit-serial
matmul at the selected precision. Non-unit paths fall through to the raw
parameters.

The applier also exposes ``weights(path, x_est)`` for stacked MoE units
(the decode path materializes expert weights at the selected precision) and
records every (bits, size) decision so the engine can account per-step
**effective bitwidth** (paper §6.3 QoS analysis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptation import AdaptationSet
from repro.core.bitplane import (QuantizedLinear, QuantizedStacked,
                                 materialize, materialize_stacked)
from repro.core.estimators import estimate
from repro.kernels.bitserial import bitserial_matmul


class DynamicLinearApplier:
    """One instance per traced step; collect ``effective_bits()`` after."""

    def __init__(
        self,
        raw_params: Dict[str, jax.Array],
        overlays: Dict[str, object],
        adaptation: Optional[AdaptationSet] = None,
        *,
        static_bits: Optional[Dict[str, int]] = None,   # static baselines
        mode: str = "dynamic",        # dynamic | static | max | exact
        use_async: bool = True,
        backend: Optional[str] = None,
        exact_deltas: Optional[Dict[str, jax.Array]] = None,
    ):
        self.raw = raw_params
        self.overlays = overlays
        self.adaptation = adaptation
        self.static_bits = static_bits or {}
        self.mode = mode
        self.use_async = use_async
        self.backend = backend
        self.exact_deltas = exact_deltas or {}
        self.records: List[Tuple[jax.Array, float]] = []

    # -- precision selection ---------------------------------------------------
    def _select_bits(self, path: str, x: jax.Array,
                     async_input) -> jax.Array:
        if self.mode == "static":
            return jnp.int32(self.static_bits[path])
        ua = self.adaptation.units[path]
        if self.mode == "max":
            return jnp.int32(ua.max_bits)
        if ua.l == ua.h:
            return jnp.int32(ua.l)
        x_est = async_input if (self.use_async and ua.async_eligible and
                                async_input is not None) else x
        if self.mode == "exact":
            xe = x_est.reshape((-1, x_est.shape[-1])).astype(jnp.float32)
            est = jnp.max(jnp.linalg.norm(xe @ self.exact_deltas[path],
                                          axis=-1))
        else:
            est = estimate(ua.est, x_est)
        return jnp.where(est > ua.threshold, jnp.int32(ua.h),
                         jnp.int32(ua.l))

    # -- lin protocol ------------------------------------------------------------
    def __call__(self, path: str, x: jax.Array, *,
                 async_input=None) -> jax.Array:
        ov = self.overlays.get(path)
        if ov is None or isinstance(ov, QuantizedStacked):
            if ov is not None:
                raise ValueError(
                    f"stacked unit {path} must use .weights(), not lin()")
            return jnp.einsum("...k,kn->...n", x,
                              self.raw[path]).astype(x.dtype)
        bits = self._select_bits(path, x, async_input)
        self.records.append((bits, float(ov.k * ov.n)))
        y = bitserial_matmul(x, ov, bits, backend=self.backend)
        return y.astype(x.dtype)

    def weights(self, path: str, x: jax.Array, *,
                async_input=None) -> jax.Array:
        """Materialized weights for stacked (MoE) units at selected bits."""
        ov = self.overlays.get(path)
        if ov is None:
            return self.raw[path]
        bits = self._select_bits(path, x, async_input)
        e, _, _, n = ov.planes.shape
        self.records.append((bits, float(e * ov.k * n)))
        return materialize_stacked(ov, bits).astype(x.dtype)

    # -- accounting ----------------------------------------------------------------
    def effective_bits(self) -> jax.Array:
        """Parameter-weighted mean of this step's precision decisions."""
        if not self.records:
            return jnp.float32(0.0)
        num = sum(b.astype(jnp.float32) * s for b, s in self.records)
        den = sum(s for _, s in self.records)
        return num / den
