"""Per-layer precision allocation under a memory budget (integer program).

Solves  argmin_{c_ib} Σ_i Σ_b c_ib · Ω_ib
        s.t. Σ_i b(i)·M_i ≤ b_budget·Σ_i M_i   (+ optional lower bound,
                                                 LLM-MQ Eq. 8)
via Lagrangian relaxation (bisection on λ with per-layer argmin) followed by
greedy marginal-gain repair — deterministic, no external MILP solver, and
within one unit-swap of the IP optimum for this separable objective
(DESIGN.md §2.3). Used for DP-LLM Phase 1 (max precisions) and for the
LLM-MQ / HAWQ-V2 static baselines.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def _choices(cost: np.ndarray, sizes: np.ndarray, bits: np.ndarray,
             lam: float) -> np.ndarray:
    """argmin_b cost[i,b] + lam * bits[b] * sizes[i], per row."""
    penal = cost + lam * sizes[:, None] * bits[None, :]
    return np.argmin(penal, axis=1)


def _avg_bits(choice: np.ndarray, sizes: np.ndarray,
              bits: np.ndarray) -> float:
    return float(np.sum(bits[choice] * sizes) / np.sum(sizes))


def allocate_precisions(
    cost: np.ndarray,          # (n_units, n_bits) predicted loss increase
    sizes: Sequence[int],      # parameter count per unit (M_i)
    bits_list: Sequence[int],  # candidate bitwidths, ascending
    budget_bits: float,        # b_targ (upper bound on avg bits)
    min_avg_bits: float = 0.0,  # optional lower bound (LLM-MQ Eq. 8)
) -> List[int]:
    cost = np.asarray(cost, np.float64)
    sizes = np.asarray(sizes, np.float64)
    bits = np.asarray(bits_list, np.float64)
    n = cost.shape[0]
    assert cost.shape[1] == len(bits)

    # λ=0 -> everyone takes min-cost (max bits); bisect up until budget holds
    lo, hi = 0.0, 1.0
    while _avg_bits(_choices(cost, sizes, bits, hi), sizes, bits) \
            > budget_bits and hi < 1e18:
        hi *= 4.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if _avg_bits(_choices(cost, sizes, bits, mid), sizes, bits) \
                > budget_bits:
            lo = mid
        else:
            hi = mid
    choice = _choices(cost, sizes, bits, hi)

    # greedy repair: spend remaining slack on the best marginal-gain upgrades
    total = np.sum(sizes)
    budget_param_bits = budget_bits * total

    def used():
        return np.sum(bits[choice] * sizes)

    improved = True
    while improved:
        improved = False
        best_gain, best_i = 0.0, -1
        for i in range(n):
            j = choice[i]
            if j + 1 >= len(bits):
                continue
            extra = (bits[j + 1] - bits[j]) * sizes[i]
            if used() + extra > budget_param_bits + 1e-9:
                continue
            gain = (cost[i, j] - cost[i, j + 1]) / max(extra, 1e-12)
            if gain > best_gain:
                best_gain, best_i = gain, i
        if best_i >= 0:
            choice[best_i] += 1
            improved = True

    # optional lower bound: bump the cheapest upgrades until satisfied
    if min_avg_bits > 0:
        while _avg_bits(choice, sizes, bits) < min_avg_bits:
            best_cost, best_i = np.inf, -1
            for i in range(n):
                j = choice[i]
                if j + 1 >= len(bits):
                    continue
                dcost = (cost[i, j + 1] - cost[i, j]) / \
                    ((bits[j + 1] - bits[j]) * sizes[i])
                if dcost < best_cost:
                    best_cost, best_i = dcost, i
            if best_i < 0:
                break
            choice[best_i] += 1

    return [int(bits_list[j]) for j in choice]


def uniform_allocation(n_units: int, bits: int) -> List[int]:
    """The Any-Precision-LLM naive baseline: same precision everywhere."""
    return [bits] * n_units
