"""Adaptation sets: the runtime-selectable model configurations.

An :class:`AdaptationSet` is the paper's end product for one target
precision: per unit, the candidate pair (l, h), the threshold T, and the
fitted estimator. A :class:`MultiScaleModel` holds the shared bit-plane
overlays plus one AdaptationSet per supported target precision — the
overlay memory is paid once (Any-Precision property), the per-target
artifacts are a few scalars + G matrices.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.estimators import EstimatorFit


@dataclass
class UnitAdaptation:
    path: str
    kind: str
    size: int                 # parameter count M_i
    p: float                  # learned average precision
    l: int
    h: int
    max_bits: int             # Phase-1 cap B_i
    threshold: float = 0.0
    async_eligible: bool = False
    est: Optional[EstimatorFit] = None


@dataclass
class AdaptationSet:
    target_precision: float
    b_min: int
    memory_budget_bits: float
    units: Dict[str, UnitAdaptation] = field(default_factory=dict)

    @property
    def avg_p(self) -> float:
        num = sum(u.p * u.size for u in self.units.values())
        den = sum(u.size for u in self.units.values())
        return num / max(den, 1)

    def estimator_overhead_bytes(self) -> int:
        """G-matrix storage (paper §5.1 'GPU memory overhead' analysis)."""
        total = 0
        for u in self.units.values():
            if u.est is not None and u.est.kind == "jl" and u.est.g is not None:
                total += int(np.prod(u.est.g.shape)) * 4
        return total

    def estimator_census(self) -> Dict[str, int]:
        census = {"linear": 0, "jl": 0, "pinned": 0}
        for u in self.units.values():
            if u.l == u.h or u.est is None:
                census["pinned"] += 1
            else:
                census[u.est.kind] += 1
        return census


@dataclass
class MultiScaleModel:
    """Shared overlays + per-target adaptation sets (+ static baselines)."""
    arch: str
    b_min: int
    memory_budget_bits: float
    max_bits: Dict[str, int]
    overlays: Dict[str, object] = field(repr=False, default_factory=dict)
    adaptations: Dict[float, AdaptationSet] = field(default_factory=dict)
    static_tables: Dict[str, Dict[float, Dict[str, int]]] = \
        field(default_factory=dict)   # method -> target -> path -> bits

    def targets(self) -> List[float]:
        return sorted(self.adaptations)

    def overlay_bytes(self) -> int:
        total = 0
        for ov in self.overlays.values():
            total += int(np.prod(ov.planes.shape)) * 4
            total += int(np.prod(ov.scale.shape)) * 8
        return total
