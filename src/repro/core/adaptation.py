"""Adaptation sets: the runtime-selectable model configurations.

An :class:`AdaptationSet` is the paper's end product for one target
precision: per unit, the candidate pair (l, h), the threshold T, and the
fitted estimator. A :class:`MultiScaleModel` holds the shared bit-plane
overlays plus one AdaptationSet per supported target precision — the
overlay memory is paid once (Any-Precision property), the per-target
artifacts are a few scalars + G matrices.

:func:`export_serve_arrays` flattens a MultiScaleModel into the serving
representation: per unit, every per-target artifact (l/h pair, threshold,
estimator a/b/γ, G matrix) stacked along a leading target axis, so the
runtime applier selects the target with a *traced index* and one compiled
decode step serves every target.

Target-stacked array layout — THE serving contract
--------------------------------------------------
Every consumer of :class:`ServeArtifacts` (the applier, the engine, the
launch lowering specs, and the mesh sharding rules) relies on this exact
layout. With ``T = len(targets)``, ``K`` the unit's (zero-padded) reduction
dim, ``N`` its output dim, and ``k_proj`` the JL sketch size, each
``est[path]`` entry holds::

    l, h       : (T,) int32    candidate pair per target (bits)
    kind       : (T,) int32    KIND_PINNED / KIND_LINEAR / KIND_JL
    threshold  : (T,) float32  relative-error threshold per target
    a, b       : (T,) float32  linear-estimator fit   (iff any target linear)
    gamma      : (T,) float32  JL scale               (iff any target JL)
    g          : (T, k_proj, K) float32 JL sketch     (ditto)
    delta      : (T, K, N) float32 exact ΔW stack     (exact mode only,
                                                       built lazily)

Axis meanings for the production mesh (``serve_array_axes`` names them,
``distributed/sharding.SERVE_RULES`` maps them): the leading T axis is
indexed by a *traced* target index and must stay replicated; ``k_proj``
is replicated; the trailing K (and N) axes carry the same logical axis as
the weight the artifact gates, so the estimator operands shard exactly
like the matmul operands beside them. Reordering or re-stacking any of
these arrays is a cross-layer breaking change.

Unit-stacked decision bundle — the fused-planner contract
---------------------------------------------------------
The per-path ``est`` dict above is the *inline* (per-unit) view. The
serving hot path instead consumes a :class:`DecisionBundle`: every
scalar artifact additionally stacked over a leading **units** axis
``(U, T)`` in a fixed row order (``paths`` / ``row_of`` is the static
unit⇄row table), plus one packed G-matrix stack ``(R, k_proj, K_max)``
holding only the JL rows (row 0 is a zero dummy) with ``g_row (U, T)``
mapping each (unit, target) to its packed row. ``g_row`` carries the
DMA-elision contract of the fused planner kernel: a non-JL (unit,
target) re-names the *previous* unit's row, so consecutive grid steps
fetch no new block (see ``kernels/jl_estimator``). ``K_max`` is the max
estimator width over units, rounded up to a TPU lane multiple; all x
rows and G matrices are zero-padded to it, which leaves every norm and
projection mathematically unchanged. The bundle's row order, paddings,
and ``g_row`` semantics are relied on by ``core/decision``,
``core/dynamic_linear``, the jl_estimator kernels, and the scheduler's
(S, U) decision carry — another cross-layer contract.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimators import EstimatorFit

# estimator-kind codes in the exported ``kind`` arrays
KIND_PINNED, KIND_LINEAR, KIND_JL = 0, 1, 2

# plane depth of the bitplane-overlay KV cache (writes always store the
# full stack; the planner's KV rows are capped here). Must match the
# ``kv_plane_bits`` the serving engine builds its decode state with.
KV_PLANE_BITS = 8


def overlay_nbytes(overlays: Dict[str, object]) -> int:
    """Device bytes of a bit-plane overlay dict, from actual itemsizes."""
    total = 0
    for ov in overlays.values():
        for arr in (ov.planes, ov.scale, ov.zero):
            total += int(np.prod(arr.shape)) * np.dtype(arr.dtype).itemsize
    return total


@dataclass
class UnitAdaptation:
    path: str
    kind: str
    size: int                 # parameter count M_i
    p: float                  # learned average precision
    l: int
    h: int
    max_bits: int             # Phase-1 cap B_i
    threshold: float = 0.0
    async_eligible: bool = False
    est: Optional[EstimatorFit] = None


@dataclass
class AdaptationSet:
    target_precision: float
    b_min: int
    memory_budget_bits: float
    units: Dict[str, UnitAdaptation] = field(default_factory=dict)

    @property
    def avg_p(self) -> float:
        num = sum(u.p * u.size for u in self.units.values())
        den = sum(u.size for u in self.units.values())
        return num / max(den, 1)

    def estimator_overhead_bytes(self) -> int:
        """G-matrix storage (paper §5.1 'GPU memory overhead' analysis)."""
        total = 0
        for u in self.units.values():
            if u.est is not None and u.est.kind == "jl" and u.est.g is not None:
                total += int(np.prod(u.est.g.shape)) * 4
        return total

    def estimator_census(self) -> Dict[str, int]:
        census = {"linear": 0, "jl": 0, "pinned": 0}
        for u in self.units.values():
            if u.l == u.h or u.est is None:
                census["pinned"] += 1
            else:
                census[u.est.kind] += 1
        return census


@dataclass
class MultiScaleModel:
    """Shared overlays + per-target adaptation sets (+ static baselines)."""
    arch: str
    b_min: int
    memory_budget_bits: float
    max_bits: Dict[str, int]
    overlays: Dict[str, object] = field(repr=False, default_factory=dict)
    adaptations: Dict[float, AdaptationSet] = field(default_factory=dict)
    static_tables: Dict[str, Dict[float, Dict[str, int]]] = \
        field(default_factory=dict)   # method -> target -> path -> bits

    def targets(self) -> List[float]:
        return sorted(self.adaptations)

    def overlay_bytes(self) -> int:
        return overlay_nbytes(self.overlays)


# ---------------------------------------------------------------------------
# Serving export: per-target artifacts -> target-stacked traced arrays
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class UnitStatic:
    """Trace-time constants for one precision unit (shapes/structure only —
    every runtime-variable quantity lives in the exported arrays)."""
    path: str
    l: int                   # lowest candidate across targets
    h: int                   # Phase-1 cap (max/prefill precision)
    est_kind: str            # "linear" | "jl" | "pinned" | "mixed"
    async_eligible: bool
    stacked: bool = False


LANE = 128                 # TPU lane width: decision-bundle K padding


@dataclass
class DecisionBundle:
    """Unit-stacked decision arrays for the fused precision planner.

    One row per precision unit, in the fixed ``paths`` order (the static
    unit⇄row table the lookup applier and the planner share). With U
    units, T targets, R packed JL rows and K_max the padded estimator
    width::

        l, h, kind   : (U, T) int32
        threshold,
        a, b, gamma  : (U, T) float32   (0 where the kind doesn't use them)
        g            : (R, k_proj, K_max) float32 — packed JL G matrices;
                       row 0 is an all-zero dummy
        g_row        : (U, T) int32 — (unit, target) -> packed G row.
                       Non-JL entries REPEAT the previous unit's row
                       (unit 0 falls back to the dummy row 0) so the
                       fused kernel's consecutive grid steps re-name the
                       same block and fetch nothing — the planner-side
                       DMA-elision contract.
        max_bits     : (U,) int32  — Phase-1 cap (mode="max" / prefill)
        sizes        : (U,) float32 — parameter counts M_i, the weights
                       of the vectorized effective-bits reduction
        k_actual     : (U,) int32  — true estimator input width per unit

    KV pseudo-rows: after the weight rows, one row per attention layer
    (path ``layers.{i}.attn.kv``) carries that layer's KV *read*
    precision. Each copies its source row's (``layers.{i}.attn.wv``)
    candidates/estimator/G-row verbatim with ``sizes = 0`` (excluded
    from effective-bits) and ``max_bits = KV_PLANE_BITS``, so the one
    fused ``plan_bits`` launch prices KV reads by the same activation
    signal that gates the value projection — no second launch, no extra
    G DMA. ``kv_rows``/``kv_src`` record the (row, source-row) pairs;
    ``n_weight_units`` is where the pseudo-rows start.
    """
    paths: Tuple[str, ...]
    row_of: Dict[str, int]
    k_pad: int
    k_proj: int
    l: np.ndarray
    h: np.ndarray
    kind: np.ndarray
    threshold: np.ndarray
    a: np.ndarray
    b: np.ndarray
    gamma: np.ndarray
    g: np.ndarray
    g_row: np.ndarray
    max_bits: np.ndarray
    sizes: np.ndarray
    k_actual: np.ndarray
    kv_rows: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.int32))
    kv_src: np.ndarray = field(
        default_factory=lambda: np.zeros((0,), np.int32))
    n_weight_units: int = -1

    @property
    def n_units(self) -> int:
        return len(self.paths)

    @property
    def weight_units(self) -> int:
        """Rows before the KV pseudo-rows (all of them, pre-KV bundles)."""
        return self.n_weight_units if self.n_weight_units >= 0 \
            else self.n_units

    def stack_static(self, static_arrays: Dict[str, np.ndarray]
                     ) -> np.ndarray:
        """``path -> (T,)`` static-method bits, stacked to ``(U, T)``.

        KV pseudo-rows (absent from every static table) inherit their
        source row's allocation, mirroring the planner's act copy.
        """
        t = self.l.shape[1]
        src_of = {int(r): int(s)
                  for r, s in zip(self.kv_rows, self.kv_src)}
        out = np.zeros((self.n_units, t), np.int32)
        for u, p in enumerate(self.paths):
            if p not in static_arrays and u in src_of:
                out[u] = out[src_of[u]]      # kv_src always precedes u
            else:
                out[u] = np.asarray(static_arrays[p], np.int32)
        return out


@dataclass
class ServeArtifacts:
    """Array-form adaptation artifacts for the unified serving applier.

    ``est[path]`` holds, per unit, arrays stacked over targets:
      l, h, kind, threshold : (T,)
      a, b                  : (T,)   — present iff any target is linear
      gamma                 : (T,)   — present iff any target is JL
      g                     : (T, k_proj, K) — ditto

    ``decision`` is the same information re-stacked over a leading units
    axis (:class:`DecisionBundle`) for the fused one-launch-per-tick
    planner; ``est`` remains the per-unit view the inline (sync
    fallback) path consumes.
    """
    targets: Tuple[float, ...]
    table: Dict[str, UnitStatic]
    est: Dict[str, Dict[str, np.ndarray]]
    decision: Optional["DecisionBundle"] = None

    def target_index(self, target: float) -> int:
        for i, t in enumerate(self.targets):
            if abs(t - target) < 1e-9:
                return i
        raise KeyError(f"target {target} not in {self.targets}")


def export_serve_arrays(model: MultiScaleModel) -> ServeArtifacts:
    """Stack every per-target adaptation artifact along a target axis."""
    targets = tuple(model.targets())
    if not targets:
        raise ValueError("model has no adaptation sets")
    asets = [model.adaptations[t] for t in targets]
    table: Dict[str, UnitStatic] = {}
    est: Dict[str, Dict[str, np.ndarray]] = {}
    for path, ua0 in asets[0].units.items():
        uas = [a.units[path] for a in asets]
        kinds, gs = [], []
        any_lin = any_jl = False
        for ua in uas:
            if ua.l == ua.h or ua.est is None:
                kinds.append(KIND_PINNED)
                gs.append(None)
            elif ua.est.kind == "linear":
                kinds.append(KIND_LINEAR)
                any_lin = True
                gs.append(None)
            else:
                kinds.append(KIND_JL)
                any_jl = True
                gs.append(np.asarray(ua.est.g, np.float32))
        entry = {
            "l": np.asarray([ua.l for ua in uas], np.int32),
            "h": np.asarray([ua.h for ua in uas], np.int32),
            "kind": np.asarray(kinds, np.int32),
            "threshold": np.asarray([ua.threshold for ua in uas],
                                    np.float32),
        }
        if any_lin:
            entry["a"] = np.asarray(
                [ua.est.a if ua.est and ua.est.kind == "linear" else 0.0
                 for ua in uas], np.float32)
            entry["b"] = np.asarray(
                [ua.est.b if ua.est and ua.est.kind == "linear" else 0.0
                 for ua in uas], np.float32)
        if any_jl:
            g_shape = next(g.shape for g in gs if g is not None)
            entry["gamma"] = np.asarray(
                [ua.est.gamma if ua.est and ua.est.kind == "jl" else 0.0
                 for ua in uas], np.float32)
            entry["g"] = np.stack(
                [g if g is not None else np.zeros(g_shape, np.float32)
                 for g in gs])
        est[path] = entry
        if all(k == KIND_PINNED for k in kinds):
            ek = "pinned"
        elif not any_jl:
            ek = "linear"
        elif not any_lin:
            ek = "jl"
        else:
            ek = "mixed"
        table[path] = UnitStatic(
            path=path,
            l=min(ua.l for ua in uas),
            h=model.max_bits.get(path, max(ua.h for ua in uas)),
            est_kind=ek,
            async_eligible=ua0.async_eligible,
            stacked=(ua0.kind or "").startswith("expert_"),
        )
    bundle = export_decision_bundle(model, table, est)
    return ServeArtifacts(targets=targets, table=table, est=est,
                          decision=bundle)


def _overlay_dims(ov) -> Tuple[int, float]:
    """(reduction dim, legacy per-decision parameter count) of an overlay."""
    if ov.planes.ndim == 4:                       # stacked (E, B, K/32, N)
        e, _, _, n = ov.planes.shape
        return ov.k, float(e * ov.k * n)
    return ov.k, float(ov.k * ov.planes.shape[-1])


def export_decision_bundle(
    model: MultiScaleModel,
    table: Dict[str, UnitStatic],
    est: Dict[str, Dict[str, np.ndarray]],
) -> DecisionBundle:
    """Re-stack the per-unit serve arrays over a leading units axis.

    Row order is the (deterministic) iteration order of ``est``; the
    ``sizes`` weights reproduce the inline applier's per-decision
    parameter counts exactly (``k * n`` per overlay, ``E * k * n`` for
    stacked MoE units), so the vectorized effective-bits reduction is
    bit-compatible with the legacy per-call records.
    """
    paths = tuple(est.keys())
    n_u = len(paths)
    n_t = len(next(iter(est.values()))["l"]) if n_u else 0
    widths = [1]
    for p in paths:
        k, _ = _overlay_dims(model.overlays[p])
        widths.append(k)
        if "g" in est[p]:
            widths.append(est[p]["g"].shape[-1])
    k_pad = -(-max(widths) // LANE) * LANE
    k_proj = max([e["g"].shape[1] for e in est.values() if "g" in e],
                 default=1)

    sh = (n_u, n_t)
    li = np.zeros(sh, np.int32)
    hi = np.zeros(sh, np.int32)
    kind = np.zeros(sh, np.int32)
    thr = np.zeros(sh, np.float32)
    a = np.zeros(sh, np.float32)
    b = np.zeros(sh, np.float32)
    gamma = np.zeros(sh, np.float32)
    g_row = np.zeros(sh, np.int32)
    max_bits = np.zeros((n_u,), np.int32)
    sizes = np.zeros((n_u,), np.float32)
    k_actual = np.zeros((n_u,), np.int32)

    g_rows: List[np.ndarray] = [np.zeros((k_proj, k_pad), np.float32)]
    prev_row = np.zeros((n_t,), np.int32)         # row 0: zero dummy
    for u, p in enumerate(paths):
        e = est[p]
        li[u], hi[u], kind[u] = e["l"], e["h"], e["kind"]
        thr[u] = e["threshold"]
        if "a" in e:
            a[u], b[u] = e["a"], e["b"]
        if "gamma" in e:
            gamma[u] = e["gamma"]
        for t in range(n_t):
            if kind[u, t] == KIND_JL and "g" in e:
                gm = np.asarray(e["g"][t], np.float32)
                pad = np.zeros((k_proj, k_pad), np.float32)
                pad[:gm.shape[0], :gm.shape[1]] = gm
                g_row[u, t] = len(g_rows)
                g_rows.append(pad)
            else:
                # non-JL: re-name the previous unit's row (DMA elision)
                g_row[u, t] = prev_row[t]
        prev_row = g_row[u]
        max_bits[u] = table[p].h
        k, size = _overlay_dims(model.overlays[p])
        sizes[u] = size
        k_actual[u] = k

    # KV pseudo-rows: one per attention layer, sourced from its value
    # projection (the weight whose activation signal best prices the KV
    # read — V rows feed the same matmul the cache replays).
    row_of = {p: i for i, p in enumerate(paths)}
    attn_ids = sorted(
        int(p.split(".")[1]) for p in paths
        if p.startswith("layers.") and p.endswith(".attn.wv"))
    kv_src = np.asarray(
        [row_of[f"layers.{i}.attn.wv"] for i in attn_ids], np.int32)
    kv_rows = n_u + np.arange(len(kv_src), dtype=np.int32)
    if len(kv_src):
        paths = paths + tuple(f"layers.{i}.attn.kv" for i in attn_ids)
        li = np.concatenate([li, li[kv_src]])
        hi = np.concatenate([hi, hi[kv_src]])
        kind = np.concatenate([kind, kind[kv_src]])
        thr = np.concatenate([thr, thr[kv_src]])
        a = np.concatenate([a, a[kv_src]])
        b = np.concatenate([b, b[kv_src]])
        gamma = np.concatenate([gamma, gamma[kv_src]])
        g_row = np.concatenate([g_row, g_row[kv_src]])
        max_bits = np.concatenate(
            [max_bits,
             np.minimum(max_bits[kv_src], KV_PLANE_BITS)])
        sizes = np.concatenate(
            [sizes, np.zeros((len(kv_src),), np.float32)])
        k_actual = np.concatenate([k_actual, k_actual[kv_src]])
    return DecisionBundle(
        paths=paths, row_of={p: i for i, p in enumerate(paths)},
        k_pad=k_pad, k_proj=k_proj, l=li, h=hi, kind=kind, threshold=thr,
        a=a, b=b, gamma=gamma, g=np.stack(g_rows), g_row=g_row,
        max_bits=max_bits, sizes=sizes, k_actual=k_actual,
        kv_rows=kv_rows, kv_src=kv_src, n_weight_units=n_u)


def serve_array_axes(
    table: Dict[str, UnitStatic],
    weight_axes: Dict[str, Tuple[Optional[str], ...]],
) -> Dict[str, Dict[str, Tuple[Optional[str], ...]]]:
    """Logical sharding axes for every exported serve array.

    ``weight_axes`` maps each unit path to its *weight's* logical axes —
    (K, N) for plain linears, (experts, K, N) for stacked MoE units (see
    ``repro.models.model_logical_axes``). The returned per-path dicts
    cover every array ``export_serve_arrays`` may emit (plus the lazy
    ``delta`` stack): the target axis and JL sketch rows are replicated,
    the K/N axes inherit the gated weight's axes so
    ``distributed/sharding.SERVE_RULES`` shards artifacts alongside the
    weights they gate.
    """
    from repro.models.common import JL_PROJ, TARGETS  # lazy: avoid cycle
    out: Dict[str, Dict[str, Tuple[Optional[str], ...]]] = {}
    for path in table:
        k_ax, n_ax = weight_axes[path][-2], weight_axes[path][-1]
        entry = {name: (TARGETS,)
                 for name in ("l", "h", "kind", "threshold", "a", "b",
                              "gamma")}
        entry["g"] = (TARGETS, JL_PROJ, k_ax)
        entry["delta"] = (TARGETS, k_ax, n_ax)
        out[path] = entry
    return out


def export_static_arrays(model: MultiScaleModel,
                         method: str) -> Dict[str, np.ndarray]:
    """``path -> (T,) int32`` bits for one static baseline method.

    Targets missing from the method's tables reuse the nearest available
    target's allocation, so the exported arrays always cover the full
    target axis of the compiled step.
    """
    tabs = model.static_tables[method]
    if not tabs:
        raise KeyError(f"static method {method!r} has no tables")
    targets = model.targets()
    avail = sorted(tabs)
    per_target = []
    for t in targets:
        if t in tabs:
            per_target.append(tabs[t])
            continue
        sub = min(avail, key=lambda a: abs(a - t))
        warnings.warn(f"static method {method!r} has no table for target "
                      f"{t}; substituting the {sub} allocation")
        per_target.append(tabs[sub])
    paths = set().union(*[set(tab) for tab in per_target])

    def bits_of(tab, p):
        if p in tab:
            return tab[p]
        for other in per_target:           # tables may disagree on units
            if p in other:
                return other[p]
        raise KeyError(p)

    return {p: np.asarray([bits_of(tab, p) for tab in per_target],
                          np.int32)
            for p in paths}
