"""Phase 3 — average-precision → threshold translation + calibration capture.

One eager forward pass over the calibration set with a *capturing* linear
applier records, per dynamic unit and per token:
- the exact relative error ``‖x·ΔW‖`` (threshold source, Algorithm 1),
- ``‖x_est‖`` and ``‖G·x_est‖`` where ``x_est`` is the **async** residual
  input for async-eligible units (q/k/v/up/ssm_in — paper Fig. 6) and the
  immediate input otherwise.

The threshold is the ``r_i``-quantile of the error list, ``r_i = 1−(p_i−l)``:
a unit with p=3.2 selects h-bit on the ~20% largest-error tokens.

MoE note (DESIGN.md §4): expert up/gate units share the router's input; their
ΔW concatenates experts along the output dim. Expert down-projections are
pinned static (l==h) because their inputs are per-expert post-dispatch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bitplane import (QuantizedStacked, materialize,
                                 materialize_stacked)
from repro.core.estimators import JL_K, make_g, sample_projection
from repro.models import forward
from repro.models.common import LinearUnit


@dataclass
class CalibRecord:
    err: np.ndarray      # exact ‖x·ΔW‖ per calibration token
    xnorm: np.ndarray    # ‖x_est‖
    jl_raw: np.ndarray   # ‖G x_est‖ (uncalibrated)
    g: np.ndarray        # sampled G = A·ΔWᵀ  (k, K)


def candidate_pair(p: float, b_min: int, b_max: int) -> Tuple[int, int]:
    """l = ⌊p⌋, h = ⌈p⌉ clamped into [b_min, b_max]."""
    p = float(np.clip(p, b_min, b_max))
    l = int(np.floor(p))
    h = int(np.ceil(p))
    if l == h:
        return l, h
    return l, h


def delta_weight_of(overlay, l: int, h: int) -> jax.Array:
    """(K, N_eff) — stacked overlays concatenate experts along N."""
    if isinstance(overlay, QuantizedStacked):
        d = materialize_stacked(overlay, h) - materialize_stacked(overlay, l)
        e, k, n = d.shape
        return jnp.moveaxis(d, 0, 1).reshape(k, e * n)
    return materialize(overlay, h) - materialize(overlay, l)


def collect_calibration(
    cfg: ModelConfig,
    run_params: Dict[str, jax.Array],      # forward-pass weights (quantized
                                           # interpolation view — faithful)
    overlays: Dict[str, object],
    units: Sequence[LinearUnit],
    p_assign: Dict[str, float],
    batches: Iterable[Tuple[np.ndarray, np.ndarray]],
    *,
    b_min: int,
    max_bits: Dict[str, int],
    key: jax.Array,
    k_proj: int = JL_K,
    pairs: Dict[str, Tuple[int, int]] = None,   # forced (l,h) override
) -> Dict[str, CalibRecord]:
    units_by_path = {u.path: u for u in units}
    dyn_paths: List[str] = []
    deltas: Dict[str, jax.Array] = {}
    gs: Dict[str, jax.Array] = {}
    for u in units:
        if pairs and u.path in pairs:
            l, h = pairs[u.path]
        else:
            l, h = candidate_pair(p_assign[u.path], b_min,
                                  max_bits[u.path])
        if l == h or u.kind == "expert_down":
            continue
        dw = delta_weight_of(overlays[u.path], l, h)
        key, sub = jax.random.split(key)
        a_mat = sample_projection(sub, k_proj, dw.shape[1])
        deltas[u.path] = dw
        gs[u.path] = make_g(a_mat, dw)
        dyn_paths.append(u.path)

    acc: Dict[str, Dict[str, List[np.ndarray]]] = {
        p: {"err": [], "xnorm": [], "jl": []} for p in dyn_paths}

    def record(path: str, x_sync: jax.Array, x_est: jax.Array):
        dw = deltas[path]
        xs = x_sync.reshape((-1, x_sync.shape[-1])).astype(jnp.float32)
        xe = x_est.reshape((-1, x_est.shape[-1])).astype(jnp.float32)
        acc[path]["err"].append(
            np.asarray(jnp.linalg.norm(xs @ dw, axis=-1)))
        acc[path]["xnorm"].append(np.asarray(jnp.linalg.norm(xe, axis=-1)))
        acc[path]["jl"].append(
            np.asarray(jnp.linalg.norm(xe @ gs[path].T, axis=-1)))

    def capture_lin(path: str, x: jax.Array, *, async_input=None):
        w = run_params[path]
        if path in acc:
            u = units_by_path[path]
            x_est = async_input if (u.async_eligible and
                                    async_input is not None) else x
            record(path, x, x_est)
        if path.endswith(".router"):
            # expert up/gate units see the router's (pre-dispatch) input
            for sib in (path[:-7] + ".w_gate", path[:-7] + ".w_up"):
                if sib in acc:
                    record(sib, x, x)
        return jnp.einsum("...k,kn->...n", x, w).astype(x.dtype)

    for tokens, _ in batches:
        forward(cfg, run_params, jnp.asarray(tokens), lin=capture_lin)

    out: Dict[str, CalibRecord] = {}
    for p in dyn_paths:
        out[p] = CalibRecord(
            err=np.concatenate(acc[p]["err"]),
            xnorm=np.concatenate(acc[p]["xnorm"]),
            jl_raw=np.concatenate(acc[p]["jl"]),
            g=np.asarray(gs[p]))
    return out


def threshold_from_quantile(err: np.ndarray, p: float, l: int) -> float:
    """T = r-quantile of the calibration error list, r = 1 − (p − l)."""
    r = float(np.clip(1.0 - (p - l), 0.0, 1.0))
    return float(np.quantile(err, r))
