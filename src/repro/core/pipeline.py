"""Offline DP-LLM pipeline: quantize → Phase 1 → Phase 2 → Phase 3 → fit
estimators — Algorithm 1 end to end, plus the LLM-MQ / HAWQ-V2 / uniform
static baselines the paper compares against.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.adaptation import (AdaptationSet, MultiScaleModel,
                                   UnitAdaptation)
from repro.core.allocator import allocate_precisions, uniform_allocation
from repro.core.bitplane import quantize_linear, quantize_stacked
from repro.core.estimators import fit_estimator
from repro.core.precision_finetune import (finetune_avg_precisions,
                                           interpolated_params,
                                           _weight_stack)
from repro.core.sensitivity import accumulate_fisher, sensitivity_tables
from repro.core.thresholds import (candidate_pair, collect_calibration,
                                   threshold_from_quantile)
from repro.models import linear_units
from repro.models.common import LinearUnit


def quantize_units(params, units: Sequence[LinearUnit],
                   bits: int) -> Dict[str, object]:
    overlays = {}
    for u in units:
        w = params[u.path]
        if w.ndim == 3:
            overlays[u.path] = quantize_stacked(w, bits)
        else:
            overlays[u.path] = quantize_linear(w, bits)
    return overlays


def unit_sizes(params, units: Sequence[LinearUnit]) -> List[int]:
    return [int(np.prod(params[u.path].shape)) for u in units]


def phase1_max_precisions(
    cfg: ModelConfig, params, overlays, units, g_mean, fisher,
    *, bits_list: Sequence[int], memory_budget_bits: float,
) -> Dict[str, int]:
    """Fisher-diagonal IP (paper Appendix A) under the memory budget."""
    cost = sensitivity_tables("fisher", units, params, overlays,
                              g_mean, fisher, bits_list)
    alloc = allocate_precisions(cost, unit_sizes(params, units), bits_list,
                                memory_budget_bits)
    return {u.path: b for u, b in zip(units, alloc)}


def static_allocation(
    method: str,                      # "llm_mq" | "hawq_v2" | "uniform"
    cfg: ModelConfig, params, overlays, units, g_mean, fisher,
    *, bits_list: Sequence[int], target_bits: float,
    max_bits: Optional[Dict[str, int]] = None,
) -> Dict[str, int]:
    """Static mixed-precision baselines (paper §6.1 / Appendix B.2)."""
    if method == "uniform":
        b = int(round(target_bits))
        return {u.path: b for u in units}
    bl = list(bits_list)
    cost = sensitivity_tables(method, units, params, overlays,
                              g_mean, fisher, bl)
    if max_bits:  # respect the memory-budget caps, like DP-LLM's Phase 1
        cost = cost.copy()
        for i, u in enumerate(units):
            for j, b in enumerate(bl):
                if b > max_bits[u.path]:
                    cost[i, j] = 1e30
    min_avg = target_bits - 0.005 if method == "llm_mq" else 0.0
    alloc = allocate_precisions(cost, unit_sizes(params, units), bl,
                                target_bits, min_avg_bits=min_avg)
    return {u.path: b for u, b in zip(units, alloc)}


def build_multiscale_model(
    cfg: ModelConfig,
    params,
    calib_batches: List[Tuple[np.ndarray, np.ndarray]],
    *,
    targets: Sequence[float],
    b_min: int = 3,
    b_max: int = 6,
    memory_budget_bits: float = 5.0,
    alpha: float = 1.0,
    finetune_epochs: int = 3,
    finetune_lr: float = 0.01,
    r2_threshold: float = 0.9,
    seed: int = 0,
    baselines: Sequence[str] = ("llm_mq", "hawq_v2"),
) -> MultiScaleModel:
    units = linear_units(cfg)
    bits_list = list(range(b_min, b_max + 1))
    overlays = quantize_units(params, units, b_max)

    # shared sensitivity pass (Fisher diag + mean grads)
    g_mean, fisher = accumulate_fisher(
        cfg, params, calib_batches, [u.path for u in units])

    # Phase 1: memory-budget max precisions
    max_bits = phase1_max_precisions(
        cfg, params, overlays, units, g_mean, fisher,
        bits_list=bits_list, memory_budget_bits=memory_budget_bits)

    model = MultiScaleModel(
        arch=cfg.name, b_min=b_min,
        memory_budget_bits=memory_budget_bits,
        max_bits=max_bits, overlays=overlays)

    sizes = unit_sizes(params, units)
    for t in targets:
        # Phase 2: learn average precisions
        ft = finetune_avg_precisions(
            cfg, params, overlays, units, max_bits, calib_batches,
            b_target=t, b_min=b_min,
            alpha=(10.0 * alpha if abs(t - 3.25) < 1e-6 else alpha),
            lr=finetune_lr, epochs=finetune_epochs)
        p_assign = {u.path: float(p) for u, p in zip(units, ft.p)}

        # Phase 3 + estimator calibration, with the adapted model's own
        # activation distribution (interpolated weights at learned p)
        stacks = {u.path: _weight_stack(overlays[u.path], b_min,
                                        max_bits[u.path]) for u in units}
        run_params = interpolated_params(
            params, stacks, [u.path for u in units],
            jnp.asarray(ft.p), b_min)
        del stacks
        records = collect_calibration(
            cfg, run_params, overlays, units, p_assign, calib_batches,
            b_min=b_min, max_bits=max_bits,
            key=jax.random.PRNGKey(seed), k_proj=64)

        aset = AdaptationSet(target_precision=t, b_min=b_min,
                             memory_budget_bits=memory_budget_bits)
        for u, size in zip(units, sizes):
            p = p_assign[u.path]
            l, h = candidate_pair(p, b_min, max_bits[u.path])
            ua = UnitAdaptation(
                path=u.path, kind=u.kind, size=size, p=p, l=l, h=h,
                max_bits=max_bits[u.path],
                async_eligible=u.async_eligible)
            if u.path in records and l != h:
                rec = records[u.path]
                ua.threshold = threshold_from_quantile(rec.err, p, l)
                ua.est = fit_estimator(rec.err, rec.xnorm, rec.jl_raw,
                                       rec.g, r2_threshold=r2_threshold)
            else:
                # pinned unit (integer p or expert_down): round to nearest
                ua.l = ua.h = int(np.clip(round(p), b_min,
                                          max_bits[u.path]))
            aset.units[u.path] = ua
        model.adaptations[t] = aset

    # static baselines at every target
    for method in baselines:
        model.static_tables[method] = {}
        for t in targets:
            model.static_tables[method][t] = static_allocation(
                method, cfg, params, overlays, units, g_mean, fisher,
                bits_list=bits_list, target_bits=t, max_bits=max_bits)
    return model
