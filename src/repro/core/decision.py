"""Two-phase decide/apply: the fused whole-model precision planner.

DP-LLM's premise is that per-layer sensitivity is re-evaluated every
decoding step *cheaply* — the decision must never block the matmuls. The
:class:`PrecisionPlanner` is the "decide" phase: given the unit-stacked
:class:`repro.core.adaptation.DecisionBundle` and one ``(U, M, K_max)``
buffer of per-unit estimator inputs, :meth:`plan` resolves the ENTIRE
tick's ``(U,)`` bits vector in one fused launch
(``kernels/jl_estimator.plan_bits`` — Pallas on TPU, one vectorized
einsum elsewhere). The "apply" phase is the lookup-mode
:class:`repro.core.dynamic_linear.DynamicLinearApplier`, which indexes
the planned vector by the static unit⇄row table and runs the bit-serial
matmuls.

Async pipelining (paper §5.2): the serving engine's scan carries the
decision vector as state — tick *t* captures its residual-stream
activations and plans tick *t+1*'s bits, so when tick *t+1* starts,
every precision is already resolved before the first matmul issues.
Tick 0 (and ``use_async=False``) falls back to the inline per-unit sync
path; ``mode=static/max/exact`` route through this same planner
(static/max are pure lookups with no estimator work at all; exact adds
per-unit ΔW estimates on top of the fused pass — an eval-mode exception
to the one-launch guarantee, documented below).

Under the scheduler's slot vmap, :meth:`plan` batches over (S, U): the
custom_vmap rule in ``kernels/jl_estimator`` collapses the slot axis
into one (S, U)-grid kernel launch with per-slot traced targets and
active flags — idle slots' rows gate to 0 bits in-kernel.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.adaptation import DecisionBundle, KIND_PINNED
from repro.kernels.jl_estimator import plan_bits

MODES = ("dynamic", "static", "max", "exact")


def draft_floor_bits(bundle: DecisionBundle, floor: int = 2) -> jax.Array:
    """The speculative DRAFT plan: every unit pinned to the overlay's bit
    floor — ``min(floor, unit max_bits)`` so shallow overlays stay valid.

    This is a static ``(U,)`` vector (no estimator inputs, no planner
    launch): the draft path runs the same bit-serial kernel through the
    lookup-mode applier with this vector as ``planned_bits``, so drafting
    k tokens costs k low-bit ticks and ZERO decide launches. The
    any-precision overlay makes the draft model free — the first
    ``floor`` bit-planes of the very same weights.
    """
    return jnp.minimum(jnp.asarray(bundle.max_bits, jnp.int32),
                       jnp.int32(floor))


class PrecisionPlanner:
    """Computes the per-tick ``(U,)`` decision vector for one mode.

    Parameters
    ----------
    bundle: the unit-stacked decision arrays (host numpy; converted to
        device arrays here, optionally placed by ``put``).
    mode: ``dynamic | static | max | exact``.
    static_stack: ``(U, T)`` int32 — required for ``mode="static"``
        (build with ``bundle.stack_static``).
    exact_deltas: ``{path: (T, K, N)}`` ΔW stacks for ``mode="exact"``
        (plain-linear units only; others keep the fused approx estimate).
    backend: kernel backend for the fused pass (None = auto).
    put: optional placement fn (mesh device_put) applied to every table.
    """

    def __init__(
        self,
        bundle: DecisionBundle,
        *,
        mode: str = "dynamic",
        static_stack=None,
        exact_deltas: Optional[Dict[str, jax.Array]] = None,
        backend: Optional[str] = None,
        put: Optional[Callable] = None,
    ):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; expected {MODES}")
        if mode == "static" and static_stack is None:
            raise ValueError("mode='static' needs a static_stack")
        put = put or jnp.asarray
        self.bundle = bundle
        self.mode = mode
        self.backend = backend
        self.tables = {name: put(jnp.asarray(getattr(bundle, name)))
                       for name in ("l", "h", "kind", "threshold", "a",
                                    "b", "gamma", "g", "g_row")}
        self.max_bits = put(jnp.asarray(bundle.max_bits))
        self.sizes = put(jnp.asarray(bundle.sizes, jnp.float32))
        # KV pseudo-rows read their source row's captured activations;
        # the copy happens on the acts buffer BEFORE the fused launch, so
        # KV read bits ride the same plan_bits call as the weights.
        self._kv_rows = put(jnp.asarray(bundle.kv_rows, jnp.int32)) \
            if len(bundle.kv_rows) else None
        self._kv_src = put(jnp.asarray(bundle.kv_src, jnp.int32)) \
            if len(bundle.kv_rows) else None
        self.static_stack = None if static_stack is None else \
            put(jnp.asarray(static_stack, jnp.int32))
        self.exact_deltas = exact_deltas or {}

    @property
    def needs_acts(self) -> bool:
        """Whether :meth:`plan` consumes captured activations."""
        return self.mode in ("dynamic", "exact")

    # -- the decide phase --------------------------------------------------------
    def plan(self, acts, target_idx, active=None) -> jax.Array:
        """The whole tick's decisions: bits ``(U,)`` int32.

        ``acts`` is the applier's captured ``(U, M, K_max)`` estimator
        inputs (ignored — pass None — for static/max). ``target_idx``
        and ``active`` are traced scalars (per-slot under vmap);
        ``active=False`` gates every decision to 0 bits.
        """
        t = jnp.asarray(target_idx, jnp.int32)
        if acts is not None and self._kv_rows is not None:
            acts = acts.at[self._kv_rows].set(acts[self._kv_src])
        if self.mode == "dynamic":
            return plan_bits(acts, self.tables, t, active,
                             backend=self.backend)
        if self.mode == "exact":
            return self._plan_exact(acts, t, active)
        if self.mode == "max":
            bits = self.max_bits
        else:                                        # static
            bits = self.static_stack[:, t]
        if active is not None:
            bits = jnp.where(jnp.asarray(active), bits, 0)
        return bits.astype(jnp.int32)

    def _plan_exact(self, acts, t, active) -> jax.Array:
        """Exact mode: fused approx pass, then per-unit ΔW overrides.

        The override loop is O(#delta units) jnp ops — exact mode is an
        eval/debug mode (the deltas themselves are full (T, K, N) weight
        stacks); the one-launch guarantee applies to the dynamic mode.
        """
        bits = plan_bits(acts, self.tables, t, active,
                         backend=self.backend)
        act = jnp.int32(1) if active is None else \
            jnp.asarray(active).astype(jnp.int32)
        mirror = {int(s): int(r) for r, s in
                  zip(self.bundle.kv_rows, self.bundle.kv_src)}
        for path, delta in self.exact_deltas.items():
            u = self.bundle.row_of[path]
            xf = acts[u][:, :delta.shape[-2]].astype(jnp.float32)
            est = jnp.max(jnp.linalg.norm(xf @ delta[t], axis=-1))
            dynamic = self.tables["kind"][u, t] != KIND_PINNED
            b_u = jnp.where(dynamic & (est > self.tables["threshold"][u, t]),
                            self.tables["h"][u, t], self.tables["l"][u, t])
            bits = bits.at[u].set(jnp.where(act > 0, b_u, 0))
            if u in mirror:               # keep the KV row tracking it
                bits = bits.at[mirror[u]].set(jnp.where(act > 0, b_u, 0))
        return bits

    # -- accounting --------------------------------------------------------------
    def inline_reference(self, acts, target_idx,
                         serve_params: Dict, table: Dict,
                         *, mode: str = "dynamic",
                         static_bits=None) -> jax.Array:
        """The legacy per-unit selector run over the same captured rows —
        the independent reference :meth:`plan` must match bit-for-bit
        (asserted by tests/test_decision.py and the CI benchmark smoke).

        ``serve_params``/``table``/``static_bits`` are the applier's
        usual inputs; rows are sliced back to each unit's true width
        before estimation, exactly as the inline path sees them.
        """
        from repro.core.dynamic_linear import DynamicLinearApplier

        lin = DynamicLinearApplier(table, serve_params,
                                   target_idx=target_idx, mode=mode,
                                   static_bits=static_bits)
        src_of = {int(r): int(s) for r, s in
                  zip(self.bundle.kv_rows, self.bundle.kv_src)}
        out = []
        for i, p in enumerate(self.bundle.paths):
            j = src_of.get(i, i)          # kv rows replay their source
            sp = self.bundle.paths[j]
            xi = acts[j, :, :int(self.bundle.k_actual[j])]
            out.append(lin._select_bits_active(table[sp], xi, None))
        return jnp.stack(out).astype(jnp.int32)

    def effective_bits(self, bits: jax.Array) -> jax.Array:
        """Parameter-weighted mean of a decision vector (matches the
        applier's legacy per-record reduction: sizes are the per-unit
        ``k*n`` / ``E*k*n`` counts)."""
        return jnp.sum(bits.astype(jnp.float32) * self.sizes) / \
            jnp.sum(self.sizes)
