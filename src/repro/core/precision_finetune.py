"""Phase 2 — layer-wise average precision assignment (paper §4, Eq. 1).

Each unit's linear op is substituted by the interpolation
``y = r·W_l x + (1−r)·W_h x`` with ``l=⌊p⌋``, ``h=⌈p⌉``, ``r=1−(p−l)``
(the s/t formulation of Algorithm 1 collapses to this), and ONLY the
``{p_i}`` are fine-tuned under

    L' = L + α·(Σ p_i·M_i / Σ M_i − b_targ)²

which pins the parameter-weighted average precision to the target while the
data term pushes sensitive layers up and insensitive layers down.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bitplane import (QuantizedLinear, QuantizedStacked,
                                 materialize, materialize_stacked)
from repro.models import loss_fn
from repro.models.common import LinearUnit
from repro.optim import adamw


@dataclass
class FinetuneResult:
    p: np.ndarray              # (n_units,) learned average precisions
    losses: List[float]        # per-iteration data loss
    reg_values: List[float]    # per-iteration regularizer values


def _weight_stack(overlay, b_lo: int, b_hi: int) -> jax.Array:
    """Stack of materialized weights for b in [b_lo, b_hi] (leading axis)."""
    mats = []
    for b in range(b_lo, b_hi + 1):
        if isinstance(overlay, QuantizedStacked):
            mats.append(materialize_stacked(overlay, b))
        else:
            mats.append(materialize(overlay, b))
    return jnp.stack(mats)


def interpolated_params(
    params: Dict[str, jax.Array],
    stacks: Dict[str, jax.Array],
    unit_order: Sequence[str],
    p_vec: jax.Array,                 # (n_units,) traced
    b_min: int,
) -> Dict[str, jax.Array]:
    """Parameter view with unit weights replaced by W(p) interpolation."""
    out = dict(params)
    for idx, path in enumerate(unit_order):
        stack = stacks[path]
        n_levels = stack.shape[0]
        p = jnp.clip(p_vec[idx], b_min, b_min + n_levels - 1)
        l_idx = jnp.clip(jnp.floor(p).astype(jnp.int32) - b_min,
                         0, n_levels - 2)
        r = 1.0 - (p - (l_idx + b_min))
        wl = jnp.take(stack, l_idx, axis=0)
        wh = jnp.take(stack, l_idx + 1, axis=0)
        out[path] = (r * wl + (1.0 - r) * wh).astype(stack.dtype)
    return out


def finetune_avg_precisions(
    cfg: ModelConfig,
    params: Dict[str, jax.Array],
    overlays: Dict[str, object],
    units: Sequence[LinearUnit],
    max_bits: Dict[str, int],          # Phase-1 per-unit maximum precision
    batches: Iterable[Tuple[np.ndarray, np.ndarray]],
    *,
    b_target: float,
    b_min: int = 3,
    alpha: float = 1.0,
    lr: float = 0.01,
    epochs: int = 5,
) -> FinetuneResult:
    unit_order = [u.path for u in units]
    sizes = jnp.asarray([float(u.k * u.n) for u in units])
    stacks = {u.path: _weight_stack(overlays[u.path], b_min,
                                    max_bits[u.path]) for u in units}
    maxb = jnp.asarray([float(max_bits[u.path]) for u in units])

    p0 = jnp.clip(jnp.full((len(units),), float(b_target)), b_min, maxb)
    opt_state = adamw.init({"p": p0})

    def objective(pv, tokens, labels):
        eff = interpolated_params(params, stacks, unit_order, pv["p"], b_min)
        data = loss_fn(cfg, eff, tokens, labels)
        avg = jnp.sum(pv["p"] * sizes) / jnp.sum(sizes)
        reg = alpha * (avg - b_target) ** 2
        return data + reg, (data, reg)

    step = jax.jit(jax.value_and_grad(objective, has_aux=True))

    p_params = {"p": p0}
    losses, regs = [], []
    batch_list = list(batches)
    for _ in range(epochs):
        for tokens, labels in batch_list:
            (_, (data, reg)), g = step(p_params, jnp.asarray(tokens),
                                       jnp.asarray(labels))
            p_params, opt_state = adamw.update(
                g, opt_state, p_params, lr=jnp.float32(lr),
                weight_decay=0.0)
            p_params = {"p": jnp.clip(p_params["p"], b_min, maxb)}
            losses.append(float(data))
            regs.append(float(reg))
    return FinetuneResult(np.asarray(p_params["p"]), losses, regs)
