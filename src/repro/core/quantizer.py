"""Per-channel asymmetric uniform quantization.

The multi-scale (Any-Precision) overlay in this framework is built on uniform
quantization rather than the upstream SqueezeLLM codebooks: uniform codes keep
the b-bit *prefix property* in closed form (``core/bitplane.py``) and let the
TPU kernel fuse dequantization into the bit-serial MXU matmul
(DESIGN.md §2.3 assumption log).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

MAX_BITS = 8  # storage parent precision (paper's window is 3..6 within this)


def quantize_channelwise(
    w: jax.Array, bits: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize ``w`` (K, N) to ``bits``-bit codes, per-output-channel (N).

    Returns ``(q, scale, zero)`` with
    ``w ≈ scale * (q - zero)``, ``q ∈ [0, 2^bits)`` stored as uint8.
    """
    if not (1 <= bits <= MAX_BITS):
        raise ValueError(f"bits must be in [1, {MAX_BITS}], got {bits}")
    w = w.astype(jnp.float32)
    lo = jnp.min(w, axis=0)                       # (N,)
    hi = jnp.max(w, axis=0)                       # (N,)
    span = jnp.maximum(hi - lo, 1e-8)
    levels = (1 << bits) - 1
    scale = span / levels                          # (N,)
    zero = -lo / scale                             # (N,) real-valued zero point
    q = jnp.clip(jnp.round(w / scale + zero), 0, levels).astype(jnp.uint8)
    return q, scale, zero


def dequantize(q: jax.Array, scale: jax.Array, zero: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_channelwise` (full-precision codes)."""
    return (q.astype(jnp.float32) - zero) * scale


def quantization_mse(w: jax.Array, bits: int) -> jax.Array:
    """Mean-squared error of quantizing ``w`` to ``bits`` (sensitivity input)."""
    q, scale, zero = quantize_channelwise(w, bits)
    return jnp.mean((w.astype(jnp.float32) - dequantize(q, scale, zero)) ** 2)
