"""Static quantization sensitivity (paper Appendix A / B.2).

One calibration pass accumulates, per linear unit:
- ``g_sum``  — mean gradient        (LLM-MQ:    ΔL ≈ |gᵀ ΔW|)
- ``g2_sum`` — squared gradients    (Fisher diag ≈ Hessian diag;
               DP-LLM Phase 1:      ΔL ≈ ½ Σ F_kk ΔW_k²
               HAWQ-V2:             ΔL ≈ mean(F) ‖ΔW‖²)

Sensitivity *tables* (unit × candidate bitwidth) feed the allocator.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bitplane import (QuantizedLinear, QuantizedStacked,
                                 materialize, materialize_stacked)
from repro.models import loss_fn
from repro.models.common import LinearUnit


def accumulate_fisher(
    cfg: ModelConfig,
    params: Dict[str, jax.Array],
    batches: Iterable[Tuple[np.ndarray, np.ndarray]],
    unit_paths: Sequence[str],
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Returns (g_mean, fisher_diag) per unit path."""
    grad_fn = jax.jit(jax.grad(
        lambda p, t, l: loss_fn(cfg, p, t, l)))
    g_sum = {p: jnp.zeros_like(params[p]) for p in unit_paths}
    g2_sum = {p: jnp.zeros_like(params[p]) for p in unit_paths}
    n = 0
    for tokens, labels in batches:
        g = grad_fn(params, jnp.asarray(tokens), jnp.asarray(labels))
        for p in unit_paths:
            g_sum[p] = g_sum[p] + g[p]
            g2_sum[p] = g2_sum[p] + jnp.square(g[p])
        n += 1
    inv = 1.0 / max(n, 1)
    return ({p: g_sum[p] * inv for p in unit_paths},
            {p: g2_sum[p] * inv for p in unit_paths})


def _materialized(overlay, b: int) -> jax.Array:
    if isinstance(overlay, QuantizedStacked):
        return materialize_stacked(overlay, b)
    return materialize(overlay, b)


def sensitivity_tables(
    method: str,                       # "fisher" (DP-LLM/HAWQ-style IP input)
                                       # | "hawq_v2" | "llm_mq"
    units: Sequence[LinearUnit],
    weights: Dict[str, jax.Array],     # full-precision unit weights
    overlays: Dict[str, object],       # path -> Quantized{Linear,Stacked}
    g_mean: Dict[str, jax.Array],
    fisher: Dict[str, jax.Array],
    bits_list: Sequence[int],
) -> np.ndarray:
    """(n_units, n_bits) predicted loss increase for each bitwidth choice."""
    rows: List[List[float]] = []
    for u in units:
        w = weights[u.path].astype(jnp.float32)
        row = []
        for b in bits_list:
            dw = w - _materialized(overlays[u.path], b)
            if method == "llm_mq":
                val = jnp.abs(jnp.sum(g_mean[u.path].astype(jnp.float32) * dw))
            elif method == "hawq_v2":
                tr = jnp.mean(fisher[u.path].astype(jnp.float32))
                val = tr * jnp.sum(dw * dw)
            else:  # fisher-diagonal second-order term (Eq. 5)
                val = 0.5 * jnp.sum(
                    fisher[u.path].astype(jnp.float32) * dw * dw)
            row.append(float(val))
        rows.append(row)
    return np.asarray(rows)
