"""Bit-plane overlay storage: the Any-Precision multi-scale substrate.

A weight matrix quantized once to ``B`` bits is stored as ``B`` bit-planes
packed into int32 words along the reduction axis K. Every lower precision
``b <= B`` is the *prefix* (top-b planes) of the same storage — reading fewer
planes reads fewer bytes, which is the entire memory-traffic mechanism the
paper's runtime adaptation exploits.

Math (per output channel n; bit 0 = MSB):
    q        = sum_{j<B} 2^(B-1-j) * plane_j            in [0, 2^B)
    v_b      = sum_{j<b} 2^(B-1-j) * plane_j            (b-bit truncation)
    q_hat_b  = v_b + (2^(B-b) - 1) / 2                  (midpoint correction)
    W_b      = scale * (q_hat_b - zero)
so  W_B == exact dequant, and the b-bit GEMV has the closed form
    y_b = scale ⊙ [ sum_{j<b} 2^(B-1-j) * (x @ plane_j)
                    + ((2^(B-b)-1)/2 - zero) * sum(x) ]
The dynamic-precision kernel (kernels/bitserial) evaluates exactly this,
loading only the first ``b`` planes from HBM.

Delta weights for a candidate pair (l, h):
    ΔW = W_h − W_l = scale ⊙ [ sum_{l<=j<h} 2^(B-1-j) plane_j
                               − (2^(B-l-1) − 2^(B-h-1)) ]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import MAX_BITS, quantize_channelwise

PACK = 32  # K positions per int32 word


def _pad_k(x: jax.Array) -> jax.Array:
    k = x.shape[0]
    pad = (-k) % PACK
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def pack_bitplanes(q: jax.Array, bits: int) -> jax.Array:
    """(K, N) uint8 codes -> (bits, K/32, N) int32 planes (bit 0 = MSB).

    Word layout: ``planes[b, kw, n]`` bit ``j`` (LSB-first) is plane ``b`` of
    K position ``kw*32 + j``.
    """
    q = _pad_k(q.astype(jnp.int32))
    k, n = q.shape
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    out = []
    for b in range(bits):
        plane = (q >> (bits - 1 - b)) & 1                      # (K, N)
        words = plane.reshape(k // PACK, PACK, n)
        packed = jnp.sum(words << shifts[None, :, None], axis=1)
        out.append(packed.astype(jnp.int32))
    return jnp.stack(out)                                       # (bits, K/32, N)


def unpack_plane(packed: jax.Array) -> jax.Array:
    """(K/32, N) int32 -> (K, N) float32 in {0, 1}."""
    kw, n = packed.shape
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    bits = (packed[:, None, :] >> shifts[None, :, None]) & 1
    return bits.reshape(kw * PACK, n).astype(jnp.float32)


@jax.tree_util.register_pytree_node_class
class QuantizedLinear:
    """Bit-plane storage for one linear layer (the overlay adaptation set)."""

    def __init__(self, planes: jax.Array, scale: jax.Array, zero: jax.Array,
                 bits: int, k: int):
        self.planes = planes      # (bits, K_pad/32, N) int32
        self.scale = scale        # (N,) f32
        self.zero = zero          # (N,) f32
        self.bits = int(bits)     # static parent precision B
        self.k = int(k)           # logical (unpadded) K

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.planes, self.scale, self.zero), (self.bits, self.k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        planes, scale, zero = children
        bits, k = aux
        return cls(planes, scale, zero, bits, k)

    # -- properties ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.planes.shape[-1]

    @property
    def bytes_at(self) -> dict:
        """HBM bytes read per decode GEMV at each precision b (planes only)."""
        per_plane = self.planes.shape[1] * self.planes.shape[2] * 4
        return {b: b * per_plane for b in range(1, self.bits + 1)}

    def __repr__(self):
        return (f"QuantizedLinear(K={self.k}, N={self.n}, bits={self.bits})")


def quantize_linear(w: jax.Array, bits: int = MAX_BITS) -> QuantizedLinear:
    """Quantize a (K, N) weight to a ``bits``-bit bit-plane overlay."""
    q, scale, zero = quantize_channelwise(w, bits)
    planes = pack_bitplanes(q, bits)
    return QuantizedLinear(planes, scale, zero, bits, w.shape[0])


def midpoint(bits: int, b) -> jax.Array:
    """Midpoint correction ``(2^(B-b) - 1) / 2`` (b may be traced)."""
    return (jnp.exp2(jnp.asarray(bits - b, jnp.float32)) - 1.0) * 0.5


def materialize(ql: QuantizedLinear, b) -> jax.Array:
    """Reconstruct the effective b-bit weight (K, N) float32.

    ``b`` may be a python int or a traced scalar; planes past ``b`` are
    masked (the kernel instead skips their DMA entirely). Truncated
    overlays (see :func:`truncate_overlay`) store fewer than ``bits``
    planes; ``b`` must then stay <= the stored plane count.
    """
    B = ql.bits
    acc = jnp.zeros((ql.planes.shape[1] * PACK, ql.n), jnp.float32)
    for j in range(ql.planes.shape[0]):
        w_j = unpack_plane(ql.planes[j]) * (2.0 ** (B - 1 - j))
        acc = acc + jnp.where(j < b, 1.0, 0.0) * w_j
    w = (acc + midpoint(B, b) - ql.zero) * ql.scale
    return w[: ql.k]


def truncate_overlay(ql: QuantizedLinear, h: int) -> QuantizedLinear:
    """Keep only the top-``h`` planes (serving stores ≤ max_bits planes —
    the Any-Precision memory budget; arithmetic stays anchored at B)."""
    return QuantizedLinear(ql.planes[:h], ql.scale, ql.zero, ql.bits, ql.k)


def truncate_stacked(qs: "QuantizedStacked", h: int) -> "QuantizedStacked":
    return QuantizedStacked(qs.planes[:, :h], qs.scale, qs.zero, qs.bits,
                            qs.k)


def delta_weight(ql: QuantizedLinear, l: int, h: int) -> jax.Array:
    """ΔW = W_h − W_l  (K, N) float32, for the relative-error metric."""
    if not (0 < l <= h <= ql.bits):
        raise ValueError(f"need 0 < l <= h <= {ql.bits}, got ({l}, {h})")
    B = ql.bits
    acc = jnp.zeros((ql.planes.shape[1] * PACK, ql.n), jnp.float32)
    for j in range(l, h):
        acc = acc + unpack_plane(ql.planes[j]) * (2.0 ** (B - 1 - j))
    corr = (2.0 ** (B - l - 1)) - (2.0 ** (B - h - 1))
    return ((acc - corr) * ql.scale)[: ql.k]


@jax.tree_util.register_pytree_node_class
class QuantizedStacked:
    """Bit-plane overlay for stacked expert weights (E, K, N).

    Experts in one projection share a runtime precision decision
    (DESIGN.md §4), so materialization is vectorized over E.
    """

    def __init__(self, planes: jax.Array, scale: jax.Array, zero: jax.Array,
                 bits: int, k: int):
        self.planes = planes      # (E, bits, K_pad/32, N) int32
        self.scale = scale        # (E, N)
        self.zero = zero          # (E, N)
        self.bits = int(bits)
        self.k = int(k)

    def tree_flatten(self):
        return (self.planes, self.scale, self.zero), (self.bits, self.k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def __repr__(self):
        e = self.planes.shape[0]
        return (f"QuantizedStacked(E={e}, K={self.k}, "
                f"N={self.planes.shape[-1]}, bits={self.bits})")


def quantize_stacked(w: jax.Array, bits: int = MAX_BITS) -> QuantizedStacked:
    """Quantize stacked expert weights (E, K, N) to per-expert overlays."""
    def one(we):
        q, scale, zero = quantize_channelwise(we, bits)
        return pack_bitplanes(q, bits), scale, zero
    planes, scale, zero = jax.vmap(one)(w)
    return QuantizedStacked(planes, scale, zero, bits, w.shape[1])


def materialize_stacked(qs: QuantizedStacked, b) -> jax.Array:
    """(E, K, N) effective b-bit weights (b may be traced)."""
    B = qs.bits
    e = qs.planes.shape[0]
    kp = qs.planes.shape[2] * PACK
    n = qs.planes.shape[-1]
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    acc = jnp.zeros((e, kp, n), jnp.float32)
    for j in range(qs.planes.shape[1]):
        words = qs.planes[:, j]                              # (E, Kw, N)
        bitsj = (words[:, :, None, :] >> shifts[None, None, :, None]) & 1
        plane = bitsj.reshape(e, kp, n).astype(jnp.float32)
        acc = acc + jnp.where(j < b, 1.0, 0.0) * plane * (2.0 ** (B - 1 - j))
    w = (acc + midpoint(B, b) - qs.zero[:, None, :]) * qs.scale[:, None, :]
    return w[:, : qs.k]


# -- row-wise encode (KV-cache overlay) --------------------------------------
# The KV cache stores one quantization group per (batch, position, head) ROW
# over the head dim — the same codebook as quantize_channelwise, transposed:
# scale/zero live per row instead of per output channel. quantize_rows is the
# ONE bitplane encode for cache entries; pack_rows lays the codes out as a
# plane stack packed along the head dim so a b-bit read is a prefix of the
# same storage, exactly like the weight overlays above.


def quantize_rows(x: jax.Array, bits: int = MAX_BITS):
    """Row-wise asymmetric uniform quantization over the LAST axis.

    x: (..., d) float -> (q (..., d) uint8, scale (..., 1) f32,
    zero (..., 1) f32) with ``x ≈ scale * (q - zero)``. All-zero rows
    encode to exactly-zero (q, scale, zero) so never-written / rewound
    cache rows stay representation-level zeros (the speculative
    zero-rows invariant holds on the packed planes themselves).
    """
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    span = jnp.maximum(hi - lo, 1e-8)
    levels = (1 << bits) - 1
    scale = span / levels
    zero = -lo / scale
    q = jnp.clip(jnp.round(xf / scale + zero), 0, levels)
    blank = (lo == 0.0) & (hi == 0.0)
    q = jnp.where(blank, 0.0, q).astype(jnp.uint8)
    scale = jnp.where(blank, 0.0, scale)
    zero = jnp.where(blank, 0.0, zero)
    return q, scale, zero


def pack_rows(q: jax.Array, bits: int) -> jax.Array:
    """(..., d) uint8 codes -> (bits, ..., d/32) int32 planes (bit 0 = MSB).

    The pack axis is the LAST (head) dim — ``planes[b, ..., w]`` bit ``j``
    (LSB-first) is plane ``b`` of position ``w*32 + j``.
    """
    d = q.shape[-1]
    pad = (-d) % PACK
    qi = q.astype(jnp.int32)
    if pad:
        qi = jnp.pad(qi, ((0, 0),) * (qi.ndim - 1) + ((0, pad),))
    dw = qi.shape[-1] // PACK
    words = qi.reshape(qi.shape[:-1] + (dw, PACK))
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    out = []
    for b in range(bits):
        plane = (words >> (bits - 1 - b)) & 1
        out.append(jnp.sum(plane << shifts, axis=-1).astype(jnp.int32))
    return jnp.stack(out)                       # (bits, ..., d/32)


def unpack_rows(words: jax.Array, d: int) -> jax.Array:
    """(..., dw) int32 -> (..., d) float32 in {0, 1} (inverse of one
    pack_rows plane; positions past ``d`` are the zero padding)."""
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    bits = (words[..., :, None] >> shifts) & 1
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * PACK,))
    return flat[..., :d].astype(jnp.float32)


def bitserial_matmul_ref(x: jax.Array, ql: QuantizedLinear, b) -> jax.Array:
    """Reference b-bit matmul via the closed form (oracle for the kernel).

    x: (..., K) float; b: int or traced scalar; returns (..., N) float32.
    """
    B = ql.bits
    xp = _pad_k(jnp.moveaxis(jnp.atleast_2d(x.astype(jnp.float32)), -1, 0))
    xp = jnp.moveaxis(xp, 0, -1)                    # (..., K_pad)
    acc = jnp.zeros(xp.shape[:-1] + (ql.n,), jnp.float32)
    for j in range(ql.planes.shape[0]):
        plane = unpack_plane(ql.planes[j])          # (K_pad, N)
        contrib = (xp @ plane) * (2.0 ** (B - 1 - j))
        acc = acc + jnp.where(j < b, 1.0, 0.0) * contrib
    sx = jnp.sum(xp, axis=-1, keepdims=True)        # (..., 1)
    y = (acc + (midpoint(B, b) - ql.zero) * sx) * ql.scale
    return y.reshape(x.shape[:-1] + (ql.n,))
