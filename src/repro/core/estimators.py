"""Relative-error estimators (paper §5): linear-regression / JL hybrid.

Offline, per unit:
1. compute the exact relative errors ``‖x·ΔW‖`` and the estimator inputs
   (the *async* residual value for async-eligible units — paper Fig. 6);
2. fit the linear model ``err ≈ a·‖x‖ + b``; if its R² ≥ R²_th (0.9), the
   unit uses the near-free linear estimator;
3. otherwise sample ``A_ij ~ N(0,1)/√k`` (JL lemma, k=64), precompute
   ``G = A·ΔWᵀ`` and calibrate a scalar gain γ to the input distribution
   (the paper's "tune G ... offline" step).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

JL_K = 64          # projection dim (paper §5.1)
R2_THRESHOLD = 0.9


@dataclass
class EstimatorFit:
    kind: str                     # "linear" | "jl"
    r2: float
    a: float = 0.0                # linear: err ≈ a·‖x‖ + b
    b: float = 0.0
    gamma: float = 1.0            # jl: err ≈ γ·‖G x‖
    g: Optional[np.ndarray] = field(default=None, repr=False)  # (k, K)


def sample_projection(key: jax.Array, k_proj: int, n_out: int) -> jax.Array:
    """A ~ N(0, 1/k) of shape (k_proj, n_out) — projects the OUTPUT error."""
    return jax.random.normal(key, (k_proj, n_out)) / np.sqrt(k_proj)


def make_g(a_mat: jax.Array, delta_w: jax.Array) -> jax.Array:
    """G = A·ΔWᵀ (k, K): the runtime estimate is ‖G x‖ ≈ ‖x·ΔW‖."""
    return jnp.einsum("pn,kn->pk", a_mat, delta_w)


def fit_linear(xnorm: np.ndarray, err: np.ndarray):
    """Least-squares err ≈ a·xnorm + b; returns (a, b, r2)."""
    x = np.asarray(xnorm, np.float64)
    y = np.asarray(err, np.float64)
    xm, ym = x.mean(), y.mean()
    vx = np.mean((x - xm) ** 2)
    cov = np.mean((x - xm) * (y - ym))
    a = cov / max(vx, 1e-30)
    b = ym - a * xm
    resid = y - (a * x + b)
    vy = np.mean((y - ym) ** 2)
    r2 = 1.0 - np.mean(resid ** 2) / max(vy, 1e-30)
    return float(a), float(b), float(r2)


def fit_gamma(jl_raw: np.ndarray, err: np.ndarray) -> float:
    """γ minimizing E[(γ·‖Gx‖ − err)²] — the G input-calibration step."""
    num = float(np.sum(jl_raw * err))
    den = float(np.sum(jl_raw * jl_raw))
    return num / max(den, 1e-30)


def fit_estimator(
    err: np.ndarray,            # exact ‖x·ΔW‖ on calibration tokens
    xnorm: np.ndarray,          # ‖x_est‖ (async input where eligible)
    jl_raw: np.ndarray,         # ‖G x_est‖ with the sampled (uncalibrated) G
    g: np.ndarray,              # the sampled G (kept if the unit goes JL)
    *,
    r2_threshold: float = R2_THRESHOLD,
) -> EstimatorFit:
    a, b, r2 = fit_linear(xnorm, err)
    if r2 >= r2_threshold:
        return EstimatorFit(kind="linear", r2=r2, a=a, b=b)
    gamma = fit_gamma(jl_raw, err)
    return EstimatorFit(kind="jl", r2=r2, gamma=gamma, g=np.asarray(g))


def estimate(fit: EstimatorFit, x: jax.Array) -> jax.Array:
    """Batched runtime estimate; reduces with max over leading dims
    (one precision decision per layer per step — DESIGN.md §2.3)."""
    xf = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    if fit.kind == "linear":
        xn = jnp.linalg.norm(xf, axis=-1)
        return jnp.max(fit.a * xn + fit.b)
    proj = xf @ jnp.asarray(fit.g).T
    return fit.gamma * jnp.max(jnp.linalg.norm(proj, axis=-1))
