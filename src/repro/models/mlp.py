"""Feed-forward variants: SwiGLU (llama), squared-ReLU (nemotron), GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GELU, SQUARED_RELU, SWIGLU


def mlp_forward(kind: str, lin, prefix: str, x: jax.Array,
                *, async_input=None) -> jax.Array:
    """Apply the FFN at ``prefix`` through the linear applier ``lin``.

    ``async_input`` is the residual-stream value usable for asynchronous
    relative-error estimation on the up/gate projections (paper Fig. 6);
    the down projection is always synchronous.
    """
    if kind == SWIGLU:
        gate = lin(f"{prefix}.w_gate", x, async_input=async_input)
        up = lin(f"{prefix}.w_up", x, async_input=async_input)
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
        return lin(f"{prefix}.w_down", h.astype(x.dtype))
    if kind == SQUARED_RELU:
        up = lin(f"{prefix}.w_up", x, async_input=async_input)
        h = jnp.square(jax.nn.relu(up.astype(jnp.float32)))
        return lin(f"{prefix}.w_down", h.astype(x.dtype))
    if kind == GELU:
        up = lin(f"{prefix}.w_up", x, async_input=async_input)
        h = jax.nn.gelu(up.astype(jnp.float32))
        return lin(f"{prefix}.w_down", h.astype(x.dtype))
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_param_dims(kind: str, d_model: int, d_ff: int):
    """(name, (K, N)) pairs for the FFN's linear units."""
    if kind == SWIGLU:
        return [("w_gate", (d_model, d_ff)), ("w_up", (d_model, d_ff)),
                ("w_down", (d_ff, d_model))]
    return [("w_up", (d_model, d_ff)), ("w_down", (d_ff, d_model))]
