"""GQA attention: flash-style chunked training/prefill path + KV-cache decode.

The chunked path keeps the working set at
``(batch, q_chunk, heads, kv_chunk)`` — never materializing the full
(seq × seq) score matrix — so 32k-token prefill lowers and fits. The online
softmax is the standard flash recurrence (running max + rescaled partials)
written in pure ``lax.scan`` so GSPMD can shard heads/batch/sequence freely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _largest_divisor(n: int, at_most: int) -> int:
    """Largest divisor of ``n`` that is <= ``at_most`` (chunk fallback)."""
    c = min(at_most, n)
    while n % c:
        c -= 1
    return c


def _soft_cap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(scores / cap)
    return scores


def flash_attention(
    q: jax.Array,            # (b, sq, hq, dh)
    k: jax.Array,            # (b, sk, hkv, dh)
    v: jax.Array,            # (b, sk, hkv, dh)
    *,
    causal: bool = True,
    q_offset: int = 0,       # absolute position of q[0] (for causal masking)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    logit_softcap: float = 0.0,
) -> jax.Array:
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv                                   # GQA group size
    scale = dh ** -0.5

    q_chunk = _largest_divisor(sq, q_chunk)
    kv_chunk = _largest_divisor(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk

    qg = q.reshape(b, nq, q_chunk, hkv, g, dh).astype(jnp.float32) * scale
    kc = k.reshape(b, nk, kv_chunk, hkv, dh).astype(jnp.float32)
    vc = v.reshape(b, nk, kv_chunk, hkv, dh).astype(jnp.float32)

    q_pos = q_offset + jnp.arange(sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(sk).reshape(nk, kv_chunk)

    def per_q_chunk(qi, q_blk):
        # q_blk: (b, q_chunk, hkv, g, dh)
        def kv_step(carry, inputs):
            m, l, acc = carry                      # running max / denom / out
            k_blk, v_blk, kpos = inputs
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk, k_blk)
            s = _soft_cap(s, logit_softcap)
            if causal:
                mask = q_pos[qi][None, :, None, None, None] >= \
                    kpos[None, None, None, None, :]
                s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, v_blk)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, q_chunk, hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, hkv, g, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), k_pos))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(
        lambda args: per_q_chunk(args[0], args[1]),
        (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,            # (b, M, hq, dh) — M = 1 (decode) or >1 (prefill)
    k_cache: jax.Array,      # (b, S, hkv, dh)  bf16/f32 or int8 (quantized)
    v_cache: jax.Array,      # (b, S, hkv, dh)
    cache_len: jax.Array,    # scalar int32 — valid prefix length (incl. new);
                             # or (M,) per-row lengths for the prefill pass
    *,
    logit_softcap: float = 0.0,
    k_scale: jax.Array = None,   # (b, S, hkv, 1) f32 — int8 cache scales
    v_scale: jax.Array = None,
    k_zero: jax.Array = None,    # (b, S, hkv, 1) f32 — int8 zero points
    v_zero: jax.Array = None,
) -> jax.Array:
    """Token attention over a (possibly sequence-sharded) KV cache.

    M == 1 is the decode hot path (unchanged math). M > 1 is the batched
    prefill stage: the M token rows were just written into the cache at
    consecutive positions, and row m masks the cache to its own causal
    prefix (``cache_len[m]`` — typically ``pos + m + 1``), so every row's
    softmax sees exactly the prefix the sequential tick-by-tick path saw.

    int8 KV (beyond-paper §Perf optimization): cache stored as int8 with
    per-(batch, position, head) scale/zero rows (the shared
    ``quantize_rows`` codebook at 8 bits) — halves the decode memory
    term at <0.5% score perturbation (tests/test_models.py).
    """
    b, m, hq, dh = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = dh ** -0.5
    kf = k_cache.astype(jnp.float32)
    if k_scale is not None:
        if k_zero is not None:
            kf = kf - k_zero
        kf = kf * k_scale
    vf = v_cache.astype(jnp.float32)
    if v_scale is not None:
        if v_zero is not None:
            vf = vf - v_zero
        vf = vf * v_scale
    if m == 1:
        qf = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale
        scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
        scores = _soft_cap(scores, logit_softcap)
        mask = jnp.arange(s)[None, None, None, :] < cache_len
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgs,bshd->bhgd", probs, vf)
        return out.reshape(b, 1, hq, dh).astype(q.dtype)
    qf = q.reshape(b, m, hkv, g, dh).astype(jnp.float32) * scale
    scores = jnp.einsum("bmhgd,bshd->bmhgs", qf, kf)
    scores = _soft_cap(scores, logit_softcap)
    lens = jnp.broadcast_to(jnp.asarray(cache_len), (m,))
    mask = jnp.arange(s)[None, :] < lens[:, None]            # (m, s)
    scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bmhgs,bshd->bmhgd", probs, vf)
    return out.reshape(b, m, hq, dh).astype(q.dtype)


def _encode_int8_rows(x: jax.Array):
    """ONE cache encode (``core.bitplane.quantize_rows``) specialized to
    the int8 representation: codes recentred to signed int8, zero-point
    folded into the stored zero so dequant is ``(v - zero) * scale``."""
    from repro.core.bitplane import quantize_rows  # deferred: pkg cycle
    q, s, z = quantize_rows(x, bits=8)
    return (q.astype(jnp.int32) - 128).astype(jnp.int8), s, z - 128.0


def update_kv_cache(
    k_cache: jax.Array, v_cache: jax.Array,
    k_new: jax.Array, v_new: jax.Array,
    pos: jax.Array,
    k_scale: jax.Array = None, v_scale: jax.Array = None,
    k_zero: jax.Array = None, v_zero: jax.Array = None,
):
    """Write one decode step's K/V at position ``pos`` (dynamic index).

    With int8 caches (k_scale/v_scale given) the new entries are encoded
    per (batch, position, head) row via the shared bitplane codebook
    (:func:`repro.core.bitplane.quantize_rows` at 8 bits — asymmetric,
    so the cache also carries zero points); returns updated
    scale/zero arrays too.
    """
    if k_scale is not None:
        k_q, k_s, k_z = _encode_int8_rows(k_new)
        v_q, v_s, v_z = _encode_int8_rows(v_new)
        at = (0, pos, 0, 0)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_q, at)
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_q, at)
        k_scale = jax.lax.dynamic_update_slice(k_scale, k_s, at)
        v_scale = jax.lax.dynamic_update_slice(v_scale, v_s, at)
        k_zero = jax.lax.dynamic_update_slice(k_zero, k_z, at)
        v_zero = jax.lax.dynamic_update_slice(v_zero, v_z, at)
        return k_cache, v_cache, k_scale, v_scale, k_zero, v_zero
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    return k_cache, v_cache, None, None, None, None


def encode_kv_rows(x: jax.Array, bits: int = 8):
    """Encode new cache rows to the bitplane overlay.

    x: (b, M, h, dh) -> (planes (b, bits, M, h, dw) int32,
    scale (b, M, h, 1) f32, zero (b, M, h, 1) f32) — the write side of
    the dynamic-precision KV cache: always the FULL plane stack; the
    read precision is decided later, per tick, by the planner.
    """
    from repro.core.bitplane import pack_rows, quantize_rows  # pkg cycle
    q, s, z = quantize_rows(x, bits)
    planes = jnp.moveaxis(pack_rows(q, bits), 0, 1)
    return planes, s, z


def update_kv_planes(
    k_planes: jax.Array, k_scale: jax.Array, k_zero: jax.Array,
    v_planes: jax.Array, v_scale: jax.Array, v_zero: jax.Array,
    k_new: jax.Array, v_new: jax.Array, pos: jax.Array, *, bits: int = 8,
):
    """Write one decode step's K/V rows into the plane-stacked cache.

    Cache layout per layer: planes (b, bits, S, hkv, dw) int32 and
    scale/zero (b, S, hkv, 1) f32. ``k_new``/``v_new`` are (b, M, hkv,
    dh) rows landing at positions [pos, pos + M).
    """
    kp, ks, kz = encode_kv_rows(k_new, bits)
    vp, vs, vz = encode_kv_rows(v_new, bits)
    zero = jnp.int32(0)
    p_at = (zero, zero, pos, zero, zero)
    s_at = (zero, pos, zero, zero)
    k_planes = jax.lax.dynamic_update_slice(k_planes, kp, p_at)
    v_planes = jax.lax.dynamic_update_slice(v_planes, vp, p_at)
    k_scale = jax.lax.dynamic_update_slice(k_scale, ks, s_at)
    v_scale = jax.lax.dynamic_update_slice(v_scale, vs, s_at)
    k_zero = jax.lax.dynamic_update_slice(k_zero, kz, s_at)
    v_zero = jax.lax.dynamic_update_slice(v_zero, vz, s_at)
    return k_planes, k_scale, k_zero, v_planes, v_scale, v_zero


@jax.custom_batching.custom_vmap
def paged_write_rows(pool_planes, pool_scale, pool_zero, page_table, pos,
                     planes, scale, zero):
    """Scatter encoded KV rows into the SHARED plane pool through a
    per-slot page table.

    pool_planes: (NP, B, page_len, hkv, dw) int32; pool scale/zero:
    (NP, page_len, hkv, 1) f32 — ONE physical pool, no slot axis.
    page_table: (b, P) int32; pos: (b,) int32 first row index; planes:
    (b, B, M, hkv, dw) (the ``encode_kv_rows`` layout); scale/zero:
    (b, M, hkv, 1). Rows land at logical positions [pos, pos + M)
    through the table; entries whose table slot is unallocated (0) land
    on the TRASH page — that is how gated/idle lanes write harmlessly.

    ``custom_vmap``: under the scheduler's vmapped tick the pool
    operands stay UNBATCHED — every lane's rows fold into ONE scatter
    (well-defined because the allocator never aliases a live page
    between slots; collisions exist only on the trash page, whose
    content is never read unmasked).
    """
    nbits, m = planes.shape[1], planes.shape[2]
    b = page_table.shape[0]
    page_len = pool_planes.shape[2]
    rows = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape((-1, 1)), (b, 1)) + \
        jnp.arange(m, dtype=jnp.int32)
    page_ix = jnp.clip(rows // page_len, 0, page_table.shape[1] - 1)
    pages = jnp.take_along_axis(jnp.maximum(page_table, 0), page_ix,
                                axis=1)
    fp = pages.reshape(-1)
    fo = (rows % page_len).reshape(-1)
    pv = jnp.moveaxis(planes, 1, 2).reshape(
        (b * m,) + (nbits,) + planes.shape[3:])
    new_planes = pool_planes.at[fp, :, fo].set(pv.astype(pool_planes.dtype))
    sv = scale.reshape((b * m,) + scale.shape[2:])
    zv = zero.reshape((b * m,) + zero.shape[2:])
    new_scale = pool_scale.at[fp, fo].set(sv.astype(pool_scale.dtype))
    new_zero = pool_zero.at[fp, fo].set(zv.astype(pool_zero.dtype))
    return new_planes, new_scale, new_zero


@paged_write_rows.def_vmap
def _paged_write_rows_vmap(axis_size, in_batched, pool_planes, pool_scale,
                           pool_zero, page_table, pos, planes, scale, zero):
    if any(in_batched[:3]):
        raise ValueError("paged KV pool operands must stay unbatched "
                         "under vmap (one shared physical pool)")

    def flat(a, batched):
        if not batched:
            a = jnp.broadcast_to(a[None], (axis_size,) + a.shape)
        return a.reshape((axis_size * a.shape[1],) + a.shape[2:])

    out = paged_write_rows(
        pool_planes, pool_scale, pool_zero,
        flat(page_table, in_batched[3]), flat(pos, in_batched[4]),
        flat(planes, in_batched[5]), flat(scale, in_batched[6]),
        flat(zero, in_batched[7]))
    return out, (False, False, False)


def update_kv_pool(
    pool_kp: jax.Array, pool_ks: jax.Array, pool_kz: jax.Array,
    pool_vp: jax.Array, pool_vs: jax.Array, pool_vz: jax.Array,
    page_table: jax.Array, k_new: jax.Array, v_new: jax.Array,
    pos: jax.Array, *, bits: int = 8,
):
    """Paged twin of :func:`update_kv_planes`: encode one step's K/V rows
    (b, M, hkv, dh) to the full plane stack and scatter them into the
    shared pool at logical positions [pos, pos + M) via the page table."""
    b = k_new.shape[0]
    pos_v = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    kp, ks, kz = encode_kv_rows(k_new, bits)
    vp, vs, vz = encode_kv_rows(v_new, bits)
    pool_kp, pool_ks, pool_kz = paged_write_rows(
        pool_kp, pool_ks, pool_kz, page_table, pos_v, kp, ks, kz)
    pool_vp, pool_vs, pool_vz = paged_write_rows(
        pool_vp, pool_vs, pool_vz, page_table, pos_v, vp, vs, vz)
    return pool_kp, pool_ks, pool_kz, pool_vp, pool_vs, pool_vz


def paged_zero_window(
    pool_kp: jax.Array, pool_ks: jax.Array, pool_kz: jax.Array,
    pool_vp: jax.Array, pool_vs: jax.Array, pool_vz: jax.Array,
    page_table: jax.Array, start: jax.Array, window: int,
):
    """Zero logical rows [start, start + window) of a slot's pages — the
    paged rollback's KV erase. Exactly a :func:`paged_write_rows` of
    zero rows, so it re-establishes the zero-rows invariant on the
    accepted window's pages ONLY (never touches other slots' pages;
    rows whose table entry is unallocated land on the trash page)."""
    b, p = page_table.shape[0], page_table.shape[1]
    del p
    nbits = pool_kp.shape[1]
    hkv = pool_kp.shape[3]
    dw = pool_kp.shape[4]
    start_v = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1),
                               (b,))
    zp = jnp.zeros((b, nbits, int(window), hkv, dw), pool_kp.dtype)
    zs = jnp.zeros((b, int(window)) + pool_ks.shape[2:], pool_ks.dtype)
    pool_kp, pool_ks, pool_kz = paged_write_rows(
        pool_kp, pool_ks, pool_kz, page_table, start_v, zp, zs, zs)
    pool_vp, pool_vs, pool_vz = paged_write_rows(
        pool_vp, pool_vs, pool_vz, page_table, start_v, zp, zs, zs)
    return pool_kp, pool_ks, pool_kz, pool_vp, pool_vs, pool_vz


def decode_attention_pool(
    q: jax.Array,                # (b, M, hq, dh)
    pool_kp: jax.Array,          # (NP, bits, page_len, hkv, dw) int32
    pool_ks: jax.Array,          # (NP, page_len, hkv, 1) f32
    pool_kz: jax.Array,
    pool_vp: jax.Array,
    pool_vs: jax.Array,
    pool_vz: jax.Array,
    page_table: jax.Array,       # (b, P) int32
    cache_len: jax.Array,        # scalar or (M,) per-row lengths
    *,
    bits: int = 8,
    kv_bits: jax.Array = None,   # per-slot read precision; None -> full B
    logit_softcap: float = 0.0,
    read: str = "plane",         # "plane" | "dense" (parity oracle)
    backend: str = None,
) -> jax.Array:
    """Paged twin of :func:`decode_attention_planes`: the cache rows live
    in ONE shared plane pool and each lane reads its own pages through
    ``page_table``. ``read="plane"`` dispatches the paged bit-serial
    kernel (page indirection composed with plane-DMA elision);
    ``read="dense"`` gathers the pages into the bucketed row layout and
    runs the dense parity oracle at full bits."""
    from repro.kernels.kv_attention import (gather_paged_kv,
                                            kv_attention_dense,
                                            kv_decode_attention_paged,
                                            materialize_kv_planes)
    b, m, hq, dh = q.shape
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape((-1,))[None, :], (b, m))
    if kv_bits is None:
        kvb = jnp.full((b,), bits, jnp.int32)
    else:
        kvb = jnp.broadcast_to(jnp.asarray(kv_bits, jnp.int32), (b,))
    if read == "dense":
        kp, ks, kz = gather_paged_kv(pool_kp, pool_ks, pool_kz, page_table)
        vp, vs, vz = gather_paged_kv(pool_vp, pool_vs, pool_vz, page_table)

        def one(qs, kpl, ksc, kzr, vpl, vsc, vzr, ls):
            kf = materialize_kv_planes(kpl, ksc, kzr, bits, bits=bits, d=dh)
            vf = materialize_kv_planes(vpl, vsc, vzr, bits, bits=bits, d=dh)
            return kv_attention_dense(qs, kf, vf, ls,
                                      logit_softcap=logit_softcap)
        out = jax.vmap(one)(q.astype(jnp.float32), kp, ks, kz, vp, vs, vz,
                            lens)
        out = jnp.where((kvb > 0)[:, None, None, None], out, 0.0)
    elif read == "plane":
        out = kv_decode_attention_paged(
            q, pool_kp, pool_ks, pool_kz, pool_vp, pool_vs, pool_vz,
            page_table, lens, kvb, bits=bits, logit_softcap=logit_softcap,
            backend=backend)
    else:
        raise ValueError(f"unknown KV read mode {read!r}")
    return out.astype(q.dtype)


def decode_attention_planes(
    q: jax.Array,                # (b, M, hq, dh)
    k_planes: jax.Array,         # (b, bits, S, hkv, dw) int32
    k_scale: jax.Array,          # (b, S, hkv, 1) f32
    k_zero: jax.Array,
    v_planes: jax.Array,
    v_scale: jax.Array,
    v_zero: jax.Array,
    cache_len: jax.Array,        # scalar or (M,) per-row lengths
    *,
    bits: int = 8,
    kv_bits: jax.Array = None,   # per-slot read precision; None -> full B
    logit_softcap: float = 0.0,
    read: str = "plane",         # "plane" | "dense" (parity oracle)
    backend: str = None,
) -> jax.Array:
    """Decode attention over the plane-stacked KV cache.

    ``read="plane"`` dispatches the slot-batched bit-serial kernel
    (`kernels.kv_attention`): slot b fetches exactly ``kv_bits[b]``
    cache planes per tile. ``read="dense"`` is the parity oracle — it
    materializes the FULL plane stack (python-int ``bits``, no masking
    arithmetic differences) and runs the shared dense attention math;
    at ``kv_bits == bits`` the plane path is bit-identical to it.
    """
    # deferred: kernels.kv_attention imports core.bitplane, and models
    # must stay importable before the kernels package (mirror of the
    # dynamic_linear deferral)
    from repro.kernels.kv_attention import (kv_attention_dense,
                                            kv_decode_attention,
                                            materialize_kv_planes)
    b, m, hq, dh = q.shape
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape((-1,))[None, :], (b, m))
    if kv_bits is None:
        kvb = jnp.full((b,), bits, jnp.int32)
    else:
        kvb = jnp.broadcast_to(jnp.asarray(kv_bits, jnp.int32), (b,))
    if read == "dense":
        def one(qs, kp, ks, kz, vp, vs, vz, ls):
            kf = materialize_kv_planes(kp, ks, kz, bits, bits=bits, d=dh)
            vf = materialize_kv_planes(vp, vs, vz, bits, bits=bits, d=dh)
            return kv_attention_dense(qs, kf, vf, ls,
                                      logit_softcap=logit_softcap)
        out = jax.vmap(one)(q.astype(jnp.float32), k_planes, k_scale,
                            k_zero, v_planes, v_scale, v_zero, lens)
        out = jnp.where((kvb > 0)[:, None, None, None], out, 0.0)
    elif read == "plane":
        out = kv_decode_attention(
            q, k_planes, k_scale, k_zero, v_planes, v_scale, v_zero,
            lens, kvb, bits=bits, logit_softcap=logit_softcap,
            backend=backend)
    else:
        raise ValueError(f"unknown KV read mode {read!r}")
    return out.astype(q.dtype)
