from repro.models.common import LinearUnit, Params, cross_entropy
from repro.models.transformer import (decode_step, forward,
                                      init_decode_state, init_model_params,
                                      init_paged_pool, init_paged_state,
                                      linear_units, loss_fn,
                                      model_logical_axes, model_param_specs)

__all__ = [
    "LinearUnit", "Params", "cross_entropy", "decode_step", "forward",
    "init_decode_state", "init_model_params", "init_paged_pool",
    "init_paged_state", "linear_units", "loss_fn", "model_logical_axes",
    "model_param_specs",
]
