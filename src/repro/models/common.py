"""Model-zoo foundations: parameter specs, norms, RoPE, losses.

Parameters are a FLAT dict ``{path: jax.Array}`` (paths like
``"layers.3.attn.wq"``) — a pytree that keeps sharding rules, quantization
targets, and checkpoint manifests trivially addressable.

Every parameter is declared once as a :class:`ParamSpec` carrying its shape,
**logical sharding axes** (resolved to mesh axes by
``repro.distributed.sharding``) and init; ``init_params`` /
``logical_axes`` / ``linear_units`` all derive from the same spec table.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Logical axis names (see distributed/sharding.py for the mesh mapping)
# ---------------------------------------------------------------------------
EMBED = "embed"        # d_model
FFN = "ffn"            # d_ff (incl. per-expert)
HEADS = "heads"        # fused q head dim (num_heads * head_dim)
KV_HEADS = "kv_heads"  # fused kv head dim
VOCAB = "vocab"
EXPERTS = "experts"
SSM_INNER = "ssm_inner"   # d_inner (and fused xBC/proj dims)
SSM_HEADS = "ssm_heads"
SSM_STATE = "ssm_state"
CONV = "conv"          # conv taps (replicated)
NOSHARD = None         # replicated scalar-ish dims

# Serve-side logical axes (adaptation artifacts + scheduler state; see
# core/adaptation.serve_array_axes and distributed/sharding.SERVE_RULES).
TARGETS = "targets"    # leading target-stacked axis of every serve artifact
JL_PROJ = "jl_proj"    # JL sketch rows (k_proj) of estimator G matrices
PLANES = "planes"      # bit-plane axis of Any-Precision overlays
SLOTS = "slots"        # continuous-batching slot axis (scheduler state)
UNITS = "units"        # unit-stacked axis of the decision bundle / the
                       # planner's (U,) bits vector and (U, M, K) inputs


@dataclass(frozen=True)
class ParamSpec:
    path: str
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis per dim
    init: str = "normal"              # normal | zeros | ones | small_normal
    fan_in: int = 0                   # 0 -> shape[0]

    def initialize(self, key: jax.Array, dtype=jnp.float32) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan = self.fan_in or (self.shape[0] if self.shape else 1)
        std = 0.02 if self.init == "small_normal" else fan ** -0.5
        return (jax.random.normal(key, self.shape) * std).astype(dtype)


@dataclass(frozen=True)
class LinearUnit:
    """One DP-LLM precision unit — a quantizable linear projection."""
    path: str
    kind: str            # q|k|v|o|gate|up|down|router|expert_w1|... |ssm_in|ssm_out
    k: int               # reduction dim
    n: int               # output dim
    async_eligible: bool  # residual-adjacent input (paper §5.2)


Params = Dict[str, jax.Array]
SpecTable = Dict[str, ParamSpec]


def init_params(specs: SpecTable, key: jax.Array, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, max(len(specs), 1))
    return {
        s.path: s.initialize(k, dtype)
        for s, k in zip(specs.values(), keys)
    }


def logical_axes(specs: SpecTable) -> Dict[str, Tuple[Optional[str], ...]]:
    return {s.path: s.axes for s in specs.values()}


# ---------------------------------------------------------------------------
# Numeric building blocks
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma).astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (dh/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean token NLL; positions with label < 0 are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0) & (labels < vocab_size)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def default_linear(params: Params) -> Callable:
    """The bf16/f32 training-path linear applier: plain ``x @ W``."""
    def apply(path: str, x: jax.Array, *, async_input=None) -> jax.Array:
        del async_input
        w = params[path]
        return jnp.einsum("...k,kn->...n", x, w).astype(x.dtype)
    return apply
