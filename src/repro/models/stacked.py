"""Scan-over-layers (stacked-parameter) model path.

Production-scale lowering: a 96-layer graph compiled as 96 inlined blocks is
~100× the HLO of one scanned block. Layer patterns in every assigned arch
are *periodic* (jamba: period 8 = 1 attn + 7 mamba, MoE on odd layers;
everything else: period 1), so ``lax.scan`` over ``num_layers/period``
steps with one period per body covers the whole pool. Parameters, decode
caches, bit-plane overlays, and estimator artifacts all stack on a leading
steps axis; ``cfg.layer_kind(r)`` / ``cfg.layer_is_moe(r)`` evaluated at the
*relative* index r are correct for every step by periodicity.

Equivalence with the per-layer loop path is asserted in
tests/test_stacked.py.
"""
from __future__ import annotations

import math
import re
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import hint
from repro.models.common import ParamSpec, default_linear, rms_norm
from repro.models.transformer import (_block, decode_step as _loop_decode,
                                      model_param_specs)

_LAYER_RE = re.compile(r"^layers\.(\d+)\.(.+)$")


def group_size(cfg: ModelConfig) -> int:
    g = 1
    if cfg.attn_every:
        g = math.lcm(g, cfg.attn_every)
    if cfg.num_experts and cfg.moe_every:
        g = math.lcm(g, cfg.moe_every)
    assert cfg.num_layers % g == 0, (cfg.num_layers, g)
    return g


def num_scan_steps(cfg: ModelConfig) -> int:
    return cfg.num_layers // group_size(cfg)


def split_layer_paths(cfg: ModelConfig):
    """Partition model_param_specs into (global, per-relative-layer)."""
    g = group_size(cfg)
    specs = model_param_specs(cfg)
    global_specs: Dict[str, ParamSpec] = {}
    rel_specs: Dict[str, ParamSpec] = {}
    for path, s in specs.items():
        m = _LAYER_RE.match(path)
        if not m:
            global_specs[path] = s
            continue
        i, rest = int(m.group(1)), m.group(2)
        if i < g:
            rel_specs[f"{i}.{rest}"] = s
    return global_specs, rel_specs


def stack_params(cfg: ModelConfig, params: Dict[str, jax.Array]):
    """Loop-layout params -> (global, stacked xs) trees."""
    g = group_size(cfg)
    steps = num_scan_steps(cfg)
    glob = {p: v for p, v in params.items() if not _LAYER_RE.match(p)}
    stacked: Dict[str, jax.Array] = {}
    _, rel = split_layer_paths(cfg)
    for rel_path in rel:
        r, rest = rel_path.split(".", 1)
        leaves = [params[f"layers.{int(r) + c * g}.{rest}"]
                  for c in range(steps)]
        stacked[rel_path] = jnp.stack(leaves)
    return glob, stacked


def _view(xs_slice: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Relative-path slice dict -> 'layers.{r}.*' view for _block."""
    return {f"layers.{p}": v for p, v in xs_slice.items()}


def forward_stacked(
    cfg: ModelConfig,
    glob: Dict[str, jax.Array],
    stacked: Dict[str, jax.Array],
    tokens: jax.Array,
    *,
    lin_factory: Optional[Callable] = None,   # (params_view, xs_extra) -> lin
    xs_extra: Optional[Dict] = None,          # extra stacked trees (overlays…)
    prefix_embeds=None,
    frames=None,
    remat: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    moe_capacity_factor: float = 1.25,
    moe_group_size: int = 512,
    carry_sharding=None,   # NamedSharding for the scan carry (seq-parallel
                           # layer-boundary activations: §Perf memory term)
) -> Tuple[jax.Array, jax.Array]:
    del frames  # enc-dec archs use the loop path (period structure differs)
    g = group_size(cfg)
    h = glob["embed.tok"][tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    if carry_sharding is not None:
        h = jax.lax.with_sharding_constraint(h, carry_sharding)
    positions = jnp.arange(h.shape[1])[None, :]

    def body(h, xs):
        params_slice, extra = xs
        view = _view(params_slice)
        lin = lin_factory(view, extra) if lin_factory else \
            default_linear(view)
        aux_total = jnp.float32(0.0)
        for r in range(g):
            h, aux = _block(cfg, view, lin, r, h, positions,
                            q_chunk=q_chunk, kv_chunk=kv_chunk,
                            moe_capacity_factor=moe_capacity_factor,
                            moe_group_size=moe_group_size)
            aux_total = aux_total + aux
        if carry_sharding is not None:
            h = jax.lax.with_sharding_constraint(h, carry_sharding)
        elif remat:
            # seq-parallel layer-boundary activations (SP): the scan saves
            # one carry per step for backward; sharding seq over 'model'
            # cut mamba2 train collectives 28x and temp 12x (§Perf iter 7).
            # Forward-only paths skip it: measured +0.7GB all-gather on
            # prefill with no backward saves to shrink.
            h = hint(h, "dp", "model", None)
        return h, aux_total

    body_fn = jax.checkpoint(body) if remat else body
    h, auxs = jax.lax.scan(body_fn, h, (stacked, xs_extra or {}))
    h = rms_norm(h, glob["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, glob["embed.tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, glob["lm_head"])
    # vocab-sharded logits: the (tokens, vocab) tensor is the largest single
    # activation in every train/prefill cell — keep it on the model axis
    logits = hint(logits, "dp", None, "model")
    return logits, jnp.sum(auxs)


def loss_fn_stacked(cfg, glob, stacked, tokens, labels, *, aux_weight=0.01,
                    **kw):
    from repro.models.common import cross_entropy
    logits, aux = forward_stacked(cfg, glob, stacked, tokens, **kw)
    if kw.get("prefix_embeds") is not None:
        logits = logits[:, kw["prefix_embeds"].shape[1]:]
    return cross_entropy(logits, labels, cfg.vocab_size) + aux_weight * aux


# ---------------------------------------------------------------------------
# Stacked decode (serving)
# ---------------------------------------------------------------------------
def stack_decode_state(cfg: ModelConfig, state: Dict[str, jax.Array]):
    """Loop-layout decode state -> (pos, stacked-cache dict)."""
    g = group_size(cfg)
    steps = num_scan_steps(cfg)
    out: Dict[str, jax.Array] = {}
    seen = set()
    for key in state:
        if key == "pos":
            continue
        kind, i, rest = key.split(".", 2)       # e.g. kv.3.k
        r = int(i) % g
        rel = f"{kind}.{r}.{rest}"
        if rel in seen:
            continue
        seen.add(rel)
        leaves = [state[f"{kind}.{int(i) % g + c * g}.{rest}"]
                  for c in range(steps)]
        out[rel] = jnp.stack(leaves)
    return out


def decode_step_stacked(
    cfg: ModelConfig,
    glob: Dict[str, jax.Array],
    stacked: Dict[str, jax.Array],
    cache: Dict[str, jax.Array],               # stacked caches (steps, ...)
    pos: jax.Array,
    tokens: jax.Array,                         # (b, 1)
    *,
    lin_factory: Optional[Callable] = None,
    xs_extra: Optional[Dict] = None,
):
    """One decode step; returns (logits, new_cache, new_pos, eff_bits)."""
    g = group_size(cfg)
    h = glob["embed.tok"][tokens]
    eff_parts = []

    def body(h, xs):
        params_slice, cache_slice, extra = xs
        view = _view(params_slice)
        lin = lin_factory(view, extra) if lin_factory else \
            default_linear(view)
        # present the cache slice under loop-path names for _loop_decode
        state_view = {"pos": pos}
        for key, v in cache_slice.items():
            kind, r, rest = key.split(".", 2)
            state_view[f"{kind}.{r}.{rest}"] = v
        # run the g layers of this period (mirrors transformer.decode_step)
        _, new_state = _period_decode(cfg, g, view, lin, dict(state_view), h)
        hh = new_state.pop("__h__")
        new_cache_slice = {}
        for key in cache_slice:
            kind, r, rest = key.split(".", 2)
            new_cache_slice[key] = new_state[f"{kind}.{r}.{rest}"]
        if hasattr(lin, "effective_bits") and lin.records:
            num = sum(b.astype(jnp.float32) * s for b, s in lin.records)
            den = sum(s for _, s in lin.records)
            eff = jnp.stack([num, jnp.float32(den)])
        else:
            eff = jnp.zeros((2,), jnp.float32)
        return hh, (new_cache_slice, eff)

    h, (new_cache, effs) = jax.lax.scan(
        body, h, (stacked, cache, xs_extra or {}))
    h = rms_norm(h, glob["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, glob["embed.tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, glob["lm_head"])
    eff_bits = jnp.sum(effs[:, 0]) / jnp.maximum(jnp.sum(effs[:, 1]), 1.0)
    return logits, new_cache, pos + 1, eff_bits


def _period_decode(cfg, g, view, lin, state, h):
    """g layers of the decode body (mirrors transformer.decode_step)."""
    from repro.models import ssm as ssm_mod
    from repro.models.attention import decode_attention, update_kv_cache
    from repro.models.common import apply_rope
    from repro.models.mlp import mlp_forward
    from repro.models.moe import moe_decode_forward
    pos = state["pos"]
    hd = cfg.resolved_head_dim
    new_state = dict(state)
    for r in range(g):
        p = f"layers.{r}"
        resid = h
        x = rms_norm(h, view[f"{p}.ln1"], cfg.norm_eps)
        if cfg.layer_kind(r) == "attn":
            b = x.shape[0]
            q = lin(f"{p}.attn.wq", x, async_input=resid)
            k = lin(f"{p}.attn.wk", x, async_input=resid)
            v = lin(f"{p}.attn.wv", x, async_input=resid)
            q = q.reshape(b, 1, cfg.num_heads, hd)
            k = k.reshape(b, 1, cfg.num_kv_heads, hd)
            v = v.reshape(b, 1, cfg.num_kv_heads, hd)
            ppos = pos[None, None].astype(jnp.float32) * jnp.ones((b, 1))
            q = apply_rope(q, ppos, cfg.rope_theta)
            k = apply_rope(k, ppos, cfg.rope_theta)
            ks = state.get(f"kv.{r}.k_scale")
            vs = state.get(f"kv.{r}.v_scale")
            kz = state.get(f"kv.{r}.k_zero")
            vz = state.get(f"kv.{r}.v_zero")
            kc, vc, ks2, vs2, kz2, vz2 = update_kv_cache(
                state[f"kv.{r}.k"], state[f"kv.{r}.v"], k, v, pos,
                k_scale=ks, v_scale=vs, k_zero=kz, v_zero=vz)
            new_state[f"kv.{r}.k"], new_state[f"kv.{r}.v"] = kc, vc
            if ks2 is not None:
                new_state[f"kv.{r}.k_scale"] = ks2
                new_state[f"kv.{r}.v_scale"] = vs2
                new_state[f"kv.{r}.k_zero"] = kz2
                new_state[f"kv.{r}.v_zero"] = vz2
            o = decode_attention(q, kc, vc, pos + 1,
                                 logit_softcap=cfg.attn_logit_softcap,
                                 k_scale=ks2, v_scale=vs2,
                                 k_zero=kz2, v_zero=vz2)
            h = resid + lin(f"{p}.attn.wo", o.reshape(b, 1, -1))
        else:
            y, conv, st = ssm_mod.ssm_decode_step(
                cfg, lin, view, f"{p}.ssm", x,
                state[f"ssm.{r}.conv"], state[f"ssm.{r}.state"],
                async_input=resid)
            new_state[f"ssm.{r}.conv"] = conv
            new_state[f"ssm.{r}.state"] = st
            h = resid + y
        if cfg.d_ff > 0:
            resid = h
            x = rms_norm(h, view[f"{p}.ln2"], cfg.norm_eps)
            if cfg.layer_is_moe(r):
                y, _ = moe_decode_forward(
                    cfg.mlp_kind, lin, view, f"{p}.moe", x,
                    num_experts=cfg.num_experts,
                    top_k=cfg.experts_per_token)
            else:
                y = mlp_forward(cfg.mlp_kind, lin, f"{p}.mlp", x,
                                async_input=resid)
            h = resid + y
    new_state["__h__"] = h
    return None, new_state
