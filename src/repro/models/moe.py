"""Top-k MoE with GShard-style grouped dispatch (EP-friendly).

Tokens are reshaped into groups; per group, top-k routing assigns a capacity
slot per expert via the cumulative-sum algorithm. Dispatch/combine are
einsums over (group, token, expert, capacity) one-hots so GSPMD can shard
experts over the model axis (EP) and groups over data — all-to-alls appear
automatically in the lowered HLO.

Expert FFN weights live in stacked tensors ``(E, K, N)``; each expert slice is
a DP-LLM precision unit in the serving path (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SWIGLU
from repro.distributed.context import hint


def _router_probs(lin, prefix: str, x: jax.Array, num_experts: int):
    logits = lin(f"{prefix}.router", x).astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1), logits


def _bitserial_matmul_grouped(*args, **kw):
    # deferred like core.dynamic_linear's: keeps model modules importable
    # without dragging the kernels package in at import time
    from repro.kernels.bitserial import bitserial_matmul_grouped
    return bitserial_matmul_grouped(*args, **kw)


def _expert_names(cfg_mlp_kind):
    return (["w_gate", "w_up", "w_down"] if cfg_mlp_kind == SWIGLU
            else ["w_up", "w_down"])


def _probe_grouped(lin, prefix: str, names, x: jax.Array, async_input=None):
    """``{name: (overlay, bits)}`` when EVERY expert unit can stream
    through the grouped kernel, else ``None`` (dense fallback for the
    whole layer — mixing would double-account decisions)."""
    gw = getattr(lin, "grouped_weights", None)
    if gw is None:
        return None
    probed = {name: gw(f"{prefix}.{name}", x, async_input=async_input)
              for name in names}
    if any(h is None for h in probed.values()):
        return None
    return probed


def _grouped_ffn(cfg_mlp_kind, handles, dx, fill, backend):
    """Expert FFN over GShard dispatch WITHOUT materializing weights.

    ``dx`` (E, g, C, d) flattens expert-major into (E·g, C, d) groups —
    one kernel group per (expert, token-group) — with the router's
    ``fill`` (g, E) as the per-group token count. The grouped bit-serial
    kernel streams each group's OWN expert plane stack at that unit's
    selected bits: empty groups (no assigned tokens) and idle slots
    (bits 0) pin their plane DMAs to one resident block and skip the
    MXU — traffic follows ``expert_plane_fetches``'s closed form, and
    no ``(E, K, N)`` dequantized stack ever exists.
    """
    e, ng, cap, d = dx.shape
    gx = hint(dx.reshape(e * ng, cap, d), "model", None, None)
    expert_of = jnp.repeat(jnp.arange(e, dtype=jnp.int32), ng)
    counts = fill.T.reshape(e * ng).astype(jnp.int32)

    def mm(name, xin):
        ov, bits = handles[name]
        b_vec = jnp.broadcast_to(jnp.asarray(bits, jnp.int32), (e * ng,))
        return _bitserial_matmul_grouped(xin, ov, expert_of, b_vec, counts,
                                         backend=backend)

    if cfg_mlp_kind == SWIGLU:
        h = jax.nn.silu(mm("w_gate", gx)) * mm("w_up", gx)
    else:
        h = jnp.square(jax.nn.relu(mm("w_up", gx)))
    ey = mm("w_down", h.astype(dx.dtype))
    return ey.reshape(e, ng, cap, -1).astype(dx.dtype)


def moe_forward(
    cfg_mlp_kind: str,
    lin,
    params,
    prefix: str,
    x: jax.Array,                 # (b, s, d)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 512,
    async_input=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (b,s,d), aux load-balancing loss scalar)."""
    b, s, d = x.shape
    tokens = b * s
    gsz = min(group_size, tokens)
    ngroups = tokens // gsz
    assert tokens % gsz == 0, (tokens, gsz)
    xg = hint(x.reshape(ngroups, gsz, d), "dp", None, None)

    probs, logits = _router_probs(lin, prefix, xg, num_experts)  # (g,t,E)
    probs = hint(probs, "dp", None, None)

    # --- top-k assignment with per-expert capacity ---------------------------
    capacity = max(1, int(gsz * top_k * capacity_factor / num_experts))
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)            # (g,t,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position-in-expert via cumulative sums, one top-k choice at a time
    dispatch = jnp.zeros((ngroups, gsz, num_experts, capacity), jnp.bool_)
    combine = jnp.zeros((ngroups, gsz, num_experts, capacity), jnp.float32)
    fill = jnp.zeros((ngroups, num_experts), jnp.int32)
    for choice in range(top_k):
        onehot = jax.nn.one_hot(gate_idx[..., choice], num_experts,
                                dtype=jnp.int32)                 # (g,t,E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + fill[:, None, :]  # (g,t,E)
        fits = (pos < capacity) & (onehot > 0)
        pos_c = jnp.clip(pos, 0, capacity - 1)
        slot = jax.nn.one_hot(pos_c, capacity, dtype=jnp.float32) * \
            fits[..., None].astype(jnp.float32)                  # (g,t,E,C)
        slot = hint(slot, "dp", None, None, None)
        dispatch = dispatch | (slot > 0)
        combine = combine + slot * gate_vals[..., choice, None, None]
        fill = fill + jnp.sum(onehot * fits.astype(jnp.int32), axis=1)

    # --- dispatch -> expert FFN -> combine -----------------------------------
    wp = getattr(lin, "weights", None)
    fetch = (lambda name: wp(f"{prefix}.{name}", xg)) if wp else \
        (lambda name: params[f"{prefix}.{name}"])
    dispatch = hint(dispatch, None, "dp", None, None)
    combine = hint(combine, None, "dp", None, None)
    dx = jnp.einsum("gtec,gtd->egcd", dispatch.astype(x.dtype), xg)
    dx = hint(dx, "model", "dp", None, None)   # EP: experts on model axis
    handles = _probe_grouped(lin, prefix, _expert_names(cfg_mlp_kind), xg)
    if handles is not None:
        ey = _grouped_ffn(cfg_mlp_kind, handles, dx, fill,
                          getattr(lin, "backend", None))
    elif cfg_mlp_kind == SWIGLU:
        gate = jnp.einsum("egcd,edf->egcf", dx, fetch("w_gate"))
        up = jnp.einsum("egcd,edf->egcf", dx, fetch("w_up"))
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
        ey = jnp.einsum("egcf,efd->egcd", h.astype(x.dtype), fetch("w_down"))
    else:
        up = jnp.einsum("egcd,edf->egcf", dx, fetch("w_up"))
        h = jnp.square(jax.nn.relu(up.astype(jnp.float32)))
        ey = jnp.einsum("egcf,efd->egcd", h.astype(x.dtype), fetch("w_down"))
    ey = hint(ey, "model", "dp", None, None)
    out = jnp.einsum("gtec,egcd->gtd", combine.astype(x.dtype), ey)
    out = hint(out, "dp", None, None)

    # --- aux loss (Switch-style load balancing) ------------------------------
    density = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], num_experts, dtype=jnp.float32),
        axis=1)                                                  # (g,E)
    density_proxy = jnp.mean(probs, axis=1)                      # (g,E)
    aux = jnp.mean(density * density_proxy) * (num_experts ** 2)

    del async_input  # expert inputs are post-dispatch; selector uses sync path
    return out.reshape(b, s, d), aux


class _FixedWeightLin:
    """lin shim for the per-row prefill MoE: router calls pass through to
    the real applier (a raw, stateless matmul); expert-weight fetches
    return the pre-materialized per-row tensors instead of re-deciding."""

    def __init__(self, lin, weights):
        self._lin, self._weights = lin, weights

    def __call__(self, path, x, **kw):
        return self._lin(path, x, **kw)

    def weights(self, path, x, **kw):
        return self._weights[path.rsplit(".", 1)[1]]


class _FixedGroupedLin:
    """lin shim for the grouped per-row prefill MoE: router calls pass
    through; ``grouped_weights`` returns the row's pre-decided
    ``(overlay, bits)`` handle instead of re-deciding — the bits were
    selected (and carry-shifted) ONCE over all M rows outside the vmap,
    so accounting stays per-chunk while the apply rides the row axis."""

    def __init__(self, lin, handles, backend):
        self._lin, self._handles = lin, handles
        self.backend = backend

    def __call__(self, path, x, **kw):
        return self._lin(path, x, **kw)

    def grouped_weights(self, path, x, **kw):
        return self._handles[path.rsplit(".", 1)[1]]


def moe_decode_rows(cfg_mlp_kind, lin, params, prefix, x, *,
                    num_experts: int, top_k: int, async_input=None):
    """M-row prefill MoE: per-row precision decisions, per-row dispatch.

    The applier decides every row's expert-unit precision in one
    vectorized pass (``weights_rows`` — row-invariant pinned units
    materialize once and broadcast), then the single-token dropless
    dispatch is ``vmap``-ed over the M row axis with each row's own
    weights — so row m's routing, capacity math, and expert GEMMs are
    exactly the sequential decode tick's, and the batched prefill stays
    bit-compatible with tick-by-tick decoding.
    """
    b, m, d = x.shape
    names = _expert_names(cfg_mlp_kind)
    handles = _probe_grouped(lin, prefix, names, x, async_input=async_input)
    if handles is not None:
        # grouped path: (M,) bits per unit decided once (with the async
        # one-row-late carry) OUTSIDE the vmap; each row's scalar rides
        # the row axis and the custom_vmap rule folds all M·E·g kernel
        # groups into ONE grouped launch — never an (M, E, K, N) stack
        backend = getattr(lin, "backend", None)
        haxes = {name: (None, 0) for name in names}

        def one_row_g(x_row, h_row):
            y, _ = moe_forward(
                cfg_mlp_kind, _FixedGroupedLin(lin, h_row, backend), params,
                prefix, x_row[:, None, :], num_experts=num_experts,
                top_k=top_k, capacity_factor=float(num_experts) / top_k,
                group_size=b)
            return y[:, 0, :]

        y = jax.vmap(one_row_g, in_axes=(1, haxes), out_axes=1)(x, handles)
        return y, jnp.float32(0.0)
    wfetch = getattr(lin, "weights_rows", None)
    weights, axes = {}, {}
    for name in names:
        w = (wfetch(f"{prefix}.{name}", x, async_input=async_input)
             if wfetch else params[f"{prefix}.{name}"])
        # (M, E, K, N) = per-row dynamic decisions; (E, K, N) = shared
        weights[name], axes[name] = (w, 0) if w.ndim == 4 else (w, None)

    def one_row(x_row, w_row):
        y, _ = moe_forward(
            cfg_mlp_kind, _FixedWeightLin(lin, w_row), params, prefix,
            x_row[:, None, :], num_experts=num_experts, top_k=top_k,
            capacity_factor=float(num_experts) / top_k, group_size=b)
        return y[:, 0, :]

    y = jax.vmap(one_row, in_axes=(1, axes), out_axes=1)(x, weights)
    return y, jnp.float32(0.0)


def _uses_gate(cfg_mlp_kind) -> bool:
    return cfg_mlp_kind == SWIGLU


def moe_decode_forward(cfg_mlp_kind, lin, params, prefix, x, *,
                       num_experts: int, top_k: int):
    """Decode-path MoE: dropless grouped dispatch (single group).

    The naive per-token weight gather materializes (tokens, k, d, f) —
    ~34TB for a dbrx decode step — so decode reuses the GShard dispatch
    with capacity == tokens (dropless: capacity_factor = E/k), which keeps
    the einsums at (E, tokens, d) scale and shards experts over the model
    axis exactly like the training path.
    """
    tokens = x.shape[0] * x.shape[1]
    return moe_forward(
        cfg_mlp_kind, lin, params, prefix, x,
        num_experts=num_experts, top_k=top_k,
        capacity_factor=float(num_experts) / top_k,   # capacity == tokens
        group_size=tokens)
