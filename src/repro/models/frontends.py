"""Modality frontend STUBS (per assignment spec).

``[audio]`` / ``[vlm]`` cells cover the transformer *backbone* only; the
frontend is a stub whose output — precomputed frame/patch embeddings of shape
``(batch, frontend_tokens, d_model)`` — arrives as a model input via
``launch/input_specs.py``. These helpers synthesize deterministic stub
embeddings for smoke tests and benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def stub_frontend_embeddings(cfg: ModelConfig, batch: int,
                             key: jax.Array | None = None,
                             dtype=jnp.float32) -> jax.Array:
    """Deterministic stand-in for conv-audio / ViT-patch frontend output."""
    n = cfg.frontend_tokens
    if n <= 0:
        raise ValueError(f"{cfg.name} has no frontend")
    if key is None:
        key = jax.random.PRNGKey(hash(cfg.name) % (2 ** 31))
    x = jax.random.normal(key, (batch, n, cfg.d_model)) * 0.02
    return x.astype(dtype)


def frontend_input_name(cfg: ModelConfig) -> str | None:
    if cfg.frontend == "audio_stub":
        return "frames"
    if cfg.frontend == "vision_stub":
        return "prefix_embeds"
    return None
