"""Mamba2 / SSD layers: chunked training scan + O(1)-state decode.

Implements the minimal SSD (state-space duality) formulation of
arXiv:2405.21060: intra-chunk quadratic (attention-like) term + inter-chunk
linear recurrence, in pure JAX (``lax.scan`` over chunks) so GSPMD shards
(batch → data, heads → model) without custom collectives.

Decode keeps a constant-size recurrent state (b, H, P, N) + conv tail — this
is what makes ``long_500k`` a constant-memory cell for SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import rms_norm


def ssm_dims(cfg: ModelConfig) -> Dict[str, int]:
    d_in = cfg.ssm_d_inner
    nh = cfg.ssm_nheads
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    d_xbc = d_in + 2 * g * n
    return dict(
        d_inner=d_in, nheads=nh, ngroups=g, d_state=n,
        d_xbc=d_xbc,
        # in_proj packs [z | x | B | C | dt]
        d_in_proj=2 * d_in + 2 * g * n + nh,
    )


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d = ssm_dims(cfg)
    z, x, bc, dt = jnp.split(
        zxbcdt,
        [d["d_inner"], 2 * d["d_inner"],
         2 * d["d_inner"] + 2 * d["ngroups"] * d["d_state"]],
        axis=-1)
    return z, x, bc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: xbc (b, s, C), w (width, C), b (C,)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + xbc.shape[1], :].astype(jnp.float32) * w[i]
    return jax.nn.silu(out + b).astype(xbc.dtype)


def ssd_chunked(
    x: jax.Array,        # (b, s, H, P)  — x * dt already applied by caller? no: raw
    dt: jax.Array,       # (b, s, H)     — softplus'd step sizes
    a_log: jax.Array,    # (H,)          — A = -exp(a_log)
    b_mat: jax.Array,    # (b, s, G, N)
    c_mat: jax.Array,    # (b, s, G, N)
    *,
    chunk: int = 128,
) -> jax.Array:
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[-2:]
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,) negative
    da = dt.astype(jnp.float32) * a                          # (b,s,H)
    xbar = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # chunked views
    da_c = da.reshape(bsz, nc, chunk, h)
    x_c = xbar.reshape(bsz, nc, chunk, h, p)
    b_c = b_mat.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    c_c = c_mat.reshape(bsz, nc, chunk, g, n).astype(jnp.float32)
    bh_c = jnp.repeat(b_c, rep, axis=3)                      # (b,c,q,H,N)
    ch_c = jnp.repeat(c_c, rep, axis=3)

    cum = jnp.cumsum(da_c, axis=2)                           # (b,c,q,H)

    # intra-chunk (quadratic) term
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (b,c,q,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    att = jnp.einsum("bcqhn,bcjhn->bcqjh", ch_c, bh_c) * l_mat
    y_diag = jnp.einsum("bcqjh,bcjhp->bcqhp", att, x_c)

    # per-chunk boundary states
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)          # (b,c,q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp",
                        bh_c, decay_states, x_c)             # (b,c,H,N,P)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (b,c,H)

    def step(s_prev, inp):
        st, dec = inp                                        # (b,H,N,P), (b,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, s_prevs = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                    # (b,c,H,N,P)

    # off-chunk contribution
    out_decay = jnp.exp(cum)                                 # (b,c,q,H)
    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                       ch_c, s_prevs, out_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y


def ssm_forward(
    cfg: ModelConfig,
    lin,
    params,
    prefix: str,
    x_in: jax.Array,     # (b, s, d_model)
    *,
    async_input=None,
    chunk: int = 128,
) -> jax.Array:
    d = ssm_dims(cfg)
    zxbcdt = lin(f"{prefix}.in_proj", x_in, async_input=async_input)
    z, x, bc, dt = _split_in_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, bc], axis=-1)
    xbc = _causal_conv(xbc, params[f"{prefix}.conv_w"],
                       params[f"{prefix}.conv_b"])
    x, bc = xbc[..., :d["d_inner"]], xbc[..., d["d_inner"]:]
    gn = d["ngroups"] * d["d_state"]
    b_mat = bc[..., :gn].reshape(*bc.shape[:-1], d["ngroups"], d["d_state"])
    c_mat = bc[..., gn:].reshape(*bc.shape[:-1], d["ngroups"], d["d_state"])

    bsz, s, _ = x.shape
    xh = x.reshape(bsz, s, d["nheads"], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params[f"{prefix}.dt_bias"])
    y = ssd_chunked(xh, dt, params[f"{prefix}.a_log"], b_mat, c_mat,
                    chunk=chunk)
    y = y + params[f"{prefix}.d_skip"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d["d_inner"]).astype(x_in.dtype)

    # gated RMSNorm (mamba2)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params[f"{prefix}.norm_g"], cfg.norm_eps)
    return lin(f"{prefix}.out_proj", y)


def ssm_decode_step(
    cfg: ModelConfig,
    lin,
    params,
    prefix: str,
    x_in: jax.Array,       # (b, 1, d_model)
    conv_state: jax.Array,  # (b, width-1, d_xbc)
    ssm_state: jax.Array,   # (b, H, N, P) float32
    *,
    async_input=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One recurrent step; returns (y, new_conv_state, new_ssm_state)."""
    d = ssm_dims(cfg)
    zxbcdt = lin(f"{prefix}.in_proj", x_in, async_input=async_input)
    z, x, bc, dt = _split_in_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([x, bc], axis=-1)[:, 0]        # (b, d_xbc)

    # conv over [state ; new]
    w = params[f"{prefix}.conv_w"]
    width = w.shape[0]
    window = jnp.concatenate(
        [conv_state, xbc_new[:, None, :]], axis=1)           # (b, width, C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)
    xbc = jax.nn.silu(out + params[f"{prefix}.conv_b"])      # (b, C) f32
    new_conv = window[:, 1:width, :]

    x = xbc[:, :d["d_inner"]]
    gn = d["ngroups"] * d["d_state"]
    b_mat = xbc[:, d["d_inner"]:d["d_inner"] + gn].reshape(
        -1, d["ngroups"], d["d_state"])
    c_mat = xbc[:, d["d_inner"] + gn:].reshape(
        -1, d["ngroups"], d["d_state"])
    rep = d["nheads"] // d["ngroups"]
    bh = jnp.repeat(b_mat, rep, axis=1)                      # (b,H,N)
    ch = jnp.repeat(c_mat, rep, axis=1)

    xh = x.reshape(-1, d["nheads"], d["d_inner"] // d["nheads"])  # (b,H,P)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                         params[f"{prefix}.dt_bias"])        # (b,H)
    a = -jnp.exp(params[f"{prefix}.a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                  # (b,H)
    # state: (b,H,N,P) <- decay*state + dt * B ⊗ x
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, bh, xh)
    new_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_state)
    y = y + params[f"{prefix}.d_skip"][:, None] * xh
    y = y.reshape(-1, 1, d["d_inner"]).astype(x_in.dtype)

    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params[f"{prefix}.norm_g"], cfg.norm_eps)
    return lin(f"{prefix}.out_proj", y), new_conv, new_state


def ssm_decode_rows(
    cfg: ModelConfig,
    lin,
    params,
    prefix: str,
    x_in: jax.Array,        # (b, M, d_model) — M consecutive token rows
    conv_state: jax.Array,  # (b, width-1, d_xbc)
    ssm_state: jax.Array,   # (b, H, N, P) float32
    *,
    valid=None,             # (M,) bool — rows ≥ the true prompt tail are
                            # pads: their conv/state updates are gated off
                            # so the carried state equals the sequential
                            # tick-by-tick state after the valid prefix
    async_input=None,
    snapshots: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """M-row prefill step: batched projections + sequential recurrence.

    The in/out projections (the precision units) run as ONE batched
    launch over all M rows; only the O(M · state) conv/SSM recurrence is
    a ``lax.scan`` — per row it applies exactly the
    :func:`ssm_decode_step` update, so the carried state and every row's
    output are the same as M sequential decode ticks.

    ``snapshots=True`` additionally returns the carried (conv, state)
    AFTER each row — ``(M, ...)``-leading stacks. Speculative decoding's
    accept/reject rolls the recurrence back to the last accepted row by
    selecting index ``n_acc`` of these, which is bit-identical to having
    stopped the sequential ticks there.
    """
    d = ssm_dims(cfg)
    bsz, m, _ = x_in.shape
    if valid is None:
        valid = jnp.ones((m,), bool)
    zxbcdt = lin(f"{prefix}.in_proj", x_in, async_input=async_input)
    z, x, bc, dt = _split_in_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([x, bc], axis=-1)              # (b, M, d_xbc)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params[f"{prefix}.dt_bias"])        # (b, M, H)
    a = -jnp.exp(params[f"{prefix}.a_log"].astype(jnp.float32))
    w = params[f"{prefix}.conv_w"]
    width = w.shape[0]
    rep = d["nheads"] // d["ngroups"]
    gn = d["ngroups"] * d["d_state"]

    def step(carry, xs):
        conv, st = carry
        xbc_m, dt_m, ok = xs                 # (b, d_xbc), (b, H), scalar
        window = jnp.concatenate([conv, xbc_m[:, None, :]], axis=1)
        out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)
        xbc = jax.nn.silu(out + params[f"{prefix}.conv_b"])
        xh = xbc[:, :d["d_inner"]].reshape(
            -1, d["nheads"], d["d_inner"] // d["nheads"])
        bh = jnp.repeat(xbc[:, d["d_inner"]:d["d_inner"] + gn].reshape(
            -1, d["ngroups"], d["d_state"]), rep, axis=1)
        ch = jnp.repeat(xbc[:, d["d_inner"] + gn:].reshape(
            -1, d["ngroups"], d["d_state"]), rep, axis=1)
        decay = jnp.exp(dt_m * a)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dt_m, bh, xh)
        new_st = st * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", ch, new_st)
        y = y + params[f"{prefix}.d_skip"][:, None] * xh
        conv = jnp.where(ok, window[:, 1:width, :], conv)
        st = jnp.where(ok, new_st, st)
        out = y.reshape(-1, d["d_inner"])
        if snapshots:
            return (conv, st), (out, conv, st)
        return (conv, st), out

    (new_conv, new_state), ys = jax.lax.scan(
        step, (conv_state, ssm_state),
        (jnp.moveaxis(xbc_new, 1, 0), jnp.moveaxis(dt, 1, 0), valid))
    snaps = None
    if snapshots:
        ys, convs, states = ys
        snaps = (convs, states)                  # (M, b, ...) per-row
    y = jnp.moveaxis(ys, 0, 1).astype(x_in.dtype)            # (b, M, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params[f"{prefix}.norm_g"], cfg.norm_eps)
    y = lin(f"{prefix}.out_proj", y)
    if snapshots:
        return y, new_conv, new_state, snaps
    return y, new_conv, new_state
