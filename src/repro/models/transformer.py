"""Config-driven transformer assembly.

One code path builds every assigned architecture: dense GQA decoders,
squared-ReLU variants, MoE layers, Mamba2/SSD mixers, jamba-style hybrid
interleaves, enc-dec (whisper) with cross-attention, and VLM prefix
embeddings. The *linear applier* ``lin(path, x, async_input=...)`` is
pluggable: plain matmul for training, DP-LLM dynamic-precision for serving.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import hint
from repro.models import ssm as ssm_mod
from repro.models.attention import (decode_attention,
                                    decode_attention_planes,
                                    decode_attention_pool, flash_attention,
                                    update_kv_cache, update_kv_planes,
                                    update_kv_pool)
from repro.models.common import (CONV, EMBED, EXPERTS, FFN, HEADS, KV_HEADS,
                                 NOSHARD, SSM_HEADS, SSM_INNER, VOCAB,
                                 LinearUnit, ParamSpec, Params, SpecTable,
                                 apply_rope, cross_entropy, default_linear,
                                 init_params, logical_axes, rms_norm)
from repro.models.mlp import mlp_forward, mlp_param_dims
from repro.models.moe import (moe_decode_forward, moe_decode_rows,
                              moe_forward)

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _attn_specs(prefix: str, cfg: ModelConfig) -> List[ParamSpec]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    return [
        ParamSpec(f"{prefix}.wq", (d, nq), (EMBED, HEADS)),
        ParamSpec(f"{prefix}.wk", (d, nkv), (EMBED, KV_HEADS)),
        ParamSpec(f"{prefix}.wv", (d, nkv), (EMBED, KV_HEADS)),
        ParamSpec(f"{prefix}.wo", (nq, d), (HEADS, EMBED)),
    ]


def _mlp_specs(prefix: str, cfg: ModelConfig) -> List[ParamSpec]:
    specs = []
    for name, (k, n) in mlp_param_dims(cfg.mlp_kind, cfg.d_model, cfg.d_ff):
        ax = (EMBED, FFN) if k == cfg.d_model else (FFN, EMBED)
        specs.append(ParamSpec(f"{prefix}.{name}", (k, n), ax))
    return specs


def _moe_specs(prefix: str, cfg: ModelConfig) -> List[ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    specs = [ParamSpec(f"{prefix}.router", (d, e), (EMBED, NOSHARD),
                       init="small_normal")]
    for name, (k, n) in mlp_param_dims(cfg.mlp_kind, d, f):
        ax = (EXPERTS, EMBED, FFN) if k == d else (EXPERTS, FFN, EMBED)
        specs.append(ParamSpec(f"{prefix}.{name}", (e, k, n), ax, fan_in=k))
    return specs


def _ssm_specs(prefix: str, cfg: ModelConfig) -> List[ParamSpec]:
    dd = ssm_mod.ssm_dims(cfg)
    d = cfg.d_model
    return [
        ParamSpec(f"{prefix}.in_proj", (d, dd["d_in_proj"]),
                  (EMBED, SSM_INNER)),
        ParamSpec(f"{prefix}.out_proj", (dd["d_inner"], d),
                  (SSM_INNER, EMBED)),
        ParamSpec(f"{prefix}.conv_w", (cfg.ssm_conv_width, dd["d_xbc"]),
                  (CONV, SSM_INNER), init="small_normal"),
        ParamSpec(f"{prefix}.conv_b", (dd["d_xbc"],), (SSM_INNER,),
                  init="zeros"),
        ParamSpec(f"{prefix}.a_log", (dd["nheads"],), (SSM_HEADS,),
                  init="zeros"),
        ParamSpec(f"{prefix}.dt_bias", (dd["nheads"],), (SSM_HEADS,),
                  init="zeros"),
        ParamSpec(f"{prefix}.d_skip", (dd["nheads"],), (SSM_HEADS,),
                  init="ones"),
        ParamSpec(f"{prefix}.norm_g", (dd["d_inner"],), (SSM_INNER,),
                  init="ones"),
    ]


def model_param_specs(cfg: ModelConfig) -> SpecTable:
    specs: List[ParamSpec] = [
        ParamSpec("embed.tok", (cfg.padded_vocab_size, cfg.d_model),
                  (VOCAB, EMBED), init="small_normal"),
        ParamSpec("final_norm", (cfg.d_model,), (NOSHARD,), init="ones"),
    ]
    if not cfg.tie_embeddings:
        specs.append(ParamSpec("lm_head",
                               (cfg.d_model, cfg.padded_vocab_size),
                               (EMBED, VOCAB)))
    for i in range(cfg.num_layers):
        p = f"layers.{i}"
        kind = cfg.layer_kind(i)
        specs.append(ParamSpec(f"{p}.ln1", (cfg.d_model,), (NOSHARD,),
                               init="ones"))
        if kind == "attn":
            specs += _attn_specs(f"{p}.attn", cfg)
        else:
            specs += _ssm_specs(f"{p}.ssm", cfg)
        if cfg.cross_attention:
            specs.append(ParamSpec(f"{p}.ln_x", (cfg.d_model,), (NOSHARD,),
                                   init="ones"))
            specs += _attn_specs(f"{p}.xattn", cfg)
        if cfg.d_ff > 0:
            specs.append(ParamSpec(f"{p}.ln2", (cfg.d_model,), (NOSHARD,),
                                   init="ones"))
            if cfg.layer_is_moe(i):
                specs += _moe_specs(f"{p}.moe", cfg)
            else:
                specs += _mlp_specs(f"{p}.mlp", cfg)
    if cfg.encoder_layers:
        for i in range(cfg.encoder_layers):
            p = f"enc.layers.{i}"
            specs.append(ParamSpec(f"{p}.ln1", (cfg.d_model,), (NOSHARD,),
                                   init="ones"))
            specs += _attn_specs(f"{p}.attn", cfg)
            specs.append(ParamSpec(f"{p}.ln2", (cfg.d_model,), (NOSHARD,),
                                   init="ones"))
            specs += _mlp_specs(f"{p}.mlp", cfg)
        specs.append(ParamSpec("enc.final_norm", (cfg.d_model,), (NOSHARD,),
                               init="ones"))
    return {s.path: s for s in specs}


# ---------------------------------------------------------------------------
# DP-LLM precision units
# ---------------------------------------------------------------------------
def linear_units(cfg: ModelConfig) -> List[LinearUnit]:
    """Quantizable linear projections = the paper's per-'layer' units."""
    units: List[LinearUnit] = []
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads * hd, cfg.num_kv_heads * hd

    def attn_units(p: str, dynamic_qkv: bool = True):
        return [
            LinearUnit(f"{p}.wq", "q", d, nq, dynamic_qkv),
            LinearUnit(f"{p}.wk", "k", d, nkv, dynamic_qkv),
            LinearUnit(f"{p}.wv", "v", d, nkv, dynamic_qkv),
            LinearUnit(f"{p}.wo", "o", nq, d, False),
        ]

    def mlp_units(p: str):
        out = []
        for name, (k, n) in mlp_param_dims(cfg.mlp_kind, d, cfg.d_ff):
            kind = name.split("_")[1]
            out.append(LinearUnit(f"{p}.{name}", kind, k, n,
                                  kind in ("gate", "up")))
        return out

    for i in range(cfg.num_layers):
        p = f"layers.{i}"
        if cfg.layer_kind(i) == "attn":
            units += attn_units(f"{p}.attn")
        else:
            dd = ssm_mod.ssm_dims(cfg)
            units += [
                LinearUnit(f"{p}.ssm.in_proj", "ssm_in", d,
                           dd["d_in_proj"], True),
                LinearUnit(f"{p}.ssm.out_proj", "ssm_out", dd["d_inner"],
                           d, False),
            ]
        if cfg.cross_attention:
            units += attn_units(f"{p}.xattn")
        if cfg.d_ff > 0:
            if cfg.layer_is_moe(i):
                # experts share one precision decision per projection
                for name, (k, n) in mlp_param_dims(cfg.mlp_kind, d, cfg.d_ff):
                    kind = "expert_" + name.split("_")[1]
                    units.append(LinearUnit(f"{p}.moe.{name}", kind, k, n,
                                            False))
            else:
                units += mlp_units(f"{p}.mlp")
    # encoder units are prefill-only (highest precision, paper §6.1) — they
    # are quantizable but never dynamic; exclude from the runtime unit list.
    return units


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _block(cfg: ModelConfig, params: Params, lin, i: int, h: jax.Array,
           positions: jax.Array, *, q_chunk: int, kv_chunk: int,
           enc_out: Optional[jax.Array] = None,
           moe_capacity_factor: float = 1.25,
           moe_group_size: int = 512) -> Tuple[jax.Array, jax.Array]:
    p = f"layers.{i}"
    resid = h
    x = rms_norm(h, params[f"{p}.ln1"], cfg.norm_eps)
    if cfg.layer_kind(i) == "attn":
        hd = cfg.resolved_head_dim
        q = lin(f"{p}.attn.wq", x, async_input=resid)
        k = lin(f"{p}.attn.wk", x, async_input=resid)
        v = lin(f"{p}.attn.wv", x, async_input=resid)
        b, s, _ = x.shape
        q = q.reshape(b, s, cfg.num_heads, hd)
        k = k.reshape(b, s, cfg.num_kv_heads, hd)
        v = v.reshape(b, s, cfg.num_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = flash_attention(q, k, v, causal=True, q_chunk=q_chunk,
                            kv_chunk=kv_chunk,
                            logit_softcap=cfg.attn_logit_softcap)
        h = resid + lin(f"{p}.attn.wo", o.reshape(b, s, -1))
    else:
        h = resid + ssm_mod.ssm_forward(cfg, lin, params, f"{p}.ssm", x,
                                        async_input=resid)
    if cfg.cross_attention and enc_out is not None:
        resid = h
        x = rms_norm(h, params[f"{p}.ln_x"], cfg.norm_eps)
        hd = cfg.resolved_head_dim
        b, s, _ = x.shape
        q = lin(f"{p}.xattn.wq", x, async_input=resid)
        k = lin(f"{p}.xattn.wk", enc_out)
        v = lin(f"{p}.xattn.wv", enc_out)
        q = q.reshape(b, s, cfg.num_heads, hd)
        k = k.reshape(b, enc_out.shape[1], cfg.num_kv_heads, hd)
        v = v.reshape(b, enc_out.shape[1], cfg.num_kv_heads, hd)
        o = flash_attention(q, k, v, causal=False, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
        h = resid + lin(f"{p}.xattn.wo", o.reshape(b, s, -1))
    aux = jnp.float32(0.0)
    if cfg.d_ff > 0:
        resid = h
        x = rms_norm(h, params[f"{p}.ln2"], cfg.norm_eps)
        if cfg.layer_is_moe(i):
            y, aux = moe_forward(cfg.mlp_kind, lin, params, f"{p}.moe", x,
                                 num_experts=cfg.num_experts,
                                 top_k=cfg.experts_per_token,
                                 capacity_factor=moe_capacity_factor,
                                 group_size=moe_group_size)
        else:
            y = mlp_forward(cfg.mlp_kind, lin, f"{p}.mlp", x,
                            async_input=resid)
        h = resid + y
    return h, aux


def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           *, lin=None, q_chunk: int = 1024, kv_chunk: int = 1024):
    """Encoder stack over precomputed frontend embeddings (b, f, d)."""
    lin = lin or default_linear(params)
    h = frames
    positions = jnp.arange(frames.shape[1])[None, :]
    for i in range(cfg.encoder_layers):
        p = f"enc.layers.{i}"
        resid = h
        x = rms_norm(h, params[f"{p}.ln1"], cfg.norm_eps)
        hd = cfg.resolved_head_dim
        b, s, _ = x.shape
        q = lin(f"{p}.attn.wq", x).reshape(b, s, cfg.num_heads, hd)
        k = lin(f"{p}.attn.wk", x).reshape(b, s, cfg.num_kv_heads, hd)
        v = lin(f"{p}.attn.wv", x).reshape(b, s, cfg.num_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = flash_attention(q, k, v, causal=False, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
        h = resid + lin(f"{p}.attn.wo", o.reshape(b, s, -1))
        resid = h
        x = rms_norm(h, params[f"{p}.ln2"], cfg.norm_eps)
        h = resid + mlp_forward(cfg.mlp_kind, lin, f"{p}.mlp", x)
    return rms_norm(h, params["enc.final_norm"], cfg.norm_eps)


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,                       # (b, s) int32
    *,
    lin: Optional[Callable] = None,
    prefix_embeds: Optional[jax.Array] = None,   # (b, n, d) VLM stub
    frames: Optional[jax.Array] = None,          # (b, f, d) audio stub
    remat: bool = False,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    moe_capacity_factor: float = 1.25,
    moe_group_size: int = 512,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (b, s_total, vocab_padded), aux_loss scalar)."""
    lin = lin or default_linear(params)
    h = params["embed.tok"][tokens]
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    positions = jnp.arange(h.shape[1])[None, :]
    enc_out = None
    if cfg.encoder_layers and frames is not None:
        enc_out = encode(cfg, params, frames, lin=lin, q_chunk=q_chunk,
                         kv_chunk=kv_chunk)

    aux_total = jnp.float32(0.0)

    def run_block(i, h):
        fn = lambda hh: _block(cfg, params, lin, i, hh, positions,
                               q_chunk=q_chunk, kv_chunk=kv_chunk,
                               enc_out=enc_out,
                               moe_capacity_factor=moe_capacity_factor,
                               moe_group_size=moe_group_size)
        if remat:
            fn = jax.checkpoint(fn)
        return fn(h)

    for i in range(cfg.num_layers):
        h, aux = run_block(i, h)
        aux_total = aux_total + aux

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed.tok"])
    else:
        logits = lin("lm_head", h)
    logits = hint(logits, "dp", None, "model")
    return logits, aux_total


# ---------------------------------------------------------------------------
# Decode (single new token, batched)
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16,
                      kv_dtype=None,
                      kv_format: str = "dense",
                      kv_plane_bits: int = 8) -> Dict[str, jax.Array]:
    """Decode-state pytree. ``kv_format="overlay"`` stores attention KV
    as full-``kv_plane_bits`` bitplane stacks (``kv.{i}.k_planes``
    (batch, B, max_len, hkv, ceil(hd/32)) int32 + per-row scale/zero)
    instead of dense ``kv.{i}.k`` rows — the write side of the
    dynamic-precision cache; read precision is a per-tick decision."""
    if kv_format not in ("dense", "overlay"):
        raise ValueError(f"unknown kv_format {kv_format!r}")
    kv_dtype = kv_dtype or dtype
    int8_kv = kv_dtype == jnp.int8
    state: Dict[str, jax.Array] = {"pos": jnp.zeros((), jnp.int32)}
    hd = cfg.resolved_head_dim
    dw = -(-hd // 32)
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "attn":
            if kv_format == "overlay":
                for side in ("k", "v"):
                    state[f"kv.{i}.{side}_planes"] = jnp.zeros(
                        (batch, kv_plane_bits, max_len,
                         cfg.num_kv_heads, dw), jnp.int32)
                    state[f"kv.{i}.{side}_scale"] = jnp.zeros(
                        (batch, max_len, cfg.num_kv_heads, 1),
                        jnp.float32)
                    state[f"kv.{i}.{side}_zero"] = jnp.zeros(
                        (batch, max_len, cfg.num_kv_heads, 1),
                        jnp.float32)
            else:
                state[f"kv.{i}.k"] = jnp.zeros(
                    (batch, max_len, cfg.num_kv_heads, hd), kv_dtype)
                state[f"kv.{i}.v"] = jnp.zeros(
                    (batch, max_len, cfg.num_kv_heads, hd), kv_dtype)
                if int8_kv:
                    for side in ("k", "v"):
                        state[f"kv.{i}.{side}_scale"] = jnp.zeros(
                            (batch, max_len, cfg.num_kv_heads, 1),
                            jnp.float32)
                        state[f"kv.{i}.{side}_zero"] = jnp.zeros(
                            (batch, max_len, cfg.num_kv_heads, 1),
                            jnp.float32)
        else:
            dd = ssm_mod.ssm_dims(cfg)
            state[f"ssm.{i}.conv"] = jnp.zeros(
                (batch, cfg.ssm_conv_width - 1, dd["d_xbc"]), dtype)
            state[f"ssm.{i}.state"] = jnp.zeros(
                (batch, dd["nheads"], dd["d_state"],
                 dd["d_inner"] // dd["nheads"]), jnp.float32)
        if cfg.cross_attention:
            # cross K/V computed once from encoder output at session start
            ft = cfg.frontend_tokens or 1
            state[f"xkv.{i}.k"] = jnp.zeros(
                (batch, ft, cfg.num_kv_heads, hd), dtype)
            state[f"xkv.{i}.v"] = jnp.zeros(
                (batch, ft, cfg.num_kv_heads, hd), dtype)
    return state


def init_paged_pool(cfg: ModelConfig, n_pages: int, page_len: int,
                    kv_plane_bits: int = 8) -> Dict[str, jax.Array]:
    """The SHARED paged KV plane pool: per attention layer
    ``pool.{i}.{k,v}_planes`` (n_pages, B, page_len, hkv, ceil(hd/32))
    int32 plus ``_scale``/``_zero`` (n_pages, page_len, hkv, 1) f32.
    No slot axis — every slot's pages live here, addressed through its
    ``page_table``. Page 0 is the reserved trash/pin page."""
    if n_pages < 2:
        raise ValueError("paged pool needs >= 2 pages (page 0 is trash)")
    hd = cfg.resolved_head_dim
    dw = -(-hd // 32)
    pool: Dict[str, jax.Array] = {}
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) != "attn":
            continue
        for side in ("k", "v"):
            pool[f"pool.{i}.{side}_planes"] = jnp.zeros(
                (n_pages, kv_plane_bits, page_len, cfg.num_kv_heads, dw),
                jnp.int32)
            pool[f"pool.{i}.{side}_scale"] = jnp.zeros(
                (n_pages, page_len, cfg.num_kv_heads, 1), jnp.float32)
            pool[f"pool.{i}.{side}_zero"] = jnp.zeros(
                (n_pages, page_len, cfg.num_kv_heads, 1), jnp.float32)
    return pool


def init_paged_state(cfg: ModelConfig, batch: int, max_len: int,
                     page_len: int, dtype=jnp.bfloat16
                     ) -> Dict[str, jax.Array]:
    """Per-slot decode state for the PAGED cache: the bucketed ``kv.*``
    arrays are replaced by a ``page_table`` (batch, ceil(max_len /
    page_len)) int32 of physical page ids (0 = unallocated → trash
    page); SSM/xkv/pos leaves are identical to the bucketed state.
    Merge with :func:`init_paged_pool`'s leaves to form the state dict
    ``decode_step`` consumes."""
    proto = init_decode_state(cfg, batch, 1, dtype=dtype)
    state = {k: v for k, v in proto.items() if not k.startswith("kv.")}
    state["page_table"] = jnp.zeros(
        (batch, -(-int(max_len) // int(page_len))), jnp.int32)
    return state


def decode_step(
    cfg: ModelConfig,
    params: Params,
    state: Dict[str, jax.Array],
    tokens: jax.Array,                       # (b, M) int32; M=1 is decode
    *,
    lin: Optional[Callable] = None,
    n_valid: Optional[jax.Array] = None,     # prefill: rows >= n_valid are
                                             # pads (bucketed prompt tail)
    row_states: bool = False,
    kv_bits: Optional[jax.Array] = None,     # overlay KV: per-attn-layer
                                             # read precisions; None -> B
    kv_read: str = "plane",                  # "plane" | "dense" (oracle)
    kv_backend: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode tick (M=1) or one batched prefill launch (M>1).

    Returns (logits (b, M, vocab_padded), new_state). The M>1 path is the
    prefill stage's decode cell: M consecutive token rows run through the
    SAME per-layer math as M sequential ticks — KV rows are written at
    ``pos..pos+M-1`` and each attention row masks to its own causal
    prefix, the SSM recurrence scans the rows sequentially (pad rows
    gated out of the carried state), and MoE dispatch is vmapped per row
    — so a prefill launch is tick-by-tick-equivalent while issuing one
    launch instead of M. ``new_state["pos"]`` advances by ``n_valid``
    (default M): pad rows beyond the true prompt leave garbage KV past
    ``pos + n_valid`` that later ticks overwrite before ever attending.

    ``row_states=True`` (the speculative VERIFY launch) returns a third
    output: per-row SSM carry snapshots ``{"ssm.i.conv"/"ssm.i.state":
    (M, b, ...)}`` — entry m is the recurrent state after consuming row
    m, which accept/reject selects to roll back to the last accepted
    row. KV needs no snapshot: rejected rows are zeroed at the stage
    boundary (``serving.kv_cache.rollback_decode_state``). The M-row
    cells are used even at M=1 so the rows-mode applier composes.
    """
    lin = lin or default_linear(params)
    pos = state["pos"]
    h = params["embed.tok"][tokens]
    new_state = dict(state)
    snaps: Dict[str, jax.Array] = {}
    hd = cfg.resolved_head_dim
    attn_idx = 0
    m = tokens.shape[1]
    rows_cells = row_states or m > 1
    if n_valid is None:
        n_valid = jnp.int32(m)
    valid = jnp.arange(m) < n_valid

    for i in range(cfg.num_layers):
        p = f"layers.{i}"
        resid = h
        x = rms_norm(h, params[f"{p}.ln1"], cfg.norm_eps)
        if cfg.layer_kind(i) == "attn":
            b = x.shape[0]
            q = lin(f"{p}.attn.wq", x, async_input=resid)
            k = lin(f"{p}.attn.wk", x, async_input=resid)
            v = lin(f"{p}.attn.wv", x, async_input=resid)
            q = q.reshape(b, m, cfg.num_heads, hd)
            k = k.reshape(b, m, cfg.num_kv_heads, hd)
            v = v.reshape(b, m, cfg.num_kv_heads, hd)
            if m == 1:
                ppos = pos[None, None].astype(jnp.float32) * jnp.ones((b, 1))
                lens = pos + 1
            else:
                ppos = (pos + jnp.arange(m))[None, :].astype(jnp.float32) \
                    * jnp.ones((b, 1))
                lens = pos + 1 + jnp.arange(m)       # per-row causal prefix
            q = apply_rope(q, ppos, cfg.rope_theta)
            k = apply_rope(k, ppos, cfg.rope_theta)
            pool_kp0 = state.get(f"pool.{i}.k_planes")
            kp0 = state.get(f"kv.{i}.k_planes")
            if pool_kp0 is not None:
                # paged overlay cache: the rows live in the SHARED plane
                # pool; this slot writes/reads its own pages through its
                # page table (unallocated entries hit the trash page)
                bits_b = pool_kp0.shape[1]
                ptab = state["page_table"]
                pk, pks, pkz, pv, pvs, pvz = update_kv_pool(
                    pool_kp0, state[f"pool.{i}.k_scale"],
                    state[f"pool.{i}.k_zero"],
                    state[f"pool.{i}.v_planes"],
                    state[f"pool.{i}.v_scale"],
                    state[f"pool.{i}.v_zero"], ptab, k, v, pos,
                    bits=bits_b)
                new_state[f"pool.{i}.k_planes"] = pk
                new_state[f"pool.{i}.k_scale"] = pks
                new_state[f"pool.{i}.k_zero"] = pkz
                new_state[f"pool.{i}.v_planes"] = pv
                new_state[f"pool.{i}.v_scale"] = pvs
                new_state[f"pool.{i}.v_zero"] = pvz
                layer_kv = None if kv_bits is None else kv_bits[attn_idx]
                o = decode_attention_pool(
                    q, pk, pks, pkz, pv, pvs, pvz, ptab, lens,
                    bits=bits_b, kv_bits=layer_kv,
                    logit_softcap=cfg.attn_logit_softcap, read=kv_read,
                    backend=kv_backend)
            elif kp0 is not None:
                # overlay cache: write the FULL plane stack, read at
                # this tick's planner-assigned per-layer precision
                bits_b = kp0.shape[1]
                kp, ks2, kz2, vp, vs2, vz2 = update_kv_planes(
                    kp0, state[f"kv.{i}.k_scale"],
                    state[f"kv.{i}.k_zero"], state[f"kv.{i}.v_planes"],
                    state[f"kv.{i}.v_scale"], state[f"kv.{i}.v_zero"],
                    k, v, pos, bits=bits_b)
                new_state[f"kv.{i}.k_planes"] = kp
                new_state[f"kv.{i}.k_scale"] = ks2
                new_state[f"kv.{i}.k_zero"] = kz2
                new_state[f"kv.{i}.v_planes"] = vp
                new_state[f"kv.{i}.v_scale"] = vs2
                new_state[f"kv.{i}.v_zero"] = vz2
                layer_kv = None if kv_bits is None else kv_bits[attn_idx]
                o = decode_attention_planes(
                    q, kp, ks2, kz2, vp, vs2, vz2, lens, bits=bits_b,
                    kv_bits=layer_kv,
                    logit_softcap=cfg.attn_logit_softcap, read=kv_read,
                    backend=kv_backend)
            else:
                ks = state.get(f"kv.{i}.k_scale")
                vs = state.get(f"kv.{i}.v_scale")
                kz = state.get(f"kv.{i}.k_zero")
                vz = state.get(f"kv.{i}.v_zero")
                kc, vc, ks2, vs2, kz2, vz2 = update_kv_cache(
                    state[f"kv.{i}.k"], state[f"kv.{i}.v"], k, v, pos,
                    k_scale=ks, v_scale=vs, k_zero=kz, v_zero=vz)
                new_state[f"kv.{i}.k"], new_state[f"kv.{i}.v"] = kc, vc
                if ks2 is not None:
                    new_state[f"kv.{i}.k_scale"] = ks2
                    new_state[f"kv.{i}.v_scale"] = vs2
                    new_state[f"kv.{i}.k_zero"] = kz2
                    new_state[f"kv.{i}.v_zero"] = vz2
                o = decode_attention(q, kc, vc, lens,
                                     logit_softcap=cfg.attn_logit_softcap,
                                     k_scale=ks2, v_scale=vs2,
                                     k_zero=kz2, v_zero=vz2)
            attn_idx += 1
            h = resid + lin(f"{p}.attn.wo", o.reshape(b, m, -1))
        else:
            if not rows_cells:
                y, conv, st = ssm_mod.ssm_decode_step(
                    cfg, lin, params, f"{p}.ssm", x,
                    state[f"ssm.{i}.conv"], state[f"ssm.{i}.state"],
                    async_input=resid)
            elif row_states:
                y, conv, st, (convs, states) = ssm_mod.ssm_decode_rows(
                    cfg, lin, params, f"{p}.ssm", x,
                    state[f"ssm.{i}.conv"], state[f"ssm.{i}.state"],
                    valid=valid, async_input=resid, snapshots=True)
                snaps[f"ssm.{i}.conv"] = convs
                snaps[f"ssm.{i}.state"] = states
            else:
                y, conv, st = ssm_mod.ssm_decode_rows(
                    cfg, lin, params, f"{p}.ssm", x,
                    state[f"ssm.{i}.conv"], state[f"ssm.{i}.state"],
                    valid=valid, async_input=resid)
            new_state[f"ssm.{i}.conv"] = conv
            new_state[f"ssm.{i}.state"] = st
            h = resid + y
        if cfg.cross_attention:
            resid = h
            x = rms_norm(h, params[f"{p}.ln_x"], cfg.norm_eps)
            b = x.shape[0]
            q = lin(f"{p}.xattn.wq", x, async_input=resid)
            q = q.reshape(b, m, cfg.num_heads, hd)
            kc = state[f"xkv.{i}.k"]
            vc = state[f"xkv.{i}.v"]
            o = decode_attention(q, kc, vc, jnp.int32(kc.shape[1]))
            h = resid + lin(f"{p}.xattn.wo", o.reshape(b, m, -1))
        if cfg.d_ff > 0:
            resid = h
            x = rms_norm(h, params[f"{p}.ln2"], cfg.norm_eps)
            if cfg.layer_is_moe(i):
                fwd = moe_decode_rows if rows_cells else moe_decode_forward
                y, _ = fwd(
                    cfg.mlp_kind, lin, params, f"{p}.moe", x,
                    num_experts=cfg.num_experts,
                    top_k=cfg.experts_per_token)
            else:
                y = mlp_forward(cfg.mlp_kind, lin, f"{p}.mlp", x,
                                async_input=resid)
            h = resid + y

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed.tok"])
    else:
        logits = lin("lm_head", h)
    new_state["pos"] = pos + (jnp.int32(1) if m == 1 else
                              n_valid.astype(jnp.int32))
    if row_states:
        return logits, new_state, snaps
    return logits, new_state


# ---------------------------------------------------------------------------
# Convenience
# ---------------------------------------------------------------------------
def loss_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
            labels: jax.Array, *, remat: bool = False,
            q_chunk: int = 1024, kv_chunk: int = 1024,
            prefix_embeds=None, frames=None,
            aux_weight: float = 0.01) -> jax.Array:
    logits, aux = forward(cfg, params, tokens, remat=remat, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, prefix_embeds=prefix_embeds,
                          frames=frames)
    if prefix_embeds is not None:
        # loss only on the text positions
        logits = logits[:, prefix_embeds.shape[1]:]
    return cross_entropy(logits, labels, cfg.vocab_size) + aux_weight * aux


def init_model_params(cfg: ModelConfig, key: jax.Array,
                      dtype=jnp.float32) -> Params:
    return init_params(model_param_specs(cfg), key, dtype)


def model_logical_axes(cfg: ModelConfig):
    return logical_axes(model_param_specs(cfg))
