from repro.data.corpus import load_corpus, sample_sequences
from repro.data.pipeline import DataConfig, ShardedBatchIterator
from repro.data.tokenizer import VOCAB_SIZE, decode, encode

__all__ = ["DataConfig", "ShardedBatchIterator", "VOCAB_SIZE", "decode",
           "encode", "load_corpus", "sample_sequences"]
