"""Sharded host data pipeline.

Deterministic iterator over packed next-token batches with per-host sharding
(each host loads only its slice of the global batch — at 1000+ nodes the
global batch never materializes on one host) and a small prefetch queue that
overlaps host data prep with device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.data.corpus import load_corpus


@dataclass
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0
    split: str = "train"
    max_bytes: int = 4_000_000


class ShardedBatchIterator:
    """Yields (tokens, labels) np arrays for this host's batch shard."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1,
                 prefetch: int = 2):
        assert cfg.global_batch % num_hosts == 0, \
            (cfg.global_batch, num_hosts)
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self.data = load_corpus(cfg.split, cfg.max_bytes)
        self._step = 0
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _make_batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        # deterministic per (step, host): reproducible across restarts
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.host_id)
        span = self.cfg.seq_len + 1
        starts = rng.integers(0, len(self.data) - span,
                              size=self.local_batch)
        seqs = np.stack([self.data[s:s + span] for s in starts])
        return seqs[:, :-1].astype(np.int32), seqs[:, 1:].astype(np.int32)

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def seek(self, step: int) -> None:
        """Restart-safe: resume the stream at ``step`` (fault tolerance)."""
        self._stop.set()
        self._thread.join(timeout=2)
        while not self._q.empty():
            self._q.get_nowait()
        self._step = step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        step, batch = self._q.get()
        return batch

    def close(self):
        self._stop.set()
