"""Offline text corpora.

The container has no internet and no datasets, so the in-container
experiments use the **Python standard library source tree** as a real,
deterministic text corpus (byte-level LM), with a synthetic Zipfian-Markov
fallback when stdlib sources are unavailable. Both are split
calibration/train/eval by file hash, so splits are stable across runs.
"""
from __future__ import annotations

import hashlib
import os
import sysconfig
from typing import Iterator, List

import numpy as np

from repro.data.tokenizer import encode

_MAX_FILE_BYTES = 200_000


def _stdlib_files(limit: int = 400) -> List[str]:
    root = sysconfig.get_paths()["stdlib"]
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in sorted(dirnames)
                       if d not in ("test", "tests", "__pycache__",
                                    "site-packages", "idlelib")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
                if len(out) >= limit:
                    return out
    return out


def _synthetic_text(n_bytes: int, seed: int = 0) -> str:
    """Zipfian-Markov word stream — a deterministic offline fallback."""
    rng = np.random.default_rng(seed)
    vocab = [f"tok{i}" for i in range(512)]
    trans = rng.dirichlet(np.full(64, 0.1), size=512)
    cand = rng.integers(0, 512, size=(512, 64))
    words, cur = [], 0
    total = 0
    while total < n_bytes:
        nxt = int(cand[cur][rng.choice(64, p=trans[cur])])
        w = vocab[nxt]
        words.append(w)
        total += len(w) + 1
        cur = nxt
    return " ".join(words)


def _split_of(path: str) -> str:
    h = int(hashlib.sha1(path.encode()).hexdigest(), 16) % 100
    if h < 70:
        return "train"
    if h < 85:
        return "calibration"
    return "eval"


def load_corpus(split: str, max_bytes: int = 4_000_000) -> np.ndarray:
    """Byte ids (int32) for ``split`` in {train, calibration, eval}."""
    files = _stdlib_files()
    chunks, total = [], 0
    for f in files:
        if _split_of(f) != split:
            continue
        try:
            with open(f, "rb") as fh:
                raw = fh.read(_MAX_FILE_BYTES)
        except OSError:
            continue
        ids = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)
        chunks.append(ids)
        total += len(ids)
        if total >= max_bytes:
            break
    if not chunks:  # fallback: synthetic
        seed = {"train": 0, "calibration": 1, "eval": 2}[split]
        return encode(_synthetic_text(max_bytes, seed))
    return np.concatenate(chunks)[:max_bytes]


def sample_sequences(data: np.ndarray, seq_len: int, count: int,
                     seed: int = 0) -> np.ndarray:
    """(count, seq_len+1) windows for next-token training/eval."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(data) - seq_len - 1, size=count)
    return np.stack([data[s:s + seq_len + 1] for s in starts])
