"""Byte-level tokenizer (vocab 256) — offline, deterministic, lossless."""
from __future__ import annotations

import numpy as np

VOCAB_SIZE = 256


def encode(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8", errors="replace"),
                         dtype=np.uint8).astype(np.int32)


def decode(ids) -> str:
    return bytes(int(i) & 0xFF for i in ids).decode("utf-8", errors="replace")
