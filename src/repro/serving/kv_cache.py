"""Decode-state management for the serving engine.

Preallocated ring-style KV caches (and SSM recurrent states) built from the
model config; byte accounting feeds the QoS latency model and the roofline.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_decode_state


def make_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return init_decode_state(cfg, batch, max_len, dtype=dtype)


def state_bytes(state: Dict[str, jax.Array]) -> int:
    return int(sum(np.prod(v.shape) * v.dtype.itemsize
                   for v in state.values()))


def reset_state(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    out = {k: jnp.zeros_like(v) for k, v in state.items()}
    return out
