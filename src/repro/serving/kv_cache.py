"""Decode-state management and the prefill→decode stage boundary.

Preallocated ring-style KV caches (and SSM recurrent states) built from the
model config; byte accounting feeds the QoS latency model and the roofline.

With prefill/decode disaggregation this module is the KV HANDOFF CONTRACT
between the two serve stages:

- :func:`make_prefill_state` allocates the prefill stage's bucketed
  scratch state — its KV length is rounded up to whole prefill chunks
  (``prefill_len``), so every prompt length shares the handful of compiled
  prefill launches instead of one shape per length;
- :func:`insert_slot_state` is the handoff — it writes a prefill-filled
  batch-1 state into one slot of the scheduler's stacked per-slot state,
  placing the KV block at a (traced) sequence ``offset``, copying the SSM
  recurrent/conv tails wholesale, and rebasing ``pos``. Compiled with the
  prefill stage's shardings on the inputs and the slot shardings on the
  outputs, GSPMD inserts the cross-slice collective here: this ONE step is
  where a KV block moves from the prefill mesh slice to the decode slice;
- :func:`handoff_state` is the explicit reshard for engine-style (slotless)
  handoffs: prefill placement in, decode placement out. On a single
  mesh/no mesh it is an identity transfer (bit-identical, tested);
- :func:`reset_state` / :func:`state_bytes` / :func:`stage_bytes` do
  buffer recycling and per-stage byte accounting. ``reset_state`` DONATES
  the incoming buffers to a jitted zero-fill, so slot retirement and
  prefill-scratch reuse rewrite the existing HBM pages instead of
  allocating a fresh pytree per query.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.models import init_decode_state


def make_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, kv_format: str = "dense",
                      kv_plane_bits: int = 8) -> Dict[str, jax.Array]:
    """``kv_format="overlay"`` allocates the dynamic-precision cache:
    per attention layer a full-``kv_plane_bits`` bitplane stack
    ``kv.{i}.{k,v}_planes`` (batch, B, max_len, hkv, ceil(hd/32)) int32
    plus per-(position, head) ``_scale``/``_zero`` rows — writes always
    store all B planes; reads fetch the planner-assigned prefix."""
    return init_decode_state(cfg, batch, max_len, dtype=dtype,
                             kv_format=kv_format,
                             kv_plane_bits=kv_plane_bits)


# ---------------------------------------------------------------------------
# Prefill-stage shapes (bucketed)
# ---------------------------------------------------------------------------
def prefill_len(prompt_len: int, prefill_chunk: int) -> int:
    """Bucketed prefill length: prompt rounded up to whole chunks."""
    if prefill_chunk <= 0:
        raise ValueError(f"prefill_chunk must be positive, "
                         f"got {prefill_chunk}")
    return -(-int(prompt_len) // int(prefill_chunk)) * int(prefill_chunk)


def n_prefill_chunks(prompt_len: int, prefill_chunk: int) -> int:
    """Launches the prefill stage issues for a prompt: ceil(p / chunk)."""
    return prefill_len(prompt_len, prefill_chunk) // int(prefill_chunk)


def make_prefill_state(cfg: ModelConfig, batch: int, max_prompt: int,
                       prefill_chunk: int,
                       dtype=jnp.bfloat16, kv_format: str = "dense",
                       kv_plane_bits: int = 8) -> Dict[str, jax.Array]:
    """The prefill stage's scratch state, sized for the LONGEST admissible
    prompt (so one allocation serves every admission) with its KV length
    rounded up to whole prefill chunks — pad rows of the final chunk
    write inside the same buffer. ``kv_format`` must match the decode
    stage's (the handoff copies representation-for-representation)."""
    return make_decode_state(cfg, batch,
                             prefill_len(max_prompt, prefill_chunk),
                             dtype=dtype, kv_format=kv_format,
                             kv_plane_bits=kv_plane_bits)


# ---------------------------------------------------------------------------
# Buffer recycling / accounting
# ---------------------------------------------------------------------------
# donated arg: XLA reuses the incoming buffers for the zero fill (one
# compiled zeroing per state shape, cached by jit)
_zero_state = jax.jit(lambda state: jax.tree.map(jnp.zeros_like, state),
                      donate_argnums=0)


def reset_state(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Zero a decode/prefill state IN PLACE (buffer donation).

    The input buffers are donated to a jitted zero-fill, so retiring a
    slot or recycling the prefill scratch between admissions rewrites
    the existing HBM pages — no fresh pytree allocation per query, no
    allocator churn at continuous-batching rates. The caller must drop
    its reference to the argument (it is consumed).
    """
    return _zero_state(state)


def state_bytes(state: Dict[str, jax.Array]) -> int:
    return int(sum(np.prod(v.shape) * v.dtype.itemsize
                   for v in state.values()))


def stage_bytes(state: Dict[str, jax.Array]) -> Dict[str, int]:
    """Per-component byte accounting of one stage's state.

    Top-level keys: ``kv`` (self-attention caches, all representations),
    ``ssm`` (recurrent + conv tails), ``xkv`` (cross-attention caches),
    ``other`` (positions etc.), ``total`` (= kv + ssm + xkv + other).
    The ``kv`` term is additionally split BY REPRESENTATION —
    ``kv_planes`` (bitplane stacks), ``kv_scales`` (scale + zero rows,
    overlay or int8), ``kv_dense`` (dense fp/int8 value rows) — with
    ``kv == kv_planes + kv_scales + kv_dense``; the splits are NOT
    double-counted into ``total``. The prefill/decode stages report
    this separately so the handoff traffic (= the prefill state's
    ``kv`` + ``ssm`` terms) is a first-class number in the benchmarks.
    """
    out = {"kv": 0, "kv_planes": 0, "kv_scales": 0, "kv_dense": 0,
           "ssm": 0, "xkv": 0, "other": 0}
    for k, v in state.items():
        nbytes = int(np.prod(v.shape) * v.dtype.itemsize)
        if k.startswith("kv."):
            out["kv"] += nbytes
            if k.endswith("_planes"):
                out["kv_planes"] += nbytes
            elif k.endswith("_scale") or k.endswith("_zero"):
                out["kv_scales"] += nbytes
            else:
                out["kv_dense"] += nbytes
        elif k.startswith("ssm."):
            out["ssm"] += nbytes
        elif k.startswith("xkv."):
            out["xkv"] += nbytes
        else:
            out["other"] += nbytes
    out["total"] = out["kv"] + out["ssm"] + out["xkv"] + out["other"]
    return out


# ---------------------------------------------------------------------------
# The handoff: prefill state -> decode placement / slot insertion
# ---------------------------------------------------------------------------
def handoff_state(state: Dict[str, jax.Array],
                  mesh: Optional[Mesh] = None,
                  spec_fn: Optional[Callable] = None
                  ) -> Dict[str, jax.Array]:
    """Reshard a prefill-stage state onto the decode stage's placement.

    ``spec_fn(mesh, key, shape) -> PartitionSpec`` names the target
    layout (normally ``distributed.sharding.decode_state_spec``). With
    ``mesh=None`` this is the single-mesh identity transfer — the SAME
    arrays come back (no copy, bit-identical by construction).
    """
    if mesh is None or spec_fn is None:
        return state
    return {k: jax.device_put(v, NamedSharding(mesh,
                                               spec_fn(mesh, k, v.shape)))
            for k, v in state.items()}


def insert_slot_state(dst: Dict[str, jax.Array],
                      src: Dict[str, jax.Array],
                      slot: jax.Array,
                      offset: jax.Array = 0) -> Dict[str, jax.Array]:
    """Write a batch-1 prefill state into slot ``slot`` of a stacked
    per-slot decode state, KV block at sequence position ``offset``.

    This is the per-slot half of the handoff contract: KV leaves (and
    their int8 scale planes) are inserted at ``(slot, 0, offset, ...)``
    via ``dynamic_update_slice`` — when the prefill bucket is longer
    than the slot's cache only the leading window that fits is copied
    (prefill pad rows past the true prompt are garbage that decode
    overwrites before ever attending); SSM conv/recurrent tails and
    cross-attention caches replace the slot's wholesale; ``pos`` is
    rebased by ``offset``. Trace this under the prefill shardings in and
    the slot shardings out and GSPMD emits the cross-slice transfer
    right here.
    """
    slot = jnp.asarray(slot, jnp.int32)
    offset = jnp.asarray(offset, jnp.int32)
    out = dict(dst)
    for k, v in src.items():
        d = dst[k]
        if k == "pos":
            out[k] = d.at[slot].set(v + offset)
        elif k.startswith("kv.") and k.endswith("_planes"):
            # plane stacks carry a leading (batch, B) prefix: the
            # sequence axis is 2 in src, 3 in the stacked dst
            keep = min(v.shape[2], d.shape[3])
            block = v[:, :, :keep][None]         # (1, 1, B, keep, ...)
            start = (slot, 0, 0, offset) + (jnp.int32(0),) * (v.ndim - 3)
            out[k] = jax.lax.dynamic_update_slice(d, block.astype(d.dtype),
                                                  start)
        elif k.startswith("kv.") and v.ndim >= 3:
            keep = min(v.shape[1], d.shape[2])   # leading window that fits
            block = v[:, :keep][None]            # (1, 1, keep, ...)
            start = (slot, 0, offset) + (jnp.int32(0),) * (v.ndim - 2)
            out[k] = jax.lax.dynamic_update_slice(d, block.astype(d.dtype),
                                                  start)
        else:
            # slot leaves are (S,) + src.shape: SSM conv/recurrent tails
            # and cross-attention caches replace the slot's wholesale
            out[k] = d.at[slot].set(v.astype(d.dtype))
    return out


def rollback_decode_state(state: Dict[str, jax.Array],
                          snaps: Dict[str, jax.Array],
                          n_keep: jax.Array,
                          window: int) -> Dict[str, jax.Array]:
    """Roll a post-VERIFY decode state back to the last accepted row.

    Speculative decoding's accept/reject stage boundary: the verify
    launch consumed a full ``window``-row block — advancing ``pos`` by
    ``window`` and writing ``window`` KV rows — but only the first
    ``n_keep`` (traced, >= 1) rows were accepted. This restores the
    exact state ``n_keep`` sequential baseline ticks would have left:

    - KV leaves (and int8 scale planes): a static ``window``-row ZERO
      block is written at the new position. Rows at or past ``pos`` are
      zero by invariant — fresh states are zero-filled and every window
      re-establishes it here — so zeroing ``[new_pos, new_pos+window)``
      erases exactly the rejected rows. The caller must size the cache
      with ``window`` rows of slack past the last possible ``new_pos``
      so the ``dynamic_update_slice`` never clamps (the engine and
      scheduler allocate ``2k`` rows of slack).
    - SSM conv/recurrent leaves: restored from the verify launch's
      per-row snapshots (``decode_step(row_states=True)`` — leading
      ``(window, ...)`` axis), selecting row ``n_keep - 1`` — which is
      bit-identical to having stopped the sequential recurrence there.
    - ``pos``: rebased to ``pos - window + n_keep``.

    Cross-attention caches are decode-invariant and pass through
    untouched. Leaves have a leading batch axis (the engine's dense
    batch, or batch-1 under the scheduler's slot ``vmap`` — vmapping
    this function over the slot axis is the per-slot rollback).
    """
    n_keep = jnp.asarray(n_keep, jnp.int32)
    out = dict(state)
    new_pos = state["pos"] - jnp.int32(window) + n_keep
    for key, v in state.items():
        if key == "pos":
            out[key] = new_pos
        elif key.startswith("kv.") and key.endswith("_planes"):
            # plane stacks: sequence axis is 2 (behind batch and B);
            # zeroing the window zeroes ALL planes + leaves the scale
            # rows to the sibling _scale/_zero branch below
            zeros = jnp.zeros(v.shape[:2] + (int(window),) + v.shape[3:],
                              v.dtype)
            start = (jnp.int32(0), jnp.int32(0), new_pos) + \
                (jnp.int32(0),) * (v.ndim - 3)
            out[key] = jax.lax.dynamic_update_slice(v, zeros, start)
        elif key.startswith("kv.") and v.ndim >= 3:
            zeros = jnp.zeros((v.shape[0], int(window)) + v.shape[2:],
                              v.dtype)
            start = (jnp.int32(0), new_pos) + \
                (jnp.int32(0),) * (v.ndim - 2)
            out[key] = jax.lax.dynamic_update_slice(v, zeros, start)
        elif key in snaps:
            out[key] = jax.lax.dynamic_index_in_dim(
                snaps[key], n_keep - 1, axis=0,
                keepdims=False).astype(v.dtype)
    return out


__all__ = ["handoff_state", "insert_slot_state", "make_decode_state",
           "make_prefill_state", "n_prefill_chunks", "prefill_len",
           "reset_state", "rollback_decode_state", "stage_bytes",
           "state_bytes"]
