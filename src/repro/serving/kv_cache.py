"""Decode-state management and the prefill→decode stage boundary.

Preallocated ring-style KV caches (and SSM recurrent states) built from the
model config; byte accounting feeds the QoS latency model and the roofline.

With prefill/decode disaggregation this module is the KV HANDOFF CONTRACT
between the two serve stages:

- :func:`make_prefill_state` allocates the prefill stage's bucketed
  scratch state — its KV length is rounded up to whole prefill chunks
  (``prefill_len``), so every prompt length shares the handful of compiled
  prefill launches instead of one shape per length;
- :func:`insert_slot_state` is the handoff — it writes a prefill-filled
  batch-1 state into one slot of the scheduler's stacked per-slot state,
  placing the KV block at a (traced) sequence ``offset``, copying the SSM
  recurrent/conv tails wholesale, and rebasing ``pos``. Compiled with the
  prefill stage's shardings on the inputs and the slot shardings on the
  outputs, GSPMD inserts the cross-slice collective here: this ONE step is
  where a KV block moves from the prefill mesh slice to the decode slice;
- :func:`handoff_state` is the explicit reshard for engine-style (slotless)
  handoffs: prefill placement in, decode placement out. On a single
  mesh/no mesh it is an identity transfer (bit-identical, tested);
- :func:`reset_state` / :func:`state_bytes` / :func:`stage_bytes` do
  buffer recycling and per-stage byte accounting. ``reset_state`` DONATES
  the incoming buffers to a jitted zero-fill, so slot retirement and
  prefill-scratch reuse rewrite the existing HBM pages instead of
  allocating a fresh pytree per query.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.models import init_decode_state, init_paged_pool, init_paged_state


def make_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, kv_format: str = "dense",
                      kv_plane_bits: int = 8) -> Dict[str, jax.Array]:
    """``kv_format="overlay"`` allocates the dynamic-precision cache:
    per attention layer a full-``kv_plane_bits`` bitplane stack
    ``kv.{i}.{k,v}_planes`` (batch, B, max_len, hkv, ceil(hd/32)) int32
    plus per-(position, head) ``_scale``/``_zero`` rows — writes always
    store all B planes; reads fetch the planner-assigned prefix."""
    return init_decode_state(cfg, batch, max_len, dtype=dtype,
                             kv_format=kv_format,
                             kv_plane_bits=kv_plane_bits)


# ---------------------------------------------------------------------------
# Paged plane pool: ONE shared store, per-slot page tables, host allocator
# ---------------------------------------------------------------------------
#: reserved trash/pin page id — never allocated; unallocated page-table
#: entries (0) route gated writes and dead-tile reads here
TRASH_PAGE = 0


def make_paged_pool(cfg: ModelConfig, n_pages: int, page_len: int,
                    kv_plane_bits: int = 8) -> Dict[str, jax.Array]:
    """The shared paged KV plane pool (``pool.{i}.*`` leaves) — see
    :func:`repro.models.init_paged_pool`. Live pages, not worst-case
    buckets, bound HBM: ``n_pages`` is the budget knob."""
    return init_paged_pool(cfg, n_pages, page_len,
                           kv_plane_bits=kv_plane_bits)


def make_paged_state(cfg: ModelConfig, batch: int, max_len: int,
                     page_len: int, dtype=jnp.bfloat16
                     ) -> Dict[str, jax.Array]:
    """Per-slot decode state for the paged cache: ``page_table`` instead
    of bucketed ``kv.*`` arrays (see :func:`repro.models.init_paged_state`)."""
    return init_paged_state(cfg, batch, max_len, page_len, dtype=dtype)


def pages_for_rows(n_rows: int, page_len: int) -> int:
    """Pages needed to cover ``n_rows`` KV rows: ceil(n / page_len)."""
    if page_len <= 0:
        raise ValueError(f"page_len must be positive, got {page_len}")
    return -(-max(0, int(n_rows)) // int(page_len))


class PagePool:
    """Host-side page allocator for the shared plane pool.

    Pages are ids in ``[1, n_pages)`` — page 0 is the reserved trash
    page and is never handed out. ``alloc`` is all-or-nothing (returns
    ``None`` when the pool can't cover the request, so the admission
    router can queue or preempt instead of partially admitting);
    ``free`` rejects double-frees and foreign ids. Every page tracks an
    ``owner`` tag so preemption can assert it reclaimed exactly the
    victim's pages, and ``high_watermark`` records the peak pages in
    use — the fragmentation bound the property tests pin.
    """

    def __init__(self, n_pages: int, page_len: int):
        if n_pages < 2:
            raise ValueError("paged pool needs >= 2 pages "
                             "(page 0 is the trash page)")
        self.n_pages = int(n_pages)
        self.page_len = int(page_len)
        self._free = list(range(self.n_pages - 1, 0, -1))
        self._owner: Dict[int, object] = {}
        self.high_watermark = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - 1 - len(self._free)

    def pages_for(self, n_rows: int) -> int:
        return pages_for_rows(n_rows, self.page_len)

    def alloc(self, n: int, owner=None):
        """Allocate ``n`` pages for ``owner``; all-or-nothing — returns
        the page-id list, or ``None`` if fewer than ``n`` are free."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        for p in ids:
            self._owner[p] = owner
        self.high_watermark = max(self.high_watermark, self.n_used)
        return ids

    def free(self, ids) -> None:
        ids = list(ids)
        for p in ids:
            if p not in self._owner:
                raise ValueError(f"free of unallocated page {p} "
                                 "(double free or trash page)")
        for p in ids:
            del self._owner[p]
            self._free.append(p)

    def owned(self, owner):
        """Pages currently allocated to ``owner`` (sorted)."""
        return sorted(p for p, o in self._owner.items() if o == owner)

    def stats(self) -> Dict[str, int]:
        return {"n_pages": self.n_pages, "page_len": self.page_len,
                "used_pages": self.n_used, "free_pages": self.n_free,
                "high_watermark_pages": self.high_watermark}


def pool_page_bytes(pool: Dict[str, jax.Array]) -> int:
    """HBM bytes ONE page costs across every ``pool.*`` leaf (all layers,
    K and V, planes + scale/zero rows)."""
    return int(sum(np.prod(v.shape[1:]) * v.dtype.itemsize
                   for k, v in pool.items() if k.startswith("pool.")))


def pool_accounting(pool: Dict[str, jax.Array], allocator: PagePool,
                    live_rows: int = 0) -> Dict[str, int]:
    """Pool accounting for the byte reports: live vs. allocated bytes
    and the fragmentation high-watermark.

    ``live_rows`` is the total KV rows actually written across live
    slots; ``allocated`` counts whole pages handed out, so
    ``fragmentation_bytes = allocated - live`` is the internal-
    fragmentation cost of the page granularity (bounded by one page per
    live slot). ``capacity_bytes`` is the whole pool — the number a
    bucketed allocator would multiply by worst-case slots."""
    page_b = pool_page_bytes(pool)
    row_b = page_b // max(1, allocator.page_len)
    allocated = allocator.n_used * page_b
    live = int(live_rows) * row_b
    return {
        "page_bytes": page_b,
        "capacity_bytes": int(sum(
            np.prod(v.shape) * v.dtype.itemsize
            for k, v in pool.items() if k.startswith("pool."))),
        "allocated_pages": allocator.n_used,
        "allocated_bytes": allocated,
        "live_rows": int(live_rows),
        "live_bytes": live,
        "fragmentation_bytes": allocated - live,
        "high_watermark_pages": allocator.high_watermark,
        "high_watermark_bytes": allocator.high_watermark * page_b,
    }


# donated: recycling freed pages rewrites the pool's own HBM (page ids
# are bucketed to powers of two by the wrapper to bound recompiles)
_zero_pages = jax.jit(
    lambda pool, ids: jax.tree.map(lambda v: v.at[ids].set(0), pool),
    donate_argnums=0)


def zero_pool_pages(pool: Dict[str, jax.Array], ids
                    ) -> Dict[str, jax.Array]:
    """Zero the given pages across every pool leaf (buffer-donated).

    Freed pages MUST be zeroed before reuse — the zero-rows invariant
    (rollback erases exactly the rows it wrote, tail rows read as
    masked zeros) is stated over page content, and a recycled page must
    look like a fresh one. The id list is padded with the trash page to
    the next power of two so one compiled zeroing serves each bucket.
    """
    ids = [int(p) for p in ids]
    if not ids:
        return pool
    n = 1
    while n < len(ids):
        n *= 2
    ids = ids + [TRASH_PAGE] * (n - len(ids))
    return _zero_pages(pool, jnp.asarray(ids, jnp.int32))


# ---------------------------------------------------------------------------
# Prefill-stage shapes (bucketed)
# ---------------------------------------------------------------------------
def prefill_len(prompt_len: int, prefill_chunk: int) -> int:
    """Bucketed prefill length: prompt rounded up to whole chunks."""
    if prefill_chunk <= 0:
        raise ValueError(f"prefill_chunk must be positive, "
                         f"got {prefill_chunk}")
    return -(-int(prompt_len) // int(prefill_chunk)) * int(prefill_chunk)


def n_prefill_chunks(prompt_len: int, prefill_chunk: int) -> int:
    """Launches the prefill stage issues for a prompt: ceil(p / chunk)."""
    return prefill_len(prompt_len, prefill_chunk) // int(prefill_chunk)


def make_prefill_state(cfg: ModelConfig, batch: int, max_prompt: int,
                       prefill_chunk: int,
                       dtype=jnp.bfloat16, kv_format: str = "dense",
                       kv_plane_bits: int = 8) -> Dict[str, jax.Array]:
    """The prefill stage's scratch state, sized for the LONGEST admissible
    prompt (so one allocation serves every admission) with its KV length
    rounded up to whole prefill chunks — pad rows of the final chunk
    write inside the same buffer. ``kv_format`` must match the decode
    stage's (the handoff copies representation-for-representation)."""
    return make_decode_state(cfg, batch,
                             prefill_len(max_prompt, prefill_chunk),
                             dtype=dtype, kv_format=kv_format,
                             kv_plane_bits=kv_plane_bits)


# ---------------------------------------------------------------------------
# Buffer recycling / accounting
# ---------------------------------------------------------------------------
# donated arg: XLA reuses the incoming buffers for the zero fill (one
# compiled zeroing per state shape, cached by jit)
_zero_state = jax.jit(lambda state: jax.tree.map(jnp.zeros_like, state),
                      donate_argnums=0)


def reset_state(state: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Zero a decode/prefill state IN PLACE (buffer donation).

    The input buffers are donated to a jitted zero-fill, so retiring a
    slot or recycling the prefill scratch between admissions rewrites
    the existing HBM pages — no fresh pytree allocation per query, no
    allocator churn at continuous-batching rates. The caller must drop
    its reference to the argument (it is consumed).
    """
    return _zero_state(state)


def state_bytes(state: Dict[str, jax.Array]) -> int:
    return int(sum(np.prod(v.shape) * v.dtype.itemsize
                   for v in state.values()))


def stage_bytes(state: Dict[str, jax.Array]) -> Dict[str, int]:
    """Per-component byte accounting of one stage's state.

    Top-level keys: ``kv`` (self-attention caches, all representations),
    ``pool`` (the shared paged plane pool), ``ssm`` (recurrent + conv
    tails), ``xkv`` (cross-attention caches), ``other`` (positions,
    page tables etc.), ``total`` (= kv + pool + ssm + xkv + other).
    The ``kv`` term is additionally split BY REPRESENTATION —
    ``kv_planes`` (bitplane stacks), ``kv_scales`` (scale + zero rows,
    overlay or int8), ``kv_dense`` (dense fp/int8 value rows) — with
    ``kv == kv_planes + kv_scales + kv_dense``; the splits are NOT
    double-counted into ``total``. The prefill/decode stages report
    this separately so the handoff traffic (= the prefill state's
    ``kv`` + ``ssm`` terms) is a first-class number in the benchmarks.
    """
    out = {"kv": 0, "kv_planes": 0, "kv_scales": 0, "kv_dense": 0,
           "pool": 0, "ssm": 0, "xkv": 0, "other": 0}
    for k, v in state.items():
        nbytes = int(np.prod(v.shape) * v.dtype.itemsize)
        if k.startswith("kv."):
            out["kv"] += nbytes
            if k.endswith("_planes"):
                out["kv_planes"] += nbytes
            elif k.endswith("_scale") or k.endswith("_zero"):
                out["kv_scales"] += nbytes
            else:
                out["kv_dense"] += nbytes
        elif k.startswith("pool."):
            # the SHARED paged plane pool: sized by live pages across
            # all slots, not per-slot buckets (see pool_accounting for
            # the live/allocated/fragmentation split)
            out["pool"] += nbytes
        elif k.startswith("ssm."):
            out["ssm"] += nbytes
        elif k.startswith("xkv."):
            out["xkv"] += nbytes
        else:
            out["other"] += nbytes
    out["total"] = out["kv"] + out["pool"] + out["ssm"] + out["xkv"] + \
        out["other"]
    return out


# ---------------------------------------------------------------------------
# The handoff: prefill state -> decode placement / slot insertion
# ---------------------------------------------------------------------------
def handoff_state(state: Dict[str, jax.Array],
                  mesh: Optional[Mesh] = None,
                  spec_fn: Optional[Callable] = None
                  ) -> Dict[str, jax.Array]:
    """Reshard a prefill-stage state onto the decode stage's placement.

    ``spec_fn(mesh, key, shape) -> PartitionSpec`` names the target
    layout (normally ``distributed.sharding.decode_state_spec``). With
    ``mesh=None`` this is the single-mesh identity transfer — the SAME
    arrays come back (no copy, bit-identical by construction).
    """
    if mesh is None or spec_fn is None:
        return state
    return {k: jax.device_put(v, NamedSharding(mesh,
                                               spec_fn(mesh, k, v.shape)))
            for k, v in state.items()}


def insert_slot_state(dst: Dict[str, jax.Array],
                      src: Dict[str, jax.Array],
                      slot: jax.Array,
                      offset: jax.Array = 0) -> Dict[str, jax.Array]:
    """Write a batch-1 prefill state into slot ``slot`` of a stacked
    per-slot decode state, KV block at sequence position ``offset``.

    This is the per-slot half of the handoff contract: KV leaves (and
    their int8 scale planes) are inserted at ``(slot, 0, offset, ...)``
    via ``dynamic_update_slice`` — when the prefill bucket is longer
    than the slot's cache only the leading window that fits is copied
    (prefill pad rows past the true prompt are garbage that decode
    overwrites before ever attending); SSM conv/recurrent tails and
    cross-attention caches replace the slot's wholesale; ``pos`` is
    rebased by ``offset``. Trace this under the prefill shardings in and
    the slot shardings out and GSPMD emits the cross-slice transfer
    right here.
    """
    slot = jnp.asarray(slot, jnp.int32)
    offset = jnp.asarray(offset, jnp.int32)
    out = dict(dst)
    for k, v in src.items():
        d = dst[k]
        if k == "pos":
            out[k] = d.at[slot].set(v + offset)
        elif k.startswith("kv.") and k.endswith("_planes"):
            # plane stacks carry a leading (batch, B) prefix: the
            # sequence axis is 2 in src, 3 in the stacked dst
            keep = min(v.shape[2], d.shape[3])
            block = v[:, :, :keep][None]         # (1, 1, B, keep, ...)
            start = (slot, 0, 0, offset) + (jnp.int32(0),) * (v.ndim - 3)
            out[k] = jax.lax.dynamic_update_slice(d, block.astype(d.dtype),
                                                  start)
        elif k.startswith("kv.") and v.ndim >= 3:
            keep = min(v.shape[1], d.shape[2])   # leading window that fits
            block = v[:, :keep][None]            # (1, 1, keep, ...)
            start = (slot, 0, offset) + (jnp.int32(0),) * (v.ndim - 2)
            out[k] = jax.lax.dynamic_update_slice(d, block.astype(d.dtype),
                                                  start)
        else:
            # slot leaves are (S,) + src.shape: SSM conv/recurrent tails
            # and cross-attention caches replace the slot's wholesale
            out[k] = d.at[slot].set(v.astype(d.dtype))
    return out


def rollback_decode_state(state: Dict[str, jax.Array],
                          snaps: Dict[str, jax.Array],
                          n_keep: jax.Array,
                          window: int) -> Dict[str, jax.Array]:
    """Roll a post-VERIFY decode state back to the last accepted row.

    Speculative decoding's accept/reject stage boundary: the verify
    launch consumed a full ``window``-row block — advancing ``pos`` by
    ``window`` and writing ``window`` KV rows — but only the first
    ``n_keep`` (traced, >= 1) rows were accepted. This restores the
    exact state ``n_keep`` sequential baseline ticks would have left:

    - KV leaves (and int8 scale planes): a static ``window``-row ZERO
      block is written at the new position. Rows at or past ``pos`` are
      zero by invariant — fresh states are zero-filled and every window
      re-establishes it here — so zeroing ``[new_pos, new_pos+window)``
      erases exactly the rejected rows. The caller must size the cache
      with ``window`` rows of slack past the last possible ``new_pos``
      so the ``dynamic_update_slice`` never clamps (the engine and
      scheduler allocate ``2k`` rows of slack).
    - SSM conv/recurrent leaves: restored from the verify launch's
      per-row snapshots (``decode_step(row_states=True)`` — leading
      ``(window, ...)`` axis), selecting row ``n_keep - 1`` — which is
      bit-identical to having stopped the sequential recurrence there.
    - ``pos``: rebased to ``pos - window + n_keep``.

    Cross-attention caches are decode-invariant and pass through
    untouched. Leaves have a leading batch axis (the engine's dense
    batch, or batch-1 under the scheduler's slot ``vmap`` — vmapping
    this function over the slot axis is the per-slot rollback).
    """
    n_keep = jnp.asarray(n_keep, jnp.int32)
    out = dict(state)
    new_pos = state["pos"] - jnp.int32(window) + n_keep
    for key, v in state.items():
        if key == "pos":
            out[key] = new_pos
        elif key.startswith("kv.") and key.endswith("_planes"):
            # plane stacks: sequence axis is 2 (behind batch and B);
            # zeroing the window zeroes ALL planes + leaves the scale
            # rows to the sibling _scale/_zero branch below
            zeros = jnp.zeros(v.shape[:2] + (int(window),) + v.shape[3:],
                              v.dtype)
            start = (jnp.int32(0), jnp.int32(0), new_pos) + \
                (jnp.int32(0),) * (v.ndim - 3)
            out[key] = jax.lax.dynamic_update_slice(v, zeros, start)
        elif key.startswith("kv.") and v.ndim >= 3:
            zeros = jnp.zeros((v.shape[0], int(window)) + v.shape[2:],
                              v.dtype)
            start = (jnp.int32(0), new_pos) + \
                (jnp.int32(0),) * (v.ndim - 2)
            out[key] = jax.lax.dynamic_update_slice(v, zeros, start)
        elif key in snaps:
            out[key] = jax.lax.dynamic_index_in_dim(
                snaps[key], n_keep - 1, axis=0,
                keepdims=False).astype(v.dtype)
    return out


def insert_slot_state_paged(dst: Dict[str, jax.Array],
                            pool: Dict[str, jax.Array],
                            src: Dict[str, jax.Array],
                            slot: jax.Array,
                            pages_row: jax.Array,
                            prompt_len: jax.Array):
    """The paged half of the prefill→decode handoff: scatter a batch-1
    BUCKETED prefill state's KV into the shared pool's pages and point
    slot ``slot``'s page table at them.

    ``pages_row`` is the slot's full host-built page-table row (P,)
    int32 — the leading ``ceil(prompt_len / page_len)`` entries are
    freshly allocated pages, the rest ``TRASH_PAGE``. Prefill-bucket
    pad rows (>= ``prompt_len``, traced) are MASKED TO ZERO before the
    scatter, re-establishing the zero-rows invariant on the new pages;
    all-zero blocks covering dead tables entries land on the trash page
    harmlessly. SSM tails / xkv / ``pos`` follow the bucketed
    :func:`insert_slot_state` semantics (offset 0 — prefill-at-admission
    fills from row 0). Returns ``(new_dst, new_pool)``; compiled with
    the prefill shardings in and slot/pool shardings out this remains
    the ONE step where GSPMD moves the KV block across mesh slices.
    """
    slot = jnp.asarray(slot, jnp.int32)
    prompt_len = jnp.asarray(prompt_len, jnp.int32)
    pages_row = jnp.asarray(pages_row, jnp.int32)
    out = dict(dst)
    new_pool = dict(pool)
    p_slot = pages_row.shape[0]
    # page geometry from any plane leaf
    page_len = next(v.shape[2] for k, v in pool.items()
                    if k.endswith("_planes"))
    for k, v in src.items():
        if k == "pos":
            out[k] = dst[k].at[slot].set(v)
        elif k.startswith("kv."):
            pkey = "pool." + k[len("kv."):]
            d = new_pool[pkey]
            if k.endswith("_planes"):
                # src (1, B, L_pf, hkv, dw): mask pad rows, split the
                # sequence axis into pages, scatter to the table row
                rows = v[0]
                n_pg = min(-(-rows.shape[1] // page_len), p_slot)
                keep = n_pg * page_len
                rows = rows[:, :keep] if keep <= rows.shape[1] else \
                    jnp.pad(rows, ((0, 0), (0, keep - rows.shape[1])) +
                            ((0, 0),) * (rows.ndim - 2))
                valid = (jnp.arange(keep) < prompt_len)
                rows = jnp.where(
                    valid[None, :, None, None], rows, 0)
                blocks = rows.reshape(
                    (rows.shape[0], n_pg, page_len) + rows.shape[2:])
                blocks = jnp.moveaxis(blocks, 1, 0)   # (n_pg, B, L, ...)
            else:
                rows = v[0]                           # (L_pf, hkv, 1)
                n_pg = min(-(-rows.shape[0] // page_len), p_slot)
                keep = n_pg * page_len
                rows = rows[:keep] if keep <= rows.shape[0] else \
                    jnp.pad(rows, ((0, keep - rows.shape[0]),) +
                            ((0, 0),) * (rows.ndim - 1))
                valid = (jnp.arange(keep) < prompt_len)
                rows = jnp.where(valid[:, None, None], rows, 0)
                blocks = rows.reshape((n_pg, page_len) + rows.shape[1:])
            new_pool[pkey] = d.at[pages_row[:n_pg]].set(
                blocks.astype(d.dtype))
        else:
            out[k] = dst[k].at[slot].set(v.astype(dst[k].dtype))
    out["page_table"] = dst["page_table"].at[slot].set(pages_row[None])
    return out, new_pool


def rollback_decode_state_paged(state: Dict[str, jax.Array],
                                pool: Dict[str, jax.Array],
                                snaps: Dict[str, jax.Array],
                                n_keep: jax.Array,
                                window: int):
    """Paged twin of :func:`rollback_decode_state`: the KV erase runs on
    the accepted window's PAGES only — a ``window``-row zero scatter
    through the slot's page table per layer — instead of zero-filling
    bucket rows. Other slots' pages are untouched by construction (the
    allocator never aliases live pages), and rows whose table entry is
    unallocated land on the trash page. SSM snapshot selection and the
    ``pos`` rebase are identical to the bucketed rollback. Freeing the
    pages past the accepted prefix back to the allocator is the HOST'S
    move (the scheduler trims at the post-sync step — page ids are host
    state); this function only restores device content. Returns
    ``(new_state, new_pool)``.
    """
    from repro.models.attention import paged_zero_window  # deferred
    n_keep = jnp.asarray(n_keep, jnp.int32)
    out = dict(state)
    new_pos = state["pos"] - jnp.int32(window) + n_keep
    for key, v in state.items():
        if key == "pos":
            out[key] = new_pos
        elif key in snaps:
            out[key] = jax.lax.dynamic_index_in_dim(
                snaps[key], n_keep - 1, axis=0,
                keepdims=False).astype(v.dtype)
    new_pool = dict(pool)
    layers = sorted({k.split(".")[1] for k in pool if k.endswith("_planes")},
                    key=int)
    for i in layers:
        kp, ks, kz, vp, vs, vz = paged_zero_window(
            pool[f"pool.{i}.k_planes"], pool[f"pool.{i}.k_scale"],
            pool[f"pool.{i}.k_zero"], pool[f"pool.{i}.v_planes"],
            pool[f"pool.{i}.v_scale"], pool[f"pool.{i}.v_zero"],
            state["page_table"], new_pos, window)
        new_pool[f"pool.{i}.k_planes"] = kp
        new_pool[f"pool.{i}.k_scale"] = ks
        new_pool[f"pool.{i}.k_zero"] = kz
        new_pool[f"pool.{i}.v_planes"] = vp
        new_pool[f"pool.{i}.v_scale"] = vs
        new_pool[f"pool.{i}.v_zero"] = vz
    return out, new_pool


__all__ = ["PagePool", "TRASH_PAGE", "handoff_state", "insert_slot_state",
           "insert_slot_state_paged", "make_decode_state",
           "make_paged_pool", "make_paged_state", "make_prefill_state",
           "n_prefill_chunks", "pages_for_rows", "pool_accounting",
           "pool_page_bytes", "prefill_len", "reset_state",
           "rollback_decode_state", "rollback_decode_state_paged",
           "stage_bytes", "state_bytes", "zero_pool_pages"]
