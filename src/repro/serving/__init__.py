from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import make_decode_state, reset_state, state_bytes
from repro.serving.qos import LatencyModel, QoSPlanner, QueryBitTracker

__all__ = ["LatencyModel", "QoSPlanner", "QueryBitTracker", "ServingEngine",
           "make_decode_state", "reset_state", "state_bytes"]
