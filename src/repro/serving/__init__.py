from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import make_decode_state, reset_state, state_bytes
from repro.serving.qos import LatencyModel, QoSPlanner, QueryBitTracker
from repro.serving.scheduler import Request, SlotScheduler

__all__ = ["LatencyModel", "QoSPlanner", "QueryBitTracker", "Request",
           "ServingEngine", "SlotScheduler", "make_decode_state",
           "reset_state", "state_bytes"]
