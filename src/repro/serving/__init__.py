from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (handoff_state, insert_slot_state,
                                    make_decode_state, make_prefill_state,
                                    n_prefill_chunks, prefill_len,
                                    reset_state, rollback_decode_state,
                                    stage_bytes, state_bytes)
from repro.serving.qos import LatencyModel, QoSPlanner, QueryBitTracker
from repro.serving.scheduler import Request, SlotScheduler

__all__ = ["LatencyModel", "QoSPlanner", "QueryBitTracker", "Request",
           "ServingEngine", "SlotScheduler", "handoff_state",
           "insert_slot_state", "make_decode_state", "make_prefill_state",
           "n_prefill_chunks", "prefill_len", "reset_state",
           "rollback_decode_state", "stage_bytes", "state_bytes"]
