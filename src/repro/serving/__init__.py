from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (PagePool, handoff_state,
                                    insert_slot_state,
                                    insert_slot_state_paged,
                                    make_decode_state, make_paged_pool,
                                    make_paged_state, make_prefill_state,
                                    n_prefill_chunks, pages_for_rows,
                                    pool_accounting, prefill_len,
                                    reset_state, rollback_decode_state,
                                    rollback_decode_state_paged,
                                    stage_bytes, state_bytes,
                                    zero_pool_pages)
from repro.serving.qos import (AdmissionRouter, LatencyModel, PriorityClass,
                               QoSPlanner, QueryBitTracker)
from repro.serving.scheduler import Request, SlotScheduler

__all__ = ["AdmissionRouter", "LatencyModel", "PagePool", "PriorityClass",
           "QoSPlanner", "QueryBitTracker", "Request", "ServingEngine",
           "SlotScheduler", "handoff_state", "insert_slot_state",
           "insert_slot_state_paged", "make_decode_state",
           "make_paged_pool", "make_paged_state", "make_prefill_state",
           "n_prefill_chunks", "pages_for_rows", "pool_accounting",
           "prefill_len", "reset_state", "rollback_decode_state",
           "rollback_decode_state_paged", "stage_bytes", "state_bytes",
           "zero_pool_pages"]
