"""Lowering-friendly serve/prefill step builders.

These builders wrap the ONE precision-selection implementation —
:class:`repro.core.dynamic_linear.DynamicLinearApplier` — into pure step
functions whose every input (bit-plane overlays, estimator G stacks,
thresholds, l/h tables, and the active target index) is a traced array, so
the production mesh can shard them and one compiled step serves every
target and every request's precision without retracing. The input arrays
follow the target-stacked layout contract of ``core/adaptation`` and
shard under ``distributed/sharding.SERVE_RULES`` (the dry-run lowers
these steps with those shardings on the 512-device meshes).

HBM-traffic honesty (DESIGN.md §2.1/§2.3): overlays arrive pre-truncated to
each unit's h planes, so the lowered HLO reads at most h planes per unit —
the paper's upper bound. The real TPU kernel further skips the (h−l) extra
planes dynamically via scalar-prefetch DMA elision; the jnp fallback lowered
here reads them and masks, which the roofline reports as the conservative
bound (the analytic effective-bits traffic is reported alongside).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.configs.base import ModelConfig
from repro.core.adaptation import DecisionBundle, UnitStatic
from repro.core.dynamic_linear import DynamicLinearApplier
from repro.models import decode_step, forward

__all__ = ["UnitStatic", "build_prefill_step", "build_serve_step"]


def build_serve_step(cfg: ModelConfig,
                     table: Dict[str, UnitStatic],
                     *, backend: Optional[str] = None,
                     use_async: bool = True,
                     bundle: Optional[DecisionBundle] = None) -> Callable:
    """One dynamic-precision decode step (the paper's runtime path).

    ``step(serve_params, state, tokens, target_idx, planned_bits=None)``
    — ``target_idx`` is a traced int32 index into the target-stacked
    adaptation arrays. With a ``bundle``, a traced ``planned_bits`` (U,)
    vector (a :class:`repro.core.decision.PrecisionPlanner` output) turns
    the step into pure lookup-and-apply — the decide/apply split the
    serving engine pipelines; without it, decisions are inline (sync).
    """

    def step(serve_params, state, tokens, target_idx=0,
             planned_bits=None):
        lin = DynamicLinearApplier(table, serve_params,
                                   target_idx=target_idx, backend=backend,
                                   use_async=use_async, bundle=bundle,
                                   planned_bits=planned_bits)
        logits, new_state = decode_step(cfg, serve_params["raw"], state,
                                        tokens, lin=lin)
        return logits, new_state, lin.effective_bits()

    return step


def build_prefill_step(cfg: ModelConfig,
                       table: Dict[str, UnitStatic],
                       *, backend: Optional[str] = None) -> Callable:
    """Prefill at each unit's highest available precision (paper §6.1).

    This is the LOWERING-oriented whole-sequence forward (no KV cache,
    no decisions) used by the dry-run's prefill cells. The serving
    path's prefill is the engine's batched M-row stage
    (``ServingEngine(prefill_chunk=...)``): KV-filling, per-row dynamic
    decisions, bit-identical to tick-by-tick decode.
    """

    def step(serve_params, tokens, frames=None, prefix_embeds=None):
        lin = DynamicLinearApplier(table, serve_params, mode="max",
                                   backend=backend)
        logits, _ = forward(cfg, serve_params["raw"], tokens, lin=lin,
                            frames=frames, prefix_embeds=prefix_embeds,
                            q_chunk=1024, kv_chunk=1024)
        return logits

    return step
