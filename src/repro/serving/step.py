"""Lowering-friendly serve/prefill step builders.

The benchmark engine (``serving/engine.py``) closes over python-side
adaptation artifacts; the *launch/dry-run* path instead needs every array —
bit-plane overlays, estimator G stacks, thresholds — to be a traced INPUT so
the production mesh can shard them. ``build_serve_step`` returns a pure
``step(serve_params, state, tokens)`` driven by a static
:class:`UnitStatic` table.

HBM-traffic honesty (DESIGN.md §2.1/§2.3): overlays arrive pre-truncated to
each unit's h planes, so the lowered HLO reads at most h planes per unit —
the paper's upper bound. The real TPU kernel further skips the (h−l) extra
planes dynamically via scalar-prefetch DMA elision; the jnp fallback lowered
here reads them and masks, which the roofline reports as the conservative
bound (the analytic effective-bits traffic is reported alongside).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.bitplane import QuantizedStacked, materialize_stacked
from repro.kernels.bitserial import bitserial_matmul
from repro.models import decode_step, forward


@dataclass(frozen=True)
class UnitStatic:
    """Trace-time constants for one precision unit."""
    path: str
    l: int
    h: int
    est_kind: str            # "linear" | "jl" | "pinned"
    async_eligible: bool
    stacked: bool = False


class ArrayAdaptationApplier:
    """lin() applier whose adaptation artifacts are traced arrays."""

    def __init__(self, table: Dict[str, UnitStatic],
                 serve_params: Dict[str, object], *,
                 backend: Optional[str] = None, use_async: bool = True):
        self.table = table
        self.raw = serve_params["raw"]
        self.overlays = serve_params["overlays"]
        self.est = serve_params["est"]
        self.backend = backend
        self.use_async = use_async
        self.records = []

    def _select(self, u: UnitStatic, x, async_input):
        if u.l == u.h or u.est_kind == "pinned":
            return jnp.int32(u.l)
        e = self.est[u.path]
        x_est = async_input if (self.use_async and u.async_eligible and
                                async_input is not None) else x
        xf = x_est.reshape((-1, x_est.shape[-1])).astype(jnp.float32)
        if u.est_kind == "linear":
            est = jnp.max(e["a"] * jnp.linalg.norm(xf, axis=-1) + e["b"])
        else:
            est = e["gamma"] * jnp.max(
                jnp.linalg.norm(xf @ e["g"].T, axis=-1))
        return jnp.where(est > e["threshold"], jnp.int32(u.h),
                         jnp.int32(u.l))

    def __call__(self, path: str, x, *, async_input=None):
        u = self.table.get(path)
        if u is None:
            return jnp.einsum("...k,kn->...n", x,
                              self.raw[path]).astype(x.dtype)
        bits = self._select(u, x, async_input)
        ov = self.overlays[path]
        self.records.append((bits, float(ov.k * ov.planes.shape[-1])))
        return bitserial_matmul(x, ov, bits,
                                backend=self.backend).astype(x.dtype)

    def weights(self, path: str, x, *, async_input=None):
        u = self.table.get(path)
        if u is None:
            return self.raw[path]
        ov: QuantizedStacked = self.overlays[path]
        bits = self._select(u, x, async_input)
        e, _, _, n = ov.planes.shape
        self.records.append((bits, float(e * ov.k * n)))
        return materialize_stacked(ov, bits).astype(x.dtype)

    def effective_bits(self):
        if not self.records:
            return jnp.float32(0.0)
        num = sum(b.astype(jnp.float32) * s for b, s in self.records)
        return num / sum(s for _, s in self.records)


def build_serve_step(cfg: ModelConfig,
                     table: Dict[str, UnitStatic],
                     *, backend: Optional[str] = None,
                     use_async: bool = True) -> Callable:
    """One dynamic-precision decode step (the paper's runtime path)."""

    def step(serve_params, state, tokens):
        lin = ArrayAdaptationApplier(table, serve_params, backend=backend,
                                     use_async=use_async)
        logits, new_state = decode_step(cfg, serve_params["raw"], state,
                                        tokens, lin=lin)
        return logits, new_state, lin.effective_bits()

    return step


def build_prefill_step(cfg: ModelConfig,
                       table: Dict[str, UnitStatic],
                       *, backend: Optional[str] = None) -> Callable:
    """Prefill at each unit's highest available precision (paper §6.1)."""
    max_table = {p: UnitStatic(p, u.h, u.h, "pinned", False, u.stacked)
                 for p, u in table.items()}

    def step(serve_params, tokens, frames=None, prefix_embeds=None):
        lin = ArrayAdaptationApplier(max_table, serve_params,
                                     backend=backend)
        logits, _ = forward(cfg, serve_params["raw"], tokens, lin=lin,
                            frames=frames, prefix_embeds=prefix_embeds,
                            q_chunk=1024, kv_chunk=1024)
        return logits

    return step
