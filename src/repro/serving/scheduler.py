"""Slot-based continuous batching with per-request QoS precision targets.

The paper's Figure-1 deployment story at serving scale: requests stream in
with individual TPOT budgets, the :class:`QoSPlanner` maps each budget to
a target precision at admission time, and every admitted request occupies
one *slot* of a shared compiled decode step. The per-slot target enters
the step as a traced index into the target-stacked adaptation arrays, so
heterogeneous targets coexist in one batch without retracing.

Mechanics:

- the engine's single-request decode tick is ``jax.vmap``-ed over the slot
  axis — each slot carries its own KV cache, its own position, its own
  target index, and makes its own per-step precision decisions (the
  estimator reduction never mixes slots);
- precision decisions are PIPELINED (``engine.use_async``, the default):
  the chunk carries a per-slot ``(S, U)`` decision matrix; every tick
  applies it by static row lookup and ONE fused (S, U) planner launch
  (``kernels/jl_estimator.plan_bits`` through its custom_vmap rule)
  replaces it for the next tick — decision work per tick is one kernel,
  not slots × units scattered estimator ops. A freshly admitted request
  runs its tick 0 *at admission time* through the engine's boot tick
  (inline sync decisions — the pipeline seed), exactly like tick 0 of
  ``engine.generate``, so a slot decoding next to strangers stays
  bit-identical to a solo run;
- the per-slot running mask rides into the vmapped tick as the applier's
  ``active`` flag: an idle (``total_len == 0``) or finished slot selects
  ``b_sel = 0``, and the vmapped bit-serial matmul — dispatched through
  ``jax.custom_batching.custom_vmap`` to the slot-batched Pallas kernel —
  fetches **none** of that slot's weight planes (per-slot DMA elision via
  the scalar-prefetched b_sel vector) and skips its MXU work, so busy
  slots never pay for idle ones and every slot's plane traffic is
  ∝ its own precision;
- prefill and decode are DISAGGREGATED stages (``engine.prefill_chunk >
  0``, the default): admission runs the whole prompt as batched M-row
  prefill launches on a recycled batch-1 scratch state — emitting the
  request's first generated token (and its effective bits) at admission
  time — then ONE compiled insert step hands the KV block, SSM tails,
  and decision carry into the freed slot (`serving/kv_cache`'s handoff
  contract; on a mesh the insert compiles prefill-slice shardings in and
  slot shardings out, so the KV block reshards exactly once). Decode
  chunks then never teacher-force: prompts no longer spend O(p) vmapped
  slot ticks inside the shared chunk starving the other slots, and TTFT
  costs O(p / prefill_chunk) launches. ``prefill_chunk=0`` keeps the
  legacy flow (spun boot tick at admission, teacher-forced prompt ticks
  inside the chunk — the disaggregated path's bit-identity reference);
- the host syncs once per *chunk* (not per token) to harvest finished
  slots, record per-request effective bits into the
  :class:`QueryBitTracker`, and admit queued requests into freed slots
  (plus one small pull per admission for the prefill-emitted first
  token);
- with ``paged=True`` the per-slot KV buckets become ONE shared plane
  pool plus per-slot page tables (``serving/kv_cache``'s paged state;
  ``kernels/kv_attention/paged.py``'s kernel) — live pages, not
  worst-case ``max_len`` buckets, bound HBM, so ``n_pages`` admits far
  more slots per byte. The host :class:`~repro.serving.kv_cache.PagePool`
  is the allocator of record: admission reserves the prompt plus one
  chunk's headroom, each chunk GROWS busy slots by ``chunk_advance``
  rows up front and TRIMS to the accepted length afterwards, retire and
  speculative-surplus frees return pages to the pool, and every freed
  page is zeroed before reuse (the zero-rows invariant is stated over
  page content). When the pool runs dry the scheduler preempts — victim
  chosen by the router (least urgent class, youngest admission), never
  anyone at least as urgent as the requester (no ping-pong), pages
  reclaimed and the request requeued at the HEAD of its class; the
  restart replays the plan-once target, so preemption is bit-invisible
  in the output stream. An optional :class:`AdmissionRouter` fronts the
  queue with priority classes and routes each admission's prefill to
  the least-loaded worker, whose queue depth prices the TTFT guard in
  :meth:`QoSPlanner.plan` (``queued_launches``).

Slot-axis array layout — the contract the mesh sharding relies on
-----------------------------------------------------------------
With ``S = slots``, ``P = max_prompt`` and ``L = max_prompt + max_new + 1``,
the compiled chunk carries exactly these per-slot arrays (leading axis is
ALWAYS the slot axis)::

    state        pytree; each leaf (S, 1, ...) — a stacked batch-1 decode
                 state per slot; KV leaves are (S, 1, L, kv_heads, head_dim)
    cur          (S,) int32   last generated token per slot
    step_count   (S,) int32   ticks consumed (prompt + generated)
    bits         (S, U) int32 pipelined decision carry (planner output;
                              admission seeds the row via the boot tick)
    prompt_buf   (S, P) int32 admitted prompt, zero-padded
    prompt_len   (S,) int32   actual prompt length
    total_len    (S,) int32   prompt_len + max_new; 0 marks an idle slot
    target_ix    (S,) int32   per-slot index into the target-stacked arrays

Paged mode swaps the KV leaves for ``page_table (S, 1, ceil(L/page_len))``
int32 (slot axis leading, like every per-slot vector) plus the SHARED
``pool.*`` leaves ``(n_pages, ...)`` — the pool has NO slot axis and rides
through the vmapped tick unbatched (``custom_vmap``); on the mesh the
pool's page axis stays replicated over 'data' (any slot's table may point
at any page — ``distributed/sharding.paged_pool_spec``) while page tables
follow the slot rule (``page_table_spec``).

On the production mesh (``distributed/sharding.SERVE_RULES``) the slot
axis maps onto the 'data' mesh axis — each data-parallel group decodes
its own admitted requests — KV heads shard over 'model' like the
attention weights, and the shared compiled tick is identical across
groups (the engine's no-retrace and host-sync invariants hold unchanged).
Construct the engine with ``mesh=`` to activate this; the scheduler picks
the mesh up from the engine and compiles its chunk and admission steps
with explicit in/out shardings.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (decision_carry_spec,
                                        page_table_spec, paged_pool_spec,
                                        prefill_spec, slot_state_spec,
                                        slot_vec_spec)
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (PagePool, insert_slot_state,
                                    insert_slot_state_paged,
                                    make_decode_state, make_paged_pool,
                                    make_paged_state, make_prefill_state,
                                    n_prefill_chunks, pages_for_rows,
                                    pool_accounting, prefill_len,
                                    reset_state, rollback_decode_state,
                                    rollback_decode_state_paged,
                                    zero_pool_pages)
from repro.serving.qos import AdmissionRouter, QoSPlanner, QueryBitTracker


@dataclass
class Request:
    """One serving request; completion fields are filled by the scheduler."""
    rid: int
    prompt: np.ndarray                 # (p,) int32
    max_new: int
    tpot_budget_s: float
    ttft_budget_s: Optional[float] = None   # admission adds a TTFT term
    # filled on completion:
    target: Optional[float] = None
    tokens: Optional[np.ndarray] = None            # (p + max_new,)
    effective_bits: Optional[np.ndarray] = None    # (max_new,)
    ttft_s: Optional[float] = None     # submit -> first generated token
    _submit_t: Optional[float] = None


@dataclass
class _Slot:
    request: Optional[Request] = None
    gen_tokens: List[int] = field(default_factory=list)
    gen_bits: List[float] = field(default_factory=list)
    admit_order: int = -1     # admission sequence number (victim ordering)


class SlotScheduler:
    """Continuous batching over a fixed pool of decode slots."""

    def __init__(
        self,
        engine: ServingEngine,
        planner: QoSPlanner,
        *,
        slots: int = 4,
        max_prompt: int = 32,
        max_new: int = 32,
        chunk: int = 8,
        mode: str = "dynamic",
        tracker: Optional[QueryBitTracker] = None,
        spec_k: Optional[int] = None,
        paged: bool = False,
        page_len: Optional[int] = None,
        n_pages: Optional[int] = None,
        router: Optional[AdmissionRouter] = None,
        prefill_workers: int = 1,
    ):
        self.engine = engine
        self.planner = planner
        self.n_slots = int(slots)
        self.max_prompt = int(max_prompt)
        self.max_new = int(max_new)
        self.chunk = int(chunk)
        self.tracker = tracker
        self.spec_k = int(spec_k) if spec_k else None
        # cumulative speculative counters (verify windows / accepted
        # drafts over running slots) — the acceptance EMA feed and the
        # closed-form launch-invariant numbers
        self.spec_windows = 0.0
        self.spec_accepted = 0.0
        self.completed: List[Request] = []
        self._queue: deque = deque()
        self._slots = [_Slot() for _ in range(self.n_slots)]
        # admission router / prefill-worker fleet: queueing moves into the
        # router's priority classes when one is supplied (or implied by a
        # multi-worker fleet); without one the plain FIFO deque stands
        self.router = router
        if self.router is None and int(prefill_workers) > 1:
            self.router = AdmissionRouter(
                prefill_workers=int(prefill_workers))
        self._admit_seq = 0
        self.preemptions = 0

        cfg = engine.cfg
        if cfg.vocab_size >= 2 ** 24:   # chunk harvest packs ids via f32
            raise ValueError("vocab too large for f32-exact token packing")
        if self.spec_k is not None:
            if self.spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
            if engine.prefill_chunk <= 0:
                # spec windows never teacher-force: prompts must be
                # consumed by the prefill-at-admission stage
                raise ValueError("spec_k needs a prefill-staged engine "
                                 "(engine.prefill_chunk > 0)")
        s = self.n_slots
        # speculative windows need 2·k rows of KV slack past the last
        # emitted position (verify block + rollback zero-block — see
        # kv_cache.rollback_decode_state)
        max_len = self.max_prompt + self.max_new + 1 + \
            2 * (self.spec_k or 0)
        self.mesh = engine.mesh
        self._mode = mode
        # pipelined decisions ride shotgun with the engine's async flag;
        # a sync engine keeps the legacy all-inline vmapped tick
        self._use_planner = engine.use_async
        self._n_units = engine.artifacts.decision.n_units
        # every scheduler-side state allocation (slot prototype, prefill
        # scratch, re-allocated scratch) matches the engine's KV
        # representation — the prefill→decode handoff is a same-layout
        # insert either way
        self._kv_fmt = {
            "kv_format": "overlay" if engine.kv_overlay else "dense",
            "kv_plane_bits": engine.kv_plane_bits}
        # prefill/decode disaggregation: admission runs the whole prompt
        # as batched prefill launches on a reusable batch-1 scratch state
        # (the prefill stage), then ONE insert step hands the KV block +
        # decision carry into the admitted slot (the decode stage). The
        # engine's prefill_chunk=0 keeps the legacy spun-boot admission.
        self._use_prefill = engine.prefill_chunk > 0
        self._pf_state = None
        self._pf_sh = None
        if self._use_prefill:
            self._pf_state = make_prefill_state(
                cfg, 1, self.max_prompt, engine.prefill_chunk,
                dtype=jnp.float32, **self._kv_fmt)
            self._pf_key = ("slot_pf", 1,
                            prefill_len(self.max_prompt,
                                        engine.prefill_chunk))
            if self.mesh is not None:
                self._pf_sh = {
                    k: NamedSharding(self.mesh,
                                     prefill_spec(self.mesh, k, v.shape))
                    for k, v in self._pf_state.items()}
                self._pf_state = {k: jax.device_put(v, self._pf_sh[k])
                                  for k, v in self._pf_state.items()}
        # paged bitplane-KV pool: per-slot bucketed KV arrays are replaced
        # by ONE shared page store + per-slot page tables. The pool leaves
        # ride inside self._state UNSTACKED (no slot axis — every vmap /
        # scan / insert below uses per-leaf axes so they flow through the
        # compiled steps unbatched; the kernels' custom_vmap rules fold
        # all slots' reads/writes into single gathers/scatters over
        # allocator-disjoint pages). The HOST owns allocation: a numpy
        # page-table mirror is the source of truth, uploaded before every
        # chunk, and the PagePool allocator grows/trims/preempts it.
        self._max_len = max_len
        self._paged = bool(paged)
        if page_len is None:
            # page granularity is the paged kernel's tile_t — consult the
            # tuning cache (kv_paged winners are page lengths) and fall
            # back to the historical default when nothing is tuned
            from repro.kernels.tuning import tuned_tile
            page_len = tuned_tile("kv_paged", n=max_len) or 16
        self.page_len = int(page_len)
        self.page_alloc: Optional[PagePool] = None
        if self._paged:
            if not engine.kv_overlay:
                raise ValueError("paged KV needs the bitplane overlay "
                                 "cache (engine kv_format='overlay')")
            if not self._use_prefill:
                raise ValueError("paged KV needs a prefill-staged engine "
                                 "(engine.prefill_chunk > 0) — the pool "
                                 "is filled through the prefill handoff")
            if self.page_len < 1:
                raise ValueError(f"page_len must be >= 1, got "
                                 f"{self.page_len}")
            self._pages_per_slot = pages_for_rows(max_len, self.page_len)
            if n_pages is None:
                # safe default: every slot can hold its worst case (no
                # savings, no preemption); callers size the pool DOWN to
                # realize the paged savings and let preemption-by-page-
                # reclaim police the budget (+1 for the trash page)
                n_pages = s * self._pages_per_slot + 1
            self.n_pages = int(n_pages)
            self.page_alloc = PagePool(self.n_pages, self.page_len)
            self._page_rows = np.zeros((s, self._pages_per_slot),
                                       np.int32)
            self._slot_pages: List[List[int]] = [[] for _ in range(s)]
            self._host_counts = np.zeros((s,), np.int64)
            # rows one chunk can touch past a slot's count: chunk decode
            # ticks (x k accepts under speculation) plus the verify
            # window's 2k write/rollback slack, plus one row of cushion
            self._chunk_advance = self.chunk * (self.spec_k or 1) + \
                2 * (self.spec_k or 0) + 1
        # per-slot state: each slot is an independent batch-1 decode state
        if self._paged:
            proto = make_paged_state(cfg, 1, max_len, self.page_len,
                                     dtype=jnp.float32)
            pool = make_paged_pool(cfg, self.n_pages, self.page_len,
                                   kv_plane_bits=engine.kv_plane_bits)
            stacked = jax.tree.map(
                lambda x: jnp.zeros((s,) + x.shape, x.dtype), proto)
            self._state = {**stacked, **pool}
            # per-leaf vmap axes: slot-stacked leaves batch on axis 0,
            # pool leaves flow through UNBATCHED (None)
            self._state_axes = {k: (None if k.startswith("pool.") else 0)
                                for k in self._state}
        else:
            proto = make_decode_state(cfg, 1, max_len, dtype=jnp.float32,
                                      **self._kv_fmt)
            self._state = jax.tree.map(
                lambda x: jnp.zeros((s,) + x.shape, x.dtype), proto)
            self._state_axes = None
        self._cur = jnp.zeros((s,), jnp.int32)
        self._step_count = jnp.zeros((s,), jnp.int32)
        self._bits = jnp.zeros((s, self._n_units), jnp.int32)
        self._prompt_buf = jnp.zeros((s, self.max_prompt), jnp.int32)
        self._prompt_len = jnp.zeros((s,), jnp.int32)
        self._total_len = jnp.zeros((s,), jnp.int32)   # 0 => slot idle
        self._target_ix = jnp.zeros((s,), jnp.int32)
        self._shardings = None
        self._state_sh = None
        if self.mesh is not None:
            self._shard_slot_state()

        self._chunk_fn = (
            self._make_spec_chunk(cfg.vocab_size, self.chunk, mode,
                                  self.spec_k)
            if self.spec_k is not None
            else self._make_chunk(cfg.vocab_size, self.chunk, mode))
        self._admit_fn = None if self._use_prefill \
            else self._make_admit(mode)
        self._insert_fn = self._make_insert(mode) if self._use_prefill \
            else None

    def _arrays(self) -> tuple:
        """The carried slot arrays, in compiled-signature order."""
        base = (self._state, self._cur, self._step_count)
        if self._use_planner:
            base = base + (self._bits,)
        return base + (self._prompt_buf, self._prompt_len,
                       self._total_len, self._target_ix)

    def _set_arrays(self, arrays) -> None:
        (self._state, self._cur, self._step_count) = arrays[:3]
        rest = arrays[3:]
        if self._use_planner:
            self._bits, rest = rest[0], rest[1:]
        (self._prompt_buf, self._prompt_len, self._total_len,
         self._target_ix) = rest

    def _shard_slot_state(self) -> None:
        """Map the slot axis onto the 'data' mesh axis.

        Every per-slot array (the stacked decode state, the decision
        carry, and the host control vectors) is device_put with its
        SERVE_RULES sharding, and the compiled chunk/admit steps are
        built with those shardings as explicit in/out shardings — so the
        donated slot state never leaves the mesh between chunks.
        """
        mesh = self.mesh
        state_sh = {}
        for k, v in self._state.items():
            if k.startswith("pool."):
                spec = paged_pool_spec(mesh, k, v.shape)
            elif k == "page_table":
                spec = page_table_spec(mesh, v.shape)
            else:
                spec = slot_state_spec(mesh, k, v.shape)
            state_sh[k] = NamedSharding(mesh, spec)
        self._state_sh = state_sh
        vec_sh = NamedSharding(mesh, slot_vec_spec(
            mesh, (self.n_slots,)))
        buf_sh = NamedSharding(mesh, slot_vec_spec(
            mesh, (self.n_slots, self.max_prompt)))
        bits_sh = NamedSharding(mesh, decision_carry_spec(
            mesh, (self.n_slots, self._n_units)))
        shardings = (state_sh, vec_sh, vec_sh)
        if self._use_planner:
            shardings = shardings + (bits_sh,)
        self._shardings = shardings + (buf_sh, vec_sh, vec_sh, vec_sh)
        self._state = {k: jax.device_put(v, state_sh[k])
                       for k, v in self._state.items()}
        self._cur = jax.device_put(self._cur, vec_sh)
        self._step_count = jax.device_put(self._step_count, vec_sh)
        self._bits = jax.device_put(self._bits, bits_sh)
        self._prompt_buf = jax.device_put(self._prompt_buf, buf_sh)
        self._prompt_len = jax.device_put(self._prompt_len, vec_sh)
        self._total_len = jax.device_put(self._total_len, vec_sh)
        self._target_ix = jax.device_put(self._target_ix, vec_sh)

    # -- compiled pieces ---------------------------------------------------------
    def _tick_pieces(self, count, prompt_buf, prompt_len, total_len, cur):
        """Per-tick control vectors shared by both chunk variants."""
        filling = count < prompt_len
        # running doubles as the per-slot active mask: an idle
        # (total_len == 0) or finished slot selects b_sel = 0 in
        # the applier, so the batched bit-serial kernel fetches
        # none of its weight planes and does no MXU work for it
        running = count < total_len
        idx = jnp.clip(count, 0, prompt_buf.shape[1] - 1)
        ptok = jnp.take_along_axis(prompt_buf, idx[:, None],
                                   axis=1)[:, 0]
        tok = jnp.where(filling, ptok, cur)
        return running, tok

    def _make_chunk(self, vocab: int, length: int, mode: str):
        if self._use_planner:
            tick = self.engine.build_planned_tick(mode)
        else:
            tick = self.engine.build_tick(mode)
        # paged mode: the pool leaves of the state dict stay UNBATCHED
        # under the slot vmap (per-leaf axes) — the paged read/write ops'
        # custom_vmap rules fold every slot's page-indirect access into
        # one gather/scatter over the shared pool
        sa = self._state_axes
        if sa is not None:
            if self._use_planner:
                vtick = jax.vmap(tick, in_axes=(sa, 0, 0, 0, 0),
                                 out_axes=(0, sa, 0, 0))
            else:
                vtick = jax.vmap(tick, in_axes=(sa, 0, 0, 0),
                                 out_axes=(0, sa, 0))
        else:
            vtick = jax.vmap(tick)

        def chunk(state, cur, step_count, *rest):
            key = ("slot_chunk", mode)
            self.engine.trace_counts[key] = \
                self.engine.trace_counts.get(key, 0) + 1
            if self._use_planner:
                (bits, prompt_buf, prompt_len, total_len,
                 target_ix) = rest
            else:
                bits = None
                prompt_buf, prompt_len, total_len, target_ix = rest

            def body(carry, _):
                state, cur, count, bits = carry
                running, tok = self._tick_pieces(
                    count, prompt_buf, prompt_len, total_len, cur)
                if self._use_planner:
                    # lookup-and-apply + ONE fused (S, U) planner launch
                    # deciding the next tick — the (S, U) carry is the
                    # scheduler's half of the async pipeline
                    logits, state, eb, bits = vtick(
                        state, tok[:, None, None], target_ix, bits,
                        running)
                else:
                    logits, state, eb = vtick(
                        state, tok[:, None, None], target_ix, running)
                nxt = jnp.argmax(logits[:, 0, 0, :vocab],
                                 axis=-1).astype(jnp.int32)
                # one mask for tokens AND bits: both come from the tick
                # that PRODUCED the emitted token (ticks prompt_len-1 ..
                # total_len-2). A separate ``running & ~filling`` bits
                # mask would be one tick late — dropping the first
                # generated token's bits and reporting the final,
                # discarded tick's bits instead.
                emit = running & (count >= prompt_len - 1) & \
                    (count < total_len - 1)
                cur = jnp.where(running, nxt, cur)
                count = count + running.astype(jnp.int32)
                return (state, cur, count, bits), (nxt, eb, emit)

            (state, cur, step_count, bits), ys = jax.lax.scan(
                body, (state, cur, step_count, bits), None, length=length)
            lead = (state, cur, step_count)
            if self._use_planner:
                lead = lead + (bits,)
            return lead + ys

        n_carry = 4 if self._use_planner else 3
        if self._shardings is None:
            return jax.jit(chunk, donate_argnums=tuple(range(n_carry)))
        state_sh, vec_sh = self._shardings[0], self._shardings[1]
        # emissions are (chunk, slots): slot axis sharded like the state
        slot_entry = vec_sh.spec[0] if len(vec_sh.spec) else None
        ys_sh = NamedSharding(self.mesh, P(None, slot_entry))
        return jax.jit(chunk, donate_argnums=tuple(range(n_carry)),
                       in_shardings=self._shardings,
                       out_shardings=self._shardings[:n_carry] +
                                     (ys_sh,) * 3)

    def _make_spec_chunk(self, vocab: int, length: int, mode: str, k: int):
        """Speculative chunk: ``length`` draft/verify windows per call.

        Each window drafts ``k - 1`` tokens per slot at the overlay's
        2-bit floor (``engine.build_draft_tick`` under the slot vmap —
        zero planner launches), then verifies all ``S x k`` rows in ONE
        batched launch at planner bits: the verify runner rides
        ``engine.build_verify_rows`` under the same slot vmap, and the
        kernel's nested custom_vmap collapse folds slots x rows onto the
        slot-batched bit-serial kernel's slot axis. Accept/reject is
        PER-SLOT (slots are independent requests — no all-over-batch
        lockstep): slot s advances ``n_acc_s + 1`` positions, emits
        window rows ``m <= n_acc_s`` still inside its budget, rolls its
        KV/SSM back via ``kv_cache.rollback_decode_state`` and rewinds
        its decision-carry row to ``dec[:, n_acc_s]``. Idle/finished
        slots ride along gated (``b_sel = 0``): their projections emit
        zero k/v over rows the zero-rows invariant already keeps zero,
        so only their ssm/conv/pos leaves (which a gated launch still
        advances) need a where-restore. Emissions harvest as
        ``length * k`` chronological rows plus two broadcast counter
        rows (windows / accepted over running slots) feeding the QoS
        planner's acceptance EMA — still ONE host sync per chunk.
        """
        draft = self.engine.build_draft_tick(mode)
        verify = self.engine.build_verify_rows(mode, k)
        use_planner = self._use_planner
        n_units = self._n_units
        paged = self._paged

        def window_slot(state, cur, bits, count, total_len, tix):
            """One window for ONE slot (batch-1 state under the vmap)."""
            running = count < total_len
            orig = {kk: v for kk, v in state.items()
                    if kk.startswith("ssm.") or kk == "pos"}

            def d_body(carry, _):
                st, tok = carry
                logits, st = draft(st, tok[None, None], tix, running)
                nxt = jnp.argmax(logits[0, 0, :vocab]).astype(jnp.int32)
                return (st, nxt), nxt

            (state, _), g = jax.lax.scan(d_body, (state, cur), None,
                                         length=k - 1)       # (k-1,)
            state = dict(state, **orig)   # drafted SSM/pos never leak
            toks = jnp.concatenate([cur[None],
                                    g.astype(jnp.int32)])[None]  # (1, k)
            if use_planner:
                logits, state, ebs, dec, snaps = verify(
                    state, toks, tix, bits, active=running)
            else:
                logits, state, ebs, dec, snaps = verify(
                    state, toks, tix, active=running)
            v = jnp.argmax(logits[0, :, :vocab],
                           axis=-1).astype(jnp.int32)         # (k,)
            if k > 1:
                ok = (g == v[:k - 1]).astype(jnp.int32)
                n_acc = jnp.sum(jnp.cumprod(ok))
            else:
                n_acc = jnp.int32(0)
            if paged:
                # paged rollback: the accepted window's pages are already
                # in the slot's table — zero the rejected rows THROUGH
                # the page indirection; freeing surplus pages back to
                # the pool is the HOST's move (post-sync trim)
                pool = {kk: vv for kk, vv in state.items()
                        if kk.startswith("pool.")}
                core = {kk: vv for kk, vv in state.items()
                        if not kk.startswith("pool.")}
                core, pool = rollback_decode_state_paged(
                    core, pool, snaps, n_acc + 1, k)
                state = {**core, **pool}
            else:
                state = rollback_decode_state(state, snaps, n_acc + 1, k)
            # gated slot: its ssm/conv/pos still advanced through the
            # gated launches — restore the pre-window leaves. KV needs
            # no restore: gated projections wrote zero k/v over rows the
            # zero-rows invariant already keeps zero.
            for kk, ov in orig.items():
                state[kk] = jnp.where(running, state[kk], ov)
            cur = jnp.where(running,
                            jax.lax.dynamic_index_in_dim(
                                v, n_acc, axis=0, keepdims=False), cur)
            if use_planner:
                bits = jnp.where(running,
                                 jax.lax.dynamic_index_in_dim(
                                     dec, n_acc, axis=1, keepdims=False),
                                 bits)
            m = jnp.arange(k, dtype=jnp.int32)
            emit = running & (m <= n_acc) & (count + m < total_len - 1)
            count = count + jnp.where(running, n_acc + 1, 0)
            return (state, cur, bits, count, v, ebs, emit,
                    running.astype(jnp.int32),
                    jnp.where(running, n_acc, 0))

        def chunk(state, cur, step_count, *rest):
            key = ("slot_spec_chunk", mode)
            self.engine.trace_counts[key] = \
                self.engine.trace_counts.get(key, 0) + 1
            if use_planner:
                (bits, prompt_buf, prompt_len, total_len, target_ix) = rest
            else:
                prompt_buf, prompt_len, total_len, target_ix = rest
                bits = jnp.zeros((cur.shape[0], n_units), jnp.int32)

            sa = self._state_axes
            if sa is not None:
                vwindow = jax.vmap(
                    window_slot, in_axes=(sa, 0, 0, 0, 0, 0),
                    out_axes=(sa, 0, 0, 0, 0, 0, 0, 0, 0))
            else:
                vwindow = jax.vmap(window_slot)

            def body(carry, _):
                state, cur, count, bits = carry
                state, cur, bits, count, v, ebs, emit, run_i, acc_i = \
                    vwindow(state, cur, bits, count, total_len, target_ix)
                return (state, cur, count, bits), \
                    (v, ebs, emit, jnp.sum(run_i), jnp.sum(acc_i))

            (state, cur, step_count, bits), ys = jax.lax.scan(
                body, (state, cur, step_count, bits), None, length=length)
            vs, ebss, emits, ws, accs = ys
            # (W, S, k) -> (W*k, S): chronological window-major rows, the
            # same harvest layout as the baseline chunk's (chunk, S)
            rows = lambda a: a.swapaxes(1, 2).reshape(length * k, -1)
            wa = jnp.stack([jnp.sum(ws), jnp.sum(accs)]
                           ).astype(jnp.float32)
            lead = (state, cur, step_count)
            if use_planner:
                lead = lead + (bits,)
            return lead + (rows(vs), rows(ebss), rows(emits), wa)

        n_carry = 4 if use_planner else 3
        if self._shardings is None:
            return jax.jit(chunk, donate_argnums=tuple(range(n_carry)))
        vec_sh = self._shardings[1]
        slot_entry = vec_sh.spec[0] if len(vec_sh.spec) else None
        ys_sh = NamedSharding(self.mesh, P(None, slot_entry))
        rep = NamedSharding(self.mesh, P())
        return jax.jit(chunk, donate_argnums=tuple(range(n_carry)),
                       in_shardings=self._shardings,
                       out_shardings=self._shardings[:n_carry] +
                                     (ys_sh,) * 3 + (rep,))

    def _make_admit(self, mode: str):
        boot = self.engine.build_boot_tick(mode) if self._use_planner \
            else None
        vocab = self.engine.cfg.vocab_size

        def admit(state, cur, step_count, *rest):
            key = ("slot_admit", mode)
            self.engine.trace_counts[key] = \
                self.engine.trace_counts.get(key, 0) + 1
            if self._use_planner:
                (bits, prompt_buf, prompt_len, total_len, target_ix,
                 slot, prow, plen, tot, tix) = rest
            else:
                (prompt_buf, prompt_len, total_len, target_ix,
                 slot, prow, plen, tot, tix) = rest

            if self._use_planner:
                # the admitted request's tick 0 runs HERE — the engine's
                # boot tick (inline sync decisions) on a fresh batch-1
                # state, exactly like tick 0 of engine.generate — so the
                # slot enters the pipelined chunk with real planned bits
                # and the first chunk tick is already lookup-and-apply
                fresh = jax.tree.map(
                    lambda a: jnp.zeros(a.shape[1:], a.dtype), state)
                logits, st1, eb0, bits1 = boot(
                    fresh, prow[0][None, None], tix, jnp.bool_(True))
                nxt = jnp.argmax(
                    logits[0, 0, :vocab]).astype(jnp.int32)
                state = jax.tree.map(lambda a, b: a.at[slot].set(b),
                                     state, st1)
                # (token, eff bits) of tick 0 for the host: emitted iff
                # the prompt is a single token (tick 0 produced output)
                boot_out = jnp.stack([nxt.astype(jnp.float32), eb0])
                out = (state,
                       cur.at[slot].set(nxt),
                       step_count.at[slot].set(1),
                       bits.at[slot].set(bits1))
            else:
                state = jax.tree.map(
                    lambda a: a.at[slot].set(
                        jnp.zeros(a.shape[1:], a.dtype)), state)
                boot_out = jnp.zeros((2,), jnp.float32)
                out = (state, cur.at[slot].set(0),
                       step_count.at[slot].set(0))
            return out + (prompt_buf.at[slot].set(prow),
                          prompt_len.at[slot].set(plen),
                          total_len.at[slot].set(tot),
                          target_ix.at[slot].set(tix),
                          boot_out)

        n_carry = 8 if self._use_planner else 7
        if self._shardings is None:
            return jax.jit(admit, donate_argnums=tuple(range(n_carry)))
        rep = NamedSharding(self.mesh, P())
        buf_rep = NamedSharding(self.mesh, P(None))
        return jax.jit(admit, donate_argnums=tuple(range(n_carry)),
                       in_shardings=self._shardings +
                                    (rep, buf_rep, rep, rep, rep),
                       out_shardings=self._shardings + (rep,))

    def _make_insert(self, mode: str):
        """The prefill→decode HANDOFF step (one compiled call/admission).

        Consumes the prefill stage's filled batch-1 state and writes it
        into the admitted slot: KV block at offset 0 of the slot's cache
        (``kv_cache.insert_slot_state``), SSM tails wholesale, ``pos``/
        ``step_count`` rebased to the prompt length, the decision carry
        into the slot's (S, U) bits row, and the request's control
        vectors. On a mesh it is compiled with the PREFILL specs on the
        incoming state and the SLOT specs on the outputs — GSPMD emits
        the prefill-slice → decode-slice transfer inside this one step,
        which is exactly the KV-handoff contract (identity on a single
        device).
        """

        def ins(state, cur, step_count, *rest):
            key = ("slot_insert", mode)
            self.engine.trace_counts[key] = \
                self.engine.trace_counts.get(key, 0) + 1
            if self._use_planner:
                (bits, prompt_buf, prompt_len, total_len, target_ix,
                 pf_state, slot, tok, carry, prow, plen, tot, tix,
                 *pg) = rest
            else:
                (prompt_buf, prompt_len, total_len, target_ix,
                 pf_state, slot, tok, prow, plen, tot, tix, *pg) = rest
            if self._paged:
                # paged handoff: scatter the prefill KV block into the
                # slot's host-allocated pages (blocks past the allocated
                # prefix land in the trash page — masked-zero rows the
                # reads never reference) and stamp the page-table row
                pool = {kk: vv for kk, vv in state.items()
                        if kk.startswith("pool.")}
                core = {kk: vv for kk, vv in state.items()
                        if not kk.startswith("pool.")}
                core, pool = insert_slot_state_paged(
                    core, pool, pf_state, slot, pg[0], plen)
                state = {**core, **pool}
            else:
                state = insert_slot_state(state, pf_state, slot, 0)
            out = (state, cur.at[slot].set(tok),
                   step_count.at[slot].set(plen))
            if self._use_planner:
                out = out + (bits.at[slot].set(carry),)
            return out + (prompt_buf.at[slot].set(prow),
                          prompt_len.at[slot].set(plen),
                          total_len.at[slot].set(tot),
                          target_ix.at[slot].set(tix))

        n_carry = 8 if self._use_planner else 7
        if self._shardings is None:
            return jax.jit(ins, donate_argnums=tuple(range(n_carry)))
        rep = NamedSharding(self.mesh, P())
        buf_rep = NamedSharding(self.mesh, P(None))
        extra = (self._pf_sh, rep, rep) + \
            ((rep,) if self._use_planner else ()) + \
            (buf_rep, rep, rep, rep) + \
            ((buf_rep,) if self._paged else ())
        return jax.jit(ins, donate_argnums=tuple(range(n_carry)),
                       in_shardings=self._shardings + extra,
                       out_shardings=self._shardings)

    # -- host control loop -------------------------------------------------------
    def submit(self, request: Request) -> None:
        p = len(np.asarray(request.prompt).reshape(-1))
        if p == 0 or p > self.max_prompt:
            raise ValueError(f"prompt length {p} not in [1, "
                             f"{self.max_prompt}]")
        if not 1 <= request.max_new <= self.max_new:
            raise ValueError(f"max_new {request.max_new} not in [1, "
                             f"{self.max_new}]")
        if self._paged:
            # a request that cannot fit even with every other slot
            # preempted would deadlock the admission loop — reject it
            # at the door instead
            worst = min(p + request.max_new - 1 + self._chunk_advance,
                        self._max_len)
            need = pages_for_rows(worst, self.page_len)
            if need > self.n_pages - 1:
                raise ValueError(
                    f"request needs up to {need} pages but the pool has "
                    f"{self.n_pages - 1} allocatable — enlarge n_pages")
        request._submit_t = time.monotonic()
        if self.router is not None:
            self.router.submit(request)
        else:
            self._queue.append(request)

    def _pending(self) -> int:
        return len(self.router) if self.router is not None \
            else len(self._queue)

    def _next_request(self) -> Optional[Request]:
        if self.router is not None:
            return self.router.next_request()
        return self._queue.popleft() if self._queue else None

    @property
    def utilization(self) -> float:
        busy = sum(1 for s in self._slots if s.request is not None)
        return busy / self.n_slots

    def _admit_ready(self) -> None:
        for si, slot in enumerate(self._slots):
            if slot.request is not None or not self._pending():
                continue
            r = self._next_request()
            if r is None:
                break
            prompt = np.asarray(r.prompt, np.int32).reshape(-1)
            # admission reserves the prompt AND the first chunk's
            # headroom — admitting with less would self-preempt at the
            # very next grow and burn the prefill
            if self._paged and not self._ensure_pages(
                    si, len(prompt) + self._chunk_advance,
                    self._urgency(r, self._admit_seq), exclude=si):
                # pool dry with nobody less urgent to preempt: defer the
                # admission (back at the head of its queue) until pages
                # free up — a retiring or trimming slot unblocks it
                if self.router is not None:
                    self.router.requeue(r)
                else:
                    self._queue.appendleft(r)
                break
            launches = n_prefill_chunks(
                len(prompt), self.engine.prefill_chunk) \
                if self._use_prefill else len(prompt)
            # route to the least-loaded prefill worker; the launches
            # already queued ahead enter the TTFT admission price
            wi, ahead = (self.router.route_prefill(launches)
                         if self.router is not None else (0, 0))
            if r.target is None:
                # planned once, at FIRST admission: a preemption restart
                # must replay the same precision, or the regenerated
                # stream would diverge from the unpreempted run
                r.target = self.planner.plan(
                    r.tpot_budget_s, self.utilization,
                    prompt_len=len(prompt), ttft_budget_s=r.ttft_budget_s,
                    prefill_chunk=self.engine.prefill_chunk or None,
                    queued_launches=ahead)
            if self._use_prefill:
                self._admit_prefill(si, r, prompt)
                if self.router is not None:
                    self.router.finish_prefill(wi, launches)
                continue
            if self.router is not None:
                self.router.finish_prefill(wi, launches)
            tix = self.engine.artifacts.target_index(r.target)
            prow = np.zeros((self.max_prompt,), np.int32)
            prow[:len(prompt)] = prompt
            with self.engine._mesh_ctx():
                out = self._admit_fn(
                    *self._arrays(), jnp.int32(si), jnp.asarray(prow),
                    jnp.int32(len(prompt)),
                    jnp.int32(len(prompt) + r.max_new), jnp.int32(tix))
            self._set_arrays(out[:-1])
            self._slots[si] = _Slot(request=r,
                                    admit_order=self._admit_seq)
            self._admit_seq += 1
            if self._use_planner and len(prompt) == 1:
                # tick 0 (run at admission) already produced this
                # request's first generated token + its bits
                boot_out = np.asarray(out[-1])
                self._slots[si].gen_tokens.append(int(boot_out[0]))
                self._slots[si].gen_bits.append(float(boot_out[1]))
                if r._submit_t is not None:
                    r.ttft_s = time.monotonic() - r._submit_t

    # -- host page management (paged mode) --------------------------------------
    def _urgency(self, request, admit_order: int) -> tuple:
        """Preemption ordering key: (class priority, admission order) —
        smaller is more urgent. Without a router every request is class
        0, so urgency is pure admission order (oldest wins)."""
        pr = self.router.classify(request).priority \
            if self.router is not None else 0
        return (pr, admit_order)

    def _ensure_pages(self, si: int, n_rows: int,
                      requester: tuple,
                      exclude: Optional[int] = None) -> bool:
        """Grow slot ``si``'s page table to cover ``n_rows`` rows.

        When the pool runs dry, preemption-by-page-reclaim kicks in: the
        victim order (least urgent class, then youngest admission) names
        a running slot whose pages are reclaimed — exactly its pages,
        zeroed for reuse — and whose request restarts from prefill
        later. Only slots STRICTLY less urgent than ``requester`` are
        eligible: a grow may never evict someone more urgent than the
        slot asking (two same-class slots would otherwise preempt each
        other forever — the ping-pong livelock). Returns False when no
        pages AND no eligible victim remain; the caller defers or
        self-preempts.
        """
        need = pages_for_rows(min(int(n_rows), self._max_len),
                              self.page_len)
        while len(self._slot_pages[si]) < need:
            got = self.page_alloc.alloc(
                need - len(self._slot_pages[si]), owner=si)
            if got is None:
                vi = self._pick_victim(requester, exclude=exclude)
                if vi is None:
                    return False
                self._preempt(vi)
                continue
            start = len(self._slot_pages[si])
            self._slot_pages[si].extend(got)
            self._page_rows[si, start:start + len(got)] = got
        return True

    def _trim_slot(self, si: int, n_rows: int) -> List[int]:
        """Free pages past what ``n_rows`` rows need (returns the freed
        ids, NOT yet zeroed — callers batch the zeroing). Trimmed pages
        hold only rows the rollback already zeroed, so the pool's
        zero-rows invariant survives the round trip."""
        keep = pages_for_rows(min(int(n_rows), self._max_len),
                              self.page_len)
        extra = self._slot_pages[si][keep:]
        if extra:
            self._slot_pages[si] = self._slot_pages[si][:keep]
            self._page_rows[si, keep:] = 0
            self.page_alloc.free(extra)
        return extra

    def _release_pages(self, si: int) -> List[int]:
        """Give ALL of slot ``si``'s pages back to the pool."""
        ids = self._slot_pages[si]
        if ids:
            self.page_alloc.free(ids)
        self._slot_pages[si] = []
        self._page_rows[si, :] = 0
        return ids

    def _zero_freed(self, ids: Sequence[int]) -> None:
        """Zero freed pages' contents — a page re-entering the pool must
        read as zero rows (the invariant every gated write and rollback
        relies on)."""
        pool = {k: v for k, v in self._state.items()
                if k.startswith("pool.")}
        pool = zero_pool_pages(pool, list(ids))
        self._state.update(pool)

    def _pick_victim(self, requester: tuple,
                     exclude: Optional[int] = None) -> Optional[int]:
        cands = [(i, s.request, s.admit_order)
                 for i, s in enumerate(self._slots)
                 if s.request is not None and i != exclude
                 and self._urgency(s.request, s.admit_order) > requester]
        if not cands:
            return None
        if self.router is not None:
            return self.router.pick_victim(cands)
        return max(cands, key=lambda t: t[2])[0]   # youngest admission

    def _preempt(self, si: int) -> None:
        """Evict slot ``si``: reclaim exactly its pages (zeroed), mark
        the device slot idle, and requeue the request at the HEAD of its
        class — it restarts from prefill, and the deterministic replay
        keeps its token stream identical to an unpreempted run."""
        slot = self._slots[si]
        r = slot.request
        freed = self._release_pages(si)
        if freed:
            self._zero_freed(freed)
        self._total_len = self._total_len.at[si].set(0)
        if self._shardings is not None:
            self._total_len = jax.device_put(self._total_len,
                                             self._shardings[1])
        self._host_counts[si] = 0
        self._slots[si] = _Slot()
        self.preemptions += 1
        r.ttft_s = None        # TTFT re-stamps at re-admission, so the
        if self.router is not None:     # preemption wait stays in the SLO
            self.router.requeue(r)
        else:
            self._queue.appendleft(r)

    def _grow_and_sync(self) -> None:
        """Pre-chunk page work: grow every busy slot's table to cover
        the rows this chunk may write, then upload the host page tables
        (the numpy mirror is the source of truth)."""
        for si, slot in enumerate(self._slots):
            if slot.request is None:
                continue
            if not self._ensure_pages(
                    si, int(self._host_counts[si]) + self._chunk_advance,
                    self._urgency(slot.request, slot.admit_order),
                    exclude=si):
                # pool dry and nobody less urgent to reclaim from: the
                # over-budget slot itself gives its pages back
                self._preempt(si)
        pt = jnp.asarray(self._page_rows[:, None, :])
        if self._state_sh is not None:
            pt = jax.device_put(pt, self._state_sh["page_table"])
        self._state["page_table"] = pt

    def paged_stats(self) -> dict:
        """Pool accounting (live vs. allocated bytes, fragmentation,
        high-watermark — ``kv_cache.pool_accounting``) plus scheduler
        counters. ``{}`` when not paged."""
        if not self._paged:
            return {}
        pool = {k: v for k, v in self._state.items()
                if k.startswith("pool.")}
        live = int(sum(int(self._host_counts[i])
                       for i, s in enumerate(self._slots)
                       if s.request is not None))
        out = pool_accounting(pool, self.page_alloc, live_rows=live)
        out["preemptions"] = self.preemptions
        return out

    def _admit_prefill(self, si: int, r: Request,
                       prompt: np.ndarray) -> None:
        """Disaggregated admission: prefill stage -> KV handoff -> slot.

        The whole prompt runs as ``ceil(p / prefill_chunk)`` batched
        launches on the recycled batch-1 prefill scratch (its buffers
        are donated through every launch and the reset — zero new HBM
        per admission), emitting the request's FIRST generated token and
        its effective bits at admission time; ONE insert step then hands
        the KV block, SSM tails, and decision carry into the slot. The
        decode chunks see ``step_count = prompt_len``, so they never
        teacher-force — prompts no longer spend O(p) vmapped slot ticks
        inside the shared chunk, and long prompts stop starving the
        other slots.
        """
        eng = self.engine
        C = eng.prefill_chunk
        p = len(prompt)
        n_ch = n_prefill_chunks(p, C)
        tix = eng.artifacts.target_index(r.target)
        toks = np.zeros((1, n_ch * C), np.int32)
        toks[0, :p] = prompt
        gold = np.zeros((1, n_ch * C), np.int32)
        if self._pf_state is None:       # lost to a failed admission
            self._pf_state = make_prefill_state(
                eng.cfg, 1, self.max_prompt, C, dtype=jnp.float32,
                **self._kv_fmt)
            if self._pf_sh is not None:
                self._pf_state = {k: jax.device_put(v, self._pf_sh[k])
                                  for k, v in self._pf_state.items()}
        state = reset_state(self._pf_state)
        self._pf_state = None            # buffers in flight (donated)
        with eng._mesh_ctx():
            for nv, state, cur, bits, _, ec, _ in eng.iter_prefill(
                    self._mode, state, toks, gold, p, jnp.int32(tix),
                    want_nll=False, state_sh=self._pf_sh,
                    cache_key=self._pf_key, counter="slot_prefill"):
                pass
            first_bits = ec[nv - 1]      # the tick that produced token 0
            extra = (state, jnp.int32(si), cur[0])
            if self._use_planner:
                extra = extra + (bits,)
            prow = np.zeros((self.max_prompt,), np.int32)
            prow[:p] = prompt
            extra = extra + (jnp.asarray(prow), jnp.int32(p),
                             jnp.int32(p + r.max_new), jnp.int32(tix))
            if self._paged:
                extra = extra + (jnp.asarray(self._page_rows[si]),)
            eng.call_counts["slot_insert"] = \
                eng.call_counts.get("slot_insert", 0) + 1
            out = self._insert_fn(*self._arrays(), *extra)
        self._set_arrays(out)
        self._pf_state = state           # recycle scratch next admission
        host = np.asarray(jnp.stack([cur[0].astype(jnp.float32),
                                     first_bits]))
        self._slots[si] = _Slot(request=r, admit_order=self._admit_seq)
        self._admit_seq += 1
        if self._paged:
            self._host_counts[si] = p
        self._slots[si].gen_tokens.append(int(host[0]))
        self._slots[si].gen_bits.append(float(host[1]))
        if r._submit_t is not None:
            r.ttft_s = time.monotonic() - r._submit_t

    def _run_chunk(self) -> None:
        n_carry = 4 if self._use_planner else 3
        if self._paged:
            self._grow_and_sync()
        with self.engine._mesh_ctx():
            out = self._chunk_fn(*self._arrays())
        self._set_arrays(out[:n_carry] + self._arrays()[n_carry:])
        if self.spec_k is not None:
            toks, ebs, emit, wa = out[n_carry:]
            c = self.chunk * self.spec_k
            extra = [jnp.broadcast_to(wa[:, None], (2, self.n_slots))]
        else:
            toks, ebs, emit = out[n_carry:]
            c = self.chunk
            extra = []
        # ONE host sync per chunk: pack emissions + slot progress into a
        # single device array and pull it once (token ids are exact in
        # f32 — vocab sizes sit far below 2**24)
        host = np.asarray(jnp.concatenate([
            toks.astype(jnp.float32), ebs.astype(jnp.float32),
            emit.astype(jnp.float32),
            self._step_count[None, :].astype(jnp.float32),
            self._total_len[None, :].astype(jnp.float32),
            *extra], axis=0))
        toks = host[:c].astype(np.int32)
        ebs = host[c:2 * c]
        emit = host[2 * c:3 * c] > 0.5
        counts, totals = host[3 * c], host[3 * c + 1]
        if self.spec_k is not None:
            w_tot, a_tot = float(host[3 * c + 2, 0]), \
                float(host[3 * c + 3, 0])
            self.spec_windows += w_tot
            self.spec_accepted += a_tot
            if (self.spec_k > 1 and w_tot > 0
                    and hasattr(self.planner, "observe_acceptance")):
                self.planner.observe_acceptance(
                    a_tot / (w_tot * (self.spec_k - 1)))
        for si, slot in enumerate(self._slots):
            if slot.request is None:
                continue
            slot.gen_tokens.extend(toks[emit[:, si], si].tolist())
            slot.gen_bits.extend(ebs[emit[:, si], si].tolist())
            if counts[si] >= totals[si]:
                self._retire(si)
        if self._paged:
            # post-chunk trim: speculative rejections can leave a slot's
            # table ahead of its count — give the surplus back (batched
            # zeroing, one donated launch for all trimmed pages)
            freed: List[int] = []
            for si, slot in enumerate(self._slots):
                if slot.request is None:
                    continue
                self._host_counts[si] = int(counts[si])
                freed += self._trim_slot(
                    si, int(counts[si]) + self._chunk_advance)
            if freed:
                self._zero_freed(freed)

    def _retire(self, si: int) -> None:
        slot = self._slots[si]
        r = slot.request
        prompt = np.asarray(r.prompt, np.int32).reshape(-1)
        r.tokens = np.concatenate(
            [prompt, np.asarray(slot.gen_tokens[:r.max_new], np.int32)])
        r.effective_bits = np.asarray(slot.gen_bits[:r.max_new])
        if self.tracker is not None:
            self.tracker.record_query(r.effective_bits)
        self.completed.append(r)
        self._slots[si] = _Slot()
        if self._paged:
            freed = self._release_pages(si)
            if freed:
                self._zero_freed(freed)
            self._host_counts[si] = 0

    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> List[Request]:
        """Drive admission + fused chunks until all requests complete.

        Returns the requests completed by THIS call; ``self.completed``
        keeps the cumulative history across waves.
        """
        start = len(self.completed)
        for r in (requests or ()):
            self.submit(r)
        while self._pending() or any(s.request is not None
                                     for s in self._slots):
            self._admit_ready()
            self._run_chunk()
        return self.completed[start:]
