"""DP-LLM serving engine: dynamic-precision batched decode.

``ServingEngine`` wraps a built :class:`MultiScaleModel`:
- overlays are truncated to each unit's Phase-1 max precision — device
  memory equals the Any-Precision budget, not the parent B;
- one jit'd decode step per (target precision, mode): the
  DynamicLinearApplier selects l/h per unit per step and the step returns
  the realized effective bitwidth alongside the logits;
- greedy generation, teacher-forced evaluation (the paper evaluates
  perplexity as a teacher-forced decoding process — precision decisions
  happen per decoding step), and per-query effective-bit tracking for the
  QoS analysis (paper §6.3).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.adaptation import MultiScaleModel
from repro.core.bitplane import (QuantizedStacked, truncate_overlay,
                                 truncate_stacked)
from repro.core.dynamic_linear import DynamicLinearApplier
from repro.core.thresholds import delta_weight_of
from repro.models import decode_step
from repro.serving.kv_cache import make_decode_state


@dataclass
class StepStats:
    effective_bits: float
    logits: np.ndarray


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict[str, jax.Array],
        model: MultiScaleModel,
        *,
        backend: Optional[str] = None,
        use_async: bool = True,
    ):
        self.cfg = cfg
        self.model = model
        self.backend = backend
        self.use_async = use_async
        # raw params for non-unit paths (norms, router, embeds, conv, head)
        self.raw = {k: v for k, v in params.items()
                    if k not in model.overlays}
        # memory-budget overlays: truncated to Phase-1 max precision
        self.overlays = {}
        for path, ov in model.overlays.items():
            h = model.max_bits[path]
            self.overlays[path] = (
                truncate_stacked(ov, h) if isinstance(ov, QuantizedStacked)
                else truncate_overlay(ov, h))
        self._steps: Dict[Tuple[float, str], callable] = {}
        self._exact_deltas: Dict[float, Dict[str, jax.Array]] = {}

    # -- step compilation -------------------------------------------------------
    def _make_step(self, target: float, mode: str):
        aset = self.model.adaptations[target]
        exact = self._exact_deltas.get(target) if mode == "exact" else None

        def step(state, tokens):
            lin = DynamicLinearApplier(
                self.raw, self.overlays, aset, mode=mode,
                use_async=self.use_async, backend=self.backend,
                exact_deltas=exact)
            logits, new_state = decode_step(self.cfg, self.raw, state,
                                            tokens, lin=lin)
            return logits, new_state, lin.effective_bits()

        return jax.jit(step, donate_argnums=(0,))

    def _make_static_step(self, method: str, target: float):
        bits_table = self.model.static_tables[method][target]

        def step(state, tokens):
            lin = DynamicLinearApplier(
                self.raw, self.overlays, None, static_bits=bits_table,
                mode="static", backend=self.backend)
            logits, new_state = decode_step(self.cfg, self.raw, state,
                                            tokens, lin=lin)
            return logits, new_state, lin.effective_bits()

        return jax.jit(step, donate_argnums=(0,))

    def get_step(self, target: float, mode: str = "dynamic"):
        key = (target, mode)
        if key not in self._steps:
            if mode == "exact" and target not in self._exact_deltas:
                aset = self.model.adaptations[target]
                self._exact_deltas[target] = {
                    ua.path: delta_weight_of(self.overlays[ua.path],
                                             ua.l, ua.h)
                    for ua in aset.units.values()
                    if ua.l != ua.h and ua.est is not None}
            if mode.startswith("static:"):
                self._steps[key] = self._make_static_step(
                    mode.split(":", 1)[1], target)
            else:
                self._steps[key] = self._make_step(target, mode)
        return self._steps[key]

    # -- evaluation / generation -----------------------------------------------
    def teacher_forced_nll(
        self, tokens: np.ndarray, target: float, mode: str = "dynamic",
        prime_len: int = 1,
    ) -> Tuple[float, List[float]]:
        """Per-token NLL over ``tokens`` (batch, seq) with per-step dynamic
        precision; returns (mean_nll, per-step effective bits)."""
        step = self.get_step(target, mode)
        b, s = tokens.shape
        state = make_decode_state(self.cfg, b, s + 1, dtype=jnp.float32)
        nlls, ebits = [], []
        toks = jnp.asarray(tokens)
        for t in range(s - 1):
            logits, state, eb = step(state, toks[:, t:t + 1])
            logp = jax.nn.log_softmax(
                logits[:, 0, : self.cfg.vocab_size].astype(jnp.float32))
            gold = jnp.take_along_axis(logp, toks[:, t + 1][:, None],
                                       axis=-1)
            if t + 1 >= prime_len:
                nlls.append(float(-jnp.mean(gold)))
            ebits.append(float(eb))
        return float(np.mean(nlls)), ebits

    def generate(
        self, prompt: np.ndarray, max_new: int, target: float,
        mode: str = "dynamic",
    ) -> Tuple[np.ndarray, List[float]]:
        """Greedy decode; returns (tokens (b, prompt+max_new), eff bits)."""
        step = self.get_step(target, mode)
        b, p = prompt.shape
        state = make_decode_state(self.cfg, b, p + max_new + 1,
                                  dtype=jnp.float32)
        ebits: List[float] = []
        toks = jnp.asarray(prompt)
        out = [toks]
        cur = None
        for t in range(p):  # prefill via teacher forcing (exact priming)
            logits, state, eb = step(state, toks[:, t:t + 1])
        cur = jnp.argmax(logits[:, :, : self.cfg.vocab_size], axis=-1)
        for _ in range(max_new):
            out.append(cur)
            logits, state, eb = step(state, cur)
            ebits.append(float(eb))
            cur = jnp.argmax(logits[:, :, : self.cfg.vocab_size], axis=-1)
        return np.asarray(jnp.concatenate(out, axis=1)), ebits

    # -- accounting ---------------------------------------------------------------
    def overlay_bytes(self) -> int:
        total = 0
        for ov in self.overlays.values():
            total += int(np.prod(ov.planes.shape)) * 4
            total += int(np.prod(ov.scale.shape)) * 8
        return total
