"""DP-LLM serving engine: dynamic-precision fused-scan batched decode.

``ServingEngine`` wraps a built :class:`MultiScaleModel`:

- overlays are truncated to each unit's Phase-1 max precision — device
  memory equals the Any-Precision budget, not the parent B;
- ONE jit'd decode step per *mode* (not per target): every adaptation
  artifact is exported as a target-stacked traced array
  (:func:`repro.core.adaptation.export_serve_arrays`) and the active
  target is a traced index, so switching targets never retraces;
- ``generate`` / ``teacher_forced_nll`` run as ``lax.scan``-fused
  multi-token decode in fixed-size chunks (bounded compile time, chunk
  graphs reused across query lengths). Per-step effective bits accumulate
  on device and sync to the host O(1) times per query — never per token;
- per-query effective-bit tracking feeds the QoS analysis (paper §6.3).

Instrumentation: ``trace_counts`` counts Python traces of each compiled
entry point (the no-retrace guarantee is testable), ``host_syncs`` counts
device→host transfer points (the O(1)-syncs guarantee is testable).

Mesh-native serving: constructed with ``mesh=``, the engine device_puts
every serve-side array — raw params, truncated overlays, and the
target-stacked adaptation artifacts — with ``SERVE_RULES`` shardings
(weights/overlays K-sharded over 'pod', N over 'model'; target axis and
JL sketch rows replicated), and the fused decode chunk is jit-compiled
with explicit ``in_shardings``/``out_shardings`` so GSPMD partitions the
scan body instead of replicating it. ``mesh=None`` (the default) is the
unchanged single-device path.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.adaptation import (MultiScaleModel, export_serve_arrays,
                                   export_static_arrays, overlay_nbytes,
                                   serve_array_axes)
from repro.core.bitplane import (QuantizedLinear, QuantizedStacked,
                                 truncate_overlay, truncate_stacked)
from repro.core.dynamic_linear import DynamicLinearApplier
from repro.core.thresholds import delta_weight_of
from repro.distributed.context import use_mesh
from repro.distributed.sharding import (SERVE_RULES, decode_state_spec,
                                        overlay_shardings, resolve_spec)
from repro.models import decode_step, model_logical_axes
from repro.serving.kv_cache import make_decode_state


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict[str, jax.Array],
        model: MultiScaleModel,
        *,
        backend: Optional[str] = None,
        use_async: bool = True,
        decode_chunk: int = 16,
        kv_bucket: int = 128,
        mesh: Optional[Mesh] = None,
    ):
        self.cfg = cfg
        self.model = model
        self.backend = backend
        self.use_async = use_async
        self.decode_chunk = int(decode_chunk)
        self.kv_bucket = int(kv_bucket)
        self.mesh = mesh
        # raw params for non-unit paths (norms, router, embeds, conv, head)
        self.raw = {k: v for k, v in params.items()
                    if k not in model.overlays}
        # memory-budget overlays: truncated to Phase-1 max precision
        self.overlays = {}
        for path, ov in model.overlays.items():
            h = model.max_bits[path]
            self.overlays[path] = (
                truncate_stacked(ov, h) if isinstance(ov, QuantizedStacked)
                else truncate_overlay(ov, h))
        # target-stacked adaptation arrays: the ONE precision-selection
        # representation, shared by every mode and target
        self.artifacts = export_serve_arrays(model)
        self.est = {p: {k: jnp.asarray(v) for k, v in e.items()}
                    for p, e in self.artifacts.est.items()}
        self._exact_est: Optional[Dict] = None
        self._static_arrays: Dict[str, Dict[str, jax.Array]] = {}
        self._ticks: Dict[str, Callable] = {}
        self._chunks: Dict[Tuple, Callable] = {}
        self.trace_counts: Dict[Tuple[str, str], int] = {}
        self.host_syncs = 0
        if mesh is not None:
            self._shard_serve_state()

    # -- mesh placement ----------------------------------------------------------
    def _put(self, arr, spec) -> jax.Array:
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, spec))

    def _shard_serve_state(self) -> None:
        """device_put every serve-side array with SERVE_RULES shardings.

        Raw params and overlays shard like weights (K→'pod', N→'model');
        the target-stacked artifacts follow ``serve_array_axes`` (target
        axis and JL rows replicated, K axis alongside the gated weight).
        """
        mesh, axes = self.mesh, model_logical_axes(self.cfg)
        for path, v in self.raw.items():
            self.raw[path] = self._put(
                v, resolve_spec(v.shape, axes[path], mesh, SERVE_RULES))
        for path, ov in self.overlays.items():
            sh = overlay_shardings(mesh, ov, axes[path],
                                   isinstance(ov, QuantizedStacked))
            self.overlays[path] = type(ov)(
                jax.device_put(jnp.asarray(ov.planes), sh["planes"]),
                jax.device_put(jnp.asarray(ov.scale), sh["scale"]),
                jax.device_put(jnp.asarray(ov.zero), sh["zero"]),
                ov.bits, ov.k)
        self._art_axes = serve_array_axes(self.artifacts.table, axes)
        for path, entry in self.est.items():
            for name, arr in entry.items():
                entry[name] = self._put(
                    arr, resolve_spec(arr.shape, self._art_axes[path][name],
                                      mesh, SERVE_RULES))

    def _mesh_ctx(self):
        """Active-mesh context for in-model sharding hints (no-op w/o mesh)."""
        return use_mesh(self.mesh) if self.mesh is not None else \
            contextlib.nullcontext()

    # -- mode-specific artifact views -------------------------------------------
    def _est_for(self, mode: str) -> Dict:
        if mode != "exact":
            return self.est
        if self._exact_est is None:
            exact = {}
            for path, e in self.est.items():
                u = self.artifacts.table[path]
                ov = self.overlays[path]
                if (u.est_kind == "pinned"
                        or not isinstance(ov, QuantizedLinear)):
                    # stacked (MoE) units keep their fitted estimator —
                    # the exact ΔW stack is only built for plain linears
                    exact[path] = e
                    continue
                ls, hs = self.artifacts.est[path]["l"], \
                    self.artifacts.est[path]["h"]
                delta = jnp.stack([delta_weight_of(ov, int(l), int(h))
                                   for l, h in zip(ls, hs)])
                if self.mesh is not None:
                    delta = self._put(delta, resolve_spec(
                        delta.shape, self._art_axes[path]["delta"],
                        self.mesh, SERVE_RULES))
                exact[path] = dict(e, delta=delta)
            self._exact_est = exact
        return self._exact_est

    def _static_for(self, method: str) -> Dict[str, jax.Array]:
        if method not in self._static_arrays:
            conv = (jnp.asarray if self.mesh is None
                    else lambda v: self._put(v, P(None)))
            self._static_arrays[method] = {
                p: conv(v)
                for p, v in export_static_arrays(self.model, method).items()}
        return self._static_arrays[method]

    # -- the single decode tick --------------------------------------------------
    def build_tick(self, mode: str = "dynamic") -> Callable:
        """Untraced ``tick(state, tokens, target_idx, active=None)``.

        The scheduler vmaps this over a slot axis (per-slot positions,
        targets, and effective bits); the engine scans it over tokens.
        ``active`` (per-slot under vmap) gates precision selection: an
        inactive (idle/retired) slot selects 0 bits, so the batched
        bit-serial kernel fetches none of its planes and its quantized
        matmuls cost no HBM traffic or MXU work.
        """
        base_mode, static_bits = mode, None
        if mode.startswith("static:"):
            base_mode = "static"
            static_bits = self._static_for(mode.split(":", 1)[1])
        est = self._est_for(base_mode)
        serve_params = {"raw": self.raw, "overlays": self.overlays,
                        "est": est}

        def tick(state, tokens, target_idx, active=None):
            lin = DynamicLinearApplier(
                self.artifacts.table, serve_params,
                target_idx=target_idx, mode=base_mode,
                static_bits=static_bits, use_async=self.use_async,
                backend=self.backend, active=active)
            logits, new_state = decode_step(self.cfg, self.raw, state,
                                            tokens, lin=lin)
            return logits, new_state, lin.effective_bits()

        return tick

    def _get_tick(self, mode: str) -> Callable:
        """Jitted single step, shared by all targets of ``mode``."""
        if mode not in self._ticks:
            tick = self.build_tick(mode)

            def counted(state, tokens, target_idx):
                key = ("tick", mode)
                self.trace_counts[key] = self.trace_counts.get(key, 0) + 1
                return tick(state, tokens, target_idx)

            self._ticks[mode] = jax.jit(counted, donate_argnums=(0,))
        return self._ticks[mode]

    def get_step(self, target: float, mode: str = "dynamic"):
        """Compat shim: ``step(state, tokens)`` at a fixed target.

        All targets of a mode share one compiled function — the target
        enters as a traced index, so calling this for a new target does
        not recompile.
        """
        fn = self._get_tick(mode)
        t_idx = jnp.int32(self.artifacts.target_index(target))
        return lambda state, tokens: fn(state, tokens, t_idx)

    # -- fused chunked decode ----------------------------------------------------
    def _get_chunk(self, mode: str, want_nll: bool,
                   state_sh=None, cache_key: Tuple = ()) -> Callable:
        """Jitted scan over ``decode_chunk`` ticks.

        ``chunk(state, cur, toks, use_prompt, gold, target_idx)`` where
        ``toks``/``gold`` are (b, C) teacher/gold tokens and ``use_prompt``
        (C,) selects teacher forcing vs. feeding the generated token.
        Returns (state, cur, tokens_out (C, b), eff_bits (C,),
        gold_logp (C, b)) — everything stays on device. With
        ``want_nll=False`` the per-tick full-vocab log-softmax is skipped
        (generation discards it) and gold_logp is zeros.

        On a mesh the chunk is compiled with explicit in/out shardings:
        the donated decode state keeps its KV sharding across chunks,
        control vectors and emissions stay replicated (``state_sh`` is the
        state's sharding tree; ``cache_key`` disambiguates state shapes,
        whose divisibility decides the resolved specs).
        """
        key = (mode, want_nll) + tuple(cache_key)
        if key in self._chunks:
            return self._chunks[key]
        tick = self.build_tick(mode)
        vocab = self.cfg.vocab_size

        def chunk(state, cur, toks, use_prompt, gold, target_idx):
            tkey = ("chunk", mode)
            self.trace_counts[tkey] = self.trace_counts.get(tkey, 0) + 1

            def body(carry, xs):
                state, cur = carry
                tok_col, use_p, gold_col = xs
                tok = jnp.where(use_p, tok_col, cur)[:, None]
                logits, state, eb = tick(state, tok, target_idx)
                if want_nll:
                    logp = jax.nn.log_softmax(
                        logits[:, 0, :vocab].astype(jnp.float32), axis=-1)
                    gold_lp = jnp.take_along_axis(
                        logp, gold_col[:, None], axis=-1)[:, 0]
                else:
                    gold_lp = jnp.zeros(tok_col.shape, jnp.float32)
                nxt = jnp.argmax(logits[:, 0, :vocab],
                                 axis=-1).astype(jnp.int32)
                return (state, nxt), (nxt, eb, gold_lp)

            (state, cur), (toks_out, ebs, gold_lps) = jax.lax.scan(
                body, (state, cur), (toks.T, use_prompt, gold.T))
            return state, cur, toks_out, ebs, gold_lps

        if self.mesh is None:
            self._chunks[key] = jax.jit(chunk, donate_argnums=(0,))
        else:
            rep = NamedSharding(self.mesh, P())
            self._chunks[key] = jax.jit(
                chunk, donate_argnums=(0,),
                in_shardings=(state_sh, rep, rep, rep, rep, rep),
                out_shardings=(state_sh, rep, rep, rep, rep))
        return self._chunks[key]

    def _run_chunks(self, mode: str, toks: np.ndarray,
                    use_prompt: np.ndarray, gold: np.ndarray,
                    target_idx: jax.Array, *, want_nll: bool):
        """Drive the fused chunks over ``total`` ticks; device outputs."""
        b, total = toks.shape
        c = self.decode_chunk
        n_chunks = -(-total // c)
        padded = n_chunks * c
        pad = padded - total
        toks = np.pad(toks, ((0, 0), (0, pad)))
        gold = np.pad(gold, ((0, 0), (0, pad)))
        use_prompt = np.pad(use_prompt, (0, pad), constant_values=True)
        # bucketed KV length: queries of different lengths share the same
        # compiled chunk (shape reuse), at a bounded memory overshoot
        kv = self.kv_bucket
        max_len = -(-(padded + 1) // kv) * kv
        state = make_decode_state(self.cfg, b, max_len, dtype=jnp.float32)
        state_sh = None
        if self.mesh is not None:
            state_sh = {k: NamedSharding(self.mesh, decode_state_spec(
                self.mesh, k, v.shape)) for k, v in state.items()}
            state = {k: jax.device_put(v, state_sh[k])
                     for k, v in state.items()}
        chunk_fn = self._get_chunk(mode, want_nll, state_sh=state_sh,
                                   cache_key=(b, max_len))
        cur = jnp.zeros((b,), jnp.int32)
        out_t, out_e, out_g = [], [], []
        # any device->host pull inside the decode loop is a per-token sync
        # regression; on accelerator backends the guard turns it into a
        # hard error (on CPU, arrays are host-resident and it cannot fire,
        # so the ``host_syncs`` counter remains the tested invariant there)
        with self._mesh_ctx(), jax.transfer_guard_device_to_host("disallow"):
            for ci in range(n_chunks):
                sl = slice(ci * c, (ci + 1) * c)
                state, cur, tc, ec, gc = chunk_fn(
                    state, cur, jnp.asarray(toks[:, sl]),
                    jnp.asarray(use_prompt[sl]), jnp.asarray(gold[:, sl]),
                    target_idx)
                out_t.append(tc)
                out_e.append(ec)
                out_g.append(gc)
            return (jnp.concatenate(out_t, axis=0),
                    jnp.concatenate(out_e, axis=0),
                    jnp.concatenate(out_g, axis=0))

    # -- evaluation / generation -----------------------------------------------
    def teacher_forced_nll(
        self, tokens: np.ndarray, target: float, mode: str = "dynamic",
        prime_len: int = 1,
    ) -> Tuple[float, List[float]]:
        """Per-token NLL over ``tokens`` (batch, seq) with per-step dynamic
        precision; returns (mean_nll, per-step effective bits).

        The whole sequence runs as fused on-device scans — ONE host sync
        at the end, regardless of sequence length.
        """
        tokens = np.asarray(tokens)
        b, s = tokens.shape
        total = s - 1
        if total <= 0:          # nothing to predict on a 1-token sequence
            return float("nan"), []
        t_idx = jnp.int32(self.artifacts.target_index(target))
        _, ebs, gold_lps = self._run_chunks(
            mode, tokens[:, :total].astype(np.int32),
            np.ones((total,), bool),
            tokens[:, 1:].astype(np.int32), t_idx, want_nll=True)
        self.host_syncs += 1
        host = np.asarray(jnp.concatenate(
            [ebs[:total], jnp.mean(gold_lps[:total], axis=-1)]))
        ebits, gold_mean = host[:total], host[total:]
        nll = float(np.mean(-gold_mean[max(prime_len - 1, 0):]))
        return nll, [float(e) for e in ebits]

    def generate(
        self, prompt: np.ndarray, max_new: int, target: float,
        mode: str = "dynamic",
    ) -> Tuple[np.ndarray, List[float]]:
        """Greedy decode; returns (tokens (b, prompt+max_new), eff bits).

        Prefill (teacher-forced over the prompt) and generation run as one
        fused chunked scan; the generated tokens and per-step effective
        bits accumulate on device and sync to the host a constant number
        of times per query (two pulls), independent of token count.
        """
        prompt = np.asarray(prompt)
        b, p = prompt.shape
        if p == 0:
            raise ValueError("generate() needs a non-empty prompt")
        total = p + max_new
        t_idx = jnp.int32(self.artifacts.target_index(target))
        toks = np.zeros((b, total), np.int32)
        toks[:, :p] = prompt
        toks_out, ebs, _ = self._run_chunks(
            mode, toks, np.arange(total) < p, np.zeros((b, total), np.int32),
            t_idx, want_nll=False)
        gen = toks_out[p - 1:p - 1 + max_new].T          # (b, max_new)
        out = jnp.concatenate([jnp.asarray(prompt), gen], axis=1)
        self.host_syncs += 2
        tokens_np = np.asarray(out)
        # ebits[i] is the tick that PRODUCED generated token i: the token
        # emitted at position p+i comes out of tick p-1+i, so the bits
        # slice is aligned with the token slice above (not shifted one
        # tick late, which would drop the first generated token's bits and
        # report the final, discarded tick instead)
        ebits = [float(e) for e in np.asarray(ebs[p - 1:p - 1 + max_new])]
        return tokens_np, ebits

    # -- accounting ---------------------------------------------------------------
    def overlay_bytes(self) -> int:
        """Resident (Phase-1 truncated) overlay bytes, actual itemsizes."""
        return self.overlay_bytes_report()["truncated"]

    def overlay_bytes_report(self) -> Dict[str, int]:
        """Truncated (serving-resident) vs. full-parent overlay bytes."""
        return {"truncated": overlay_nbytes(self.overlays),
                "full_parent": overlay_nbytes(self.model.overlays)}
