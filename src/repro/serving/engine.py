"""DP-LLM serving engine: dynamic-precision fused-scan batched decode.

``ServingEngine`` wraps a built :class:`MultiScaleModel`:

- overlays are truncated to each unit's Phase-1 max precision — device
  memory equals the Any-Precision budget, not the parent B;
- ONE jit'd decode step per *mode* (not per target): every adaptation
  artifact is exported as a target-stacked traced array
  (:func:`repro.core.adaptation.export_serve_arrays`) and the active
  target is a traced index, so switching targets never retraces;
- ``generate`` / ``teacher_forced_nll`` run as a TWO-STAGE pipeline:
  the prompt executes as the batched PREFILL stage —
  ``ceil(prompt_len / prefill_chunk)`` M-row fused launches with
  per-row precision decisions (bit-identical to the legacy
  tick-by-tick path, which ``prefill_chunk=0`` preserves) — and the
  generation ticks as ``lax.scan``-fused decode chunks seeded by the
  prefill's decision carry (bounded compile time, chunk graphs reused
  across query lengths). Per-step effective bits accumulate on device
  and sync to the host O(1) times per query — never per token;
- per-query effective-bit tracking feeds the QoS analysis (paper §6.3).

Pipelined decision pass (``use_async=True``, the default): the scan
carries the planner's ``(U,)`` decision vector as state. Tick *t*'s
applier is pure lookup-and-apply (zero estimator ops between matmuls);
at the end of tick *t* the :class:`repro.core.decision.PrecisionPlanner`
turns the tick's captured residual-stream activations into tick *t+1*'s
bits in ONE fused launch — the paper's async estimator scheme, with the
decision work off the decode critical path. Tick 0 of every query runs
as a separate "boot" tick with inline (sync, same-tick) decisions — the
pipeline's seed — and ``use_async=False`` keeps the fully-inline legacy
chunks. ``mode=static/max/exact`` route through the same planner
(static/max plan with no estimator work at all).

Instrumentation: ``trace_counts`` counts Python traces of each compiled
entry point (the no-retrace guarantee is testable), ``host_syncs`` counts
device→host transfer points (the O(1)-syncs guarantee is testable).

Mesh-native serving: constructed with ``mesh=``, the engine device_puts
every serve-side array — raw params, truncated overlays, and the
target-stacked adaptation artifacts — with ``SERVE_RULES`` shardings
(weights/overlays K-sharded over 'pod', N over 'model'; target axis and
JL sketch rows replicated), and the fused decode chunk is jit-compiled
with explicit ``in_shardings``/``out_shardings`` so GSPMD partitions the
scan body instead of replicating it. ``mesh=None`` (the default) is the
unchanged single-device path.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.adaptation import (MultiScaleModel, export_serve_arrays,
                                   export_static_arrays, overlay_nbytes,
                                   serve_array_axes)
from repro.core.bitplane import (QuantizedLinear, QuantizedStacked,
                                 truncate_overlay, truncate_stacked)
from repro.core.decision import PrecisionPlanner, draft_floor_bits
from repro.core.dynamic_linear import (DynamicLinearApplier,
                                       StaticDraftLinear,
                                       materialize_draft_weights)
from repro.core.thresholds import delta_weight_of
from repro.distributed.context import use_mesh
from repro.distributed.sharding import (SERVE_RULES, decision_carry_spec,
                                        decode_state_spec,
                                        overlay_shardings, resolve_spec)
from repro.models import decode_step, model_logical_axes
from repro.serving.kv_cache import (make_decode_state, make_paged_pool,
                                    pages_for_rows, rollback_decode_state)


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict[str, jax.Array],
        model: MultiScaleModel,
        *,
        backend: Optional[str] = None,
        use_async: bool = True,
        use_grouped: bool = True,
        decode_chunk: int = 16,
        prefill_chunk: Optional[int] = 16,
        kv_bucket: int = 128,
        mesh: Optional[Mesh] = None,
        kv_overlay: bool = False,
        kv_plane_bits: int = 8,
        kv_read: str = "plane",
        kv_dynamic: bool = True,
        kv_backend: Optional[str] = None,
    ):
        self.cfg = cfg
        self.model = model
        self.backend = backend
        self.use_async = use_async
        # dynamic-precision KV cache: store the full kv_plane_bits-deep
        # bitplane stack per token and let the planner pick each layer's
        # READ precision per tick. kv_read="dense" keeps the plane store
        # but materializes full-precision rows (the parity oracle);
        # kv_dynamic=False pins every read to the full stack (kv_bits is
        # None on every tick) — the bit-identity configuration.
        self.kv_overlay = bool(kv_overlay)
        self.kv_plane_bits = int(kv_plane_bits)
        self.kv_read = kv_read
        self.kv_dynamic = bool(kv_dynamic)
        self.kv_backend = kv_backend
        # MoE expert units stream through the grouped bit-serial kernel
        # (per-expert plane-DMA elision) instead of materializing dense
        # (E, K, N) / per-row (M, E, K, N) stacks. False = legacy dense
        # materialization (the grouped path's parity oracle).
        self.use_grouped = use_grouped
        self.decode_chunk = int(decode_chunk)
        # batched prefill stage: a whole prompt (or a prefill_chunk-sized
        # piece of a long one) runs as ONE M-row fused launch instead of
        # M teacher-forced decode ticks. None/0 keeps the legacy
        # tick-by-tick path (the prefill stage's bit-identity reference).
        self.prefill_chunk = int(prefill_chunk or 0)
        self.kv_bucket = int(kv_bucket)
        self.mesh = mesh
        # raw params for non-unit paths (norms, router, embeds, conv, head)
        self.raw = {k: v for k, v in params.items()
                    if k not in model.overlays}
        # memory-budget overlays: truncated to Phase-1 max precision
        self.overlays = {}
        for path, ov in model.overlays.items():
            h = model.max_bits[path]
            self.overlays[path] = (
                truncate_stacked(ov, h) if isinstance(ov, QuantizedStacked)
                else truncate_overlay(ov, h))
        # target-stacked adaptation arrays: the ONE precision-selection
        # representation, shared by every mode and target
        self.artifacts = export_serve_arrays(model)
        self.est = {p: {k: jnp.asarray(v) for k, v in e.items()}
                    for p, e in self.artifacts.est.items()}
        self._exact_est: Optional[Dict] = None
        self._static_arrays: Dict[str, Dict[str, jax.Array]] = {}
        self._ticks: Dict[Tuple[str, str], Callable] = {}
        self._chunks: Dict[Tuple, Callable] = {}
        self._boots: Dict[Tuple, Callable] = {}
        self._prefills: Dict[Tuple, Callable] = {}
        self._planners: Dict[str, PrecisionPlanner] = {}
        self._specs: Dict[Tuple, Callable] = {}
        # dense floor-bit draft weights (lazy; see build_draft_tick)
        self._draft_dense: Optional[Dict[str, jax.Array]] = None
        # per-query speculative stats (windows, accepted, acceptance_rate,
        # launches_per_token) — refreshed by every generate(spec_k=...)
        self.last_spec: Dict[str, float] = {}
        self.trace_counts: Dict[Tuple[str, str], int] = {}
        # compiled-call launch counters ("prefill"/"boot"/"chunk"): the
        # O(prompt_len / prefill_chunk)-launches guarantee is testable
        self.call_counts: Dict[str, int] = {}
        self.host_syncs = 0
        if mesh is not None:
            self._shard_serve_state()

    # -- decode-state construction ----------------------------------------------
    def _make_state(self, batch: int, max_len: int):
        """The engine's ONE decode-state factory: every query state (and
        the scheduler's slot/prefill prototypes, via ``state_factory``)
        is built here, so the KV representation is decided in exactly
        one place."""
        return make_decode_state(
            self.cfg, batch, max_len, dtype=jnp.float32,
            kv_format="overlay" if self.kv_overlay else "dense",
            kv_plane_bits=self.kv_plane_bits)

    def _kv_kw(self, planned_bits=None, active=None) -> Dict:
        """``decode_step`` KV-read kwargs for one tick.

        With a planned (U,) vector on a dynamic-KV engine, the tail
        rows past ``n_weight_units`` ARE the per-layer KV read bits —
        sliced here, gated by ``active`` like every other decision.
        Every other tick (sync/boot/prefill/draft/verify) reads the
        full plane stack (``kv_bits=None``)."""
        if not self.kv_overlay:
            return {}
        kw = {"kv_read": self.kv_read, "kv_backend": self.kv_backend}
        if planned_bits is not None and self.kv_dynamic:
            kv_bits = planned_bits[self.artifacts.decision.weight_units:]
            if active is not None:
                kv_bits = jnp.where(jnp.asarray(active), kv_bits, 0)
            kw["kv_bits"] = kv_bits
        return kw

    # -- mesh placement ----------------------------------------------------------
    def _put(self, arr, spec) -> jax.Array:
        return jax.device_put(jnp.asarray(arr),
                              NamedSharding(self.mesh, spec))

    def _shard_serve_state(self) -> None:
        """device_put every serve-side array with SERVE_RULES shardings.

        Raw params and overlays shard like weights (K→'pod', N→'model');
        the target-stacked artifacts follow ``serve_array_axes`` (target
        axis and JL rows replicated, K axis alongside the gated weight).
        """
        mesh, axes = self.mesh, model_logical_axes(self.cfg)
        for path, v in self.raw.items():
            self.raw[path] = self._put(
                v, resolve_spec(v.shape, axes[path], mesh, SERVE_RULES))
        for path, ov in self.overlays.items():
            sh = overlay_shardings(mesh, ov, axes[path],
                                   isinstance(ov, QuantizedStacked))
            self.overlays[path] = type(ov)(
                jax.device_put(jnp.asarray(ov.planes), sh["planes"]),
                jax.device_put(jnp.asarray(ov.scale), sh["scale"]),
                jax.device_put(jnp.asarray(ov.zero), sh["zero"]),
                ov.bits, ov.k)
        self._art_axes = serve_array_axes(self.artifacts.table, axes)
        for path, entry in self.est.items():
            for name, arr in entry.items():
                entry[name] = self._put(
                    arr, resolve_spec(arr.shape, self._art_axes[path][name],
                                      mesh, SERVE_RULES))

    def _mesh_ctx(self):
        """Active-mesh context for in-model sharding hints (no-op w/o mesh)."""
        return use_mesh(self.mesh) if self.mesh is not None else \
            contextlib.nullcontext()

    # -- mode-specific artifact views -------------------------------------------
    def _est_for(self, mode: str) -> Dict:
        if mode != "exact":
            return self.est
        if self._exact_est is None:
            exact = {}
            for path, e in self.est.items():
                u = self.artifacts.table[path]
                ov = self.overlays[path]
                if (u.est_kind == "pinned"
                        or not isinstance(ov, QuantizedLinear)):
                    # stacked (MoE) units keep their fitted estimator —
                    # the exact ΔW stack is only built for plain linears
                    exact[path] = e
                    continue
                ls, hs = self.artifacts.est[path]["l"], \
                    self.artifacts.est[path]["h"]
                delta = jnp.stack([delta_weight_of(ov, int(l), int(h))
                                   for l, h in zip(ls, hs)])
                if self.mesh is not None:
                    delta = self._put(delta, resolve_spec(
                        delta.shape, self._art_axes[path]["delta"],
                        self.mesh, SERVE_RULES))
                exact[path] = dict(e, delta=delta)
            self._exact_est = exact
        return self._exact_est

    def _static_for(self, method: str) -> Dict[str, jax.Array]:
        if method not in self._static_arrays:
            conv = (jnp.asarray if self.mesh is None
                    else lambda v: self._put(v, P(None)))
            self._static_arrays[method] = {
                p: conv(v)
                for p, v in export_static_arrays(self.model, method).items()}
        return self._static_arrays[method]

    # -- mode plumbing -----------------------------------------------------------
    def _mode_env(self, mode: str):
        """(base_mode, static_bits, serve_params) for a mode string."""
        base_mode, static_bits = mode, None
        if mode.startswith("static:"):
            base_mode = "static"
            static_bits = self._static_for(mode.split(":", 1)[1])
        est = self._est_for(base_mode)
        return base_mode, static_bits, {"raw": self.raw,
                                        "overlays": self.overlays,
                                        "est": est}

    def planner(self, mode: str = "dynamic") -> PrecisionPlanner:
        """The mode's fused decision planner (shared by all targets)."""
        if mode not in self._planners:
            base_mode, static_stack, exact_deltas = mode, None, None
            if mode.startswith("static:"):
                base_mode = "static"
                static_stack = self.artifacts.decision.stack_static(
                    self._static_for(mode.split(":", 1)[1]))
            if base_mode == "exact":
                exact_deltas = {p: e["delta"]
                                for p, e in self._est_for("exact").items()
                                if "delta" in e}
            put = None
            if self.mesh is not None:
                put = lambda a: self._put(a, P())   # tables replicate
            self._planners[mode] = PrecisionPlanner(
                self.artifacts.decision, mode=base_mode,
                static_stack=static_stack, exact_deltas=exact_deltas,
                backend=self.backend, put=put)
        return self._planners[mode]

    # -- the single decode tick --------------------------------------------------
    def build_tick(self, mode: str = "dynamic") -> Callable:
        """Untraced inline ``tick(state, tokens, target_idx, active=None)``.

        The *sync* tick: every unit's precision is decided inline from
        the current tick's activations (the legacy per-unit path). Used
        for ``use_async=False`` and as the reference semantics; the
        pipelined hot path uses :meth:`build_planned_tick`. ``active``
        (per-slot under vmap) gates precision selection: an inactive
        (idle/retired) slot selects 0 bits, so the batched bit-serial
        kernel fetches none of its planes and its quantized matmuls cost
        no HBM traffic or MXU work.
        """
        base_mode, static_bits, serve_params = self._mode_env(mode)

        def tick(state, tokens, target_idx, active=None):
            lin = DynamicLinearApplier(
                self.artifacts.table, serve_params,
                target_idx=target_idx, mode=base_mode,
                static_bits=static_bits, use_async=self.use_async,
                backend=self.backend, grouped=self.use_grouped,
                active=active,
                bundle=self.artifacts.decision)
            logits, new_state = decode_step(self.cfg, self.raw, state,
                                            tokens, lin=lin,
                                            **self._kv_kw())
            return logits, new_state, lin.effective_bits()

        return tick

    def build_planned_tick(self, mode: str = "dynamic") -> Callable:
        """Untraced pipelined ``tick(state, tokens, target_idx,
        planned_bits, active=None) -> (logits, state, eff_bits,
        next_bits)``.

        The decode hot path: the applier is pure lookup-and-apply over
        ``planned_bits`` (zero estimator ops between the matmuls), and
        ONE fused planner launch at the end of the tick turns the
        captured activations into the NEXT tick's decisions (the paper's
        async pipelining — decisions are one tick stale by design). With
        ``planned_bits=None`` the applier falls back to inline (sync,
        same-tick) decisions — the boot variant. The scheduler vmaps
        this over its slot axis; the planner's custom_vmap rule
        collapses that into one (S, U) launch.
        """
        base_mode, static_bits, serve_params = self._mode_env(mode)
        planner = self.planner(mode)

        def tick(state, tokens, target_idx, planned_bits=None,
                 active=None):
            lin = DynamicLinearApplier(
                self.artifacts.table, serve_params,
                target_idx=target_idx, mode=base_mode,
                static_bits=static_bits, use_async=self.use_async,
                backend=self.backend, grouped=self.use_grouped,
                active=active,
                bundle=self.artifacts.decision,
                planned_bits=planned_bits, capture=planner.needs_acts)
            logits, new_state = decode_step(
                self.cfg, self.raw, state, tokens, lin=lin,
                **self._kv_kw(planned_bits, active))
            acts = lin.planner_inputs() if planner.needs_acts else None
            next_bits = planner.plan(acts, target_idx, active)
            return logits, new_state, lin.effective_bits(), next_bits

        return tick

    def build_boot_tick(self, mode: str = "dynamic") -> Callable:
        """Untraced pipeline-seeding tick: the planned tick with NO
        planned bits — inline (sync) decisions plus the planner pass
        over the tick's captured activations, returning ``(logits,
        state, eff_bits, next_bits)``. Tick 0 of every query (and of
        every admitted scheduler slot) runs through this, so the first
        pipelined tick starts with real decisions instead of a cold
        vector."""
        planned = self.build_planned_tick(mode)

        def tick(state, tokens, target_idx, active=None):
            return planned(state, tokens, target_idx, None, active)

        return tick

    def build_prefill_rows(self, mode: str, rows: int,
                           carried: bool) -> Callable:
        """Untraced M-row prefill pass: ``run(state, tokens (b, M),
        target_idx, n_valid[, carry]) -> (logits, state, eff_bits (M,),
        dec (U, M))``.

        One launch replaces M teacher-forced ticks: the applier decides
        every row's precision in one vectorized pass (row m applies row
        m-1's decision under ``use_async`` — ``carry`` seeds row 0 when
        ``carried``, else row 0 boots with its own sync decision), the
        per-row bit-serial matmuls ride the slot-batched kernel, and
        ``dec[:, n_valid-1]`` is the decision carry the decode stage's
        first pipelined tick applies (the prefill→decode handoff, KV
        side handled by ``serving.kv_cache``).
        """
        base_mode, static_bits, serve_params = self._mode_env(mode)

        def run(state, tokens, target_idx, n_valid, carry=None):
            lin = DynamicLinearApplier(
                self.artifacts.table, serve_params,
                target_idx=target_idx, mode=base_mode,
                static_bits=static_bits, use_async=self.use_async,
                backend=self.backend, grouped=self.use_grouped,
                bundle=self.artifacts.decision,
                rows=rows, carry_bits=carry)
            logits, new_state = decode_step(self.cfg, self.raw, state,
                                            tokens, lin=lin,
                                            n_valid=n_valid,
                                            **self._kv_kw())
            return logits, new_state, lin.effective_bits(), \
                lin.planned_rows()

        if carried:
            return run
        return lambda state, tokens, target_idx, n_valid: \
            run(state, tokens, target_idx, n_valid)

    def build_draft_tick(self, mode: str = "dynamic") -> Callable:
        """Untraced speculative DRAFT tick: ``tick(state, tokens (b, 1),
        target_idx, active=None) -> (logits, state)``.

        Every unit is pinned to the overlay's 2-bit floor via a STATIC
        plan (:func:`repro.core.decision.draft_floor_bits`): a draft
        tick reads only the first two bit-planes of the same weights —
        the any-precision overlay's free draft model — with ZERO planner
        launches and zero estimator ops. Identical across modes (the
        floor doesn't depend on the estimator); drafted KV rows are
        garbage the verify launch overwrites, and the caller restores
        the SSM/pos leaves it snapshotted before drafting.

        Two executions of the same function: on the Pallas backend the
        lookup-mode applier drives the bit-serial kernel, whose per-slot
        index_map clamp makes a 2-bit tick fetch exactly two plane
        blocks (the DMA elision IS the draft's cheapness). Where the
        matmul would run the jnp oracle — whose plane loop costs
        full-``B`` compute regardless of ``b_sel`` — the floor prefix is
        instead materialized ONCE into dense weights
        (:class:`StaticDraftLinear`) so a draft tick is one GEMV per
        unit. Same floor-bit function up to float association; draft
        rounding only steers acceptance, the verify launch re-derives
        every emitted token. The dense path ignores ``active``: every
        drafted row (KV written past ``pos``) is overwritten by the
        gated verify launch, zeros included for idle slots.
        """
        base_mode, static_bits, serve_params = self._mode_env(mode)
        on_kernel = self.backend == "pallas" or (
            self.backend is None and jax.default_backend() == "tpu")
        if not on_kernel and self.mesh is None:
            # single-device oracle fast path; under a mesh the overlay
            # arrays already carry SERVE_RULES placements and the
            # bit-serial draft below reuses them as-is
            if self._draft_dense is None:
                self._draft_dense = materialize_draft_weights(
                    self.overlays, draft_floor_bits(self.artifacts.decision),
                    self.artifacts.decision.row_of)
            lin_dense = StaticDraftLinear(self.raw, self._draft_dense)

            def dense_tick(state, tokens, target_idx, active=None):
                logits, new_state = decode_step(self.cfg, self.raw, state,
                                                tokens, lin=lin_dense,
                                                **self._kv_kw())
                return logits, new_state

            return dense_tick
        draft_vec = draft_floor_bits(self.artifacts.decision)

        def tick(state, tokens, target_idx, active=None):
            lin = DynamicLinearApplier(
                self.artifacts.table, serve_params,
                target_idx=target_idx, mode=base_mode,
                static_bits=static_bits, use_async=self.use_async,
                backend=self.backend, grouped=self.use_grouped,
                active=active,
                bundle=self.artifacts.decision, planned_bits=draft_vec)
            logits, new_state = decode_step(self.cfg, self.raw, state,
                                            tokens, lin=lin,
                                            **self._kv_kw())
            return logits, new_state

        return tick

    def build_verify_rows(self, mode: str, k: int) -> Callable:
        """Untraced speculative VERIFY launch: ``run(state, tokens (b, k),
        target_idx[, carry], active=None) -> (logits, state, eff_bits
        (k,), dec (U, k), snaps)``.

        ONE batched k-row launch at the planner-assigned bits, reusing
        the prefill-stage decode cells (``decode_step`` M>1 —
        ``ssm_decode_rows``/``moe_decode_rows``) with per-row precision
        through the slot-batched kernel (rows ride the kernel's slot
        axis; under the scheduler's slot vmap the nested custom_vmap
        collapse folds all S·k rows into one launch). Row semantics are
        the prefill contract: under ``use_async`` row m applies row
        m-1's decision with ``carry`` seeding row 0 — exactly the
        pipelined bits baseline ticks would have applied — so greedy
        verification is token- AND bits-identical to baseline decode.
        ``decode_step(row_states=True)`` adds the per-row SSM snapshots
        accept/reject rolls back with; ``dec[:, n_acc]`` is the carry
        rewind (row n_acc's plan = baseline's next-tick decision).
        """
        base_mode, static_bits, serve_params = self._mode_env(mode)
        carried = self.use_async

        def run(state, tokens, target_idx, carry=None, active=None):
            lin = DynamicLinearApplier(
                self.artifacts.table, serve_params,
                target_idx=target_idx, mode=base_mode,
                static_bits=static_bits, use_async=self.use_async,
                backend=self.backend, grouped=self.use_grouped,
                active=active,
                bundle=self.artifacts.decision, rows=k, carry_bits=carry)
            logits, new_state, snaps = decode_step(
                self.cfg, self.raw, state, tokens, lin=lin,
                row_states=True, **self._kv_kw())
            return logits, new_state, lin.effective_bits(), \
                lin.planned_rows(), snaps

        if carried:
            return run
        return lambda state, tokens, target_idx, active=None: \
            run(state, tokens, target_idx, active=active)

    def _get_prefill(self, mode: str, want_nll: bool, boot: bool,
                     state_sh=None, cache_key: Tuple = ()) -> Callable:
        """Jitted prefill launch over one ``prefill_chunk``-row bucket.

        Async: ``pf(state[, carry], toks (b, C), gold (b, C), n_valid,
        target_idx) -> (state, cur (b,), next_carry (U,), toks_out
        (C, b), eff_bits (C,), gold_logp (C, b))`` — the boot variant
        (first chunk of a query) takes no ``carry`` and seeds row 0 with
        its own sync decision. Sync (``use_async=False``): no carry in
        or out. Emissions are row-aligned with the sequential ticks the
        launch replaces; ``cur``/``next_carry`` are row ``n_valid - 1``'s
        (the last REAL prompt row — pad rows of the bucketed final chunk
        never feed the decode stage).
        """
        C = self.prefill_chunk
        key = (mode, want_nll, boot) + tuple(cache_key)
        if key in self._prefills:
            return self._prefills[key]
        carried = self.use_async and not boot
        run = self.build_prefill_rows(mode, C, carried)
        vocab = self.cfg.vocab_size

        def emit_rows(logits, gold):
            lv = logits[:, :, :vocab]
            nxt = jnp.argmax(lv, axis=-1).astype(jnp.int32)    # (b, C)
            if want_nll:
                logp = jax.nn.log_softmax(lv.astype(jnp.float32), axis=-1)
                gold_lp = jnp.take_along_axis(
                    logp, gold[..., None], axis=-1)[..., 0]
            else:
                gold_lp = jnp.zeros(gold.shape, jnp.float32)
            return nxt, gold_lp

        def body(state, toks, gold, n_valid, t_idx, carry=None):
            tkey = ("prefill", mode)
            self.trace_counts[tkey] = self.trace_counts.get(tkey, 0) + 1
            n_valid = jnp.asarray(n_valid, jnp.int32)
            args = (state, toks, t_idx, n_valid) + \
                ((carry,) if carried else ())
            logits, state, ebs, dec = run(*args)
            nxt, gold_lp = emit_rows(logits, gold)
            cur = jnp.take_along_axis(nxt, (n_valid - 1)[None, None],
                                      axis=1)[:, 0]
            out = (state, cur)
            if self.use_async:
                out = out + (dec[:, n_valid - 1],)
            return out + (nxt.T, ebs, gold_lp.T)

        if carried:
            pf = lambda state, carry, toks, gold, n_valid, t_idx: \
                body(state, toks, gold, n_valid, t_idx, carry)
        else:
            pf = lambda state, toks, gold, n_valid, t_idx: \
                body(state, toks, gold, n_valid, t_idx)

        n_in = 6 if carried else 5
        n_out = 6 if self.use_async else 5
        if self.mesh is None:
            self._prefills[key] = jax.jit(pf, donate_argnums=(0,))
        else:
            rep = NamedSharding(self.mesh, P())
            in_sh = [state_sh] + [rep] * (n_in - 1)
            out_sh = [state_sh] + [rep] * (n_out - 1)
            if carried:
                in_sh[1] = self._bits_sharding()
            if self.use_async:
                out_sh[2] = self._bits_sharding()
            self._prefills[key] = jax.jit(
                pf, donate_argnums=(0,),
                in_shardings=tuple(in_sh), out_shardings=tuple(out_sh))
        return self._prefills[key]

    def iter_prefill(self, mode: str, state, toks_pf: np.ndarray,
                     gold_pf: np.ndarray, n_pf: int, target_idx,
                     *, want_nll: bool, state_sh=None,
                     cache_key: Tuple = (), counter: str = "prefill"):
        """Drive the prefill stage: ``ceil(n_pf / prefill_chunk)``
        launches over ``toks_pf`` (already padded to whole chunks),
        threading the boot/carry protocol and the launch counter.

        Yields ``(nv, state, cur, bits, toks_out, eff_bits, gold_lps)``
        per launch (``bits`` is None for a sync engine; ``nv`` is the
        chunk's valid-row count). The ONE place the prefill callable's
        signature is assembled — the engine's two-stage path and the
        scheduler's prefill-at-admission both drive through here.
        """
        C = self.prefill_chunk
        bits = None
        for ci in range(-(-n_pf // C)):
            boot = (ci == 0) if self.use_async else True
            nv = min(C, n_pf - ci * C)
            pf = self._get_prefill(mode, want_nll, boot,
                                   state_sh=state_sh, cache_key=cache_key)
            args = (state,)
            if self.use_async and not boot:
                args = args + (bits,)
            args = args + (jnp.asarray(toks_pf[:, ci * C:(ci + 1) * C]),
                           jnp.asarray(gold_pf[:, ci * C:(ci + 1) * C]),
                           jnp.int32(nv), target_idx)
            self.call_counts[counter] = \
                self.call_counts.get(counter, 0) + 1
            out = pf(*args)
            if self.use_async:
                state, cur, bits, tc, ec, gc = out
            else:
                state, cur, tc, ec, gc = out
            yield nv, state, cur, bits, tc, ec, gc

    def _counted_jit(self, key: Tuple[str, str], fn: Callable,
                     **jit_kw) -> Callable:
        def counted(*args):
            self.trace_counts[key] = self.trace_counts.get(key, 0) + 1
            return fn(*args)

        return jax.jit(counted, **jit_kw)

    @staticmethod
    def kernel_traces() -> Dict[str, int]:
        """Process-wide bit-serial kernel trace counters (per dispatch
        family: ``"single"``/``"slots"``/``"grouped"``), the
        kernel-level complement of :attr:`trace_counts`: one grouped
        MoE trace per (bits, backend) the engine serves, regardless of
        tick count, expert count, or M — the custom_vmap fold's
        no-retrace guarantee, asserted in tests/test_moe_grouped.py."""
        from repro.kernels.bitserial import TRACE_COUNTS
        return dict(TRACE_COUNTS)

    def _get_tick(self, mode: str, kind: str = "sync") -> Callable:
        """Jitted single step, shared by all targets of ``mode``.

        ``kind``: ``sync`` (inline decisions), ``boot`` (inline + next
        bits), ``planned`` (lookup + next bits).
        """
        key = (mode, kind)
        if key not in self._ticks:
            build = {"sync": self.build_tick,
                     "boot": self.build_boot_tick,
                     "planned": self.build_planned_tick}[kind]
            self._ticks[key] = self._counted_jit(
                (f"{kind}_tick", mode), build(mode), donate_argnums=(0,))
        return self._ticks[key]

    def get_step(self, target: float, mode: str = "dynamic"):
        """Compat shim: ``step(state, tokens)`` at a fixed target.

        All targets of a mode share one compiled function — the target
        enters as a traced index, so calling this for a new target does
        not recompile. With ``use_async=True`` the returned closure is
        STATEFUL across calls: it threads the pipelined decision vector
        (call 0 is the inline boot tick, later calls apply the bits the
        previous call planned) — driving it token-by-token reproduces
        the fused scan exactly for ONE query. To reuse the closure on a
        fresh decode state, call ``step.reset()`` first (it clears the
        carry so tick 0 boots again). ``use_async=False`` returns the
        stateless inline step.
        """
        t_idx = jnp.int32(self.artifacts.target_index(target))
        if not self.use_async:
            fn = self._get_tick(mode, "sync")
            return lambda state, tokens: fn(state, tokens, t_idx)
        boot = self._get_tick(mode, "boot")
        planned = self._get_tick(mode, "planned")
        carry = {"bits": None}

        def step(state, tokens):
            if carry["bits"] is None:
                logits, state, eb, bits = boot(state, tokens, t_idx)
            else:
                logits, state, eb, bits = planned(state, tokens, t_idx,
                                                  carry["bits"])
            carry["bits"] = bits
            return logits, state, eb

        # one closure == one query's tick stream; call reset() before
        # reusing it on a fresh decode state, or the first tick of the
        # next query would apply the PREVIOUS query's final planned bits
        # instead of running the sync boot tick
        step.reset = lambda: carry.update(bits=None)
        return step

    # -- fused chunked decode ----------------------------------------------------
    def _emit(self, logits, gold_col, want_nll: bool):
        """(next token (b,), gold log-prob (b,)) from one tick's logits."""
        vocab = self.cfg.vocab_size
        if want_nll:
            logp = jax.nn.log_softmax(
                logits[:, 0, :vocab].astype(jnp.float32), axis=-1)
            gold_lp = jnp.take_along_axis(
                logp, gold_col[:, None], axis=-1)[:, 0]
        else:
            gold_lp = jnp.zeros(gold_col.shape, jnp.float32)
        nxt = jnp.argmax(logits[:, 0, :vocab], axis=-1).astype(jnp.int32)
        return nxt, gold_lp

    def _get_chunk(self, mode: str, want_nll: bool,
                   state_sh=None, cache_key: Tuple = ()) -> Callable:
        """Jitted scan over ``decode_chunk`` ticks.

        Pipelined (``use_async=True``):
        ``chunk(state, cur, bits, toks, use_prompt, gold, target_idx)``
        — ``bits`` is the carried (U,) decision vector: each tick applies
        it by lookup and the planner replaces it for the next tick.
        Sync (``use_async=False``): the legacy inline chunk without the
        bits carry. In both, ``toks``/``gold`` are (b, C) teacher/gold
        tokens and ``use_prompt`` (C,) selects teacher forcing vs.
        feeding the generated token. Returns (state, cur[, bits],
        tokens_out (C, b), eff_bits (C,), gold_logp (C, b)) — everything
        stays on device. With ``want_nll=False`` the per-tick full-vocab
        log-softmax is skipped (generation discards it) and gold_logp is
        zeros.

        On a mesh the chunk is compiled with explicit in/out shardings:
        the donated decode state keeps its KV sharding across chunks,
        control vectors, the decision carry, and emissions stay
        replicated (``state_sh`` is the state's sharding tree;
        ``cache_key`` disambiguates state shapes, whose divisibility
        decides the resolved specs).
        """
        key = (mode, want_nll) + tuple(cache_key)
        if key in self._chunks:
            return self._chunks[key]

        if self.use_async:
            tick = self.build_planned_tick(mode)

            def chunk(state, cur, bits, toks, use_prompt, gold,
                      target_idx):
                tkey = ("chunk", mode)
                self.trace_counts[tkey] = \
                    self.trace_counts.get(tkey, 0) + 1

                def body(carry, xs):
                    state, cur, bits = carry
                    tok_col, use_p, gold_col = xs
                    tok = jnp.where(use_p, tok_col, cur)[:, None]
                    logits, state, eb, bits = tick(state, tok, target_idx,
                                                   bits)
                    nxt, gold_lp = self._emit(logits, gold_col, want_nll)
                    return (state, nxt, bits), (nxt, eb, gold_lp)

                (state, cur, bits), (toks_out, ebs, gold_lps) = \
                    jax.lax.scan(body, (state, cur, bits),
                                 (toks.T, use_prompt, gold.T))
                return state, cur, bits, toks_out, ebs, gold_lps

            n_in, n_out = 7, 6
        else:
            tick = self.build_tick(mode)

            def chunk(state, cur, toks, use_prompt, gold, target_idx):
                tkey = ("chunk", mode)
                self.trace_counts[tkey] = \
                    self.trace_counts.get(tkey, 0) + 1

                def body(carry, xs):
                    state, cur = carry
                    tok_col, use_p, gold_col = xs
                    tok = jnp.where(use_p, tok_col, cur)[:, None]
                    logits, state, eb = tick(state, tok, target_idx)
                    nxt, gold_lp = self._emit(logits, gold_col, want_nll)
                    return (state, nxt), (nxt, eb, gold_lp)

                (state, cur), (toks_out, ebs, gold_lps) = jax.lax.scan(
                    body, (state, cur), (toks.T, use_prompt, gold.T))
                return state, cur, toks_out, ebs, gold_lps

            n_in, n_out = 6, 5

        if self.mesh is None:
            self._chunks[key] = jax.jit(chunk, donate_argnums=(0,))
        else:
            rep = NamedSharding(self.mesh, P())
            in_sh = [state_sh] + [rep] * (n_in - 1)
            out_sh = [state_sh] + [rep] * (n_out - 1)
            if self.use_async:
                # the (U,) decision carry rides at position 2 in both
                # directions; its named spec (units replicated) is the
                # same contract the scheduler's (S, U) carry shards by
                in_sh[2] = out_sh[2] = self._bits_sharding()
            self._chunks[key] = jax.jit(
                chunk, donate_argnums=(0,),
                in_shardings=tuple(in_sh), out_shardings=tuple(out_sh))
        return self._chunks[key]

    def _bits_sharding(self) -> NamedSharding:
        """The engine-path (U,) decision carry's named sharding."""
        return NamedSharding(self.mesh, decision_carry_spec(
            self.mesh, (self.artifacts.decision.n_units,)))

    def _get_boot(self, mode: str, want_nll: bool,
                  state_sh=None, cache_key: Tuple = ()) -> Callable:
        """Jitted query-seeding step: tick 0 with inline (sync) decisions.

        ``boot(state, cur, tok0, use_p0, gold0, target_idx) -> (state,
        cur, bits, tok_out (b,), eff_bits (), gold_logp (b,))`` — same
        emissions as one chunk tick, plus the planner's decision vector
        for tick 1 (the pipeline seed).
        """
        key = (mode, want_nll) + tuple(cache_key)
        if key in self._boots:
            return self._boots[key]
        tick = self.build_boot_tick(mode)

        def boot(state, cur, tok0, use_p0, gold0, target_idx):
            tkey = ("boot", mode)
            self.trace_counts[tkey] = self.trace_counts.get(tkey, 0) + 1
            tok = jnp.where(use_p0, tok0, cur)[:, None]
            logits, state, eb, bits = tick(state, tok, target_idx)
            nxt, gold_lp = self._emit(logits, gold0, want_nll)
            return state, nxt, bits, nxt, eb, gold_lp

        if self.mesh is None:
            self._boots[key] = jax.jit(boot, donate_argnums=(0,))
        else:
            rep = NamedSharding(self.mesh, P())
            out_sh = [state_sh] + [rep] * 5
            out_sh[2] = self._bits_sharding()     # the seeded carry
            self._boots[key] = jax.jit(
                boot, donate_argnums=(0,),
                in_shardings=(state_sh,) + (rep,) * 5,
                out_shardings=tuple(out_sh))
        return self._boots[key]

    def _run_chunks(self, mode: str, toks: np.ndarray,
                    use_prompt: np.ndarray, gold: np.ndarray,
                    target_idx: jax.Array, *, want_nll: bool):
        """Drive the fused decode over ``total`` ticks; device outputs.

        Two-stage path (``prefill_chunk > 0``, the default): the leading
        teacher-forced run of ticks — the prompt — executes as the
        batched PREFILL stage (O(prompt_len / prefill_chunk) M-row
        launches that fill the KV cache, emit every row's token/bits/
        gold-logp, and hand the decision carry to the decode stage);
        the remaining generation ticks run as the pipelined decode
        chunks, seeded by the prefill carry instead of a boot tick.

        Legacy path (``prefill_chunk=0``): tick 0 runs as the boot step
        (inline sync decisions seed the pipeline), ticks 1.. run as
        bits-carrying chunks — O(prompt_len) launches; the prefill
        stage's bit-identity reference. Sync path: all-inline chunks.
        """
        if self.prefill_chunk > 0:
            up = np.asarray(use_prompt, bool)
            n_pf = int(np.argmin(up)) if not np.all(up) else len(up)
            # the stage split needs a pure prompt-then-generate shape;
            # teacher forcing resuming mid-stream falls back to legacy
            if n_pf >= 1 and not np.any(up[n_pf:]):
                return self._run_prefill_decode(
                    mode, toks, gold, n_pf, target_idx, want_nll=want_nll)
        b, total = toks.shape
        c = self.decode_chunk
        lead = 1 if self.use_async else 0        # boot consumes tick 0
        n_chunks = -(-(total - lead) // c) if total > lead else 0
        padded = lead + n_chunks * c
        pad = padded - total
        toks = np.pad(toks, ((0, 0), (0, pad)))
        gold = np.pad(gold, ((0, 0), (0, pad)))
        use_prompt = np.pad(use_prompt, (0, pad), constant_values=True)
        # bucketed KV length: queries of different lengths share the same
        # compiled chunk (shape reuse), at a bounded memory overshoot
        kv = self.kv_bucket
        max_len = -(-(padded + 1) // kv) * kv
        state = self._make_state(b, max_len)
        state_sh, state = self._decode_state_shardings(state)
        chunk_fn = self._get_chunk(mode, want_nll, state_sh=state_sh,
                                   cache_key=(b, max_len)) \
            if n_chunks else None
        cur = jnp.zeros((b,), jnp.int32)
        out_t, out_e, out_g = [], [], []
        # any device->host pull inside the decode loop is a per-token sync
        # regression; on accelerator backends the guard turns it into a
        # hard error (on CPU, arrays are host-resident and it cannot fire,
        # so the ``host_syncs`` counter remains the tested invariant there)
        with self._mesh_ctx(), jax.transfer_guard_device_to_host("disallow"):
            bits = None
            if self.use_async:
                boot_fn = self._get_boot(mode, want_nll, state_sh=state_sh,
                                         cache_key=(b, max_len))
                self.call_counts["boot"] = \
                    self.call_counts.get("boot", 0) + 1
                state, cur, bits, t0, e0, g0 = boot_fn(
                    state, cur, jnp.asarray(toks[:, 0]),
                    jnp.asarray(use_prompt[0]), jnp.asarray(gold[:, 0]),
                    target_idx)
                out_t.append(t0[None])
                out_e.append(e0[None])
                out_g.append(g0[None])
            self._drive_chunks(chunk_fn, n_chunks, toks[:, lead:],
                               use_prompt[lead:], gold[:, lead:],
                               target_idx, (state, cur, bits),
                               out_t, out_e, out_g)
            return (jnp.concatenate(out_t, axis=0),
                    jnp.concatenate(out_e, axis=0),
                    jnp.concatenate(out_g, axis=0))

    def _drive_chunks(self, chunk_fn, n_chunks: int, toks, use_prompt,
                      gold, target_idx, carry, out_t, out_e, out_g):
        """Drive ``n_chunks`` decode-chunk calls from host arrays.

        ``carry`` is ``(state, cur, bits)`` (``bits`` ignored for a sync
        engine); emissions append to the ``out_*`` lists. Shared by the
        legacy path (post-boot ticks) and the two-stage path (generation
        ticks after the prefill stage) so the carry/unpack/count logic
        exists exactly once.
        """
        state, cur, bits = carry
        c = self.decode_chunk
        for ci in range(n_chunks):
            sl = slice(ci * c, (ci + 1) * c)
            args = (state, cur) + ((bits,) if self.use_async else ()) \
                + (jnp.asarray(toks[:, sl]), jnp.asarray(use_prompt[sl]),
                   jnp.asarray(gold[:, sl]), target_idx)
            self.call_counts["chunk"] = \
                self.call_counts.get("chunk", 0) + 1
            out = chunk_fn(*args)
            if self.use_async:
                state, cur, bits, tc, ec, gc = out
            else:
                state, cur, tc, ec, gc = out
            out_t.append(tc)
            out_e.append(ec)
            out_g.append(gc)
        return state, cur, bits

    def _decode_state_shardings(self, state):
        if self.mesh is None:
            return None, state
        state_sh = {k: NamedSharding(self.mesh, decode_state_spec(
            self.mesh, k, v.shape)) for k, v in state.items()}
        return state_sh, {k: jax.device_put(v, state_sh[k])
                          for k, v in state.items()}

    def _run_prefill_decode(self, mode: str, toks: np.ndarray,
                            gold: np.ndarray, n_pf: int,
                            target_idx: jax.Array, *, want_nll: bool):
        """The disaggregated two-stage path behind ``_run_chunks``.

        Stage 1 (prefill): ticks ``[0, n_pf)`` — the teacher-forced
        prompt — run as ``ceil(n_pf / prefill_chunk)`` M-row launches on
        the SAME decode state (engine-side handoff is the identity: the
        KV rows are written in place). Stage 2 (decode): the remaining
        generation ticks run as the usual pipelined chunks, with the
        decision carry seeded by the prefill's last valid row instead of
        a boot tick. Emissions from both stages concatenate row-aligned
        with the legacy tick stream, so the callers' slicing is
        unchanged.
        """
        b, total = toks.shape
        C, c = self.prefill_chunk, self.decode_chunk
        n_pf_chunks = -(-n_pf // C)
        pf_padded = n_pf_chunks * C
        rem = total - n_pf
        n_chunks = -(-rem // c) if rem > 0 else 0
        kv = self.kv_bucket
        # the cache must hold the bucketed prefill (pad rows write past
        # the prompt; decode overwrites them) AND the decode ticks
        need = max(pf_padded, n_pf + n_chunks * c + 1)
        max_len = -(-need // kv) * kv
        state = self._make_state(b, max_len)
        state_sh, state = self._decode_state_shardings(state)
        toks_pf = np.zeros((b, pf_padded), np.int32)
        toks_pf[:, :n_pf] = toks[:, :n_pf]
        gold_pf = np.zeros((b, pf_padded), np.int32)
        gold_pf[:, :n_pf] = gold[:, :n_pf]
        dec_gold = np.zeros((b, n_chunks * c), np.int32)
        if rem > 0:
            dec_gold[:, :rem] = gold[:, n_pf:]
        dec_toks = np.zeros((b, n_chunks * c), np.int32)  # never consumed
        out_t, out_e, out_g = [], [], []
        cur = jnp.zeros((b,), jnp.int32)
        bits = None
        with self._mesh_ctx(), jax.transfer_guard_device_to_host("disallow"):
            for nv, state, cur, bits, tc, ec, gc in self.iter_prefill(
                    mode, state, toks_pf, gold_pf, n_pf, target_idx,
                    want_nll=want_nll, state_sh=state_sh,
                    cache_key=(b, max_len)):
                # bucketed final chunk: only the real prompt rows emit
                out_t.append(tc[:nv])
                out_e.append(ec[:nv])
                out_g.append(gc[:nv])
            if n_chunks:
                chunk_fn = self._get_chunk(mode, want_nll,
                                           state_sh=state_sh,
                                           cache_key=(b, max_len))
                self._drive_chunks(
                    chunk_fn, n_chunks, dec_toks,
                    np.zeros((n_chunks * c,), bool),  # pure generation
                    dec_gold, target_idx, (state, cur, bits),
                    out_t, out_e, out_g)
            return (jnp.concatenate(out_t, axis=0),
                    jnp.concatenate(out_e, axis=0),
                    jnp.concatenate(out_g, axis=0))

    # -- evaluation / generation -----------------------------------------------
    def teacher_forced_nll(
        self, tokens: np.ndarray, target: float, mode: str = "dynamic",
        prime_len: int = 1,
    ) -> Tuple[float, List[float]]:
        """Per-token NLL over ``tokens`` (batch, seq) with per-step dynamic
        precision; returns (mean_nll, per-step effective bits).

        The whole sequence runs as fused on-device scans — ONE host sync
        at the end, regardless of sequence length.
        """
        tokens = np.asarray(tokens)
        b, s = tokens.shape
        total = s - 1
        if total <= 0:          # nothing to predict on a 1-token sequence
            return float("nan"), []
        t_idx = jnp.int32(self.artifacts.target_index(target))
        _, ebs, gold_lps = self._run_chunks(
            mode, tokens[:, :total].astype(np.int32),
            np.ones((total,), bool),
            tokens[:, 1:].astype(np.int32), t_idx, want_nll=True)
        self.host_syncs += 1
        host = np.asarray(jnp.concatenate(
            [ebs[:total], jnp.mean(gold_lps[:total], axis=-1)]))
        ebits, gold_mean = host[:total], host[total:]
        nll = float(np.mean(-gold_mean[max(prime_len - 1, 0):]))
        return nll, [float(e) for e in ebits]

    def generate(
        self, prompt: np.ndarray, max_new: int, target: float,
        mode: str = "dynamic", spec_k: Optional[int] = None,
    ) -> Tuple[np.ndarray, List[float]]:
        """Greedy decode; returns (tokens (b, prompt+max_new), eff bits).

        Prefill (teacher-forced over the prompt) and generation run as one
        fused chunked scan; the generated tokens and per-step effective
        bits accumulate on device and sync to the host a constant number
        of times per query (two pulls), independent of token count.

        ``spec_k``: speculative decoding window — draft ``spec_k - 1``
        tokens at the overlay's 2-bit floor, verify all ``spec_k`` rows
        in one batched launch at the planner-assigned bits, accept the
        longest matching prefix on device (:meth:`_generate_spec`).
        Greedy verification makes the output token- and bits-identical
        to ``spec_k=None``; per-query stats land in ``last_spec``.
        """
        prompt = np.asarray(prompt)
        b, p = prompt.shape
        if p == 0:
            raise ValueError("generate() needs a non-empty prompt")
        if spec_k is not None:
            return self._generate_spec(prompt, max_new, target, mode,
                                       int(spec_k))
        total = p + max_new
        t_idx = jnp.int32(self.artifacts.target_index(target))
        toks = np.zeros((b, total), np.int32)
        toks[:, :p] = prompt
        toks_out, ebs, _ = self._run_chunks(
            mode, toks, np.arange(total) < p, np.zeros((b, total), np.int32),
            t_idx, want_nll=False)
        gen = toks_out[p - 1:p - 1 + max_new].T          # (b, max_new)
        out = jnp.concatenate([jnp.asarray(prompt), gen], axis=1)
        self.host_syncs += 2
        tokens_np = np.asarray(out)
        # ebits[i] is the tick that PRODUCED generated token i: the token
        # emitted at position p+i comes out of tick p-1+i, so the bits
        # slice is aligned with the token slice above (not shifted one
        # tick late, which would drop the first generated token's bits and
        # report the final, discarded tick instead)
        ebits = [float(e) for e in np.asarray(ebs[p - 1:p - 1 + max_new])]
        return tokens_np, ebits

    # -- speculative decode (draft @ floor bits / batched verify) ---------------
    def _run_prompt(self, mode: str, prompt: np.ndarray, target_idx,
                    max_len: int):
        """Consume the prompt; return ``(state, cur, bits, eb_last,
        state_sh)`` — the decode-ready carry the speculative loop starts
        from (``cur`` is generated token 0, ``eb_last`` the effective
        bits of the tick that produced it, ``bits`` the pipelined
        decision carry — None for a sync engine).

        Staged engines (``prefill_chunk > 0``) drive :meth:`iter_prefill`
        — the usual O(prompt/chunk) batched launches. Legacy engines run
        the prompt tick-by-tick through the boot/planned (or sync) jitted
        ticks: O(prompt) launches, same as the legacy chunked path, kept
        as the bit-identity reference. Everything stays on device.
        """
        b, p = prompt.shape
        state = self._make_state(b, max_len)
        state_sh, state = self._decode_state_shardings(state)
        if self.prefill_chunk > 0:
            C = self.prefill_chunk
            pf_padded = -(-p // C) * C
            toks_pf = np.zeros((b, pf_padded), np.int32)
            toks_pf[:, :p] = prompt
            gold_pf = np.zeros((b, pf_padded), np.int32)
            cur = bits = eb_last = None
            for nv, state, cur, bits, tc, ec, gc in self.iter_prefill(
                    mode, state, toks_pf, gold_pf, p, target_idx,
                    want_nll=False, state_sh=state_sh,
                    cache_key=(b, max_len)):
                eb_last = ec[nv - 1]
            return state, cur, bits, eb_last, state_sh
        vocab = self.cfg.vocab_size
        bits = None
        if self.use_async:
            boot = self._get_tick(mode, "boot")
            planned = self._get_tick(mode, "planned")
        else:
            sync = self._get_tick(mode, "sync")
        for i in range(p):
            tok = jnp.asarray(prompt[:, i])[:, None]
            self.call_counts["spec_prompt_tick"] = \
                self.call_counts.get("spec_prompt_tick", 0) + 1
            if not self.use_async:
                logits, state, eb = sync(state, tok, target_idx)
            elif i == 0:
                logits, state, eb, bits = boot(state, tok, target_idx)
            else:
                logits, state, eb, bits = planned(state, tok, target_idx,
                                                  bits)
        cur = jnp.argmax(logits[:, 0, :vocab], axis=-1).astype(jnp.int32)
        return state, cur, bits, eb, state_sh

    def _get_spec_loop(self, mode: str, k: int, state_sh=None,
                       cache_key: Tuple = ()) -> Callable:
        """Jitted speculative decode loop — ONE compiled call per query.

        ``spec(state, cur[, bits], target_idx, rem) -> (tok_buf (cap, b),
        eb_buf (cap,), windows, accepted)`` — a ``lax.while_loop`` whose
        body is one draft/verify window:

        1. snapshot the SSM/pos leaves, draft ``k-1`` tokens
           autoregressively at the 2-bit floor (KV rows written past
           ``pos`` are garbage the verify overwrites), restore SSM/pos;
        2. verify ``[cur, g_1..g_{k-1}]`` in ONE batched k-row launch at
           planner bits (``build_verify_rows``);
        3. greedy longest-prefix accept on device: ``n_acc`` = leading
           rows where the draft matched the verify argmax (all-over-
           batch — lockstep windows for a dense batch), emitting
           ``n_acc + 1`` baseline-exact tokens (the bonus token is the
           verify output after the last match);
        4. roll back KV/SSM to the last accepted row
           (``rollback_decode_state``), rewind the decision carry to
           ``dec[:, n_acc]`` (row ``n_acc``'s plan IS baseline's
           next-tick decision), bump the device counters.

        Emissions land in a ``cap``-row device buffer at a dynamic
        offset (``cap`` >= rem + k - 1, bucketed by ``decode_chunk`` —
        the final window may legally overshoot ``rem``; the caller
        slices the first ``rem`` rows, every one of them accepted). The
        counters make the closed-form launch invariant testable:
        verify launches == ``windows``, raw emitted == ``windows +
        accepted``, so launches-per-emitted-token == ``windows /
        (windows + accepted)`` < 1 whenever anything was accepted.
        ``rem`` is traced — one compiled loop serves every ``max_new``
        within a ``cap`` bucket; the cache key is (mode, k, shapes).
        """
        key = (mode, k) + tuple(cache_key)
        if key in self._specs:
            return self._specs[key]
        cap = cache_key[-1]
        verify = self.build_verify_rows(mode, k)
        draft = self.build_draft_tick(mode)
        vocab = self.cfg.vocab_size
        use_async = self.use_async
        snap_of = lambda st: {kk: v for kk, v in st.items()
                              if kk.startswith("ssm.") or kk == "pos"}

        def window(state, cur, bits, t_idx):
            """One draft/verify/accept window; returns the advanced
            carry plus (v (b, k), ebs (k,), n_acc)."""
            snap = snap_of(state)

            def d_body(carry, _):
                st, tok = carry
                logits, st = draft(st, tok[:, None], t_idx)
                nxt = jnp.argmax(logits[:, 0, :vocab],
                                 axis=-1).astype(jnp.int32)
                return (st, nxt), nxt

            (state, _), g = jax.lax.scan(d_body, (state, cur), None,
                                         length=k - 1)     # g (k-1, b)
            state = dict(state, **snap)     # drafted SSM/pos never leak
            toks = jnp.concatenate([cur[:, None], g.T.astype(jnp.int32)],
                                   axis=1) if k > 1 else cur[:, None]
            args = (state, toks, t_idx) + ((bits,) if use_async else ())
            logits, state, ebs, dec, snaps = verify(*args)
            v = jnp.argmax(logits[:, :, :vocab],
                           axis=-1).astype(jnp.int32)       # (b, k)
            if k > 1:
                ok = jnp.all(g.T == v[:, :k - 1], axis=0)   # (k-1,)
                n_acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))
            else:
                n_acc = jnp.int32(0)
            state = rollback_decode_state(state, snaps, n_acc + 1, k)
            cur = jax.lax.dynamic_index_in_dim(v, n_acc, axis=1,
                                               keepdims=False)
            if use_async:
                bits = jax.lax.dynamic_index_in_dim(dec, n_acc, axis=1,
                                                    keepdims=False)
            return state, cur, bits, v, ebs, n_acc

        def spec(state, cur, *rest):
            tkey = ("spec", mode)
            self.trace_counts[tkey] = self.trace_counts.get(tkey, 0) + 1
            if use_async:
                bits, t_idx, rem = rest
            else:
                (t_idx, rem), bits = rest, jnp.int32(0)
            b = cur.shape[0]
            buf0 = (jnp.zeros((cap, b), jnp.int32),
                    jnp.zeros((cap,), jnp.float32))

            def cond(c):
                return c[3] < rem

            def body(c):
                state, cur, bits, n, w, a, tok_buf, eb_buf = c
                state, cur, bits, v, ebs, n_acc = window(state, cur, bits,
                                                         t_idx)
                tok_buf = jax.lax.dynamic_update_slice(tok_buf, v.T,
                                                       (n, 0))
                eb_buf = jax.lax.dynamic_update_slice(eb_buf, ebs, (n,))
                return (state, cur, bits, n + n_acc + 1, w + 1,
                        a + n_acc, tok_buf, eb_buf)

            out = jax.lax.while_loop(
                cond, body,
                (state, cur, bits, jnp.int32(0), jnp.int32(0),
                 jnp.int32(0)) + buf0)
            _, _, _, _, w, a, tok_buf, eb_buf = out
            return tok_buf, eb_buf, w, a

        if self.mesh is None:
            self._specs[key] = jax.jit(spec, donate_argnums=(0,))
        else:
            rep = NamedSharding(self.mesh, P())
            n_in = 5 if use_async else 4
            in_sh = [state_sh] + [rep] * (n_in - 1)
            if use_async:
                in_sh[2] = self._bits_sharding()
            self._specs[key] = jax.jit(
                spec, donate_argnums=(0,), in_shardings=tuple(in_sh),
                out_shardings=(rep,) * 4)
        return self._specs[key]

    def _generate_spec(self, prompt: np.ndarray, max_new: int,
                       target: float, mode: str, k: int
                       ) -> Tuple[np.ndarray, List[float]]:
        """Speculative :meth:`generate`: prompt stage + ONE jitted
        draft/verify loop; two host pulls per query, like the baseline.

        Token 0 comes out of the prompt's last tick (as in the baseline
        scan); the loop emits the remaining ``max_new - 1``. Emitted
        effective bits are the VERIFY rows' applied bits — draft-floor
        bits are never attributed to an emitted token.
        """
        if k < 1:
            raise ValueError(f"spec_k must be >= 1, got {k}")
        b, p = prompt.shape
        t_idx = jnp.int32(self.artifacts.target_index(target))
        rem = max_new - 1
        C, c, kv = self.prefill_chunk, self.decode_chunk, self.kv_bucket
        pf_padded = (-(-p // C) * C) if C > 0 else 0
        # 2k rows of slack: a window may verify k rows starting at the
        # final emitted position, and the rollback zero-block extends k
        # more — dynamic_update_slice must never clamp (kv_cache contract)
        need = max(pf_padded, p + max_new + 2 * k)
        max_len = -(-need // kv) * kv
        cap = -(-(max(rem, 1) + k - 1) // c) * c
        with self._mesh_ctx(), \
                jax.transfer_guard_device_to_host("disallow"):
            state, cur, bits, eb_last, state_sh = self._run_prompt(
                mode, prompt, t_idx, max_len)
            if rem > 0:
                spec_fn = self._get_spec_loop(mode, k, state_sh=state_sh,
                                              cache_key=(b, max_len, cap))
                self.call_counts["spec_loop"] = \
                    self.call_counts.get("spec_loop", 0) + 1
                args = (state, cur) + \
                    ((bits,) if self.use_async else ()) + \
                    (t_idx, jnp.int32(rem))
                tok_buf, eb_buf, w, a = spec_fn(*args)
                gen = jnp.concatenate([cur[:, None], tok_buf[:rem].T],
                                      axis=1)
            else:
                w = a = jnp.int32(0)
                eb_buf = jnp.zeros((0,), jnp.float32)
                gen = cur[:, None]
            out = jnp.concatenate([jnp.asarray(prompt, jnp.int32), gen],
                                  axis=1)
            packed = jnp.concatenate([
                eb_last[None].astype(jnp.float32), eb_buf[:max(rem, 0)],
                w.astype(jnp.float32)[None], a.astype(jnp.float32)[None]])
        self.host_syncs += 2
        tokens_np = np.asarray(out)
        host = np.asarray(packed)
        ebits = [float(e) for e in host[:1 + rem]]
        w_f, a_f = float(host[-2]), float(host[-1])
        emitted = w_f + a_f
        self.last_spec = {
            "k": k, "windows": w_f, "accepted": a_f,
            "verify_launches": w_f, "emitted_raw": emitted,
            "acceptance_rate": (a_f / (w_f * (k - 1)))
            if k > 1 and w_f else 0.0,
            "launches_per_token": (w_f / emitted) if emitted else 0.0,
        }
        return tokens_np, ebits

    # -- accounting ---------------------------------------------------------------
    def overlay_bytes(self) -> int:
        """Resident (Phase-1 truncated) overlay bytes, actual itemsizes."""
        return self.overlay_bytes_report()["truncated"]

    def overlay_bytes_report(self) -> Dict[str, int]:
        """Truncated (serving-resident) vs. full-parent overlay bytes."""
        return {"truncated": overlay_nbytes(self.overlays),
                "full_parent": overlay_nbytes(self.model.overlays)}

    def kv_bytes_saved(self, batch: int = 1,
                       max_len: Optional[int] = None) -> int:
        """Dense-fp32 KV bytes minus this engine's KV bytes for one
        decode state of the given shape — pure static-shape accounting
        (``jax.eval_shape``; no device sync, O(1) host work). 0 for a
        dense-KV engine."""
        if not self.kv_overlay:
            return 0
        ml = int(max_len or self.kv_bucket)

        def kv_nbytes(fmt):
            st = jax.eval_shape(lambda: make_decode_state(
                self.cfg, batch, ml, dtype=jnp.float32, kv_format=fmt,
                kv_plane_bits=self.kv_plane_bits))
            return sum(int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
                       for k, v in st.items() if k.startswith("kv."))

        return kv_nbytes("dense") - kv_nbytes("overlay")

    def paged_bytes_report(self, slots: int, max_len: int,
                           page_len: int = 16,
                           n_pages: Optional[int] = None
                           ) -> Dict[str, int]:
        """Paged-pool vs. bucketed HBM accounting — the paged companion
        of :meth:`kv_bytes_saved`, reported next to it by the serving
        benchmark. Pure static-shape accounting (``jax.eval_shape``).

        ``bucketed`` is what ``slots`` per-slot worst-case overlay
        buckets of ``max_len`` rows cost; ``paged`` is the shared pool
        (``n_pages`` pages of ``page_len`` rows, default sized to the
        same worst case) plus the page tables; ``saved`` is their
        difference — it goes positive exactly when the pool is sized to
        LIVE tokens instead of worst-case buckets, which is where the
        "more concurrent slots per HBM budget" multiplier comes from.
        """
        if not self.kv_overlay:
            return {"bucketed": 0, "paged": 0, "saved": 0}
        pages_per_slot = pages_for_rows(int(max_len), int(page_len))
        if n_pages is None:
            n_pages = int(slots) * pages_per_slot + 1
        pool = jax.eval_shape(lambda: make_paged_pool(
            self.cfg, int(n_pages), int(page_len),
            kv_plane_bits=self.kv_plane_bits))
        nbytes = lambda st: sum(
            int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
            for v in st.values())
        st = jax.eval_shape(lambda: make_decode_state(
            self.cfg, 1, int(max_len), dtype=jnp.float32,
            kv_format="overlay", kv_plane_bits=self.kv_plane_bits))
        bucketed = int(slots) * sum(
            int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
            for k, v in st.items() if k.startswith("kv."))
        tables = int(slots) * pages_per_slot * 4     # int32 page tables
        paged = nbytes(pool) + tables
        return {"bucketed": bucketed, "paged": paged,
                "saved": bucketed - paged}
