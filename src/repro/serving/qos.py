"""QoS control: map per-query latency budgets to target precisions.

The runtime-adaptation story of the paper (Fig. 1): queries arrive with a
TPOT budget; the planner picks the highest target precision whose predicted
decode latency fits the current slack. The latency model is the v5e
weight-traffic roofline (decode is memory-bound): t(b) ≈ bytes(b)/HBM_bw +
overhead, calibrated against measured step times when available.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

HBM_BW = 819e9      # bytes/s per chip (v5e)


@dataclass
class LatencyModel:
    bytes_per_bit: float          # overlay bytes per effective bit
    overhead_s: float = 2e-4      # selector + cache + dispatch

    def tpot(self, bits: float, chips: int = 1) -> float:
        return self.bytes_per_bit * bits / (HBM_BW * chips) + self.overhead_s

    def ttft(self, bits: float, prompt_len: int, prefill_chunk: int,
             chips: int = 1, queued_launches: int = 0) -> float:
        """Predicted time-to-first-token of the batched prefill stage.

        Each of the ``ceil(p / prefill_chunk)`` launches streams the
        overlay once (weight traffic is amortized over the chunk's rows
        — the arithmetic-intensity flip that motivates disaggregation)
        plus the per-launch dispatch overhead. The legacy tick-by-tick
        prefill is the ``prefill_chunk=1`` special case: p launches,
        p× the weight traffic — which is exactly why long prompts used
        to blow short TPOT budgets.

        ``queued_launches`` is the prefill-worker QUEUE DEPTH — launches
        already queued ahead of this request on its assigned worker.
        A request admitted behind a burst waits for those first, so
        pricing only the request's own ``ceil(p / prefill_chunk)``
        underestimates TTFT exactly when the fleet is busiest.
        """
        launches = max(1, -(-int(prompt_len) // max(1, int(prefill_chunk))))
        return (launches + max(0, int(queued_launches))) * \
            self.tpot(bits, chips)

    def spec_tpot(self, bits: float, k: int, acceptance: float,
                  draft_bits: float = 2.0, chips: int = 1) -> float:
        """Predicted per-emitted-token latency under speculative decode.

        One draft/verify window costs ``k - 1`` draft ticks streaming
        the ``draft_bits``-plane prefix plus ONE verify launch streaming
        the full ``bits`` overlay (weight traffic amortized over the
        window's k rows, like prefill), and emits ``1 + acceptance *
        (k - 1)`` tokens in expectation::

            t = ((k-1) * tpot(draft) + tpot(bits)) / (1 + a * (k-1))

        ``k=1`` (or ``acceptance=0``) degenerates to plain ``tpot`` —
        verify-only windows emit exactly one token each. The acceptance
        input is the planner's observed EMA, so admission predictions
        track the workload's actual draft quality.
        """
        k = max(1, int(k))
        a = min(1.0, max(0.0, float(acceptance)))
        window = (k - 1) * self.tpot(draft_bits, chips) + \
            self.tpot(bits, chips)
        return window / (1.0 + a * (k - 1))


@dataclass
class QoSPlanner:
    targets: Sequence[float]          # supported target precisions
    latency: LatencyModel
    chips: int = 1
    # speculative serving: when spec_k is set, admission predicts TPOT
    # with the draft/verify window model at the OBSERVED acceptance EMA
    # (scheduler feeds observe_acceptance after every chunk) — a workload
    # whose drafts keep landing admits higher precisions into the same
    # TPOT budget, which is the paper's runtime-adaptation dial extended
    # from "how many bit-planes" to "how many tokens per launch"
    spec_k: Optional[int] = None
    draft_bits: float = 2.0
    acceptance_ema: float = 0.0

    def observe_acceptance(self, rate: float, alpha: float = 0.2) -> None:
        """Fold one chunk's measured acceptance rate into the EMA."""
        r = min(1.0, max(0.0, float(rate)))
        self.acceptance_ema = (1.0 - alpha) * self.acceptance_ema + \
            alpha * r

    def _tpot(self, bits: float) -> float:
        if self.spec_k is not None and self.spec_k > 1:
            return self.latency.spec_tpot(
                bits, self.spec_k, self.acceptance_ema,
                draft_bits=self.draft_bits, chips=self.chips)
        return self.latency.tpot(bits, self.chips)

    def plan(self, tpot_budget_s: float,
             utilization: float = 0.0,
             prompt_len: Optional[int] = None,
             ttft_budget_s: Optional[float] = None,
             prefill_chunk: Optional[int] = None,
             queued_launches: int = 0) -> float:
        """Highest precision fitting the budget at current utilization.

        With a ``ttft_budget_s`` (and the prompt length), a TTFT term
        joins the admission test: a target is feasible only if the
        prefill-stage cost model says the prompt's first token lands
        inside the TTFT budget too — so a long prompt can no longer
        admit at a precision whose prefill alone blows a short-budget
        slot's deadline. ``prefill_chunk=None`` models the tick-by-tick
        prefill (chunk of 1 — the legacy worst case, p launches).
        Requests without a TTFT budget keep the TPOT-only admission.

        ``queued_launches`` prices the prefill-worker queue depth into
        the TTFT guard: the request waits behind launches already queued
        on its assigned worker, not just its own ``ceil(p / chunk)`` —
        the admission router reports the depth of the least-loaded
        worker at routing time.
        """
        if ttft_budget_s is not None and not prompt_len:
            raise ValueError("a ttft_budget_s needs prompt_len — without "
                             "it the TTFT guard would be silently skipped")
        slack = tpot_budget_s * max(0.0, 1.0 - utilization)
        feasible = [t for t in sorted(self.targets)
                    if self._tpot(t) <= slack]
        if prompt_len and ttft_budget_s is not None:
            chunk = prefill_chunk or 1
            feasible = [t for t in feasible
                        if self.latency.ttft(
                            t, prompt_len, chunk, self.chips,
                            queued_launches=queued_launches)
                        <= ttft_budget_s]
        return feasible[-1] if feasible else min(self.targets)


@dataclass
class PriorityClass:
    """One admission class of the router: a priority rank and the
    per-class SLOs goodput is measured against. Lower ``priority`` is
    more urgent. A request belongs to the most urgent class whose SLOs
    cover its budgets (``classify``); requests with no budgets fall to
    the least urgent class."""
    name: str
    priority: int
    ttft_slo_s: float
    tpot_slo_s: float


DEFAULT_CLASSES = (
    PriorityClass("interactive", 0, ttft_slo_s=0.25, tpot_slo_s=0.03),
    PriorityClass("standard", 1, ttft_slo_s=1.0, tpot_slo_s=0.10),
    PriorityClass("batch", 2, ttft_slo_s=10.0, tpot_slo_s=1.00),
)


class AdmissionRouter:
    """Priority-class admission in front of the decode scheduler, plus
    the prefill-worker fleet's routing/queue-depth bookkeeping.

    Requests queue per class and drain most-urgent-first (FIFO within a
    class). Each admission is routed to the least-loaded prefill worker;
    the launches already queued on that worker are reported so
    :meth:`QoSPlanner.plan` prices the real TTFT (queue depth included,
    not just the request's own launches). ``pick_victim`` names the
    preemption order for page reclaim: least urgent class first, then
    the youngest admission — an over-budget prompt gives its pages back
    before anyone more urgent degrades.
    """

    def __init__(self, classes: Sequence[PriorityClass] = DEFAULT_CLASSES,
                 prefill_workers: int = 1):
        if not classes:
            raise ValueError("router needs at least one priority class")
        if prefill_workers < 1:
            raise ValueError("router needs at least one prefill worker")
        self.classes = sorted(classes, key=lambda c: c.priority)
        self._queues: Dict[str, deque] = {c.name: deque()
                                          for c in self.classes}
        self.n_workers = int(prefill_workers)
        self._worker_queued = [0] * self.n_workers

    def classify(self, request) -> PriorityClass:
        tpot = getattr(request, "tpot_budget_s", None)
        ttft = getattr(request, "ttft_budget_s", None)
        for c in self.classes:
            ttft_ok = ttft is not None and ttft <= c.ttft_slo_s
            tpot_ok = tpot is not None and tpot <= c.tpot_slo_s
            if ttft_ok or tpot_ok:
                return c
        return self.classes[-1]

    def submit(self, request) -> PriorityClass:
        c = self.classify(request)
        self._queues[c.name].append(request)
        return c

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def next_request(self):
        """Pop the most urgent queued request (None if all queues empty)."""
        for c in self.classes:
            q = self._queues[c.name]
            if q:
                return q.popleft()
        return None

    def requeue(self, request) -> PriorityClass:
        """Put a preempted request BACK at the head of its class queue —
        it already waited once; preemption must not also demote it."""
        c = self.classify(request)
        self._queues[c.name].appendleft(request)
        return c

    # -- prefill-worker fleet bookkeeping ---------------------------------
    def route_prefill(self, launches: int):
        """Assign a prefill job to the least-loaded worker.

        Returns ``(worker_index, queued_ahead)`` — the launches already
        queued on that worker BEFORE this job (the queue-depth term of
        the TTFT price) — and enqueues the job's own launches.
        """
        wi = min(range(self.n_workers),
                 key=lambda i: self._worker_queued[i])
        ahead = self._worker_queued[wi]
        self._worker_queued[wi] += max(1, int(launches))
        return wi, ahead

    def finish_prefill(self, worker_index: int, launches: int) -> None:
        """Drain a completed job's launches from its worker's queue."""
        self._worker_queued[worker_index] = max(
            0, self._worker_queued[worker_index] - max(1, int(launches)))

    def queue_depth(self, worker_index: Optional[int] = None) -> int:
        if worker_index is None:
            return min(self._worker_queued)
        return self._worker_queued[worker_index]

    def pick_victim(self, candidates):
        """Choose the preemption victim from ``(slot_index, request,
        admit_order)`` triples: least urgent class first, youngest
        admission within it. Returns the slot index (None if empty)."""
        if not candidates:
            return None
        best = max(candidates,
                   key=lambda t: (self.classify(t[1]).priority, t[2]))
        return best[0]


@dataclass
class QueryBitTracker:
    """Per-query effective-bitwidth distribution (paper Table 7)."""
    per_query_bits: List[float] = field(default_factory=list)

    def record_query(self, step_bits: Sequence[float]) -> None:
        if len(step_bits):
            self.per_query_bits.append(float(np.mean(step_bits)))

    def percentile_increase(self, q: float) -> float:
        """(q-th percentile − mean) / mean of per-query effective bits.

        Defined as 0.0 for an empty or zero-mean tracker (no queries to
        deviate from / no scale to deviate against) — never NaN and never
        a numpy RuntimeWarning.
        """
        if not self.per_query_bits:
            return 0.0
        arr = np.asarray(self.per_query_bits)
        mean = arr.mean()
        if mean == 0.0:
            return 0.0
        return float((np.percentile(arr, q) - mean) / mean)

    def summary(self) -> Dict[str, float]:
        """Distribution report; ``{}`` when no queries were recorded
        (callers key off the empty dict instead of catching NaN)."""
        if not self.per_query_bits:
            return {}
        arr = np.asarray(self.per_query_bits)
        return {
            "mean": float(arr.mean()),
            "p90_increase": self.percentile_increase(90),
            "p99_increase": self.percentile_increase(99),
        }
