"""QoS control: map per-query latency budgets to target precisions.

The runtime-adaptation story of the paper (Fig. 1): queries arrive with a
TPOT budget; the planner picks the highest target precision whose predicted
decode latency fits the current slack. The latency model is the v5e
weight-traffic roofline (decode is memory-bound): t(b) ≈ bytes(b)/HBM_bw +
overhead, calibrated against measured step times when available.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

HBM_BW = 819e9      # bytes/s per chip (v5e)


@dataclass
class LatencyModel:
    bytes_per_bit: float          # overlay bytes per effective bit
    overhead_s: float = 2e-4      # selector + cache + dispatch

    def tpot(self, bits: float, chips: int = 1) -> float:
        return self.bytes_per_bit * bits / (HBM_BW * chips) + self.overhead_s

    def ttft(self, bits: float, prompt_len: int, prefill_chunk: int,
             chips: int = 1) -> float:
        """Predicted time-to-first-token of the batched prefill stage.

        Each of the ``ceil(p / prefill_chunk)`` launches streams the
        overlay once (weight traffic is amortized over the chunk's rows
        — the arithmetic-intensity flip that motivates disaggregation)
        plus the per-launch dispatch overhead. The legacy tick-by-tick
        prefill is the ``prefill_chunk=1`` special case: p launches,
        p× the weight traffic — which is exactly why long prompts used
        to blow short TPOT budgets.
        """
        launches = max(1, -(-int(prompt_len) // max(1, int(prefill_chunk))))
        return launches * self.tpot(bits, chips)

    def spec_tpot(self, bits: float, k: int, acceptance: float,
                  draft_bits: float = 2.0, chips: int = 1) -> float:
        """Predicted per-emitted-token latency under speculative decode.

        One draft/verify window costs ``k - 1`` draft ticks streaming
        the ``draft_bits``-plane prefix plus ONE verify launch streaming
        the full ``bits`` overlay (weight traffic amortized over the
        window's k rows, like prefill), and emits ``1 + acceptance *
        (k - 1)`` tokens in expectation::

            t = ((k-1) * tpot(draft) + tpot(bits)) / (1 + a * (k-1))

        ``k=1`` (or ``acceptance=0``) degenerates to plain ``tpot`` —
        verify-only windows emit exactly one token each. The acceptance
        input is the planner's observed EMA, so admission predictions
        track the workload's actual draft quality.
        """
        k = max(1, int(k))
        a = min(1.0, max(0.0, float(acceptance)))
        window = (k - 1) * self.tpot(draft_bits, chips) + \
            self.tpot(bits, chips)
        return window / (1.0 + a * (k - 1))


@dataclass
class QoSPlanner:
    targets: Sequence[float]          # supported target precisions
    latency: LatencyModel
    chips: int = 1
    # speculative serving: when spec_k is set, admission predicts TPOT
    # with the draft/verify window model at the OBSERVED acceptance EMA
    # (scheduler feeds observe_acceptance after every chunk) — a workload
    # whose drafts keep landing admits higher precisions into the same
    # TPOT budget, which is the paper's runtime-adaptation dial extended
    # from "how many bit-planes" to "how many tokens per launch"
    spec_k: Optional[int] = None
    draft_bits: float = 2.0
    acceptance_ema: float = 0.0

    def observe_acceptance(self, rate: float, alpha: float = 0.2) -> None:
        """Fold one chunk's measured acceptance rate into the EMA."""
        r = min(1.0, max(0.0, float(rate)))
        self.acceptance_ema = (1.0 - alpha) * self.acceptance_ema + \
            alpha * r

    def _tpot(self, bits: float) -> float:
        if self.spec_k is not None and self.spec_k > 1:
            return self.latency.spec_tpot(
                bits, self.spec_k, self.acceptance_ema,
                draft_bits=self.draft_bits, chips=self.chips)
        return self.latency.tpot(bits, self.chips)

    def plan(self, tpot_budget_s: float,
             utilization: float = 0.0,
             prompt_len: Optional[int] = None,
             ttft_budget_s: Optional[float] = None,
             prefill_chunk: Optional[int] = None) -> float:
        """Highest precision fitting the budget at current utilization.

        With a ``ttft_budget_s`` (and the prompt length), a TTFT term
        joins the admission test: a target is feasible only if the
        prefill-stage cost model says the prompt's first token lands
        inside the TTFT budget too — so a long prompt can no longer
        admit at a precision whose prefill alone blows a short-budget
        slot's deadline. ``prefill_chunk=None`` models the tick-by-tick
        prefill (chunk of 1 — the legacy worst case, p launches).
        Requests without a TTFT budget keep the TPOT-only admission.
        """
        if ttft_budget_s is not None and not prompt_len:
            raise ValueError("a ttft_budget_s needs prompt_len — without "
                             "it the TTFT guard would be silently skipped")
        slack = tpot_budget_s * max(0.0, 1.0 - utilization)
        feasible = [t for t in sorted(self.targets)
                    if self._tpot(t) <= slack]
        if prompt_len and ttft_budget_s is not None:
            chunk = prefill_chunk or 1
            feasible = [t for t in feasible
                        if self.latency.ttft(t, prompt_len, chunk,
                                             self.chips) <= ttft_budget_s]
        return feasible[-1] if feasible else min(self.targets)


@dataclass
class QueryBitTracker:
    """Per-query effective-bitwidth distribution (paper Table 7)."""
    per_query_bits: List[float] = field(default_factory=list)

    def record_query(self, step_bits: Sequence[float]) -> None:
        if len(step_bits):
            self.per_query_bits.append(float(np.mean(step_bits)))

    def percentile_increase(self, q: float) -> float:
        """(q-th percentile − mean) / mean of per-query effective bits.

        Defined as 0.0 for an empty or zero-mean tracker (no queries to
        deviate from / no scale to deviate against) — never NaN and never
        a numpy RuntimeWarning.
        """
        if not self.per_query_bits:
            return 0.0
        arr = np.asarray(self.per_query_bits)
        mean = arr.mean()
        if mean == 0.0:
            return 0.0
        return float((np.percentile(arr, q) - mean) / mean)

    def summary(self) -> Dict[str, float]:
        """Distribution report; ``{}`` when no queries were recorded
        (callers key off the empty dict instead of catching NaN)."""
        if not self.per_query_bits:
            return {}
        arr = np.asarray(self.per_query_bits)
        return {
            "mean": float(arr.mean()),
            "p90_increase": self.percentile_increase(90),
            "p99_increase": self.percentile_increase(99),
        }
