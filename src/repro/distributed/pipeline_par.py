"""GPipe-style pipeline parallelism over the 'pod' mesh axis (optional).

The default multi-pod layout treats 'pod' as pure data parallelism; this
module offers the alternative: pipeline stages across pods, microbatches
streamed through ``shard_map`` + ``ppermute``. The schedule is the classic
GPipe loop with ``num_microbatches + num_stages − 1`` ticks; bubble fraction
``(S−1)/(M+S−1)``.

Stage functions receive (stage_params, activations) and every device holds
only its stage's parameters — combined with TP over 'model' inside each
stage this gives DP×PP×TP 3D parallelism.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe_forward(
    stage_fn: Callable,          # (stage_params, x, stage_idx) -> x
    stage_params,                # pytree; leaves stacked on leading pod dim
    x: jax.Array,                # (num_microbatches, mb, seq, d)
    mesh: Mesh,
    *,
    axis: str = "pod",
) -> jax.Array:
    """Runs every microbatch through all S stages; returns final outputs."""
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1

    def per_pod(params_local, x_local):
        # params_local: this stage's params (leading dim 1) ; squeeze
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(x_local[0])          # current activation slot
        outs = jnp.zeros_like(x_local)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if in range)
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            fresh = x_local[inject]
            buf = jnp.where(stage == 0, fresh, buf)
            # every stage applies its layer block
            y = stage_fn(params_local, buf, stage)
            # last stage banks its output for microbatch (t - S + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.cond(
                bank,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0),
                lambda o: o, outs)
            # shift activations to the next stage
            nxt = jax.lax.ppermute(
                y, axis_name=axis,
                perm=[(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(ticks))
        # only the last stage banked real outputs (others hold zeros);
        # psum broadcasts them so the replicated out_spec is truthful
        return jax.lax.psum(outs, axis_name=axis)

    in_specs = (P(axis), P())        # params stacked over pods; x replicated
    out_specs = P()                  # outputs valid on the last stage
    fn = shard_map(per_pod, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn(stage_params, x)
