"""Fault tolerance: restart-from-checkpoint, heartbeats, straggler watch.

In-container there is no real cluster, so liveness comes from an injectable
clock/failure source; the *control logic* (what a 1000-node launcher runs)
is real and tested:

- :class:`HeartbeatMonitor` — per-worker deadlines, dead/straggler flags;
- :func:`run_with_restarts` — supervises a train function; on failure,
  restores the latest checkpoint and replays the data stream to the failed
  step (ShardedBatchIterator.seek), up to ``max_restarts``;
- :class:`StragglerMitigator` — EMA of step times; slow steps beyond
  ``threshold×EMA`` are flagged and (policy) the offending host's shard can
  be re-assigned — here surfaced as advisory events the launcher logs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests/chaos hooks)."""


@dataclass
class HeartbeatMonitor:
    num_workers: int
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic
    last_seen: Dict[int, float] = field(default_factory=dict)

    def beat(self, worker: int) -> None:
        self.last_seen[worker] = self.clock()

    def dead_workers(self) -> List[int]:
        now = self.clock()
        return [w for w in range(self.num_workers)
                if now - self.last_seen.get(w, -1e18) > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_workers()


@dataclass
class StragglerMitigator:
    threshold: float = 2.0
    ema_decay: float = 0.9
    ema: Optional[float] = None
    events: List[dict] = field(default_factory=list)

    def observe(self, step: int, duration_s: float) -> bool:
        """Returns True when this step counts as a straggler."""
        if self.ema is None:
            self.ema = duration_s
            return False
        slow = duration_s > self.threshold * self.ema
        if slow:
            self.events.append({"step": step, "duration": duration_s,
                                "ema": self.ema})
        # slow steps don't poison the EMA
        if not slow:
            self.ema = self.ema_decay * self.ema + \
                (1 - self.ema_decay) * duration_s
        return slow


def run_with_restarts(
    train_fn: Callable[[int], int],   # (start_step) -> last_step; raises on failure
    *,
    restore_fn: Callable[[], int],    # -> step to resume from
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
) -> int:
    """Supervision loop: restart ``train_fn`` from the latest checkpoint."""
    restarts = 0
    start = restore_fn()
    while True:
        try:
            return train_fn(start)
        except (SimulatedFailure, OSError) as e:
            restarts += 1
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded {max_restarts} restarts") from e
            start = restore_fn()
            if on_restart is not None:
                on_restart(start, e)
