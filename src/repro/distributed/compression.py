"""Gradient compression for the data-parallel all-reduce.

Scheme (1-bit-Adam/PowerSGD-family, adapted to ring collectives):
  1. ``psum_scatter`` the f32 gradient — the reduction itself stays exact
     and each device ends with its shard of the true mean;
  2. add the (scatter-shaped) error-feedback residual;
  3. quantize the reduced shard to int8 + one f32 scale;
  4. ``all_gather`` the int8 shards — the broadcast half of the all-reduce
     at 1/4 the bytes — and dequantize;
  5. the local quantization error becomes the next step's residual
     (scatter-shaped: no extra traffic).

Traffic vs plain ring all-reduce: (1 + 1/4)/2 = 0.625× — a 37.5% cut on the
cross-pod DCI hop where bandwidth is scarcest, with error feedback keeping
convergence (validated in tests/test_distributed.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.collectives import axis_size


def residual_shape(n_elements: int, axis_size: int) -> Tuple[int]:
    padded = n_elements + ((-n_elements) % axis_size)
    return (padded // axis_size,)


def _quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_allreduce_shard(
    grad: jax.Array,           # local gradient (any shape), inside shard_map
    residual: jax.Array,       # (padded_size/axis_n,) error-feedback state
    *,
    axis: str,
) -> Tuple[jax.Array, jax.Array]:
    """Mean-all-reduce with int8-compressed broadcast + error feedback.

    Returns (mean_grad (grad.shape), new_residual (residual.shape)).
    """
    n = axis_size(axis)
    flat = grad.astype(jnp.float32).reshape((-1,))
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # 1. exact reduce-scatter (f32), then 2. error feedback on my shard
    shard = jax.lax.psum_scatter(flat, axis_name=axis, tiled=True) / n
    shard = shard + residual
    # 3. compress my shard
    q, scale = _quantize_int8(shard)
    deq = q.astype(jnp.float32) * scale
    new_residual = shard - deq
    # 4. int8 broadcast
    gathered_q = jax.lax.all_gather(q, axis_name=axis, tiled=True)
    gathered_s = jax.lax.all_gather(scale, axis_name=axis)
    mean = (gathered_q.reshape(n, -1).astype(jnp.float32) *
            gathered_s.reshape(n, 1)).reshape((-1,))
    if pad:
        mean = mean[:-pad]
    return mean.reshape(grad.shape).astype(grad.dtype), new_residual


def plain_allreduce_shard(grad: jax.Array, *, axis: str) -> jax.Array:
    return jax.lax.pmean(grad, axis_name=axis)
