from repro.distributed.compression import (compressed_allreduce_shard,
                                           plain_allreduce_shard,
                                           residual_shape)
from repro.distributed.elastic import best_mesh, reshard_tree
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               SimulatedFailure,
                                               StragglerMitigator,
                                               run_with_restarts)
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES, batch_spec,
                                        decode_state_spec, kv_cache_spec,
                                        overlay_axes, overlay_shardings,
                                        param_shardings, resolve_spec,
                                        slot_state_spec, slot_vec_spec)

__all__ = [
    "HeartbeatMonitor", "SERVE_RULES", "SimulatedFailure",
    "StragglerMitigator", "TRAIN_RULES", "batch_spec", "best_mesh",
    "compressed_allreduce_shard", "decode_state_spec", "kv_cache_spec",
    "overlay_axes", "overlay_shardings", "param_shardings",
    "plain_allreduce_shard", "reshard_tree", "residual_shape",
    "resolve_spec", "run_with_restarts", "slot_state_spec",
    "slot_vec_spec",
]
