"""Elastic scaling: re-mesh and re-shard state when the device pool changes.

A checkpoint written on one mesh restores onto any other (the checkpointer
stores full logical arrays; ``jax.device_put`` re-shards under the target
mesh). ``best_mesh`` picks the largest (data, model) grid for the surviving
device count, preferring to shrink the data axis first (model-parallel
groups are harder to rebuild than batch).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def best_mesh(
    n_devices: int,
    *,
    model_parallel: int,
    devices: Optional[Sequence] = None,
    multi_pod_threshold: int = 0,
) -> Mesh:
    """Largest usable (data, model) mesh for ``n_devices``.

    Shrinks model_parallel (halving) until it divides the pool; the rest
    becomes the data axis. With ``multi_pod_threshold`` > 0 and enough
    devices, a leading 'pod' axis is added.
    """
    devs = list(devices if devices is not None else jax.devices())[:n_devices]
    mp = model_parallel
    while mp > 1 and (len(devs) % mp != 0):
        mp //= 2
    dp = len(devs) // mp
    if multi_pod_threshold and dp % 2 == 0 and \
            len(devs) >= multi_pod_threshold:
        arr = np.array(devs).reshape(2, dp // 2, mp)
        return Mesh(arr, ("pod", "data", "model"))
    arr = np.array(devs).reshape(dp, mp)
    return Mesh(arr, ("data", "model"))


def reshard_tree(tree, shardings):
    """Re-place every leaf under the target shardings (cross-mesh restore)."""
    return jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
