"""Divisibility-aware logical-axis sharding rules.

Every parameter/state tensor carries logical axis names (models/common.py);
one rules table maps them to mesh axes. The resolver enforces:
- only mesh axes that exist on the current mesh are used (the same rules
  serve the 16×16 single-pod and 2×16×16 multi-pod meshes);
- a mesh axis is used at most once per tensor (first logical dim wins —
  e.g. MoE (experts, embed, ffn) gets EP on 'model', ffn replicated);
- a dim must divide by the product of its mesh axes; otherwise axes are
  dropped right-to-left until it does (e.g. kv_heads=8 on model=16 →
  replicated) — so every assigned arch lowers cleanly.

Two profiles:
- TRAIN: TP on 'model', FSDP/ZeRO-3 on ('pod','data') over the weights'
  embed/reduction dims (mandatory to fit 340B+ training), batch on
  ('pod','data').
- SERVE: TP on 'model'; weights replicated over 'data' (each data-parallel
  group serves its own requests); KV caches shard batch→data,
  heads→model with sequence fallback for long-context cells.

Serve-artifact axes (the mesh-native decode path): every adaptation
artifact exported by ``core/adaptation.export_serve_arrays`` is a
target-stacked array whose leading 'targets' axis is replicated (a traced
index selects into it — slicing a sharded axis would all-gather), the JL
sketch-row axis 'jl_proj' is replicated (k_proj ≈ 64, not worth a
collective), and the G matrix's trailing K axis carries the *same logical
axis as the weight it gates* — so under SERVE_RULES the estimator inputs
are sharded exactly like the matmul operands next to them (weight-K over
'pod', replicated over 'model'). The scheduler's 'slots' axis maps onto
'data': each data-parallel group serves its own admitted requests while
sharing one compiled tick.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import (CONV, EMBED, EXPERTS, FFN, HEADS, JL_PROJ,
                                 KV_HEADS, PLANES, SLOTS, SSM_HEADS,
                                 SSM_INNER, TARGETS, UNITS, VOCAB)

Rules = Dict[Optional[str], Tuple[str, ...]]

TRAIN_RULES: Rules = {
    VOCAB: ("model",),
    HEADS: ("model",),
    KV_HEADS: ("model",),
    FFN: ("model",),
    EXPERTS: ("model",),
    SSM_INNER: ("model",),
    SSM_HEADS: ("model",),
    EMBED: ("pod", "data"),     # FSDP / ZeRO-3 weight sharding
    CONV: (),
    None: (),
}

SERVE_RULES: Rules = {
    VOCAB: ("model",),
    HEADS: ("model",),
    KV_HEADS: ("model",),
    FFN: ("model",),
    EXPERTS: ("model",),
    SSM_INNER: ("model",),
    SSM_HEADS: ("model",),
    EMBED: ("pod",),            # multi-pod: 2-way weight-K sharding halves
                                # per-chip overlay bytes (340B+ decode fit);
                                # single-pod mesh has no 'pod' axis -> noop
    CONV: (),
    # serve artifacts (target-stacked adaptation arrays + overlays)
    TARGETS: (),                # traced-index axis: must stay replicated
    JL_PROJ: (),                # k_proj sketch rows: tiny, replicated
    PLANES: (),                 # bit-plane axis: the precision mechanism
                                # reads a *prefix* of it — never shard
    SLOTS: ("data",),           # continuous-batching slots: each DP group
                                # decodes its own admitted requests
    UNITS: (),                  # decision-bundle unit axis: the planner's
                                # (U,) bits vector is consumed by static
                                # row lookups inside every layer — it must
                                # stay replicated (its K_max pad mixes
                                # units with different weight axes, so the
                                # packed G stack replicates too)
    None: (),
}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Rules,
) -> P:
    sizes = _mesh_axis_sizes(mesh)
    used = set()
    entries = []
    for dim, ax in zip(shape, axes):
        want = [m for m in rules.get(ax, ()) if m in sizes and m not in used]
        # drop axes right-to-left until the dim divides
        while want and dim % int(np.prod([sizes[m] for m in want])) != 0:
            want.pop()
        if want:
            used.update(want)
            entries.append(tuple(want) if len(want) > 1 else want[0])
        else:
            entries.append(None)
    return P(*entries)


def param_shardings(
    mesh: Mesh,
    logical_axes: Dict[str, Tuple[Optional[str], ...]],
    shapes: Dict[str, Tuple[int, ...]],
    rules: Optional[Rules] = None,
) -> Dict[str, NamedSharding]:
    rules = rules or TRAIN_RULES
    return {
        path: NamedSharding(mesh, resolve_spec(shapes[path], axes, mesh,
                                               rules))
        for path, axes in logical_axes.items()
    }


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """Shard the leading batch dim over ('pod','data') where divisible."""
    sizes = _mesh_axis_sizes(mesh)
    want = [m for m in ("pod", "data") if m in sizes]
    while want and batch % int(np.prod([sizes[m] for m in want])) != 0:
        want.pop()
    lead = tuple(want) if len(want) > 1 else (want[0] if want else None)
    return P(lead, *([None] * extra_dims))


def kv_cache_spec(mesh: Mesh, batch: int, seq: int, kv_heads: int) -> P:
    """(batch, seq, kv_heads, head_dim) decode-cache sharding.

    batch→(pod,data) when divisible; kv_heads→model when divisible, else
    seq→model (sequence-parallel decode — GSPMD inserts the partial-softmax
    collectives); leftover batch capacity spills onto seq too
    (long_500k batch=1 shards seq over every axis).
    """
    sizes = _mesh_axis_sizes(mesh)
    b_axes = [m for m in ("pod", "data") if m in sizes]
    while b_axes and batch % int(np.prod([sizes[m] for m in b_axes])) != 0:
        b_axes.pop()
    seq_axes = []
    if kv_heads % sizes.get("model", 1) == 0:
        head_entry = "model"
    else:
        head_entry = None
        if seq % sizes.get("model", 1) == 0:
            seq_axes.append("model")
    # unused batch axes spill to seq
    spill = [m for m in ("pod", "data")
             if m in sizes and m not in b_axes]
    for m in spill:
        if seq % int(np.prod([sizes[a] for a in seq_axes + [m]])) == 0:
            seq_axes.append(m)
    b_entry = tuple(b_axes) if len(b_axes) > 1 else \
        (b_axes[0] if b_axes else None)
    s_entry = tuple(seq_axes) if len(seq_axes) > 1 else \
        (seq_axes[0] if seq_axes else None)
    return P(b_entry, s_entry, head_entry, None)


# ---------------------------------------------------------------------------
# Serve-path shardings (mesh-native decode: overlays, artifacts, slot state)
# ---------------------------------------------------------------------------
def overlay_axes(weight_axes: Sequence[Optional[str]],
                 stacked: bool) -> Dict[str, Tuple[Optional[str], ...]]:
    """Logical axes of a bit-plane overlay's components.

    ``weight_axes`` are the parent weight's axes — (K, N) for plain
    linears, (experts, K, N) for stacked MoE units. The packed-K and N
    dims of the planes inherit the weight's axes (the overlay IS the
    weight, stored bit-serially); the plane axis itself is never sharded
    (a precision is a *prefix* of planes — splitting it would turn every
    precision switch into a collective).
    """
    if stacked:
        e_ax, k_ax, n_ax = weight_axes
        return {"planes": (e_ax, PLANES, k_ax, n_ax),
                "scale": (e_ax, n_ax), "zero": (e_ax, n_ax)}
    k_ax, n_ax = weight_axes
    return {"planes": (PLANES, k_ax, n_ax),
            "scale": (n_ax,), "zero": (n_ax,)}


def overlay_shardings(mesh: Mesh, ov, weight_axes: Sequence[Optional[str]],
                      stacked: bool, rules: Optional[Rules] = None):
    """``{planes, scale, zero} -> NamedSharding`` for one overlay."""
    rules = rules or SERVE_RULES
    axes = overlay_axes(weight_axes, stacked)
    return {name: NamedSharding(mesh, resolve_spec(
                getattr(ov, name).shape, ax, mesh, rules))
            for name, ax in axes.items()}


def slot_state_spec(mesh: Mesh, key: str, shape: Sequence[int],
                    rules: Optional[Rules] = None) -> P:
    """Scheduler per-slot decode-state sharding.

    The leading dim is the slot axis (→ 'data': each data-parallel group
    decodes its own admitted requests); KV caches additionally shard
    heads → 'model' like the attention weights that fill them. Everything
    else inside a slot is replicated — slots are batch-1 decodes.
    """
    rules = rules or SERVE_RULES
    axes = [SLOTS] + [None] * (len(shape) - 1)
    if key.startswith("kv.") and key.endswith("_planes") and \
            len(shape) == 6:
        # (slots, 1, B, seq, kv_heads, dw): the plane axis carries the
        # PLANES rule — a read precision is a *prefix* of planes, so it
        # stays unsplit; heads shard like the dense cache's
        axes[2] = PLANES
        axes[4] = KV_HEADS
    elif (key.startswith("kv.") or key.startswith("xkv.")) and \
            len(shape) == 5:
        axes[3] = KV_HEADS
    return resolve_spec(shape, axes, mesh, rules)


def paged_pool_spec(mesh: Mesh, key: str, shape: Sequence[int],
                    rules: Optional[Rules] = None) -> P:
    """Paged bitplane-KV pool sharding — a named, test-asserted contract
    like :func:`slot_prefetch_spec`.

    Pool leaves have NO slot axis: the pool is one shared page store and
    every slot's page table may point anywhere in it, so the page axis
    must stay replicated over 'data' (sharding pages would turn each
    slot's gather into a cross-group collective and break the
    slots → 'data' locality every other serve tensor keeps). Within a
    page the layout mirrors the bucketed overlay cache it replaces:
    kv_heads → 'model' like the attention weights that fill the rows,
    and the plane axis stays whole (a read precision is a *prefix* of
    planes — splitting it would turn every precision switch into a
    collective).

    Leaf shapes: planes ``(n_pages, B, page_len, kv_heads, dw)``,
    scale/zero ``(n_pages, page_len, kv_heads, 1)``.
    """
    rules = rules or SERVE_RULES
    if key.endswith("_planes") and len(shape) == 5:
        axes: Tuple[Optional[str], ...] = (None, PLANES, None, KV_HEADS,
                                           None)
    elif len(shape) == 4:
        axes = (None, None, KV_HEADS, None)
    else:
        axes = (None,) * len(shape)
    return resolve_spec(shape, axes, mesh, rules)


def page_table_spec(mesh: Mesh, shape: Sequence[int],
                    rules: Optional[Rules] = None) -> P:
    """Per-slot page tables ``(slots, 1, pages_per_slot)``: the slot axis
    shards over 'data' like every per-slot control vector — each
    data-parallel group holds only its own slots' indirection rows —
    and the page-id axis is replicated within a slot (the ids index the
    replicated page axis of :func:`paged_pool_spec`, so a local lookup
    never crosses groups)."""
    return slot_vec_spec(mesh, shape, rules)


def slot_vec_spec(mesh: Mesh, shape: Sequence[int],
                  rules: Optional[Rules] = None) -> P:
    """Per-slot host-control vectors (cur, counts, prompt buffer rows):
    leading slot dim → 'data' when divisible, trailing dims replicated."""
    rules = rules or SERVE_RULES
    axes = (SLOTS,) + (None,) * (len(shape) - 1)
    return resolve_spec(shape, axes, mesh, rules)


def slot_prefetch_spec(mesh: Mesh, slots: int,
                       rules: Optional[Rules] = None) -> P:
    """EXPECTED sharding of the batched bit-serial kernel's scalar-prefetch
    vector — a named, test-asserted contract, not active wiring.

    The slot-batched kernel (kernels/bitserial) takes a per-slot ``(S,)``
    int32 ``b_sel`` vector as its scalar-prefetch operand. That vector is
    derived *inside* the compiled tick (from the per-slot running mask and
    precision decisions), so its layout comes from SPMD propagation off
    the slot-sharded operands — nothing device_puts it explicitly. This
    function names the layout propagation must (and does — see
    tests/test_sharded_serve.py) arrive at: the SAME slot axis as every
    per-slot control vector (slots → 'data', each data-parallel group
    prefetches only its own slots' precisions; replicated when S doesn't
    divide 'data'). A future dispatch that compiles the kernel with
    explicit shardings must use this spec for b_sel.
    """
    return slot_vec_spec(mesh, (slots,), rules)


def verify_batch_spec(mesh: Mesh, slots: int, k: int,
                      rules: Optional[Rules] = None) -> P:
    """EXPECTED sharding of the speculative VERIFY batch — a named,
    test-asserted contract like :func:`slot_prefetch_spec`.

    The verify launch is ONE (S, k)-row batched decode: the scheduler's
    S slots each carry a k-row speculation window, and the nested
    custom_vmap collapse (kernels/bitserial ``_slots_batchable``) lands
    all S·k rows on the batched kernel's slot axis. The layout follows
    the slot axis — slots → 'data' when divisible (each data-parallel
    group verifies its own slots' windows), the k row axis replicated
    (a window's rows are one sequential speculation, never split across
    groups) — so propagation off the slot-sharded state keeps the
    verify batch aligned with every other per-slot control tensor.
    """
    return slot_vec_spec(mesh, (slots, k), rules)


def expert_group_spec(mesh: Mesh, shape: Sequence[int],
                      rules: Optional[Rules] = None) -> P:
    """EXPECTED sharding of the grouped MoE kernel's operands — a named,
    test-asserted contract like :func:`slot_prefetch_spec`.

    The grouped bit-serial kernel flattens the GShard dispatch
    EXPERT-MAJOR: group ``e·ng + i`` is (expert e, token-group i), so
    the leading G axis of the activations ``(G, C, K)`` and of the
    scalar-prefetch tables ``expert_of``/``b_sel``/``counts`` ``(G,)``
    IS the expert axis in coarse form — it shards over 'model' exactly
    like the stacked overlay's E axis (EXPERTS rule), keeping expert
    parallelism intact when the dense materialization is gone: each
    model-group runs only its own experts' groups, and the plane axis
    stays unsplit (a precision is a *prefix* of planes). Replicated
    when G doesn't divide 'model'. Derived inside the compiled step via
    SPMD propagation off the expert-sharded overlays — nothing
    device_puts these explicitly; a future dispatch compiling the
    kernel with explicit shardings must use this spec.
    """
    rules = rules or SERVE_RULES
    axes = (EXPERTS,) + (None,) * (len(shape) - 1)
    return resolve_spec(shape, axes, mesh, rules)


def decision_carry_spec(mesh: Mesh, shape: Sequence[int],
                        rules: Optional[Rules] = None) -> P:
    """The pipelined decision carry's sharding.

    ``(U,)`` — the engine's per-tick bits vector — is replicated (UNITS
    never shards: every layer's lookup reads it). ``(S, U)`` — the
    scheduler's per-slot carry — shards slots → 'data' like every other
    per-slot control vector, units replicated, so each data-parallel
    group carries only its own slots' decisions.
    """
    rules = rules or SERVE_RULES
    axes = (SLOTS, UNITS) if len(shape) == 2 else (UNITS,)
    return resolve_spec(shape, axes, mesh, rules)


def decode_state_spec(mesh: Mesh, key: str, shape: Sequence[int]) -> P:
    """Engine (batched, slot-free) decode-state sharding.

    KV caches go through :func:`kv_cache_spec`; SSM recurrent states shard
    batch → ('pod','data'); the scalar position is replicated.
    """
    if key.startswith("kv.") and key.endswith("_planes") and \
            len(shape) == 5:
        # (batch, B, seq, kv_heads, dw): reuse the dense cache's layout
        # decisions, keeping the plane axis whole (reads slice a prefix
        # of planes — splitting it would turn every read into a gather)
        dense = kv_cache_spec(mesh, shape[0], shape[2], shape[3])
        return P(dense[0], None, dense[1], dense[2], None)
    if (key.startswith("kv.") or key.startswith("xkv.")) and len(shape) == 4:
        return kv_cache_spec(mesh, shape[0], shape[1], shape[2])
    if key.startswith("ssm.") and len(shape) >= 2:
        return batch_spec(mesh, shape[0], len(shape) - 1)
    return P()


def prefill_spec(mesh: Mesh, key: str, shape: Sequence[int]) -> P:
    """Prefill-STAGE state sharding — ``decode_state_spec``'s pair half.

    The prefill slice is the 'model' (× 'pod' weight-K) axis group: a
    prefill launch is one arithmetic-intense batched forward whose
    activations and KV rows shard over the tensor-parallel axes only.
    The 'data' axis — the decode scheduler's slot axis — is deliberately
    LEFT OUT of every leaf, so a prefill-stage state is replicated
    across data-parallel groups and the KV block changes placement
    exactly once, at the handoff (``serving.kv_cache.insert_slot_state``
    compiled with these specs in and the slot specs out — GSPMD emits
    the slice-to-slice transfer there; on a mesh without 'data', or with
    no mesh at all, the handoff degenerates to an identity transfer).

    KV leaves shard heads → 'model' (like the attention weights that
    fill them, when divisible); everything else in the batch-1 prefill
    scratch is small and stays replicated.
    """
    sizes = _mesh_axis_sizes(mesh)
    if key.startswith("kv.") and key.endswith("_planes") and \
            len(shape) == 5:
        head_entry = "model" if ("model" in sizes and
                                 shape[3] % sizes["model"] == 0) else None
        return P(None, None, None, head_entry, None)
    if (key.startswith("kv.") or key.startswith("xkv.")) and len(shape) == 4:
        head_entry = "model" if ("model" in sizes and
                                 shape[2] % sizes["model"] == 0) else None
        return P(None, None, head_entry, None)
    return P()


def tree_shardings(mesh: Mesh, tree, spec_fn) -> object:
    """Map ``spec_fn(path_str, leaf) -> PartitionSpec`` over a pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append(NamedSharding(mesh, spec_fn(key, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)
