"""Mesh context for in-model sharding constraints.

Model code (MoE dispatch, scan carries, logits) sometimes needs explicit
``with_sharding_constraint`` hints — GSPMD drops shardings through one-hot/
cumsum/reshape chains and replicated intermediates blow past HBM (measured:
granite-moe train temp went to 308GB/dev without these). Model code cannot
depend on a concrete mesh, so constraints go through this context: when no
mesh is active (unit tests, single-device benches) every hint is a no-op.

Hints are divisibility-filtered per dim, like distributed/sharding.py, so
the same model code lowers on any mesh.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT: list = [None]


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    _CURRENT.append(mesh)
    try:
        yield mesh
    finally:
        _CURRENT.pop()


def current_mesh() -> Optional[Mesh]:
    return _CURRENT[-1]


def dp_axes(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def hint(x: jax.Array, *entries) -> jax.Array:
    """Best-effort sharding constraint; silently weakens to fit the mesh.

    ``entries`` align with x's dims: None, an axis name, or a tuple of axis
    names. The special string "dp" expands to the data-parallel axes.
    """
    mesh = current_mesh()
    if mesh is None or os.environ.get("REPRO_NO_HINTS"):
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    spec = []
    for dim, e in zip(x.shape, entries):
        if e == "dp":
            axes = list(dp_axes(mesh))
        elif e is None:
            axes = []
        else:
            axes = list(e) if isinstance(e, tuple) else [e]
        axes = [a for a in axes if a in sizes and a not in used]
        while axes and dim % int(np.prod([sizes[a] for a in axes])) != 0:
            axes.pop()
        used.update(axes)
        spec.append(tuple(axes) if len(axes) > 1
                    else (axes[0] if axes else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
