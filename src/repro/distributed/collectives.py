"""Collective helpers used by the shard_map code paths."""
from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp

AxisName = Union[str, Sequence[str]]


def psum(x, axis: AxisName):
    return jax.lax.psum(x, axis_name=axis)


def pmean(x, axis: AxisName):
    return jax.lax.pmean(x, axis_name=axis)


def all_gather(x, axis: AxisName, *, tiled: bool = True):
    return jax.lax.all_gather(x, axis_name=axis, tiled=tiled)


def psum_scatter(x, axis: AxisName, *, tiled: bool = True):
    return jax.lax.psum_scatter(x, axis_name=axis, tiled=tiled)


def axis_size(axis: AxisName) -> int:
    """Mapped-axis size, version-portable: ``psum(1, axis)`` constant-folds
    to a concrete int (``jax.lax.axis_size`` is absent in older releases)."""
    return int(jax.lax.psum(1, axis_name=axis))


def ring_permute(x, axis: str, shift: int = 1):
    """Send to the next device along ``axis`` (pipeline hop)."""
    n = axis_size(axis)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name=axis, perm=perm)
