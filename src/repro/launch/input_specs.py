"""ShapeDtypeStruct input builders for every (arch × shape × mesh) cell.

Everything here is allocation-free: weak-type-correct ``ShapeDtypeStruct``
stand-ins with production shardings attached, for ``jit(...).lower()``.

Cell kinds:
- ``train``   → (params bf16, AdamW state, batch)        for ``train_step``
- ``prefill`` → (serve_params [quantized overlays], batch) for ``prefill_step``
- ``decode``  → (serve_params, decode state, tokens)       for ``serve_step``

The serve-side unit table is synthesized per arch at the paper's standard
operating point: 5-bit memory budget, target 4.5 → (l,h)=(4,5) everywhere
dynamic, estimator kinds split 50/50 linear/JL (the paper's Llama-3-8B
census, Table 8).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DECODE, PREFILL, TRAIN, ModelConfig, SHAPES
from repro.core.bitplane import PACK, QuantizedLinear, QuantizedStacked
from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES, batch_spec,
                                        kv_cache_spec, resolve_spec)
from repro.models import (linear_units, model_logical_axes,
                          model_param_specs)
from repro.core.adaptation import UnitStatic
from repro.models.common import EXPERTS, JL_PROJ, PLANES, TARGETS
from repro.models.ssm import ssm_dims

JL_K = 64
SERVE_BUDGET_BITS = 5       # Phase-1 cap: overlays store 5 planes
SERVE_L, SERVE_H = 4, 5     # target 4.5 candidate pair
PARENT_BITS = 6


# length of the traced target axis in the lowering specs: the compiled
# step serves this many target precisions via a traced index. Specs here
# are shapes only — the actual per-target l/h/threshold values are filled
# by export_serve_arrays at launch time.
N_SERVE_TARGETS = 3


def _est_entry_specs(st: UnitStatic, kpad: int, k_ax, mesh,
                     steps: Optional[int] = None):
    """Canonical target-stacked estimator-array SDS for one dynamic unit.

    Axis annotations follow ``core/adaptation.serve_array_axes``: the
    target axis (TARGETS) and JL sketch rows (JL_PROJ) resolve to
    replicated under SERVE_RULES, the G matrix's trailing K axis carries
    the gated weight's logical axis (weight-K over 'pod' on the multi-pod
    mesh). An optional leading scan-steps dim is replicated.
    """
    n_t = N_SERVE_TARGETS
    lead = (steps,) if steps is not None else ()
    lax_ = (None,) if steps is not None else ()

    def small(dtype):
        shape, axes = lead + (n_t,), lax_ + (TARGETS,)
        return _sds(shape, dtype, mesh,
                    resolve_spec(shape, axes, mesh, SERVE_RULES))

    entry = {"l": small(jnp.int32), "h": small(jnp.int32),
             "kind": small(jnp.int32), "threshold": small(jnp.float32)}
    if st.est_kind == "linear":
        entry["a"] = small(jnp.float32)
        entry["b"] = small(jnp.float32)
    else:
        g_shape = lead + (n_t, JL_K, kpad)
        g_axes = lax_ + (TARGETS, JL_PROJ, k_ax)
        entry["gamma"] = small(jnp.float32)
        entry["g"] = _sds(g_shape, jnp.float32, mesh,
                          resolve_spec(g_shape, g_axes, mesh, SERVE_RULES))
    return entry


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Train cells
# ---------------------------------------------------------------------------
def train_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh):
    from repro.optim.adamw import AdamWState
    shp = SHAPES[shape_name]
    specs = model_param_specs(cfg)
    axes = model_logical_axes(cfg)
    params, m, v = {}, {}, {}
    for path, s in specs.items():
        pspec = resolve_spec(s.shape, axes[path], mesh, TRAIN_RULES)
        params[path] = _sds(s.shape, jnp.bfloat16, mesh, pspec)
        m[path] = _sds(s.shape, jnp.float32, mesh, pspec)
        v[path] = _sds(s.shape, jnp.float32, mesh, pspec)
    opt = AdamWState(
        step=_sds((), jnp.int32, mesh, P()), m=m, v=v)
    bspec = batch_spec(mesh, shp.global_batch)
    batch = {
        "tokens": _sds((shp.global_batch, shp.seq_len), jnp.int32, mesh,
                       bspec),
        "labels": _sds((shp.global_batch, shp.seq_len), jnp.int32, mesh,
                       bspec),
    }
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = _sds(
            (shp.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16, mesh, batch_spec(mesh, shp.global_batch, 2))
    if cfg.frontend == "audio_stub":
        batch["frames"] = _sds(
            (shp.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16, mesh, batch_spec(mesh, shp.global_batch, 2))
    return params, opt, batch


# ---------------------------------------------------------------------------
# Serve cells (prefill / decode)
# ---------------------------------------------------------------------------
def make_unit_table(cfg: ModelConfig) -> Dict[str, UnitStatic]:
    table = {}
    for i, u in enumerate(linear_units(cfg)):
        stacked = u.kind.startswith("expert_")
        if u.kind == "expert_down":
            table[u.path] = UnitStatic(u.path, SERVE_H, SERVE_H, "pinned",
                                       False, stacked)
            continue
        kind = "linear" if i % 2 == 0 else "jl"
        table[u.path] = UnitStatic(u.path, SERVE_L, SERVE_H, kind,
                                   u.async_eligible, stacked)
    return table


def serve_param_specs(cfg: ModelConfig, mesh: Mesh,
                      table: Dict[str, UnitStatic]):
    """SDS tree {raw, overlays, est} under SERVE_RULES shardings."""
    specs = model_param_specs(cfg)
    axes = model_logical_axes(cfg)
    raw = {}
    for path, s in specs.items():
        if path in table:
            continue
        pspec = resolve_spec(s.shape, axes[path], mesh, SERVE_RULES)
        raw[path] = _sds(s.shape, jnp.bfloat16, mesh, pspec)

    overlays, est = {}, {}
    for u in linear_units(cfg):
        st = table[u.path]
        w_axes = axes[u.path]
        kpad = u.k + ((-u.k) % PACK)
        if st.stacked:
            e_dim = cfg.num_experts
            k_ax, n_ax = w_axes[1], w_axes[2]
            pl_spec = resolve_spec(
                (e_dim, st.h, kpad // PACK, u.n),
                (EXPERTS, PLANES, k_ax, n_ax), mesh, SERVE_RULES)
            sc_spec = resolve_spec((e_dim, u.n), (EXPERTS, n_ax), mesh,
                                   SERVE_RULES)
            overlays[u.path] = QuantizedStacked(
                _sds((e_dim, st.h, kpad // PACK, u.n), jnp.int32, mesh,
                     pl_spec),
                _sds((e_dim, u.n), jnp.float32, mesh, sc_spec),
                _sds((e_dim, u.n), jnp.float32, mesh, sc_spec),
                PARENT_BITS, u.k)
        else:
            k_ax, n_ax = w_axes[0], w_axes[1]
            pl_spec = resolve_spec((st.h, kpad // PACK, u.n),
                                   (PLANES, k_ax, n_ax), mesh, SERVE_RULES)
            sc_spec = resolve_spec((u.n,), (n_ax,), mesh, SERVE_RULES)
            overlays[u.path] = QuantizedLinear(
                _sds((st.h, kpad // PACK, u.n), jnp.int32, mesh, pl_spec),
                _sds((u.n,), jnp.float32, mesh, sc_spec),
                _sds((u.n,), jnp.float32, mesh, sc_spec),
                PARENT_BITS, u.k)
        if st.est_kind == "pinned":
            continue
        est[u.path] = _est_entry_specs(st, kpad, k_ax, mesh)
    return {"raw": raw, "overlays": overlays, "est": est}


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                       max_len: int):
    state = {"pos": _sds((), jnp.int32, mesh, P())}
    hd = cfg.resolved_head_dim
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "attn":
            spec = kv_cache_spec(mesh, batch, max_len, cfg.num_kv_heads)
            shape = (batch, max_len, cfg.num_kv_heads, hd)
            state[f"kv.{i}.k"] = _sds(shape, jnp.bfloat16, mesh, spec)
            state[f"kv.{i}.v"] = _sds(shape, jnp.bfloat16, mesh, spec)
        else:
            dd = ssm_dims(cfg)
            bspec = batch_spec(mesh, batch, 2)
            state[f"ssm.{i}.conv"] = _sds(
                (batch, cfg.ssm_conv_width - 1, dd["d_xbc"]), jnp.bfloat16,
                mesh, bspec)
            state[f"ssm.{i}.state"] = _sds(
                (batch, dd["nheads"], dd["d_state"],
                 dd["d_inner"] // dd["nheads"]), jnp.float32, mesh,
                batch_spec(mesh, batch, 3))
        if cfg.cross_attention:
            ft = cfg.frontend_tokens or 1
            spec = kv_cache_spec(mesh, batch, ft, cfg.num_kv_heads)
            shape = (batch, ft, cfg.num_kv_heads, hd)
            state[f"xkv.{i}.k"] = _sds(shape, jnp.bfloat16, mesh, spec)
            state[f"xkv.{i}.v"] = _sds(shape, jnp.bfloat16, mesh, spec)
    return state


def decode_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                 table: Dict[str, UnitStatic]):
    shp = SHAPES[shape_name]
    serve_params = serve_param_specs(cfg, mesh, table)
    state = decode_state_specs(cfg, mesh, shp.global_batch, shp.seq_len)
    tokens = _sds((shp.global_batch, 1), jnp.int32, mesh,
                  batch_spec(mesh, shp.global_batch))
    target_idx = _sds((), jnp.int32, mesh, P())
    return serve_params, state, tokens, target_idx


# ---------------------------------------------------------------------------
# Stacked-layer (scan) cells — the production lowering path
# ---------------------------------------------------------------------------
EST_KIND_BY_UNIT = {
    # ~50/50 linear/JL split per layer, mirroring the paper's census
    "q": "linear", "k": "jl", "v": "linear", "o": "jl",
    "gate": "linear", "up": "jl", "down": "linear",
    "ssm_in": "jl", "ssm_out": "linear",
    "expert_gate": "jl", "expert_up": "jl", "expert_down": "pinned",
}


def make_unit_table_rel(cfg: ModelConfig) -> Dict[str, UnitStatic]:
    """Unit table for the first period's layers (relative paths)."""
    from repro.models.stacked import group_size
    g = group_size(cfg)
    table = {}
    for u in linear_units(cfg):
        layer_idx = int(u.path.split(".")[1])
        if layer_idx >= g:
            continue
        stacked = u.kind.startswith("expert_")
        kind = EST_KIND_BY_UNIT.get(u.kind, "jl")
        if kind == "pinned":
            table[u.path] = UnitStatic(u.path, SERVE_H, SERVE_H, "pinned",
                                       False, stacked)
        else:
            table[u.path] = UnitStatic(u.path, SERVE_L, SERVE_H, kind,
                                       u.async_eligible, stacked)
    return table


def _add_steps_dim(shape, axes, steps):
    return (steps,) + tuple(shape), (None,) + tuple(axes)


def stacked_train_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                        optimizer: str = "adamw"):
    """(glob, stacked, opt_state, batch) SDS trees for the scan path."""
    from repro.models.stacked import num_scan_steps, split_layer_paths
    from repro.optim.adafactor import AdafactorState
    from repro.optim.adamw import AdamWState
    shp = SHAPES[shape_name]
    steps = num_scan_steps(cfg)
    glob_specs, rel_specs = split_layer_paths(cfg)
    axes = model_logical_axes(cfg)

    def sds_of(shape, ax, dtype):
        return _sds(shape, dtype, mesh,
                    resolve_spec(shape, ax, mesh, TRAIN_RULES))

    glob = {p: sds_of(s.shape, axes[p], jnp.bfloat16)
            for p, s in glob_specs.items()}
    stacked = {}
    for rel, s in rel_specs.items():
        shape, ax = _add_steps_dim(s.shape, s.axes, steps)
        stacked[rel] = sds_of(shape, ax, jnp.bfloat16)
    params = {"glob": glob, "stack": stacked}

    def like(tree, dtype):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, dtype,
                                           sharding=x.sharding), tree)

    if optimizer == "adamw":
        opt = AdamWState(step=_sds((), jnp.int32, mesh, P()),
                         m=like(params, jnp.float32),
                         v=like(params, jnp.float32))
    else:
        def fac_row(x):
            shape = x.shape[:-1] if len(x.shape) >= 2 else x.shape
            return _sds(shape, jnp.float32, mesh,
                        P(*x.sharding.spec[:len(shape)]))

        def fac_col(x):
            if len(x.shape) >= 2:
                shape = x.shape[:-2] + x.shape[-1:]
                spec = tuple(x.sharding.spec[:len(x.shape) - 2]) + \
                    (x.sharding.spec[len(x.shape) - 1]
                     if len(x.sharding.spec) == len(x.shape) else None,)
                return _sds(shape, jnp.float32, mesh, P(*spec))
            return _sds((1,), jnp.float32, mesh, P())
        opt = AdafactorState(step=_sds((), jnp.int32, mesh, P()),
                             v_row=jax.tree.map(fac_row, params),
                             v_col=jax.tree.map(fac_col, params))

    bspec = batch_spec(mesh, shp.global_batch)
    batch = {
        "tokens": _sds((shp.global_batch, shp.seq_len), jnp.int32, mesh,
                       bspec),
        "labels": _sds((shp.global_batch, shp.seq_len), jnp.int32, mesh,
                       bspec),
    }
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = _sds(
            (shp.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16, mesh, batch_spec(mesh, shp.global_batch, 2))
    return params["glob"], params["stack"], opt, batch


def stacked_serve_param_specs(cfg: ModelConfig, mesh: Mesh,
                              table_rel: Dict[str, UnitStatic]):
    """{glob, stack, overlays, est} SDS trees for the scan serve path."""
    from repro.models.stacked import num_scan_steps, split_layer_paths
    steps = num_scan_steps(cfg)
    glob_specs, rel_specs = split_layer_paths(cfg)
    axes = model_logical_axes(cfg)

    def sds_of(shape, ax, dtype):
        return _sds(shape, dtype, mesh,
                    resolve_spec(shape, ax, mesh, SERVE_RULES))

    glob = {p: sds_of(s.shape, axes[p], jnp.bfloat16)
            for p, s in glob_specs.items()}
    stack, overlays, est = {}, {}, {}
    units = {u.path: u for u in linear_units(cfg)}
    for rel, s in rel_specs.items():
        full = f"layers.{rel}"
        if full in table_rel:
            st = table_rel[full]
            u = units[full]
            kpad = u.k + ((-u.k) % PACK)
            w_axes = axes[full]
            if st.stacked:
                e_dim = cfg.num_experts
                k_ax, n_ax = w_axes[1], w_axes[2]
                pshape, pax = _add_steps_dim(
                    (e_dim, st.h, kpad // PACK, u.n),
                    (EXPERTS, PLANES, k_ax, n_ax), steps)
                sshape, sax = _add_steps_dim((e_dim, u.n),
                                             (EXPERTS, n_ax), steps)
                overlays[full] = QuantizedStacked(
                    sds_of(pshape, pax, jnp.int32),
                    sds_of(sshape, sax, jnp.float32),
                    sds_of(sshape, sax, jnp.float32),
                    PARENT_BITS, u.k)
            else:
                k_ax, n_ax = w_axes[0], w_axes[1]
                pshape, pax = _add_steps_dim((st.h, kpad // PACK, u.n),
                                             (PLANES, k_ax, n_ax), steps)
                sshape, sax = _add_steps_dim((u.n,), (n_ax,), steps)
                overlays[full] = QuantizedLinear(
                    sds_of(pshape, pax, jnp.int32),
                    sds_of(sshape, sax, jnp.float32),
                    sds_of(sshape, sax, jnp.float32),
                    PARENT_BITS, u.k)
            if st.est_kind != "pinned":
                est[full] = _est_entry_specs(st, kpad, k_ax, mesh,
                                             steps=steps)
        else:
            shape, ax = _add_steps_dim(s.shape, s.axes, steps)
            stack[rel] = sds_of(shape, ax, jnp.bfloat16)
    return {"glob": glob, "stack": stack, "overlays": overlays, "est": est}


def stacked_cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                        max_len: int, kv_dtype=jnp.bfloat16):
    from repro.models.stacked import group_size, num_scan_steps
    g, steps = group_size(cfg), num_scan_steps(cfg)
    cache = {}
    hd = cfg.resolved_head_dim
    for r in range(g):
        if cfg.layer_kind(r) == "attn":
            spec = kv_cache_spec(mesh, batch, max_len, cfg.num_kv_heads)
            spec = P(None, *spec)
            shape = (steps, batch, max_len, cfg.num_kv_heads, hd)
            cache[f"kv.{r}.k"] = _sds(shape, kv_dtype, mesh, spec)
            cache[f"kv.{r}.v"] = _sds(shape, kv_dtype, mesh, spec)
            if kv_dtype == jnp.int8:
                sshape = (steps, batch, max_len, cfg.num_kv_heads, 1)
                for leaf in ("k_scale", "v_scale", "k_zero", "v_zero"):
                    cache[f"kv.{r}.{leaf}"] = _sds(sshape, jnp.float32,
                                                   mesh, spec)
        else:
            dd = ssm_dims(cfg)
            bspec = P(None, *batch_spec(mesh, batch, 2))
            cache[f"ssm.{r}.conv"] = _sds(
                (steps, batch, cfg.ssm_conv_width - 1, dd["d_xbc"]),
                jnp.bfloat16, mesh, bspec)
            cache[f"ssm.{r}.state"] = _sds(
                (steps, batch, dd["nheads"], dd["d_state"],
                 dd["d_inner"] // dd["nheads"]), jnp.float32, mesh,
                P(None, *batch_spec(mesh, batch, 3)))
    return cache


def stacked_decode_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                         table_rel: Dict[str, UnitStatic],
                         kv_dtype=jnp.bfloat16):
    shp = SHAPES[shape_name]
    serve_params = stacked_serve_param_specs(cfg, mesh, table_rel)
    cache = stacked_cache_specs(cfg, mesh, shp.global_batch, shp.seq_len,
                                kv_dtype=kv_dtype)
    pos = _sds((), jnp.int32, mesh, P())
    tokens = _sds((shp.global_batch, 1), jnp.int32, mesh,
                  batch_spec(mesh, shp.global_batch))
    target_idx = _sds((), jnp.int32, mesh, P())
    return serve_params, cache, pos, tokens, target_idx


def stacked_prefill_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                          table_rel: Dict[str, UnitStatic]):
    shp = SHAPES[shape_name]
    serve_params = stacked_serve_param_specs(cfg, mesh, table_rel)
    bspec = batch_spec(mesh, shp.global_batch)
    tokens = _sds((shp.global_batch, shp.seq_len), jnp.int32, mesh, bspec)
    extras = {}
    if cfg.frontend == "vision_stub":
        extras["prefix_embeds"] = _sds(
            (shp.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16, mesh, batch_spec(mesh, shp.global_batch, 2))
    return serve_params, tokens, extras


def prefill_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                  table: Dict[str, UnitStatic]):
    shp = SHAPES[shape_name]
    serve_params = serve_param_specs(cfg, mesh, table)
    bspec = batch_spec(mesh, shp.global_batch)
    tokens = _sds((shp.global_batch, shp.seq_len), jnp.int32, mesh, bspec)
    extras = {}
    if cfg.frontend == "vision_stub":
        extras["prefix_embeds"] = _sds(
            (shp.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16, mesh, batch_spec(mesh, shp.global_batch, 2))
    if cfg.frontend == "audio_stub":
        extras["frames"] = _sds(
            (shp.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16, mesh, batch_spec(mesh, shp.global_batch, 2))
    return serve_params, tokens, extras
