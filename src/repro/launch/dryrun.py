import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# 512 placeholder host devices back both the 16x16 single-pod mesh and the
# 2x16x16 multi-pod mesh. Never set this globally — smoke tests and benches
# must see the single real CPU device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds allocation-free ShapeDtypeStruct inputs with production
     shardings (launch/input_specs.py),
  2. ``jit(step).lower(...).compile()`` — sharding mismatches, OOMs and
     unsupported collectives surface here as hard failures,
  3. prints ``compiled.memory_analysis()`` (fits-in-HBM proof) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
  4. parses the partitioned HLO for collective ops and their shapes,
  5. writes a JSON record under experiments/dryrun/ for the roofline
     tooling (benchmarks/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both        # every cell
(no ``from __future__`` import here: the XLA_FLAGS lines must stay first.)
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPE_ORDER, ASSIGNED_ARCHS, get_config, SHAPES
from repro.configs.base import skipped_shapes

OUT_DIR = "experiments/dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str):
    """Per-device bytes moved per collective type, from partitioned HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for coll in _COLLECTIVES:
            if re.search(rf"\b{coll}(-start|-done)?\(", rhs):
                if coll + "-done" in rhs:   # avoid double counting start/done
                    continue
                head = rhs.split("(", 1)[0]
                nbytes = 0
                for dt, dims in _SHAPE_RE.findall(head):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dt]
                out[coll] += nbytes
                counts[coll] += 1
                break
    return out, counts


def build_cell(arch: str, shape_name: str, mesh, kv_dtype="bf16"):
    """Returns (fn, args, donate) for one cell."""
    import jax.numpy as _jnp
    kvd = {"bf16": _jnp.bfloat16, "int8": _jnp.int8}[kv_dtype]
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    use_stacked = not cfg.is_encoder_decoder

    if shp.kind == "train":
        if use_stacked:
            from repro.launch.input_specs import stacked_train_specs
            from repro.launch.steps import (build_train_step,
                                            pick_microbatches,
                                            pick_optimizer)
            optname = pick_optimizer(cfg)
            glob, stack, opt, batch = stacked_train_specs(
                cfg, shape_name, mesh, optimizer=optname)
            step = build_train_step(
                cfg, optimizer=optname,
                num_microbatches=pick_microbatches(
                    cfg, shp.global_batch, shp.seq_len))
            return step, (glob, stack, opt, batch), (0, 1, 2)
        # loop path (whisper enc-dec)
        from repro.launch.input_specs import train_specs
        from repro.launch.steps import pick_microbatches
        from repro.models import loss_fn
        from repro.optim import adamw
        from repro.optim.clip import clip_by_global_norm
        params, opt, batch = train_specs(cfg, shape_name, mesh)

        n_micro = pick_microbatches(cfg, shp.global_batch, shp.seq_len)

        def step(params, opt_state, batch):
            def lf(p, tok, lab, frames):
                return loss_fn(cfg, p, tok, lab, remat=True, q_chunk=512,
                               kv_chunk=1024, frames=frames,
                               prefix_embeds=batch.get("prefix_embeds"))
            mb = batch["tokens"].shape[0] // n_micro

            def micro(carry, idx):
                gsum, lsum = carry
                sl = lambda a: jax.lax.dynamic_slice_in_dim(
                    a, idx * mb, mb, axis=0)
                fr = sl(batch["frames"]) if "frames" in batch else None
                l, g = jax.value_and_grad(
                    lambda p: lf(p, sl(batch["tokens"]),
                                 sl(batch["labels"]), fr))(params)
                return (jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g),
                    lsum + l), None

            g0 = jax.tree.map(lambda q: jnp.zeros(q.shape, jnp.float32),
                              params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (g0, jnp.float32(0.0)), jnp.arange(n_micro))
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            grads, gn = clip_by_global_norm(grads, 1.0)
            new_p, new_o = adamw.update(grads, opt_state, params,
                                        lr=jnp.float32(3e-4))
            return new_p, new_o, {"loss": lsum / n_micro, "grad_norm": gn}

        return step, (params, opt, batch), (0, 1)

    if shp.kind == "prefill":
        if use_stacked:
            from repro.launch.input_specs import (make_unit_table_rel,
                                                  stacked_prefill_specs)
            from repro.launch.steps import build_prefill_step
            table = make_unit_table_rel(cfg)
            serve_params, tokens, extras = stacked_prefill_specs(
                cfg, shape_name, mesh, table)
            step = build_prefill_step(cfg, table, backend="ref")
            return step, (serve_params, tokens, extras), ()
        from repro.launch.input_specs import make_unit_table, prefill_specs
        from repro.serving.step import build_prefill_step as loop_prefill
        table = make_unit_table(cfg)
        serve_params, tokens, extras = prefill_specs(cfg, shape_name, mesh,
                                                     table)
        step = loop_prefill(cfg, table, backend="ref")

        def fn(sp, tok, ex):
            return step(sp, tok, frames=ex.get("frames"),
                        prefix_embeds=ex.get("prefix_embeds"))
        return fn, (serve_params, tokens, extras), ()

    # decode — the *sharded tick*: the target index is a traced input (one
    # compiled step serves every target precision without retracing) and
    # every serve artifact lowers with its SERVE_RULES sharding — the
    # target-stacked tables and JL sketch rows replicated, G matrices and
    # overlays K-sharded over 'pod' alongside the weights they gate
    # (core/adaptation.serve_array_axes names the axes).
    if use_stacked:
        from repro.launch.input_specs import (make_unit_table_rel,
                                              stacked_decode_specs)
        from repro.launch.steps import build_serve_step
        table = make_unit_table_rel(cfg)
        serve_params, cache, pos, tokens, target_idx = stacked_decode_specs(
            cfg, shape_name, mesh, table, kv_dtype=kvd)
        step = build_serve_step(cfg, table, backend="ref")
        return step, (serve_params, cache, pos, tokens, target_idx), (1,)
    from repro.launch.input_specs import decode_specs, make_unit_table
    from repro.serving.step import build_serve_step as loop_serve
    table = make_unit_table(cfg)
    serve_params, state, tokens, target_idx = decode_specs(
        cfg, shape_name, mesh, table)
    step = loop_serve(cfg, table, backend="ref")
    return step, (serve_params, state, tokens, target_idx), (1,)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = OUT_DIR, kv_dtype: str = "bf16") -> dict:
    from repro.launch.mesh import make_production_mesh
    cfg = get_config(arch)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "time": time.time()}
    skips = dict(skipped_shapes(cfg))
    if shape_name in skips:
        record.update(status="SKIP", reason=skips[shape_name])
        return record

    from repro.distributed.context import use_mesh
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    with use_mesh(mesh):
        fn, args, donate = build_cell(arch, shape_name, mesh,
                                      kv_dtype=kv_dtype)
        t0 = time.time()
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # older jax: one dict per device
        ca = ca[0] if ca else {}
    coll, coll_counts = parse_collective_bytes(compiled.as_text())

    shp = SHAPES[shape_name]
    record.update(
        status="OK",
        devices=n_dev,
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        utilization_keys=sorted(k for k in ca if "util" in k.lower())[:8],
        memory=mem,
        collective_bytes=coll,
        collective_counts=coll_counts,
        params_total=cfg.param_count(),
        params_active=cfg.param_count(active_only=True),
        tokens=shp.global_batch * (shp.seq_len if shp.kind != "decode"
                                   else 1),
        kind=shp.kind,
    )
    if shp.kind == "decode":
        from repro.launch.input_specs import N_SERVE_TARGETS
        record["serve_targets"] = N_SERVE_TARGETS
    print(f"[{arch} × {shape_name} × {mesh_kind}] "
          f"lower {record['lower_s']}s compile {record['compile_s']}s")
    print("  memory_analysis:", json.dumps(mem))
    print(f"  cost_analysis: flops/dev={record['flops']:.3e} "
          f"bytes/dev={record['bytes_accessed']:.3e}")
    print("  collectives:", json.dumps(coll))
    return record


def cell_path(out_dir: str, arch: str, shape: str, mesh: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--serve-bits", default=None,
                    help="override 'L,H' candidate pair (e.g. 3,4)")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh process (isolation)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s, m) for a in ASSIGNED_ARCHS for s in SHAPE_ORDER
                 for m in meshes]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, mesh_kind in cells:
        path = cell_path(args.out, arch, shape, mesh_kind)
        if os.path.exists(path) and not args.force:
            with open(path) as fh:
                rec = json.load(fh)
            if rec.get("status") in ("OK", "SKIP"):
                print(f"[cached] {arch} × {shape} × {mesh_kind}: "
                      f"{rec['status']}")
                continue
        if args.subprocess and args.all:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--out", args.out] + (["--force"] if args.force else [])
            r = subprocess.run(cmd, env={**os.environ})
            if r.returncode != 0:
                failures += 1
            continue
        if args.serve_bits:
            from repro.launch import input_specs as _specs
            lo, hi = (int(v) for v in args.serve_bits.split(","))
            _specs.SERVE_L, _specs.SERVE_H = lo, hi
        try:
            rec = run_cell(arch, shape, mesh_kind, args.out,
                           kv_dtype=args.kv_dtype)
        except Exception as e:  # record the failure for triage
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-4000:]}
            failures += 1
            print(f"[FAIL] {arch} × {shape} × {mesh_kind}: {e}")
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
