"""Production mesh construction.

IMPORTANT: never build a mesh at import time — jax locks the device count on
first initialization, and smoke tests / benches must see the real (single)
CPU device while the dry-run sees 512 placeholder devices via XLA_FLAGS set
in ``dryrun.py``'s first two lines.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests, CPU examples).

    Axes are ("data", "model") — the same names SERVE_RULES maps, so the
    sharded serve path (engine + slot scheduler) runs unchanged on a
    local mesh: slots shard over 'data', weight N dims over 'model'.
    """
    n = len(jax.devices())
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_serve_mesh(slots: int, model_parallel=None):
    """Local serve mesh sized for the slot scheduler.

    Defaults the 'model' axis to devices/slots so the 'data' axis equals
    the slot count and the slot axis shards fully (a larger data axis
    would leave slots replicated — resolve_spec drops non-dividing axes).
    """
    mp = model_parallel or max(1, len(jax.devices()) // max(slots, 1))
    return make_local_mesh(model_parallel=mp)


def serve_chips(mesh) -> int:
    """Chips that serve ONE request's decode bandwidth on ``mesh``.

    Under SERVE_RULES weights are replicated over 'data' (each
    data-parallel group decodes its own requests), so per-request HBM
    bandwidth scales only with the 'model' (× 'pod' weight-K) axes —
    never with the total device count.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1) * sizes.get("pod", 1)
