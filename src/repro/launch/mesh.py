"""Production mesh construction.

IMPORTANT: never build a mesh at import time — jax locks the device count on
first initialization, and smoke tests / benches must see the real (single)
CPU device while the dry-run sees 512 placeholder devices via XLA_FLAGS set
in ``dryrun.py``'s first two lines.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests, CPU examples)."""
    n = len(jax.devices())
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    return jax.make_mesh((n // mp, mp), ("data", "model"))
