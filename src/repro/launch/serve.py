"""Serving launcher: continuous-batching requests with QoS precision plans.

Demonstrates the paper's Figure-1 scenario end to end on a small model:
queries arrive with TPOT budgets, the planner picks a target precision per
request at admission, the slot scheduler decodes all admitted requests in
one shared compiled step (per-slot target indices — no retracing), and the
tracker reports per-query effective-bit percentiles.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch bench-lm
  PYTHONPATH=src python -m repro.launch.serve --arch bench-lm --mesh local
(``--mesh local`` shards the serve path over every visible device: slots
over the 'data' axis, weights over 'model' — the mesh-native decode tick.)
"""
from __future__ import annotations

import argparse
import pickle
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_multiscale_model
from repro.models import init_model_params
from repro.serving import (LatencyModel, QoSPlanner, QueryBitTracker,
                           Request, ServingEngine, SlotScheduler)


def serve_demo(arch: str = "bench-lm", params=None, model=None,
               targets=(3.5, 4.0, 4.5), n_queries: int = 6,
               tokens_per_query: int = 12, slots: int = 4,
               seed: int = 0, mesh=None, prefill_chunk: int = 16,
               spec_k=None, paged: bool = False, page_len: int = 4,
               n_pages=None, log=print):
    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    if params is None:
        params = init_model_params(cfg, jax.random.PRNGKey(seed))
    if model is None:
        calib = [(rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32),
                  rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32))
                 for _ in range(2)]
        model = build_multiscale_model(cfg, params, calib, targets=targets,
                                       finetune_epochs=1, baselines=())
    engine = ServingEngine(cfg, params, model, mesh=mesh,
                           prefill_chunk=prefill_chunk,
                           kv_overlay=paged)
    chips = 1
    if mesh is not None:
        from repro.distributed.sharding import slot_vec_spec
        from repro.launch.mesh import serve_chips
        chips = serve_chips(mesh)
        log(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
            f"({mesh.devices.size} devices; slot sharding "
            f"{slot_vec_spec(mesh, (slots,))}; {chips} chip(s)/request)")
    planner = QoSPlanner(
        list(model.adaptations), LatencyModel(
            bytes_per_bit=engine.overlay_bytes() / 5), chips=chips,
        spec_k=spec_k)
    tracker = QueryBitTracker()
    sched_kw = dict(slots=slots, max_prompt=8, max_new=tokens_per_query,
                    tracker=tracker, spec_k=spec_k)
    if paged:
        sched_kw.update(paged=True, page_len=page_len, n_pages=n_pages)
    scheduler = SlotScheduler(engine, planner, **sched_kw)

    requests = [
        Request(rid=qi,
                prompt=rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32),
                max_new=tokens_per_query,
                tpot_budget_s=float(rng.uniform(0.5e-3, 5e-3)))
        for qi in range(n_queries)]
    t0 = time.monotonic()
    completed = scheduler.run(requests)
    wall = time.monotonic() - t0
    for r in completed:
        ttft = f"; TTFT {r.ttft_s*1e3:.0f}ms" if r.ttft_s else ""
        log(f"query {r.rid}: budget {r.tpot_budget_s*1e3:.2f}ms -> "
            f"target {r.target}b; realized eff bits "
            f"{np.mean(r.effective_bits):.2f}{ttft}")
    log(f"{len(completed)} queries on {slots} slots in {wall*1e3:.0f}ms "
        f"({wall / max(1, n_queries * tokens_per_query) * 1e3:.1f}ms/token "
        f"amortized)")
    if spec_k and spec_k > 1 and scheduler.spec_windows:
        w, a = scheduler.spec_windows, scheduler.spec_accepted
        log(f"speculative k={spec_k}: {w:.0f} verify windows, "
            f"{a:.0f} drafts accepted "
            f"(acceptance {a / (w * (spec_k - 1)):.2f}, "
            f"{w / (w + a):.2f} launches/token; planner EMA "
            f"{planner.acceptance_ema:.2f})")
    if paged:
        sp = scheduler.paged_stats()
        log(f"paged pool: {scheduler.n_pages} pages x {scheduler.page_len} "
            f"rows; high watermark {sp['high_watermark_pages']} pages "
            f"({sp['high_watermark_bytes']} B), "
            f"{sp['preemptions']} preemption(s)")
    log("per-query QoS summary: "
        f"{ {k: round(v, 4) for k, v in tracker.summary().items()} }")
    return tracker


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bench-lm")
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--mesh", default="none", choices=["none", "local"],
                    help="'local' serves on a data×model mesh over all "
                         "visible devices (sharded slots + weights)")
    ap.add_argument("--model-parallel", type=int, default=None,
                    help="'model' axis size of the local mesh (default: "
                         "devices/slots, so the slot axis shards fully "
                         "over 'data')")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="token rows per batched prefill launch at "
                         "admission (0 = legacy tick-by-tick prefill)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="speculative window size: draft k-1 tokens at "
                         "the 2-bit floor, verify all k in one batched "
                         "launch (needs --prefill-chunk > 0)")
    ap.add_argument("--paged", action="store_true",
                    help="paged bitplane-KV: one shared plane pool + "
                         "per-slot page tables instead of worst-case "
                         "per-slot buckets (implies the overlay KV "
                         "engine, kv_overlay=True)")
    ap.add_argument("--page-len", type=int, default=4,
                    help="KV rows per page (with --paged)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="pool size; smaller than worst-case demand "
                         "turns on preemption-by-page-reclaim (default: "
                         "worst case — every slot can fill its window)")
    ap.add_argument("--artifacts", default=None,
                    help="pickle produced by examples/train_lm.py")
    args = ap.parse_args()
    params = model = None
    if args.artifacts:
        with open(args.artifacts, "rb") as fh:
            blob = pickle.load(fh)
        params, model = blob["params"], blob["model"]
    mesh = None
    if args.mesh == "local":
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.slots, args.model_parallel)
    serve_demo(args.arch, params=params, model=model,
               n_queries=args.queries, slots=args.slots, mesh=mesh,
               prefill_chunk=args.prefill_chunk, spec_k=args.spec_k,
               paged=args.paged, page_len=args.page_len,
               n_pages=args.n_pages)


if __name__ == "__main__":
    main()
