"""Serving launcher: batched requests with QoS-driven precision planning.

Demonstrates the paper's Figure-1 scenario end to end on a small model:
queries arrive with TPOT budgets, the planner picks a target precision per
query batch, the DP-LLM engine decodes with per-step dynamic layer-wise
precision, and the tracker reports per-query effective-bit percentiles.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch bench-lm
"""
from __future__ import annotations

import argparse
import pickle
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_multiscale_model
from repro.models import init_model_params
from repro.serving import (LatencyModel, QoSPlanner, QueryBitTracker,
                           ServingEngine)


def serve_demo(arch: str = "bench-lm", params=None, model=None,
               targets=(3.5, 4.0, 4.5), n_queries: int = 6,
               tokens_per_query: int = 12, seed: int = 0, log=print):
    cfg = get_config(arch)
    rng = np.random.default_rng(seed)
    if params is None:
        params = init_model_params(cfg, jax.random.PRNGKey(seed))
    if model is None:
        calib = [(rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32),
                  rng.integers(0, cfg.vocab_size, (2, 64)).astype(np.int32))
                 for _ in range(2)]
        model = build_multiscale_model(cfg, params, calib, targets=targets,
                                       finetune_epochs=1, baselines=())
    engine = ServingEngine(cfg, params, model)
    planner = QoSPlanner(
        list(model.adaptations), LatencyModel(
            bytes_per_bit=engine.overlay_bytes() / 5), chips=1)
    tracker = QueryBitTracker()

    budgets = rng.uniform(0.5e-3, 5e-3, size=n_queries)
    for qi, budget in enumerate(budgets):
        util = float(rng.uniform(0.0, 0.5))
        target = planner.plan(budget, util)
        prompt = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
        t0 = time.monotonic()
        out, ebits = engine.generate(prompt, tokens_per_query, target)
        dt = (time.monotonic() - t0) / max(tokens_per_query, 1)
        tracker.record_query(ebits)
        log(f"query {qi}: budget {budget*1e3:.2f}ms util {util:.2f} -> "
            f"target {target}b; realized eff bits "
            f"{np.mean(ebits):.2f}; wall/token {dt*1e3:.1f}ms")
    log("per-query QoS summary: "
        f"{ {k: round(v, 4) for k, v in tracker.summary().items()} }")
    return tracker


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bench-lm")
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--artifacts", default=None,
                    help="pickle produced by examples/train_lm.py")
    args = ap.parse_args()
    params = model = None
    if args.artifacts:
        with open(args.artifacts, "rb") as fh:
            blob = pickle.load(fh)
        params, model = blob["params"], blob["model"]
    serve_demo(args.arch, params=params, model=model,
               n_queries=args.queries)


if __name__ == "__main__":
    main()
