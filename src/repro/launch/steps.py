"""Production step functions (stacked-layer path) for train/prefill/decode.

These are the functions the multi-pod dry-run lowers and the launchers run:
- ``build_train_step``  — remat + scan-over-layers + microbatch gradient
  accumulation + AdamW/Adafactor, one jit-able pure function;
- ``build_serve_step``  — dynamic-precision decode over stacked overlays
  (the *sharded tick*: every serve artifact lowers with its SERVE_RULES
  sharding; ``launch/input_specs.py`` builds the annotated inputs);
- ``build_prefill_step``— max-precision quantized prefill.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.stacked import (decode_step_stacked, forward_stacked,
                                  group_size, loss_fn_stacked)
from repro.optim import adafactor, adamw
from repro.optim.clip import clip_by_global_norm
from repro.core.adaptation import UnitStatic
from repro.core.dynamic_linear import DynamicLinearApplier


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------
def build_train_step(
    cfg: ModelConfig,
    *,
    optimizer: str = "adamw",          # adamw | adafactor
    num_microbatches: int = 1,
    lr: float = 3e-4,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    remat: bool = True,
    carry_sharding=None,
) -> Callable:
    opt = adamw if optimizer == "adamw" else adafactor

    def train_step(glob, stacked, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        extra = {k: batch[k] for k in ("prefix_embeds", "frames")
                 if k in batch}

        def loss_of(g_, s_, tok, lab, ex):
            return loss_fn_stacked(
                cfg, g_, s_, tok, lab, remat=remat, q_chunk=q_chunk,
                kv_chunk=kv_chunk, carry_sharding=carry_sharding, **ex)

        params = {"glob": glob, "stack": stacked}
        if num_microbatches > 1:
            mb = tokens.shape[0] // num_microbatches

            def micro(carry, idx):
                gsum, lsum = carry
                sl = lambda a: jax.lax.dynamic_slice_in_dim(
                    a, idx * mb, mb, axis=0)
                ex = {k: sl(v) for k, v in extra.items()}
                l, g = jax.value_and_grad(
                    lambda p: loss_of(p["glob"], p["stack"], sl(tokens),
                                      sl(labels), ex))(params)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (g0, jnp.float32(0.0)),
                jnp.arange(num_microbatches))
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            loss = lsum / num_microbatches
        else:
            loss, grads = jax.value_and_grad(
                lambda p: loss_of(p["glob"], p["stack"], tokens, labels,
                                  extra))(params)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt.update(
            grads, opt_state, params, lr=jnp.float32(lr),
            weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params["glob"], new_params["stack"], new_opt, metrics

    return train_step


def init_opt_state(glob, stacked, optimizer: str = "adamw"):
    params = {"glob": glob, "stack": stacked}
    return (adamw if optimizer == "adamw" else adafactor).init(params)


def pick_optimizer(cfg: ModelConfig) -> str:
    """Adafactor for ≥50B total params (AdamW f32 moments overflow HBM)."""
    return "adafactor" if cfg.param_count() > 50e9 else "adamw"


def pick_microbatches(cfg: ModelConfig, global_batch: int,
                      seq_len: int = 4096) -> int:
    """Keep live microbatch activations near 128k tokens (and more pieces
    for >100B models where the f32 grad-accum buffer dominates)."""
    n = cfg.param_count()
    target = 65_536 if cfg.num_experts else 131_072   # MoE dispatch
    # one-hots scale with live tokens -> smaller microbatches
    m = max(1, (global_batch * seq_len) // target)
    if n > 100e9:
        m = max(m, 16)
    while global_batch % m:
        m -= 1
    return max(m, 1)


# ---------------------------------------------------------------------------
# Serving (stacked)
# ---------------------------------------------------------------------------
def build_serve_step(cfg: ModelConfig,
                     table_rel: Dict[str, UnitStatic],
                     *, backend: Optional[str] = None,
                     use_async: bool = True,
                     bundle=None) -> Callable:
    """Dynamic-precision decode:
    step(serve_params, cache, pos, tokens, target_idx[, planned_bits]).

    ``planned_bits`` (with a decision ``bundle``) lowers the
    lookup-and-apply half of the engine's decide/apply pipeline — the
    dry-run's default (None) keeps inline decisions.
    """

    def serve_step(serve_params, cache, pos, tokens, target_idx=0,
                   planned_bits=None):
        def lin_factory(view, extra):
            return DynamicLinearApplier(
                table_rel,
                {"raw": view, "overlays": extra["overlays"],
                 "est": extra["est"]},
                target_idx=target_idx, backend=backend,
                use_async=use_async, bundle=bundle,
                planned_bits=planned_bits)

        logits, new_cache, new_pos, eff = decode_step_stacked(
            cfg, serve_params["glob"], serve_params["stack"], cache, pos,
            tokens, lin_factory=lin_factory,
            xs_extra={"overlays": serve_params["overlays"],
                      "est": serve_params["est"]})
        return logits, new_cache, new_pos, eff

    return serve_step


def build_prefill_step(cfg: ModelConfig,
                       table_rel: Dict[str, UnitStatic],
                       *, backend: Optional[str] = None) -> Callable:
    """Max-precision quantized prefill: step(serve_params, tokens, ...)."""

    def lin_factory(view, extra):
        return DynamicLinearApplier(
            table_rel,
            {"raw": view, "overlays": extra["overlays"], "est": {}},
            mode="max", backend=backend)

    def prefill_step(serve_params, tokens, extras):
        logits, _ = forward_stacked(
            cfg, serve_params["glob"], serve_params["stack"], tokens,
            lin_factory=lin_factory,
            xs_extra={"overlays": serve_params["overlays"],
                      "est": serve_params["est"]},
            remat=False,      # forward-only: no backward saves; the carry
                              # SP hint is remat-gated (§Perf iter 7)
            q_chunk=1024, kv_chunk=1024,
            prefix_embeds=extras.get("prefix_embeds"))
        return logits

    return prefill_step
