"""End-to-end training launcher (runnable in-container on CPU).

Wires every substrate together: config → stacked model → sharded data
pipeline → AdamW/Adafactor train step → checkpoint manager → fault-tolerant
supervision (restart-from-checkpoint, straggler watch). The same loop is
what a multi-host launcher would run per host; here the mesh is whatever
devices exist.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch bench-lm --steps 200
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, ShardedBatchIterator
from repro.distributed.fault_tolerance import (SimulatedFailure,
                                               StragglerMitigator,
                                               run_with_restarts)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_train_step, init_opt_state
from repro.models import init_model_params
from repro.models.stacked import stack_params


def train(
    arch: str = "bench-lm",
    steps: int = 200,
    seq_len: int = 256,
    global_batch: int = 8,
    lr: float = 1e-3,
    ckpt_dir: Optional[str] = None,
    save_every: int = 50,
    optimizer: str = "adamw",
    log_every: int = 10,
    fail_at_step: int = -1,          # chaos hook: inject a failure once
    seed: int = 0,
    log=print,
):
    cfg = get_config(arch)
    mesh = make_local_mesh()
    params = init_model_params(cfg, jax.random.PRNGKey(seed),
                               dtype=jnp.float32)
    glob, stacked = stack_params(cfg, params)
    opt_state = init_opt_state(glob, stacked, optimizer)
    step_fn = jax.jit(build_train_step(
        cfg, optimizer=optimizer, lr=lr, q_chunk=256, kv_chunk=256,
        remat=False), donate_argnums=(0, 1, 2))

    data = ShardedBatchIterator(
        DataConfig(seq_len=seq_len, global_batch=global_batch, seed=seed))
    mgr = CheckpointManager(ckpt_dir, save_every=save_every) if ckpt_dir \
        else None
    straggler = StragglerMitigator()
    state = {"glob": glob, "stack": stacked, "opt": opt_state}
    injected = {"done": False}
    losses = []

    def restore():
        if mgr is None:
            return 0
        tree, step = mgr.restore_latest(state)
        state.update(tree)
        data.seek(step)
        return step

    def run(start_step: int) -> int:
        nonlocal losses
        for step in range(start_step, steps):
            if step == fail_at_step and not injected["done"]:
                injected["done"] = True
                raise SimulatedFailure(f"injected failure at {step}")
            tokens, labels = next(data)
            t0 = time.monotonic()
            g, s, o, metrics = step_fn(
                state["glob"], state["stack"], state["opt"],
                {"tokens": jnp.asarray(tokens),
                 "labels": jnp.asarray(labels)})
            state.update(glob=g, stack=s, opt=o)
            loss = float(metrics["loss"])
            losses.append(loss)
            if straggler.observe(step, time.monotonic() - t0):
                log(f"[straggler] step {step} took "
                    f"{time.monotonic() - t0:.2f}s")
            if mgr is not None:
                mgr.maybe_save(step + 1, state, {"loss": loss})
            if step % log_every == 0:
                log(f"step {step:5d}  loss {loss:.4f}  "
                    f"gnorm {float(metrics['grad_norm']):.3f}")
        if mgr is not None:
            mgr.maybe_save(steps, state, {"loss": losses[-1]}, force=True)
            mgr.wait()
        return steps

    run_with_restarts(run, restore_fn=restore, max_restarts=2,
                      on_restart=lambda s, e: log(f"[restart] from step {s}"
                                                  f" after {e}"))
    data.close()
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bench-lm")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.seq_len, args.batch,
                      args.lr, args.ckpt_dir, optimizer=args.optimizer,
                      fail_at_step=args.fail_at_step)
    print(f"final loss: {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
