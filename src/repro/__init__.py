"""repro: DP-LLM (dynamic layer-wise precision) on a multi-pod JAX stack."""
__version__ = "1.0.0"
