"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

For ≥100B-parameter training the AdamW f32 moments alone exceed a v5e pod's
HBM (341B × 8B = 2.7TB); Adafactor's row/col-factored v and optional zero
momentum cut optimizer state to ~O(rows+cols), which is what makes the
nemotron-4-340b / jamba-398b train cells fit (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    v_row: Any      # per-leaf: (..., K) or full v for <2D leaves
    v_col: Any      # per-leaf: (..., N) or (1,) placeholder


def _is_factored(p) -> bool:
    return p.ndim >= 2


def init(params) -> AdafactorState:
    v_row = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-1], jnp.float32) if _is_factored(p)
        else jnp.zeros(p.shape, jnp.float32), params)
    v_col = jax.tree.map(
        lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        if _is_factored(p) else jnp.zeros((1,), jnp.float32), params)
    return AdafactorState(jnp.zeros((), jnp.int32), v_row, v_col)


def update(
    grads, state: AdafactorState, params, *,
    lr: jax.Array,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Tuple[Any, AdafactorState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)          # increasing decay schedule

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _is_factored(p):   # static: shapes known at trace time
            vr_new = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc_new = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            # v_hat = (vr ⊗ vc) / mean(vr)   (Shazeer & Stern Eq. 4)
            vr_mean = jnp.maximum(jnp.mean(vr_new, axis=-1, keepdims=True),
                                  eps)
            v_hat = (vr_new / vr_mean)[..., None] * vc_new[..., None, :]
            u = g * jax.lax.rsqrt(v_hat + eps)
        else:
            vr_new = beta * vr + (1 - beta) * g2
            vc_new = vc
            u = g / (jnp.sqrt(vr_new) + 1e-12)
        # update clipping (RMS(u) <= threshold)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        p_new = (p.astype(jnp.float32) - lr * (
            u + weight_decay * p.astype(jnp.float32))).astype(p.dtype)
        return p_new, vr_new, vc_new

    flat = jax.tree.map(upd, grads, state.v_row, state.v_col, params)
    is_t = lambda x: isinstance(x, tuple)
    params_new = jax.tree.map(lambda x: x[0], flat, is_leaf=is_t)
    vr_new = jax.tree.map(lambda x: x[1], flat, is_leaf=is_t)
    vc_new = jax.tree.map(lambda x: x[2], flat, is_leaf=is_t)
    return params_new, AdafactorState(step, vr_new, vc_new)
