"""AdamW with decoupled weight decay — pure-pytree implementation.

Params may be bf16; first/second moments are kept in f32 (mixed-precision
training convention). State is a flat pytree compatible with the
checkpointer and the sharding rules (moments inherit the param sharding).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + \
            weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    params_new = jax.tree.map(lambda x: x[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda x: x[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda x: x[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return params_new, AdamWState(step, m_new, v_new)
