from repro.optim import adafactor, adamw
from repro.optim.adamw import AdamWState
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.schedules import constant, warmup_cosine

__all__ = ["AdamWState", "adamw", "clip_by_global_norm", "constant",
           "global_norm", "warmup_cosine"]
