"""Phi-3-Medium (14B) — the DP-LLM paper's second evaluation model.

Not part of the assigned pool; included for paper fidelity.
[arXiv:2404.14219; verified-tier: hf]
"""
from repro.configs.base import DENSE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium",
    family=DENSE,
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=32064,
    mlp_kind=SWIGLU,
    rope_theta=10_000.0,
    max_seq_len=131_072,
    source="arXiv:2404.14219 (DP-LLM paper evaluation model)",
)
