"""DBRX-132B — fine-grained MoE: 16 experts, top-4 routing, GQA attention.

[hf:databricks/dbrx-base; verified-tier: unverified]
"""
from repro.configs.base import MOE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family=MOE,
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    mlp_kind=SWIGLU,
    num_experts=16,
    experts_per_token=4,
    moe_every=1,          # MoE FFN on every layer
    moe_offset=0,
    rope_theta=500_000.0,
    max_seq_len=524_288,
    source="hf:databricks/dbrx-base",
)
