"""Whisper-base — encoder-decoder speech transformer; conv frontend is a STUB
(``input_specs()`` provides precomputed frame embeddings).

[arXiv:2212.04356; verified-tier: unverified]
"""
from repro.configs.base import AUDIO, GELU, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family=AUDIO,
    num_layers=6,           # decoder layers
    encoder_layers=6,
    cross_attention=True,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,         # MHA (assigned spec: GQA kv=8 == num_heads)
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    mlp_kind=GELU,
    frontend="audio_stub",
    frontend_tokens=1500,   # mel frames after conv frontend (stub)
    rope_theta=10_000.0,    # upstream uses learned/sinusoidal pos; RoPE here
                            # keeps one attention code path (documented)
    max_seq_len=65_536,
    source="arXiv:2212.04356",
)
