"""Llama-3-8B — dense GQA transformer, 128k vocab. The paper's primary model.

[arXiv:2407.21783; verified-tier: unverified]
"""
from repro.configs.base import DENSE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    mlp_kind=SWIGLU,
    rope_theta=500_000.0,
    max_seq_len=524_288,
    source="arXiv:2407.21783 (DP-LLM paper evaluation model)",
)
