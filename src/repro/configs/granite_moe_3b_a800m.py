"""Granite-MoE-3B-A800M — fine-grained MoE, 40 experts top-8, small d_ff.

[hf:ibm-granite/granite-3.0-3b-a800m-base; verified-tier: hf]
(assigned-spec structured fields: 40 experts, top-8, d_ff=512)
"""
from repro.configs.base import MOE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family=MOE,
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    mlp_kind=SWIGLU,
    num_experts=40,
    experts_per_token=8,
    moe_every=1,
    moe_offset=0,
    rope_theta=10_000.0,
    max_seq_len=524_288,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
)
