"""Granite-8B-Code — llama-arch dense GQA transformer for code.

[arXiv:2405.04324; hf:ibm-granite/granite-8b-code-base; verified-tier: hf]
"""
from repro.configs.base import DENSE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family=DENSE,
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    mlp_kind=SWIGLU,
    rope_theta=10_000_000.0,
    max_seq_len=524_288,
    tie_embeddings=True,
    source="arXiv:2405.04324 (hf:ibm-granite/granite-8b-code-base)",
)
