"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a single frozen ``ModelConfig``.
The model zoo (``repro.models``) builds the concrete network purely from this
description, so adding an architecture is a config file, not code.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"  # encoder-decoder with audio frontend stub

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO)

# MLP kinds
SWIGLU = "swiglu"          # gate/up/down (llama-style)
SQUARED_RELU = "squared_relu"  # up/down with relu(x)^2 (nemotron-style)
GELU = "gelu"              # up/down with gelu (whisper-style)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (public-literature configs; see configs/*.py)."""

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    head_dim: int = 0                 # 0 -> d_model // num_heads
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0

    # mlp
    mlp_kind: str = SWIGLU

    # MoE (0 experts -> dense FFN)
    num_experts: int = 0
    experts_per_token: int = 0

    # SSM (mamba2 / SSD)
    ssm_state: int = 0                # d_state; 0 -> no ssm layers
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_ngroups: int = 1

    # hybrid interleave (jamba-style): attention on layers where
    # ``layer_idx % attn_every == attn_offset``; 0 -> all-attention
    # (or all-ssm when family == SSM).
    attn_every: int = 0
    attn_offset: int = 0
    # MoE on layers where ``layer_idx % moe_every == moe_offset`` (hybrid);
    # 0 with num_experts>0 -> MoE every layer.
    moe_every: int = 0
    moe_offset: int = 1

    # encoder-decoder (whisper-style)
    encoder_layers: int = 0
    cross_attention: bool = False

    # modality frontend stub: "none" | "audio_stub" | "vision_stub"
    frontend: str = "none"
    frontend_tokens: int = 0          # precomputed embeddings fed as input

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 8192
    # vocab padded to a multiple of this for clean TP sharding
    vocab_pad_multiple: int = 256

    source: str = ""                  # provenance citation

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab_size(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def layer_kind(self, layer_idx: int) -> str:
        """'attn' | 'ssm' for the mixer at ``layer_idx`` (decoder stack)."""
        if self.family == SSM:
            return "ssm"
        if self.family == HYBRID and self.attn_every > 0:
            return "attn" if layer_idx % self.attn_every == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.num_experts <= 0:
            return False
        if self.moe_every <= 0:
            return True
        return layer_idx % self.moe_every == self.moe_offset

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def uses_full_attention(self) -> bool:
        """True when *every* decoder mixer is full attention (no SSM)."""
        return self.family not in (SSM, HYBRID)

    # rough parameter counts (used for roofline MODEL_FLOPS and allocator)
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.padded_vocab_size
        hd = self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        attn = d * n_q + 2 * d * n_kv + n_q * d
        if self.mlp_kind == SWIGLU:
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        ssm = 0
        if self.ssm_state:
            di = self.ssm_d_inner
            # in_proj (x, z, B, C, dt) + out_proj + conv
            bc = 2 * self.ssm_ngroups * self.ssm_state
            in_p = d * (2 * di + bc + self.ssm_nheads)
            ssm = in_p + di * d + self.ssm_conv_width * (di + bc)
        total = 0
        for i in range(self.num_layers):
            total += attn if self.layer_kind(i) == "attn" else ssm
            if self.layer_is_moe(i):
                k = self.experts_per_token if active_only else self.num_experts
                total += k * mlp + d * self.num_experts  # + router
            else:
                total += mlp
        # encoder stack (attention + mlp + optional cross-attn in decoder)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp)
            if self.cross_attention:
                total += self.num_layers * attn
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)


# ---------------------------------------------------------------------------
# Input shapes (assigned per-architecture shape set)
# ---------------------------------------------------------------------------
TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, TRAIN),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, PREFILL),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, DECODE),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, DECODE),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Shape cells that apply to ``cfg``.

    ``long_500k`` needs sub-quadratic attention: only SSM/hybrid run it
    (spec + DESIGN.md §4). Encoder-only archs would skip decode shapes, but
    every assigned arch has a decoder.
    """
    out = []
    for s in SHAPE_ORDER:
        if s == "long_500k" and cfg.uses_full_attention:
            continue
        out.append(s)
    return tuple(out)


def skipped_shapes(cfg: ModelConfig) -> Tuple[Tuple[str, str], ...]:
    """(shape, reason) pairs for cells skipped per the assignment spec."""
    out = []
    for s in SHAPE_ORDER:
        if s == "long_500k" and cfg.uses_full_attention:
            out.append((s, "pure full-attention arch: 500k-token decode is "
                           "quadratic-KV; skipped per assignment spec"))
    return tuple(out)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant of ``cfg`` for CPU smoke tests.

    Keeps: family, mixer interleave pattern, MLP kind, GQA ratio, MoE top-k
    structure. Shrinks: widths, depth, vocab, expert count.
    """
    q_per_kv = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    num_kv = 2
    num_heads = num_kv * q_per_kv
    n_layers = min(cfg.num_layers, 4)
    if cfg.family == HYBRID and cfg.attn_every:
        n_layers = max(n_layers, cfg.attn_every)  # keep >=1 attn layer
    small = dict(
        name=f"tiny-{cfg.name}",
        num_layers=n_layers,
        d_model=128,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=128 // num_heads if 128 % num_heads == 0 else 16,
        d_ff=256,
        vocab_size=512,
        vocab_pad_multiple=64,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.num_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=32,
        ssm_ngroups=1,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_tokens=16 if cfg.frontend != "none" else 0,
        max_seq_len=512,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
