"""Nemotron-4-340B — dense GQA transformer with squared-ReLU MLP.

[arXiv:2402.16819 / arXiv:2406.11704; verified-tier: unverified]
"""
from repro.configs.base import DENSE, SQUARED_RELU, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family=DENSE,
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    mlp_kind=SQUARED_RELU,
    rope_theta=10_000.0,
    max_seq_len=524_288,
    source="arXiv:2402.16819",
)
