"""Yi-6B — llama-arch dense transformer with GQA (kv=4).

[arXiv:2403.04652; hf:01-ai/Yi-6B; verified-tier: hf]
"""
from repro.configs.base import DENSE, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_kind=SWIGLU,
    rope_theta=5_000_000.0,
    max_seq_len=524_288,
    source="arXiv:2403.04652 (hf:01-ai/Yi-6B)",
)
