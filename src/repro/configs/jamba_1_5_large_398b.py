"""Jamba-1.5-Large-398B — hybrid Mamba+attention (1:7 interleave) with MoE.

Attention on 1 of every 8 layers; MoE FFN on every other layer (16 experts,
top-2). SSM layers use the Mamba2/SSD formulation for uniformity with the
mamba2 config (documented substitution — Jamba v1 uses Mamba1 cells).

[arXiv:2403.19887; hf:ai21labs/AI21-Jamba-1.5-Large; verified-tier: hf]
"""
from repro.configs.base import HYBRID, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family=HYBRID,
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    mlp_kind=SWIGLU,
    num_experts=16,
    experts_per_token=2,
    attn_every=8,          # 1:7 attention:mamba interleave
    attn_offset=4,
    moe_every=2,           # MoE on every other layer
    moe_offset=1,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_ngroups=8,
    rope_theta=10_000.0,   # jamba attention layers are RoPE-free upstream;
                           # kept for uniform attention code path
    max_seq_len=1_048_576,
    source="arXiv:2403.19887 (hf:ai21labs/AI21-Jamba-1.5-Large)",
)
