"""Reduced same-family configs for CPU smoke tests and in-container benchmarks.

``tiny-<family>`` configs are hand-tuned to be fast on one CPU core while
exercising the same code paths (GQA ratios, MoE routing, SSD scan, hybrid
interleave, enc-dec cross-attn, frontend stubs) as the full assigned configs.
"""
from repro.configs.base import (
    AUDIO, DENSE, GELU, HYBRID, MOE, SQUARED_RELU, SSM, SWIGLU, VLM,
    ModelConfig,
)

TINY_DENSE = ModelConfig(
    name="tiny-dense",
    family=DENSE,
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab_size=512,
    vocab_pad_multiple=64,
    mlp_kind=SWIGLU,
    max_seq_len=1024,
    source="reduced config (this repo)",
)

TINY_SQRELU = ModelConfig(
    name="tiny-sqrelu",
    family=DENSE,
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab_size=512,
    vocab_pad_multiple=64,
    mlp_kind=SQUARED_RELU,
    max_seq_len=1024,
    source="reduced config (this repo)",
)

TINY_MOE = ModelConfig(
    name="tiny-moe",
    family=MOE,
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    vocab_pad_multiple=64,
    mlp_kind=SWIGLU,
    num_experts=8,
    experts_per_token=2,
    moe_every=1,
    moe_offset=0,
    max_seq_len=1024,
    source="reduced config (this repo)",
)

TINY_SSM = ModelConfig(
    name="tiny-ssm",
    family=SSM,
    num_layers=4,
    d_model=128,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    vocab_pad_multiple=64,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,     # d_inner=256 -> 8 ssm heads
    ssm_conv_width=4,
    ssm_ngroups=1,
    tie_embeddings=True,
    max_seq_len=2048,
    source="reduced config (this repo)",
)

TINY_HYBRID = ModelConfig(
    name="tiny-hybrid",
    family=HYBRID,
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    vocab_pad_multiple=64,
    mlp_kind=SWIGLU,
    num_experts=4,
    experts_per_token=2,
    attn_every=4,
    attn_offset=1,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    ssm_conv_width=4,
    ssm_ngroups=2,
    max_seq_len=2048,
    source="reduced config (this repo)",
)

TINY_VLM = ModelConfig(
    name="tiny-vlm",
    family=VLM,
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    vocab_pad_multiple=64,
    mlp_kind=SWIGLU,
    frontend="vision_stub",
    frontend_tokens=16,
    max_seq_len=1024,
    source="reduced config (this repo)",
)

TINY_ENCDEC = ModelConfig(
    name="tiny-encdec",
    family=AUDIO,
    num_layers=2,
    encoder_layers=2,
    cross_attention=True,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    vocab_pad_multiple=64,
    mlp_kind=GELU,
    frontend="audio_stub",
    frontend_tokens=16,
    max_seq_len=1024,
    source="reduced config (this repo)",
)

# ~8M-param LM used by the paper-table benchmarks (trained in-container).
BENCH_LM = ModelConfig(
    name="bench-lm",
    family=DENSE,
    num_layers=6,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    head_dim=32,
    d_ff=768,
    vocab_size=256,        # byte-level
    vocab_pad_multiple=128,
    mlp_kind=SWIGLU,
    max_seq_len=1024,
    source="reduced config (this repo, byte-level LM)",
)

# ~100M-param LM for the end-to-end training example.
TRAIN_100M = ModelConfig(
    name="train-100m",
    family=DENSE,
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=256,
    vocab_pad_multiple=128,
    mlp_kind=SWIGLU,
    max_seq_len=2048,
    source="reduced config (this repo, byte-level LM)",
)

TINY_CONFIGS = {
    c.name: c
    for c in (
        TINY_DENSE, TINY_SQRELU, TINY_MOE, TINY_SSM, TINY_HYBRID,
        TINY_VLM, TINY_ENCDEC, BENCH_LM, TRAIN_100M,
    )
}
