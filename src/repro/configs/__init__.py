"""Architecture config registry.

``get_config("llama3-8b")`` returns the exact assigned config;
``get_config("tiny-moe")`` etc. return reduced smoke-test configs;
``get_config("llama3-8b", reduced=True)`` shrinks any full config in-family.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exports)
    AUDIO, DECODE, DENSE, HYBRID, MOE, PREFILL, SHAPES, SHAPE_ORDER, SSM,
    TRAIN, VLM, ModelConfig, ShapeConfig, applicable_shapes, reduced,
    skipped_shapes,
)
from repro.configs.tiny import TINY_CONFIGS

# assigned pool (10) + the paper's second model (phi3-medium)
_ARCH_MODULES = {
    "yi-6b": "yi_6b",
    "nemotron-4-340b": "nemotron_4_340b",
    "llama3-8b": "llama3_8b",
    "granite-8b": "granite_8b",
    "mamba2-370m": "mamba2_370m",
    "pixtral-12b": "pixtral_12b",
    "dbrx-132b": "dbrx_132b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-base": "whisper_base",
    "phi3-medium": "phi3_medium",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "phi3-medium")


def get_config(name: str, reduced_: bool = False) -> ModelConfig:
    if name in TINY_CONFIGS:
        return TINY_CONFIGS[name]
    if name not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES) + sorted(TINY_CONFIGS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    cfg: ModelConfig = mod.CONFIG
    if reduced_:
        cfg = reduced(cfg)
    return cfg


def list_configs() -> List[str]:
    return sorted(_ARCH_MODULES) + sorted(TINY_CONFIGS)


def all_cells() -> List[tuple]:
    """Every assigned (arch, shape) cell, including spec-mandated skips.

    Returns (arch_name, shape_name, skip_reason_or_None).
    """
    cells = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        skips = dict(skipped_shapes(cfg))
        for shape in SHAPE_ORDER:
            cells.append((arch, shape, skips.get(shape)))
    return cells
