"""Pixtral-12B — pixtral-ViT frontend (STUB) + mistral-nemo style backbone.

The assignment specifies the transformer BACKBONE only; the vision frontend is
a stub whose precomputed patch embeddings arrive via ``input_specs()``.

[hf:mistralai/Pixtral-12B-2409; verified-tier: unverified]
"""
from repro.configs.base import SWIGLU, VLM, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family=VLM,
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,         # d_model / num_heads per assigned spec
    d_ff=14336,
    vocab_size=131072,
    mlp_kind=SWIGLU,
    rope_theta=1_000_000_000.0,
    frontend="vision_stub",
    frontend_tokens=1024,  # precomputed patch embeddings (stub)
    max_seq_len=524_288,
    source="hf:mistralai/Pixtral-12B-2409",
)
