"""Mamba2-370M — attention-free SSM using SSD (state-space duality).

[arXiv:2405.21060; hf:state-spaces/mamba2-370m; verified-tier: unverified]
"""
from repro.configs.base import SSM, SWIGLU, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family=SSM,
    num_layers=48,
    d_model=1024,
    num_heads=0,          # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,               # mamba blocks carry their own expansion; no FFN
    vocab_size=50280,
    mlp_kind=SWIGLU,      # unused (d_ff == 0)
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,      # d_inner=2048 -> 32 ssm heads
    ssm_conv_width=4,
    ssm_ngroups=1,
    max_seq_len=1_048_576,
    tie_embeddings=True,
    source="arXiv:2405.21060 (hf:state-spaces/mamba2-370m)",
)
