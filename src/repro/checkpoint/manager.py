"""Checkpoint lifecycle: retention policy + restart-safe resume."""
from __future__ import annotations

import os
import shutil
from typing import Any, Dict, Optional

from repro.checkpoint.checkpointer import Checkpointer


class CheckpointManager:
    def __init__(self, directory: str, *, save_every: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.ckpt = Checkpointer(directory, async_save=async_save)
        self.save_every = save_every
        self.keep = keep

    def maybe_save(self, step: int, tree,
                   meta: Optional[Dict[str, Any]] = None,
                   force: bool = False) -> bool:
        if not force and (step == 0 or step % self.save_every != 0):
            return False
        self.ckpt.save(step, tree, meta)
        self._gc()
        return True

    def _gc(self) -> None:
        self.ckpt.wait()  # the in-flight save must land before retention
        steps = self.ckpt.available_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.ckpt.directory,
                                       f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, tree_like, shardings=None):
        """Returns (tree, step) or (tree_like, 0) when no checkpoint exists."""
        step = self.ckpt.latest_step()
        if step is None:
            return tree_like, 0
        return self.ckpt.restore(tree_like, step, shardings=shardings)

    def wait(self):
        self.ckpt.wait()
