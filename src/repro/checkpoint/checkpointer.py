"""Fault-tolerant checkpointing.

- template-based restore (orbax-style): any pytree of arrays round-trips;
- atomic commit: write to ``step_XXXX.tmp`` then rename — a crash mid-save
  can never corrupt the latest good checkpoint;
- async save: serialization runs on a background thread so the train loop
  keeps stepping (device→host copy happens before handoff);
- cross-mesh restore: arrays are loaded host-side and re-placed with
  ``jax.device_put`` under *target* shardings, so a checkpoint written on a
  512-chip mesh restores onto 256 chips (elastic scaling) unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def _unflatten(tree_like, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs "
                f"template {like.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, async_save: bool = True):
        self.directory = directory
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save -----------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _write(self, step: int, arrays: Dict[str, np.ndarray],
               meta: Dict[str, Any]) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        try:
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "meta.json"), "w") as fh:
                json.dump(meta, fh)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)   # atomic commit
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e
            raise

    def save(self, step: int, tree, meta: Optional[Dict[str, Any]] = None,
             blocking: Optional[bool] = None) -> None:
        self.wait()  # one in-flight save at a time; re-raise past errors
        # device->host copy happens here, synchronously, so the caller may
        # donate/overwrite device buffers immediately afterwards.
        arrays = _flatten(jax.tree.map(np.asarray, tree))
        meta = dict(meta or {}, step=step, time=time.time())
        if blocking is None:
            blocking = not self.async_save
        if blocking:
            self._write(step, arrays, meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, meta), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- restore ---------------------------------------------------------------
    def available_steps(self):
        steps = []
        if not os.path.isdir(self.directory):
            return steps
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional pytree (same structure) of
        ``jax.sharding.Sharding`` — used for elastic cross-mesh restore.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self._step_dir(step), "arrays.npz")
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        tree = _unflatten(tree_like, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        return tree, step

    def read_meta(self, step: int) -> Dict[str, Any]:
        with open(os.path.join(self._step_dir(step), "meta.json")) as fh:
            return json.load(fh)
