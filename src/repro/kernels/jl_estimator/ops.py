"""jit'd public wrapper for the fused JL estimator."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.jl_estimator.kernel import jl_estimate_pallas
from repro.kernels.jl_estimator.ref import jl_estimate_ref


@functools.partial(jax.jit, static_argnames=("backend",))
def _dispatch(x, g_stack, thresholds, *, backend: str):
    if backend == "ref":
        return jl_estimate_ref(x, g_stack, thresholds)
    return jl_estimate_pallas(
        x, g_stack, thresholds, interpret=(backend == "interpret"))


def jl_estimate(
    x: jax.Array,            # (..., K) shared input for the layer group
    g_stack: jax.Array,      # (L, kproj, K)
    thresholds: jax.Array,   # (L,)
    *,
    backend: Optional[str] = None,
):
    """Returns (err (L,), select_high (L,) int32)."""
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    xm = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    err, sel = _dispatch(
        xm, g_stack.astype(jnp.float32),
        thresholds.reshape((-1, 1)).astype(jnp.float32), backend=backend)
    return err[:, 0], sel[:, 0]
