"""jit'd public wrappers for the fused JL estimator / decision planner.

``jl_estimate`` is the layer-group estimator (paper DESIGN.md §2.2);
``plan_bits`` is the whole-model decision pass the serving engine runs
once per decode tick: every unit's precision resolved in ONE fused
launch (Pallas on TPU, a single vectorized einsum elsewhere), instead of
~5 scattered jnp ops per unit inlined between the decode matmuls.

Batched dispatch (the continuous-batching scheduler): ``plan_bits`` is
wrapped in :func:`jax.custom_batching.custom_vmap`, so when the
scheduler vmaps the decode tick over slots the planner collapses into
the (S, U)-grid slot kernel — per-slot traced targets and active flags,
one launch for the whole batch — rather than being generically lifted.
``TRACE_COUNTS`` counts Python traces of each dispatch entry point (the
no-retrace-across-targets/slots guarantee is testable).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.jl_estimator.kernel import (jl_estimate_pallas,
                                               plan_bits_pallas,
                                               plan_bits_slots_pallas)
from repro.kernels.jl_estimator.ref import jl_estimate_ref, plan_bits_ref
from repro.kernels.tuning import tuned_tile

#: tuning-cache kernel family for the planner's unit-tile knob
TUNE_KERNEL = "jl_plan"

# Python-trace counters per dispatch entry point ("estimate" / "plan" /
# "plan_slots"): increments happen at trace time only, so a counter that
# stays flat across calls with different targets/activations proves the
# compiled kernel is reused.
TRACE_COUNTS: Dict[str, int] = {}


def _count_trace(key: str) -> None:
    TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1


@functools.partial(jax.jit, static_argnames=("backend",))
def _dispatch(x, g_stack, thresholds, *, backend: str):
    _count_trace("estimate")
    if backend == "ref":
        return jl_estimate_ref(x, g_stack, thresholds)
    return jl_estimate_pallas(
        x, g_stack, thresholds, interpret=(backend == "interpret"))


def jl_estimate(
    x: jax.Array,            # (..., K) shared input for the layer group
    g_stack: jax.Array,      # (L, kproj, K)
    thresholds: jax.Array,   # (L,)
    *,
    backend: Optional[str] = None,
):
    """Returns (err (L,), select_high (L,) int32).

    Multi-row contract: ``x`` with leading dims is flattened to (M, K)
    and the M rows form a *batch sharing one decision per layer* — the
    kernel reduces ``max`` over rows (the conservative aggregate: any
    row that needs the high precision upgrades the layer). Callers that
    want per-row estimates must loop rows themselves; nothing here ever
    silently returns row 0's estimate.
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    xm = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    err, sel = _dispatch(
        xm, g_stack.astype(jnp.float32),
        thresholds.reshape((-1, 1)).astype(jnp.float32), backend=backend)
    return err[:, 0], sel[:, 0]


# ---------------------------------------------------------------------------
# Fused decision planner
# ---------------------------------------------------------------------------
def resolve_u_tile(u: int) -> int:
    """The planner's tuned unit-tile for a ``u``-unit model, or 1 (the
    original one-unit-per-grid-step layout) on cache miss or when the
    tuned tile doesn't divide ``u``."""
    tuned = tuned_tile(TUNE_KERNEL, n=u)
    if tuned and tuned > 1 and u % tuned == 0:
        return tuned
    return 1


@functools.partial(jax.jit, static_argnames=("backend", "u_tile"))
def _plan_dispatch(x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t,
                   thr_t, t_act, *, backend: str, u_tile: int = 1):
    _count_trace("plan")
    if backend == "ref":
        return plan_bits_ref(x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t,
                             gamma_t, thr_t, t_act)
    bits = plan_bits_pallas(
        x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t, thr_t, t_act,
        u_tile=u_tile, interpret=(backend == "interpret"))
    return bits[:, 0]


@functools.partial(jax.jit, static_argnames=("backend",))
def _plan_dispatch_slots(x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t,
                         gamma_t, thr_t, t_act, *, backend: str):
    """Slot-batched planner: x (S, U, M, K), tables (S, U), t_act (S, 2)."""
    _count_trace("plan_slots")
    if backend == "ref":
        return jax.vmap(plan_bits_ref,
                        in_axes=(0, None, 0, 0, 0, 0, 0, 0, 0, 0, 0))(
            x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t, thr_t,
            t_act)
    return plan_bits_slots_pallas(
        x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t, thr_t,
        t_act[:, 1], interpret=(backend == "interpret"))


@functools.lru_cache(maxsize=None)
def _plan_batchable(backend: str, u_tile: int = 1):
    """custom_vmap'd core: unmapped calls run the single-tick planner;
    a mapped call (the scheduler's slot axis) collapses into the (S, U)
    slot kernel instead of generic Pallas batching.

    Cached per (backend, u_tile) so repeated traces reuse ONE
    custom_vmap object. ``u_tile`` only shapes the single-tick launch;
    the slot kernel's grid is already (S, U)."""

    @jax.custom_batching.custom_vmap
    def fn(x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t, thr_t,
           t_act):
        return _plan_dispatch(x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t,
                              gamma_t, thr_t, t_act, backend=backend,
                              u_tile=u_tile)

    @fn.def_vmap
    def _vmap_rule(axis_size, in_batched, x, g, g_row_t, l_t, h_t, kind_t,
                   a_t, b_t, gamma_t, thr_t, t_act):
        if in_batched[1]:
            # a batched G stack is not the serving layout: generic mapping
            axes = tuple(0 if b else None for b in in_batched)
            y = jax.vmap(functools.partial(_plan_dispatch, backend=backend,
                                           u_tile=u_tile),
                         in_axes=axes)(x, g, g_row_t, l_t, h_t, kind_t,
                                       a_t, b_t, gamma_t, thr_t, t_act)
            return y, True

        def bc(v, batched):
            return v if batched else \
                jnp.broadcast_to(v[None], (axis_size,) + v.shape)

        args = [x, None, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t,
                thr_t, t_act]
        for i in (0, 2, 3, 4, 5, 6, 7, 8, 9, 10):
            args[i] = bc(args[i], in_batched[i])
        y = _plan_dispatch_slots(args[0], g, *args[2:], backend=backend)
        return y, True

    return fn


def plan_bits(
    x: jax.Array,                       # (U, M, K) per-unit estimator rows
    tables: Dict[str, jax.Array],       # unit-stacked decision arrays
    target_idx,
    active=None,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """All units' precision decisions for one tick — one fused launch.

    ``tables`` follows the :class:`repro.core.adaptation.DecisionBundle`
    layout: l/h/kind/threshold/a/b/gamma/g_row (U, T) and the packed G
    stack g (R, kproj, K). ``target_idx`` is a traced scalar (per-slot
    under the scheduler's vmap — the custom_vmap rule collapses the slot
    axis into the (S, U) kernel); ``active=False`` gates every decision
    to 0 bits (idle slot). Returns bits (U,) int32.
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    elif backend not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}; expected "
                         f"'pallas', 'interpret', or 'ref'")
    t = jnp.asarray(target_idx, jnp.int32)
    act = jnp.int32(1) if active is None else \
        jnp.asarray(active).astype(jnp.int32)
    t_act = jnp.stack([t, act])
    gather = lambda name: tables[name][:, t]
    # tuned unit-tile resolved ONCE here (host code, outside jit); only
    # the kernel backends take the knob — ref math has no DMA to batch
    u_tile = resolve_u_tile(int(x.shape[0])) if backend != "ref" else 1
    return _plan_batchable(backend, u_tile)(
        x.astype(jnp.float32), tables["g"],
        gather("g_row"), gather("l"), gather("h"), gather("kind"),
        gather("a").astype(jnp.float32), gather("b").astype(jnp.float32),
        gather("gamma").astype(jnp.float32),
        gather("threshold").astype(jnp.float32), t_act)
