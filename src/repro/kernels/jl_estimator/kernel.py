"""Fused JL relative-error estimator (Pallas TPU).

Estimates ``err_l = ||G_l x||`` for a *stack* of layers that share the same
input — exactly the async-eligible q/k/v/up group of one transformer block
(DESIGN.md §2.2) — and compares against per-layer thresholds in-kernel,
emitting both the estimate and the high/low precision decision.

For batched decode the per-layer decision must stay uniform across the batch
(one GEMM per layer), so the kernel reduces with ``max`` over batch rows —
the conservative aggregate (any row that needs h-bit upgrades the layer).

Grid = (L,): one step per stacked layer; ``x`` is named by a constant
index_map so it is copied into VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed upstream (TPUCompilerParams -> CompilerParams); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kernel(x_ref, g_ref, t_ref, err_ref, sel_ref):
    g = g_ref[0]                                   # (kproj, K)
    x = x_ref[...]                                 # (M, K)
    y = jax.lax.dot_general(
        g, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (kproj, M)
    sq = jnp.sum(y * y, axis=0)                    # (M,)
    err = jnp.sqrt(jnp.max(sq))                    # batch-max ||G x||
    err_ref[0, 0] = err
    sel_ref[0, 0] = (err > t_ref[0, 0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def jl_estimate_pallas(
    x: jax.Array,          # (M, K) float32 — shared input (prev residual)
    g_stack: jax.Array,    # (L, kproj, K) float32 — calibrated G = A ΔW
    thresholds: jax.Array,  # (L, 1) float32
    *,
    interpret: bool = False,
):
    """Returns (err[L,1] f32, select_high[L,1] i32)."""
    m, k = x.shape
    l, kproj, k2 = g_stack.shape
    assert k == k2, (k, k2)

    def x_map(i):
        del i
        return (0, 0)

    def g_map(i):
        return (i, 0, 0)

    def row_map(i):
        return (i, 0)

    out_shape = (
        jax.ShapeDtypeStruct((l, 1), jnp.float32),
        jax.ShapeDtypeStruct((l, 1), jnp.int32),
    )
    return pl.pallas_call(
        _kernel,
        grid=(l,),
        in_specs=[
            pl.BlockSpec((m, k), x_map),
            pl.BlockSpec((1, kproj, k), g_map),
            pl.BlockSpec((1, 1), row_map),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), row_map),
            pl.BlockSpec((1, 1), row_map),
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(x, g_stack, thresholds)
