"""Fused JL relative-error estimator + decision planner (Pallas TPU).

Two kernels share this file:

* ``jl_estimate_pallas`` — estimates ``err_l = ||G_l x||`` for a *stack*
  of layers that share the same input — the async-eligible q/k/v/up group
  of one transformer block (DESIGN.md §2.2) — and compares against
  per-layer thresholds in-kernel, emitting both the estimate and the
  high/low precision decision.

* ``plan_bits_pallas`` — the whole-model decision pass: ONE launch
  resolves the precision of every unit for a decode tick. Grid = (U,),
  one step per unit; per-unit estimator inputs ride in as a unit-stacked
  ``(U, M, K_max)`` buffer, the target-gathered l/h/kind/a/b/γ/threshold
  scalars ride in as SMEM scalar-prefetch vectors, and the packed JL
  G-matrix stack's ``index_map`` reads the scalar-prefetched ``g_row``
  table: linear/pinned units *re-name the previous unit's G block*
  (:func:`_g_block`), so Pallas elides their HBM→VMEM copy — G traffic
  is ∝ the number of JL units at the active target, not U
  (:func:`g_block_fetches` is the host-side model of this contract).
  The idle gate (``active == 0``) zeroes every decision in-kernel — the
  batched bit-serial matmul treats 0 bits as "fetch no planes".
  ``plan_bits_slots_pallas`` is the continuous-batching variant: grid
  (S, U) with per-slot traced targets and active flags.

For batched decode the per-layer decision must stay uniform across the
batch (one GEMM per layer), so both kernels reduce with ``max`` over
batch rows — the conservative aggregate (any row that needs h-bit
upgrades the layer). The M axis is NEVER a per-row decision axis.

Grid = (L,): one step per stacked layer; ``x`` is named by a constant
index_map so it is copied into VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.jl_estimator.ref import KIND_LINEAR, KIND_PINNED

# renamed upstream (TPUCompilerParams -> CompilerParams); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kernel(x_ref, g_ref, t_ref, err_ref, sel_ref):
    g = g_ref[0]                                   # (kproj, K)
    x = x_ref[...]                                 # (M, K)
    y = jax.lax.dot_general(
        g, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (kproj, M)
    sq = jnp.sum(y * y, axis=0)                    # (M,)
    err = jnp.sqrt(jnp.max(sq))                    # batch-max ||G x||
    err_ref[0, 0] = err
    sel_ref[0, 0] = (err > t_ref[0, 0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def jl_estimate_pallas(
    x: jax.Array,          # (M, K) float32 — shared input (prev residual)
    g_stack: jax.Array,    # (L, kproj, K) float32 — calibrated G = A ΔW
    thresholds: jax.Array,  # (L, 1) float32
    *,
    interpret: bool = False,
):
    """Returns (err[L,1] f32, select_high[L,1] i32)."""
    m, k = x.shape
    l, kproj, k2 = g_stack.shape
    assert k == k2, (k, k2)

    def x_map(i):
        del i
        return (0, 0)

    def g_map(i):
        return (i, 0, 0)

    def row_map(i):
        return (i, 0)

    out_shape = (
        jax.ShapeDtypeStruct((l, 1), jnp.float32),
        jax.ShapeDtypeStruct((l, 1), jnp.int32),
    )
    return pl.pallas_call(
        _kernel,
        grid=(l,),
        in_specs=[
            pl.BlockSpec((m, k), x_map),
            pl.BlockSpec((1, kproj, k), g_map),
            pl.BlockSpec((1, 1), row_map),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), row_map),
            pl.BlockSpec((1, 1), row_map),
        ),
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(x, g_stack, thresholds)


# ---------------------------------------------------------------------------
# Fused decision planner: one launch resolves every unit's precision
# ---------------------------------------------------------------------------
def _plan_unit_bits(x, g, l, h, kind, a, b, gamma, thr, act):
    """One unit's decision from VMEM-resident x (M, K) and g (kproj, K).

    Shared by the single and slot-batched kernel bodies. The linear and
    JL estimates are both evaluated (the JL GEMM is k_proj × K × M —
    noise next to the decode matmuls; skipping it per-kind would cost a
    branch without saving meaningful MXU time) and selected by kind; the
    *DMA* for non-JL units is already elided by the G index_map.
    """
    xn = jnp.sqrt(jnp.sum(x * x, axis=-1))                 # (M,)
    est_lin = jnp.max(a * xn + b)
    y = jax.lax.dot_general(g, x, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (kproj, M)
    est_jl = gamma * jnp.sqrt(jnp.max(jnp.sum(y * y, axis=0)))
    est = jnp.where(kind == KIND_LINEAR, est_lin, est_jl)
    bits = jnp.where(kind == KIND_PINNED, l,
                     jnp.where(est > thr, h, l))
    return jnp.where(act > 0, bits, 0).astype(jnp.int32)


def _plan_kernel(t_act_ref, grow_ref, l_ref, h_ref, kind_ref, a_ref, b_ref,
                 gam_ref, thr_ref, x_ref, g_ref, bits_ref):
    u = pl.program_id(0)
    bits_ref[0, 0] = _plan_unit_bits(
        x_ref[0], g_ref[0], l_ref[u], h_ref[u], kind_ref[u], a_ref[u],
        b_ref[u], gam_ref[u], thr_ref[u], t_act_ref[1])


def _plan_tiled_kernel(t_act_ref, grow_ref, l_ref, h_ref, kind_ref, a_ref,
                       b_ref, gam_ref, thr_ref, x_ref, g_ref, bits_ref):
    # grid (U/u_tile, u_tile): the x block carries u_tile units' rows and
    # is revisited across the inner axis (one DMA per outer step instead
    # of one per unit — the granularity knob the autotuner measures)
    i, j = pl.program_id(0), pl.program_id(1)
    u = i * pl.num_programs(1) + j
    x = x_ref[pl.ds(j, 1)][0]
    bits_ref[0, 0] = _plan_unit_bits(
        x, g_ref[0], l_ref[u], h_ref[u], kind_ref[u], a_ref[u],
        b_ref[u], gam_ref[u], thr_ref[u], t_act_ref[1])


@functools.partial(jax.jit, static_argnames=("u_tile", "interpret"))
def plan_bits_pallas(
    x: jax.Array,          # (U, M, K) float32 — per-unit estimator inputs
    g: jax.Array,          # (R, kproj, K) float32 — packed JL G stack
    g_row_t: jax.Array,    # (U,) int32 — packed G row per unit (elision)
    l_t: jax.Array,        # (U,) int32
    h_t: jax.Array,        # (U,) int32
    kind_t: jax.Array,     # (U,) int32
    a_t: jax.Array,        # (U,) float32
    b_t: jax.Array,        # (U,) float32
    gamma_t: jax.Array,    # (U,) float32
    thr_t: jax.Array,      # (U,) float32
    t_act: jax.Array,      # (2,) int32 [target_idx, active]
    *,
    u_tile: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Returns bits (U, 1) int32 — the whole tick's decisions, one launch.

    ``u_tile > 1`` (autotuned knob, must divide U) regroups the grid as
    ``(U/u_tile, u_tile)`` with the x buffer blocked ``u_tile`` units at
    a time: the block is DMA'd once per outer step and revisited across
    the inner axis, trading VMEM footprint for fewer DMA issues. The
    G-stack walk visits units in the same flat order, so the g_row
    elision contract (:func:`g_block_fetches`) is unchanged, and the
    per-unit math is identical — ``u_tile`` is bit-invariant.
    """
    u, m, k = x.shape
    r, kproj, k2 = g.shape
    assert k == k2, (k, k2)

    if u_tile > 1:
        assert u % u_tile == 0, (u, u_tile)

        def x_map_t(i, j, *refs):
            del j, refs
            return (i, 0, 0)

        def g_map_t(i, j, t_act_ref, grow_ref, *refs):
            del t_act_ref, refs
            return (grow_ref[i * u_tile + j], 0, 0)

        def out_map_t(i, j, *refs):
            del refs
            return (i * u_tile + j, 0)

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=9,
            grid=(u // u_tile, u_tile),
            in_specs=[
                pl.BlockSpec((u_tile, m, k), x_map_t),
                pl.BlockSpec((1, kproj, k), g_map_t),
            ],
            out_specs=pl.BlockSpec((1, 1), out_map_t),
        )
        return pl.pallas_call(
            _plan_tiled_kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((u, 1), jnp.int32),
            compiler_params=_CompilerParams(
                dimension_semantics=("arbitrary", "arbitrary"),
            ),
            interpret=interpret,
        )(t_act, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t, thr_t, x, g)

    def x_map(i, *refs):
        del refs
        return (i, 0, 0)

    def g_map(i, t_act_ref, grow_ref, *refs):
        del t_act_ref, refs
        # non-JL rows repeat the previous unit's row -> copy elided
        return (grow_ref[i], 0, 0)

    def out_map(i, *refs):
        del refs
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=9,
        grid=(u,),
        in_specs=[
            pl.BlockSpec((1, m, k), x_map),
            pl.BlockSpec((1, kproj, k), g_map),
        ],
        out_specs=pl.BlockSpec((1, 1), out_map),
    )
    return pl.pallas_call(
        _plan_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((u, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(t_act, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t, thr_t, x, g)


def _plan_slots_kernel(act_ref, grow_ref, l_ref, h_ref, kind_ref, a_ref,
                       b_ref, gam_ref, thr_ref, x_ref, g_ref, bits_ref):
    s, u = pl.program_id(0), pl.program_id(1)
    bits_ref[0, 0] = _plan_unit_bits(
        x_ref[0, 0], g_ref[0], l_ref[s, u], h_ref[s, u], kind_ref[s, u],
        a_ref[s, u], b_ref[s, u], gam_ref[s, u], thr_ref[s, u], act_ref[s])


@functools.partial(jax.jit, static_argnames=("interpret",))
def plan_bits_slots_pallas(
    x: jax.Array,          # (S, U, M, K) float32
    g: jax.Array,          # (R, kproj, K) float32 — shared packed stack
    g_row_t: jax.Array,    # (S, U) int32 — per-slot target-gathered rows
    l_t: jax.Array,        # (S, U) int32
    h_t: jax.Array,        # (S, U) int32
    kind_t: jax.Array,     # (S, U) int32
    a_t: jax.Array,        # (S, U) float32
    b_t: jax.Array,        # (S, U) float32
    gamma_t: jax.Array,    # (S, U) float32
    thr_t: jax.Array,      # (S, U) float32
    active: jax.Array,     # (S,) int32 — 0 gates the slot's row to 0 bits
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns bits (S, U) int32 — all slots' decisions in one launch."""
    s, u, m, k = x.shape
    r, kproj, k2 = g.shape
    assert k == k2, (k, k2)

    def x_map(si, i, *refs):
        del refs
        return (si, i, 0, 0)

    def g_map(si, i, act_ref, grow_ref, *refs):
        del act_ref, refs
        return (grow_ref[si, i], 0, 0)

    def out_map(si, i, *refs):
        del refs
        return (si, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=9,
        grid=(s, u),
        in_specs=[
            pl.BlockSpec((1, 1, m, k), x_map),
            pl.BlockSpec((1, kproj, k), g_map),
        ],
        out_specs=pl.BlockSpec((1, 1), out_map),
    )
    return pl.pallas_call(
        _plan_slots_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, u), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(active, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t, thr_t, x, g)


def g_block_fetches(g_row_t) -> int:
    """Host-side model of the planner kernel's G-matrix HBM traffic.

    Walks the planner grid in iteration order through the actual G
    ``index_map`` (the scalar-prefetched ``g_row`` table) and counts the
    steps whose named block differs from the previous step's — exactly
    the HBM→VMEM copies Pallas cannot elide. Because non-JL units repeat
    the previous unit's row (core/adaptation's ``g_row`` contract), the
    count equals the number of JL units at the active target, plus one
    fetch when the walk *starts* on the zero dummy row (a leading non-JL
    run) — i.e. G traffic is ∝ #JL units, not U. Accepts a (U,) single
    walk or (S, U) slot-batched rows (flattened in grid order).
    """
    rows = np.asarray(g_row_t, dtype=np.int64).reshape(-1)
    fetches, prev = 0, None
    for r in rows:
        if int(r) != prev:
            fetches += 1
            prev = int(r)
    return fetches
