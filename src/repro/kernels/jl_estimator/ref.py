"""Pure-jnp oracle for the fused JL estimator."""
from __future__ import annotations

import jax.numpy as jnp


def jl_estimate_ref(x, g_stack, thresholds):
    """x (M,K); g_stack (L,kproj,K); thresholds (L,1) ->
    (err (L,1) f32, select_high (L,1) i32)."""
    y = jnp.einsum("lpk,mk->lpm", g_stack.astype(jnp.float32),
                   x.astype(jnp.float32))
    sq = jnp.sum(y * y, axis=1)                    # (L, M)
    err = jnp.sqrt(jnp.max(sq, axis=-1, keepdims=True))  # (L, 1)
    sel = (err > thresholds).astype(jnp.int32)
    return err, sel
