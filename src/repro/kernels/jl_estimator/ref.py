"""Pure-jnp oracles for the fused JL estimator and the decision planner."""
from __future__ import annotations

import jax.numpy as jnp

# estimator-kind codes (mirrors core/adaptation; no import to keep the
# kernel package dependency-free)
KIND_PINNED, KIND_LINEAR, KIND_JL = 0, 1, 2


def jl_estimate_ref(x, g_stack, thresholds):
    """x (M,K); g_stack (L,kproj,K); thresholds (L,1) ->
    (err (L,1) f32, select_high (L,1) i32).

    Multi-row contract: the M rows are a *batch sharing one decision per
    layer* — err is the row-max ||G x_m|| (the conservative aggregate:
    any row that needs the high precision upgrades the layer), never
    row 0 alone.
    """
    y = jnp.einsum("lpk,mk->lpm", g_stack.astype(jnp.float32),
                   x.astype(jnp.float32))
    sq = jnp.sum(y * y, axis=1)                    # (L, M)
    err = jnp.sqrt(jnp.max(sq, axis=-1, keepdims=True))  # (L, 1)
    sel = (err > thresholds).astype(jnp.int32)
    return err, sel


def plan_bits_ref(x, g, g_row_t, l_t, h_t, kind_t, a_t, b_t, gamma_t,
                  thr_t, t_act):
    """Fused decision oracle over the whole unit stack.

    x        (U, M, K)        per-unit estimator inputs (zero-padded K)
    g        (R, kproj, K)    packed JL G matrices (row 0 = zero dummy)
    g_row_t  (U,) i32         per-unit packed G row at the active target
    l/h/kind (U,) i32, a/b/gamma/thr (U,) f32 — target-gathered scalars
    t_act    (2,) i32         [target_idx, active]; active == 0 gates
                              every decision to 0 bits (idle slot)

    Returns bits (U,) int32. Per unit: linear estimate
    ``max_m(a*||x_m|| + b)``, JL estimate ``gamma * max_m ||G x_m||``,
    selected by kind; pinned rows always take l. The row reduction is the
    same conservative batch-max as :func:`jl_estimate_ref`.
    """
    xf = x.astype(jnp.float32)
    xn = jnp.linalg.norm(xf, axis=-1)                       # (U, M)
    est_lin = jnp.max(a_t[:, None] * xn + b_t[:, None], axis=-1)
    g_t = g.astype(jnp.float32)[g_row_t]                    # (U, kproj, K)
    proj = jnp.einsum("umk,upk->ump", xf, g_t)              # (U, M, kproj)
    est_jl = gamma_t * jnp.max(jnp.linalg.norm(proj, axis=-1), axis=-1)
    est = jnp.where(kind_t == KIND_LINEAR, est_lin, est_jl)
    bits = jnp.where(kind_t == KIND_PINNED, l_t,
                     jnp.where(est > thr_t, h_t, l_t))
    return jnp.where(t_act[1] > 0, bits, 0).astype(jnp.int32)
