from repro.kernels.jl_estimator.kernel import jl_estimate_pallas
from repro.kernels.jl_estimator.ops import jl_estimate
from repro.kernels.jl_estimator.ref import jl_estimate_ref

__all__ = ["jl_estimate", "jl_estimate_pallas", "jl_estimate_ref"]
