from repro.kernels.jl_estimator.kernel import (g_block_fetches,
                                               jl_estimate_pallas,
                                               plan_bits_pallas,
                                               plan_bits_slots_pallas)
from repro.kernels.jl_estimator.ops import (TRACE_COUNTS, jl_estimate,
                                            plan_bits)
from repro.kernels.jl_estimator.ref import jl_estimate_ref, plan_bits_ref

__all__ = ["TRACE_COUNTS", "g_block_fetches", "jl_estimate",
           "jl_estimate_pallas", "jl_estimate_ref", "plan_bits",
           "plan_bits_pallas", "plan_bits_ref", "plan_bits_slots_pallas"]
