"""Measured-performance layer: timing harness + versioned tile-tuning cache.

Everything the kernel layer previously *modeled* (tile sizes hardcoded to
``DEFAULT_TILE_N``, DMA-elision savings as closed-form walks) becomes
*measurable* through three small pieces that live here so both the
``kernels/*/ops.py`` dispatch layer and the ``benchmarks/`` drivers can
share them without a circular import:

* a portable wall-timing harness — warmup + ``block_until_ready`` +
  median-of-repeats, with an injectable clock so tuning logic is
  unit-testable without real time passing;
* a ``set_platform``-style platform/XLA-flag configurator (the bayespec
  idiom) so the same harness runs on the CPU oracle, CPU interpret, GPU
  (Triton lowering where available), and TPU Mosaic;
* the versioned ``tuning_cache.json`` contract: winners persisted by the
  autotuner (``benchmarks/autotune.py``) keyed on
  ``(platform, kernel, shape-bucket, bits)`` and consumed by the ops
  dispatch via :func:`tuned_tile` — a cache miss (or version mismatch,
  or corrupt file) falls back to the hardcoded defaults, so behavior is
  bit-identical to the pre-tuning layer unless a cache is installed.

The cache is installed explicitly (:func:`use_cache`) or through the
``REPRO_TUNING_CACHE`` environment variable; nothing is auto-loaded from
the working directory, so a stray file can never silently change kernel
dispatch. Because the ops layer resolves the tile in the *public*
wrapper (outside jit) and threads it through the dispatch caches as a
static key, installing or clearing a cache takes effect on the next
call — no stale-trace invalidation dance.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax

#: bump when the key schema or entry layout changes: a mismatched file
#: loads as EMPTY (every lookup misses -> default tiles), never as garbage
CACHE_VERSION = 1

ENV_CACHE_VAR = "REPRO_TUNING_CACHE"


# ---------------------------------------------------------------------------
# Platform configuration (the bayespec ``set_platform`` idiom)
# ---------------------------------------------------------------------------
#: XLA flags worth setting before the first GPU computation — Triton
#: fusion + async scheduling (see jax.dev gpu_performance_tips)
GPU_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true "
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
)


def set_platform(platform: str = "cpu") -> None:
    """Pin jax to ``'cpu' | 'gpu' | 'tpu'`` — only effective before the
    first computation. On GPU also sets the Triton/async XLA flags so a
    Pallas-Triton lowering (where available) sees the tuned pipeline."""
    if platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(f"unknown platform {platform!r}")
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        prev = os.environ.get("XLA_FLAGS", "")
        for flag in GPU_XLA_FLAGS.split():
            if flag.split("=")[0] not in prev:
                prev = f"{prev} {flag}".strip()
        os.environ["XLA_FLAGS"] = prev


def platform_name() -> str:
    """The cache-key platform of the running process: jax's default
    backend (``cpu`` covers both the jnp oracle and interpret mode —
    tiles tuned on this host apply to either)."""
    return jax.default_backend()


def kernel_backend(explicit: Optional[str] = None) -> str:
    """The measurement backend for this platform: the compiled Pallas
    kernel on TPU, the interpret twin elsewhere (same kernel body, so
    block/tile behavior is exercised even where Mosaic can't lower)."""
    if explicit is not None:
        return explicit
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------
@dataclass
class MeasureResult:
    seconds: float           # median over reps
    out: Any = None          # last rep's output (parity checks ride along)
    samples: tuple = ()


def measure(fn: Callable, *args, warmup: int = 1, reps: int = 5,
            clock: Optional[Callable[[], float]] = None) -> MeasureResult:
    """Median-of-repeats wall timing: ``warmup`` untimed calls (compile +
    cache priming), then ``reps`` timed calls each fenced by
    ``jax.block_until_ready`` so async dispatch can't hide the work.

    ``clock`` is injectable (default ``time.perf_counter``) — a fake
    clock makes winner selection deterministic in unit tests.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    clk = time.perf_counter if clock is None else clock
    out = None
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(reps):
        t0 = clk()
        out = jax.block_until_ready(fn(*args))
        samples.append(clk() - t0)
    ordered = sorted(samples)
    mid = len(ordered) // 2
    if len(ordered) % 2:
        med = ordered[mid]
    else:
        med = 0.5 * (ordered[mid - 1] + ordered[mid])
    return MeasureResult(seconds=med, out=out, samples=tuple(samples))


def median_time_s(fn: Callable, *args, warmup: int = 1, reps: int = 5,
                  clock: Optional[Callable[[], float]] = None) -> float:
    return measure(fn, *args, warmup=warmup, reps=reps,
                   clock=clock).seconds


def time_us(fn: Callable, *args, warmup: int = 1, reps: int = 5,
            clock: Optional[Callable[[], float]] = None) -> float:
    """Benchmark convenience: median microseconds per call."""
    return median_time_s(fn, *args, warmup=warmup, reps=reps,
                         clock=clock) * 1e6


# ---------------------------------------------------------------------------
# Tuning cache
# ---------------------------------------------------------------------------
def shape_bucket(n: int) -> int:
    """Power-of-two bucket for a shape dim: the smallest pow2 >= n.

    Keys bucket so one tuned entry serves a family of nearby shapes; the
    dispatch layer still validates divisibility against the ACTUAL dim
    and falls back to defaults when the tuned tile doesn't divide it.
    """
    n = max(1, int(n))
    b = 1
    while b < n:
        b *= 2
    return b


@dataclass
class TuningCache:
    """Versioned (platform, kernel, shape-bucket, bits) -> tile map."""

    entries: Dict[str, int] = field(default_factory=dict)
    meta: Dict[str, Any] = field(default_factory=dict)

    @staticmethod
    def key(platform: str, kernel: str, n: int, bits: int) -> str:
        return f"{platform}/{kernel}/n{shape_bucket(n)}/b{int(bits)}"

    def lookup(self, platform: str, kernel: str, n: int,
               bits: int) -> Optional[int]:
        v = self.entries.get(self.key(platform, kernel, n, bits))
        return int(v) if v else None

    def put(self, platform: str, kernel: str, n: int, bits: int,
            tile: int) -> str:
        k = self.key(platform, kernel, n, bits)
        self.entries[k] = int(tile)
        return k

    def save(self, path: str) -> None:
        blob = {"version": CACHE_VERSION, "entries": self.entries,
                "meta": self.meta}
        with open(path, "w") as fh:
            json.dump(blob, fh, indent=1, sort_keys=True)
            fh.write("\n")

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        """Load a cache file; ANY problem (missing file, corrupt JSON,
        version mismatch, wrong types) yields an EMPTY cache — the
        fallback-to-defaults contract the dispatch layer relies on."""
        try:
            with open(path) as fh:
                blob = json.load(fh)
            if blob.get("version") != CACHE_VERSION:
                return cls()
            entries = {str(k): int(v)
                       for k, v in blob.get("entries", {}).items()}
            meta = blob.get("meta", {})
            return cls(entries=entries,
                       meta=meta if isinstance(meta, dict) else {})
        except (OSError, ValueError, TypeError, AttributeError):
            return cls()


# process-global active cache: None = nothing installed (pure defaults);
# the env var is consulted lazily so `REPRO_TUNING_CACHE=... python ...`
# just works without an explicit use_cache() call
_ACTIVE: Optional[TuningCache] = None
_ENV_LOADED_FROM: Optional[str] = None


def use_cache(cache: "TuningCache | str | None") -> Optional[TuningCache]:
    """Install (or clear, with ``None``) the process-wide tuning cache.

    Accepts a :class:`TuningCache` or a path. Takes effect on the next
    kernel dispatch — tiles are resolved per call in the public ops
    wrappers and threaded through the jit caches as static keys.

    An explicit call PINS the choice: ``use_cache(None)`` means "pure
    defaults" even when ``REPRO_TUNING_CACHE`` is set (the tuned-vs-
    default comparison in ``benchmarks.measured`` depends on this — its
    default leg must not silently reload the env cache).
    """
    global _ACTIVE, _ENV_LOADED_FROM
    _ENV_LOADED_FROM = "<explicit>"
    if cache is None:
        _ACTIVE = None
    elif isinstance(cache, str):
        _ACTIVE = TuningCache.load(cache)
    else:
        _ACTIVE = cache
    return _ACTIVE


def active_cache() -> Optional[TuningCache]:
    global _ACTIVE, _ENV_LOADED_FROM
    env = os.environ.get(ENV_CACHE_VAR)
    if _ENV_LOADED_FROM == "<explicit>":
        return _ACTIVE
    if env:
        if env != _ENV_LOADED_FROM:      # (re)load on first sight / change
            _ACTIVE = TuningCache.load(env)
            _ENV_LOADED_FROM = env
        return _ACTIVE
    if _ENV_LOADED_FROM is not None:     # env var removed -> defaults
        _ACTIVE, _ENV_LOADED_FROM = None, None
    return _ACTIVE


def tuned_tile(kernel: str, *, n: int, bits: int = 0,
               platform: Optional[str] = None) -> Optional[int]:
    """The tuned tile for ``(platform, kernel, bucket(n), bits)`` or
    ``None`` on cache miss — the dispatch layer's single entry point.

    Callers own divisibility: a tuned tile that doesn't divide the
    actual dim is either ignored (auto paths) or used as the padding
    granularity (explicit kernel backends pad up to it).
    """
    cache = active_cache()
    if cache is None:
        return None
    plat = platform_name() if platform is None else platform
    return cache.lookup(plat, kernel, n, bits)
