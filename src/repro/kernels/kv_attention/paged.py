"""Paged bit-serial KV decode attention: page-table indirection composed
with per-slot plane-DMA elision.

The cache is ONE shared plane pool per layer per stream —
``(n_pages, B, page_len, hkv, dw)`` int32 plane words plus
``(n_pages, page_len, hkv, 1)`` f32 scale/zero rows — and each slot owns
an ordered page table ``(P,)`` int32 mapping logical tile ``i`` (rows
``[i*page_len, (i+1)*page_len)``) to a physical page. Page 0 is the
RESERVED trash/pin page: the allocator never hands it out, idle slots'
tables point at it, and gated writes land there harmlessly.

The Pallas kernel walks grid ``(slots, P, bits)`` with ``tile_t ==
page_len``: the plane index_map reads the page id through scalar
prefetch, clamps the plane coordinate at ``kv_b - 1`` (the bucketed
kernel's plane-DMA elision), and pins DEAD tiles — tiles at or past the
slot's live page count — to the previous tile's LAST fetched block, so
Pallas's revisiting-block elision skips their DMA entirely. Traffic is

    sum_s n_live_tiles(s) * kv_b[s]    (+ one block per idle run)

per K/V stream — proportional to LIVE tokens, not the bucketed
``max_len``; ``kv_plane_fetches_paged`` walks the real index_map and the
property tests pin the closed form.

Bit-identity with the bucketed path holds exactly: the oracle gathers a
slot's pages into the bucketed row layout and reuses
``kv_decode_attention_ref`` verbatim, and the kernel's dead-tile skip is
bitwise-identical to the bucketed kernel's masked fold (a fully-masked
tile contributes ``p = 0.0`` exactly — ``o_acc``/``l_run``/``m_run``
are unchanged either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kv_attention.kernel import (NEG_INF, _CompilerParams,
                                               _unpack_block)
from repro.kernels.kv_attention.ref import kv_decode_attention_ref

#: the reserved trash/pin page — never allocated, absorbs gated writes
TRASH_PAGE = 0


# ---------------------------------------------------------------------------
# Gather oracle (ref backend / dense parity read)
# ---------------------------------------------------------------------------
def gather_paged_kv(pool_planes: jax.Array, pool_scale: jax.Array,
                    pool_zero: jax.Array, page_table: jax.Array):
    """Assemble per-slot bucketed plane stacks from the pool.

    pool_planes: (NP, B, page_len, hkv, dw); pool scale/zero:
    (NP, page_len, hkv, 1); page_table: (S, P) int32. Returns
    (planes (S, B, P*page_len, hkv, dw), scale/zero (S, P*page_len,
    hkv, 1)) — rows beyond a slot's live length come from the trash
    page or zeroed free pages; callers mask them by ``lens`` exactly
    like the bucketed path masks its own tail rows.
    """
    pt = jnp.maximum(jnp.asarray(page_table, jnp.int32), 0)
    s, p = pt.shape
    bits, page_len = pool_planes.shape[1], pool_planes.shape[2]
    g = jnp.moveaxis(pool_planes[pt], 2, 1)          # (S, B, P, L, hkv, dw)
    planes = g.reshape(s, bits, p * page_len, *pool_planes.shape[3:])
    scale = pool_scale[pt].reshape(s, p * page_len, *pool_scale.shape[2:])
    zero = pool_zero[pt].reshape(s, p * page_len, *pool_zero.shape[2:])
    return planes, scale, zero


def kv_decode_attention_paged_ref(q, pool_kp, pool_ks, pool_kz, pool_vp,
                                  pool_vs, pool_vz, page_table, lens, kv_b,
                                  *, bits: int,
                                  logit_softcap: float = 0.0) -> jax.Array:
    """Oracle: gather pages into the bucketed layout, then run the
    bucketed oracle verbatim — paged-vs-bucketed bit-identity by
    construction (tail rows are masked identically in both)."""
    kp, ks, kz = gather_paged_kv(pool_kp, pool_ks, pool_kz, page_table)
    vp, vs, vz = gather_paged_kv(pool_vp, pool_vs, pool_vz, page_table)
    return kv_decode_attention_ref(q, kp, ks, kz, vp, vs, vz, lens, kv_b,
                                   bits=bits, logit_softcap=logit_softcap)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------
def _paged_kernel(kv_b_ref, lens_ref, pt_ref, nl_ref, q_ref, kp_ref, ks_ref,
                  kz_ref, vp_ref, vs_ref, vz_ref, out_ref, s_acc, vv_acc,
                  m_run, l_run, o_acc, *, bits, page_len, m_rows, group,
                  softcap):
    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    n_tiles = pl.num_programs(1)
    b_sel = kv_b_ref[s]
    active = b_sel > 0
    live = active & (i < jnp.maximum(nl_ref[s], 1))

    @pl.when(active & (i == 0) & (j == 0))
    def _init_flash():
        m_run[...] = jnp.full_like(m_run[...], NEG_INF)
        l_run[...] = jnp.zeros_like(l_run[...])
        o_acc[...] = jnp.zeros_like(o_acc[...])

    @pl.when(live & (j == 0))
    def _init_tile():
        s_acc[...] = jnp.zeros_like(s_acc[...])
        vv_acc[...] = jnp.zeros_like(vv_acc[...])

    @pl.when(live & (j < b_sel))
    def _accumulate():
        w = 2.0 ** (bits - 1 - j)
        kb = _unpack_block(kp_ref[0, 0])            # (hkv, page_len, dh_w)
        qv = q_ref[0]                               # (hkv, Mg, dh_w)
        contrib = jax.lax.dot_general(
            qv, kb, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)     # (hkv, Mg, page_len)
        s_acc[...] += contrib * w
        vv_acc[...] += _unpack_block(vp_ref[0, 0]) * w

    @pl.when(live & (j == bits - 1))
    def _fold_tile():
        # identical to the bucketed kernel's fold: dead tiles are
        # SKIPPED here instead of folded masked — bitwise the same
        # (a fully-masked fold leaves m/l/o unchanged exactly)
        mid = (jnp.exp2((bits - b_sel).astype(jnp.float32)) - 1.0) * 0.5
        ks = ks_ref[0].T                            # (hkv, page_len)
        kz = kz_ref[0].T
        vs = vs_ref[0].T
        vz = vz_ref[0].T
        qv = q_ref[0]
        sum_q = jnp.sum(qv, axis=-1)                # (hkv, Mg)
        scores = (s_acc[...] +
                  (mid - kz)[:, None, :] * sum_q[:, :, None]) * \
            ks[:, None, :]                          # (hkv, Mg, page_len)
        if softcap and softcap > 0.0:
            scores = softcap * jnp.tanh(scores / softcap)
        mg = sum_q.shape[-1]
        col = i * page_len + jax.lax.broadcasted_iota(
            jnp.int32, (mg, page_len), 1)
        row_len = jnp.repeat(
            jnp.stack([lens_ref[s * m_rows + mm]
                       for mm in range(m_rows)]), group)
        valid = col < row_len[:, None]              # (Mg, page_len)
        scores = jnp.where(valid[None], scores, NEG_INF)
        vvals = (vv_acc[...] + mid - vz[:, :, None]) * vs[:, :, None]
        m_new = jnp.maximum(m_run[...],
                            jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_run[...] - m_new)
        p = jnp.where(valid[None], jnp.exp(scores - m_new), 0.0)
        l_run[...] = l_run[...] * alpha + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        o_acc[...] = o_acc[...] * alpha + jax.lax.dot_general(
            p, vvals, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_run[...] = m_new

    @pl.when(active & (j == bits - 1) & (i == n_tiles - 1))
    def _write():
        out_ref[0] = o_acc[...] / l_run[...]


@functools.partial(jax.jit, static_argnames=("bits", "m_rows", "softcap",
                                             "interpret"))
def kv_attention_paged_pallas(q, pool_kp, pool_ks, pool_kz, pool_vp,
                              pool_vs, pool_vz, page_table, lens, n_live,
                              kv_b, *, bits: int, m_rows: int,
                              softcap: float = 0.0,
                              interpret: bool = False) -> jax.Array:
    """Paged bit-serial decode attention through a prefetched page table.

    q: (S, hkv, M*g, dh_w) f32 (prescaled + word-padded, the bucketed
    kernel's layout); pool planes: (NP, B, page_len, hkv, dw) int32;
    pool scale/zero: (NP, page_len, hkv) f32; page_table: (S*P,) int32
    flattened per-slot page rows; lens: (S*M,) int32; n_live: (S,) int32
    live tile counts (ceil(max row len / page_len)); kv_b: (S,) int32.
    Grid (slots, P, bits) with tile_t == page_len: live tiles fetch
    ``kv_b[s]`` plane blocks through their page id, dead tiles pin to
    the previous tile's last block (zero DMA), idle slots pin to the
    trash page. Returns (S, hkv, M*g, dh_w) f32; idle slots' blocks are
    unwritten (callers mask on ``kv_b > 0``).
    """
    slots, hkv, mg, dh_w = q.shape
    n_pages, _, page_len, _, dw = pool_kp.shape
    pages_per_slot = page_table.shape[0] // slots
    group = mg // m_rows
    grid = (slots, pages_per_slot, bits)

    def q_map(s, i, j, b_ref, l_ref, pt_ref, nl_ref):
        return (s, 0, 0, 0)

    def plane_map(s, i, j, b_ref, l_ref, pt_ref, nl_ref):
        b = b_ref[s]
        active = b > 0
        nl = jnp.maximum(nl_ref[s], 1)
        live = active & (i < nl)
        ic = jnp.minimum(i, nl - 1)
        page = jnp.where(active, pt_ref[s * pages_per_slot + ic], 0)
        jc = jnp.maximum(jnp.minimum(j, b - 1), 0)
        # dead tiles revisit the last live tile's final plane block —
        # same page, same plane — so the copy is fully elided
        jc = jnp.where(live, jc, jnp.maximum(b - 1, 0))
        return (page, jc, 0, 0, 0)

    def sz_map(s, i, j, b_ref, l_ref, pt_ref, nl_ref):
        b = b_ref[s]
        active = b > 0
        nl = jnp.maximum(nl_ref[s], 1)
        ic = jnp.minimum(i, nl - 1)
        page = jnp.where(active, pt_ref[s * pages_per_slot + ic], 0)
        return (page, 0, 0)

    def out_map(s, i, j, b_ref, l_ref, pt_ref, nl_ref):
        return (s, 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hkv, mg, dh_w), q_map),
            pl.BlockSpec((1, 1, page_len, hkv, dw), plane_map),
            pl.BlockSpec((1, page_len, hkv), sz_map),
            pl.BlockSpec((1, page_len, hkv), sz_map),
            pl.BlockSpec((1, 1, page_len, hkv, dw), plane_map),
            pl.BlockSpec((1, page_len, hkv), sz_map),
            pl.BlockSpec((1, page_len, hkv), sz_map),
        ],
        out_specs=pl.BlockSpec((1, hkv, mg, dh_w), out_map),
        scratch_shapes=[
            pltpu.VMEM((hkv, mg, page_len), jnp.float32),
            pltpu.VMEM((hkv, page_len, dh_w), jnp.float32),
            pltpu.VMEM((hkv, mg, 1), jnp.float32),
            pltpu.VMEM((hkv, mg, 1), jnp.float32),
            pltpu.VMEM((hkv, mg, dh_w), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, bits=bits, page_len=page_len,
                               m_rows=m_rows, group=group, softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, hkv, mg, dh_w),
                                       jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * 3),
        interpret=interpret,
    )(jnp.asarray(kv_b, jnp.int32), jnp.asarray(lens, jnp.int32),
      jnp.asarray(page_table, jnp.int32), jnp.asarray(n_live, jnp.int32),
      q, pool_kp, pool_ks, pool_kz, pool_vp, pool_vs, pool_vz)


# ---------------------------------------------------------------------------
# Modeled traffic (the closed form the property tests pin)
# ---------------------------------------------------------------------------
def kv_plane_fetches_paged(page_table, lens, kv_b, *, page_len: int,
                           bits: int) -> int:
    """Modeled HBM plane-block traffic of ONE pool stream (K or V).

    Walks the REAL paged index_map in grid order — (slot, tile, plane),
    plane innermost — counting consecutive-distinct blocks. Equals

        sum_s n_live_tiles(s) * kv_b[s]  +  idle/pin runs

    where ``n_live_tiles(s) = ceil(max(lens[s]) / page_len)``: dead
    tiles revisit the last live block (zero fetches) and idle slots pin
    one trash block per run — traffic follows LIVE tokens, not the
    bucketed ``max_len``.
    """
    pt = np.asarray(page_table)
    slots = pt.shape[0]
    lens = np.asarray(lens).reshape(slots, -1)
    fetches = 0
    prev = None
    for s, b in enumerate(int(x) for x in kv_b):
        nl = max(1, -(-max(1, int(lens[s].max())) // int(page_len)))
        for i in range(pt.shape[1]):
            for j in range(bits):
                active = b > 0
                live = active and i < nl
                ic = min(i, nl - 1)
                page = int(pt[s, ic]) if active else 0
                jc = max(min(j, b - 1), 0)
                if not live:
                    jc = max(b - 1, 0)
                blk = (page, jc, 0, 0, 0)
                if blk != prev:
                    fetches += 1
                    prev = blk
    return fetches


# ---------------------------------------------------------------------------
# Dispatch (custom_vmap: pool stays UNBATCHED through any vmap nesting)
# ---------------------------------------------------------------------------
def _dispatch_paged_kernel(q, pool_kp, pool_ks, pool_kz, pool_vp, pool_vs,
                           pool_vz, pt, lens, kv_b, *, bits, softcap,
                           backend):
    slots, m, hq, dh = q.shape
    hkv = pool_kp.shape[3]
    dw = pool_kp.shape[-1]
    page_len = pool_kp.shape[2]
    dh_w = dw * 32
    g = hq // hkv

    qp = q.astype(jnp.float32) * (dh ** -0.5)
    qp = qp.reshape(slots, m, hkv, g, dh).transpose(0, 2, 1, 3, 4)
    qp = qp.reshape(slots, hkv, m * g, dh)
    if dh_w > dh:
        qp = jnp.pad(qp, ((0, 0),) * 3 + ((0, dh_w - dh),))

    max_len = jnp.maximum(jnp.max(lens, axis=1), 1)
    n_live = (max_len + page_len - 1) // page_len
    n_live = jnp.minimum(n_live, pt.shape[1])

    out = kv_attention_paged_pallas(
        qp, pool_kp, pool_ks[..., 0], pool_kz[..., 0], pool_vp,
        pool_vs[..., 0], pool_vz[..., 0],
        jnp.maximum(pt, 0).reshape(-1), lens.reshape(-1), n_live, kv_b,
        bits=bits, m_rows=m, softcap=softcap,
        interpret=(backend == "interpret"))
    out = out[..., :dh].reshape(slots, hkv, m, g, dh)
    out = out.transpose(0, 2, 1, 3, 4).reshape(slots, m, hq, dh)
    return jnp.where((kv_b > 0)[:, None, None, None], out, 0.0)


@functools.partial(jax.jit, static_argnames=("bits", "softcap", "backend"))
def _dispatch_paged(q, pool_kp, pool_ks, pool_kz, pool_vp, pool_vs,
                    pool_vz, pt, lens, kv_b, *, bits, softcap, backend):
    from repro.kernels.kv_attention.ops import TRACE_COUNTS
    key = ("paged", bits, backend)
    TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1
    if backend == "ref":
        return kv_decode_attention_paged_ref(
            q.astype(jnp.float32), pool_kp, pool_ks, pool_kz, pool_vp,
            pool_vs, pool_vz, pt, lens, kv_b, bits=bits,
            logit_softcap=softcap)
    return _dispatch_paged_kernel(q, pool_kp, pool_ks, pool_kz, pool_vp,
                                  pool_vs, pool_vz, pt, lens, kv_b,
                                  bits=bits, softcap=softcap,
                                  backend=backend)


@functools.lru_cache(maxsize=None)
def _kv_paged_batchable(bits: int, softcap: float, backend: str):
    """One custom_vmap per (bits, softcap, backend): the mapped slot axes
    FLATTEN onto the kernel's slot axis while the pool operands pass
    through UNBATCHED — the scheduler's vmapped tick shares one physical
    pool across every slot and still dispatches ONE launch."""

    @jax.custom_batching.custom_vmap
    def fn(q, pool_kp, pool_ks, pool_kz, pool_vp, pool_vs, pool_vz, pt,
           lens, kv_b):
        return _dispatch_paged(q, pool_kp, pool_ks, pool_kz, pool_vp,
                               pool_vs, pool_vz, pt, lens, kv_b,
                               bits=bits, softcap=softcap, backend=backend)

    @fn.def_vmap
    def _vmap_rule(axis_size, in_batched, q, pool_kp, pool_ks, pool_kz,
                   pool_vp, pool_vs, pool_vz, pt, lens, kv_b):
        if any(in_batched[1:7]):
            raise ValueError("paged KV pool operands must stay unbatched "
                             "under vmap (one shared physical pool)")
        slot_args = [q, pt, lens, kv_b]
        slot_batched = [in_batched[0], in_batched[7], in_batched[8],
                        in_batched[9]]
        full = []
        for a, batched in zip(slot_args, slot_batched):
            if not batched:
                a = jnp.broadcast_to(a[None], (axis_size,) + a.shape)
            full.append(a)
        inner = full[0].shape[1]
        flat = [a.reshape((axis_size * a.shape[1],) + a.shape[2:])
                for a in full]
        y = fn(flat[0], pool_kp, pool_ks, pool_kz, pool_vp, pool_vs,
               pool_vz, flat[1], flat[2], flat[3])
        return y.reshape((axis_size, inner) + y.shape[1:]), True

    return fn


def kv_decode_attention_paged(q, pool_kp, pool_ks, pool_kz, pool_vp,
                              pool_vs, pool_vz, page_table, lens, kv_b, *,
                              bits: int, logit_softcap: float = 0.0,
                              backend=None) -> jax.Array:
    """Slot-batched plane-read decode attention through a page table.

    q: (S, M, hq, dh); pool planes: (NP, B, page_len, hkv, dw) int32;
    pool scale/zero: (NP, page_len, hkv, 1) f32 — ONE shared pool, no
    slot axis; page_table: (S, P) int32; lens: (S, M) int32; kv_b: (S,)
    int32 read precisions (0 = idle). Returns (S, M, hq, dh) f32.
    Backend contract mirrors ``kv_decode_attention``.
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    if pool_kp.shape[1] != bits:
        raise ValueError(
            f"pool carries {pool_kp.shape[1]} planes, bits={bits}")
    fn = _kv_paged_batchable(bits, float(logit_softcap), backend)
    return fn(q, pool_kp, pool_ks, pool_kz, pool_vp, pool_vs, pool_vz,
              jnp.asarray(page_table, jnp.int32),
              jnp.asarray(lens, jnp.int32), jnp.asarray(kv_b, jnp.int32))


__all__ = [
    "TRASH_PAGE",
    "gather_paged_kv",
    "kv_attention_paged_pallas",
    "kv_decode_attention_paged",
    "kv_decode_attention_paged_ref",
    "kv_plane_fetches_paged",
]
