"""jnp oracle for the bit-serial KV decode-attention kernel.

Two pieces live here because every parity story routes through them:

``kv_attention_dense``
    THE dense decode-attention math (per slot: (M, hq, dh) query rows
    against a (T, hkv, dh) cache with per-row causal lengths). The
    models' dense parity oracle and this module's plane-read reference
    both call it, so "plane read at full precision == dense oracle"
    reduces to "materialization at ``b == B`` is exact" — which it is,
    bit-for-bit: every kept plane is multiplied by an IEEE-exact 1.0
    and the midpoint correction at ``b == B`` is exactly 0.0.

``kv_decode_attention_ref``
    The kernel's oracle twin: per-slot materialize-at-``kv_b`` over the
    plane stacks (masked closed form, planes past ``kv_b`` multiplied
    by 0.0) feeding ``kv_attention_dense``. Costs full-``B`` compute
    regardless of ``kv_b`` — the Pallas kernel instead skips the
    elided planes' DMA entirely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitplane import midpoint, unpack_rows

NEG_INF = -1e30


def _soft_cap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


def materialize_kv_planes(planes: jax.Array, scale: jax.Array,
                          zero: jax.Array, b, *, bits: int,
                          d: int) -> jax.Array:
    """Reconstruct ``b``-bit cache rows from one slot's plane stack.

    planes: (bits, T, hkv, dw) int32; scale/zero: (T, hkv, 1) f32;
    ``b`` may be a python int or a traced scalar. Returns (T, hkv, d)
    f32 — rows whose scale is 0 (never written / rewound) come back
    exactly 0 for every ``b``.
    """
    B = bits
    t, hkv = planes.shape[1], planes.shape[2]
    acc = jnp.zeros((t, hkv, d), jnp.float32)
    for j in range(planes.shape[0]):
        w_j = unpack_rows(planes[j], d) * (2.0 ** (B - 1 - j))
        acc = acc + jnp.where(j < b, 1.0, 0.0) * w_j
    return (acc + midpoint(B, b) - zero) * scale


def kv_attention_dense(q: jax.Array, kf: jax.Array, vf: jax.Array,
                       lens: jax.Array, *,
                       logit_softcap: float = 0.0) -> jax.Array:
    """One slot's decode attention: (M, hq, dh) x (T, hkv, dh) -> (M, hq, dh).

    ``lens`` is (M,) — row m attends to cache positions < lens[m] (the
    multi-row causal-prefix contract of the decode cells). GQA folds
    hq = hkv * g query heads onto the hkv cache heads.
    """
    m, hq, dh = q.shape
    hkv = kf.shape[1]
    g = hq // hkv
    qf = q.reshape(m, hkv, g, dh).astype(jnp.float32) * (dh ** -0.5)
    scores = jnp.einsum("mhgd,shd->mhgs", qf, kf)
    scores = _soft_cap(scores, logit_softcap)
    mask = jnp.arange(kf.shape[0])[None, None, None, :] < \
        lens[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("mhgs,shd->mhgd", probs, vf)
    return out.reshape(m, hq, dh)


def kv_decode_attention_ref(q, k_planes, k_scale, k_zero, v_planes,
                            v_scale, v_zero, lens, kv_b, *, bits: int,
                            logit_softcap: float = 0.0) -> jax.Array:
    """Oracle: per-slot plane-read decode attention.

    q: (S, M, hq, dh); k/v_planes: (S, bits, T, hkv, dw) int32;
    k/v scale/zero: (S, T, hkv, 1) f32; lens: (S, M) int32;
    kv_b: (S,) int32 read precisions (0 = idle slot -> zeros out).
    """
    d = q.shape[-1]

    def one(qs, kp, ks, kz, vp, vs, vz, ls, b):
        kf = materialize_kv_planes(kp, ks, kz, b, bits=bits, d=d)
        vf = materialize_kv_planes(vp, vs, vz, b, bits=bits, d=d)
        return kv_attention_dense(qs, kf, vf, ls,
                                  logit_softcap=logit_softcap)

    out = jax.vmap(one)(q, k_planes, k_scale, k_zero, v_planes, v_scale,
                        v_zero, lens, kv_b)
    return jnp.where((kv_b > 0)[:, None, None, None], out, 0.0)
