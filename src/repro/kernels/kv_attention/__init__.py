"""Bit-serial KV decode-attention kernel (dynamic-precision cache reads).

The KV cache stores full-``B`` bitplane stacks; each tick the planner
assigns a per-layer READ precision and this kernel fetches exactly that
many cache planes per slot — the weight kernels' plane-DMA elision,
applied to the cache. See docs/ARCHITECTURE.md §9.
"""
from repro.kernels.kv_attention.kernel import (kv_attention_slots_pallas,
                                               kv_plane_fetches)
from repro.kernels.kv_attention.ops import (TRACE_COUNTS,
                                            kv_decode_attention)
from repro.kernels.kv_attention.paged import (TRASH_PAGE, gather_paged_kv,
                                              kv_attention_paged_pallas,
                                              kv_decode_attention_paged,
                                              kv_decode_attention_paged_ref,
                                              kv_plane_fetches_paged)
from repro.kernels.kv_attention.ref import (kv_attention_dense,
                                            kv_decode_attention_ref,
                                            materialize_kv_planes)

__all__ = [
    "kv_attention_slots_pallas",
    "kv_plane_fetches",
    "kv_decode_attention",
    "kv_decode_attention_ref",
    "kv_attention_dense",
    "materialize_kv_planes",
    "TRACE_COUNTS",
    "TRASH_PAGE",
    "gather_paged_kv",
    "kv_attention_paged_pallas",
    "kv_decode_attention_paged",
    "kv_decode_attention_paged_ref",
    "kv_plane_fetches_paged",
]
