"""Dispatch for the bit-serial KV decode-attention kernel.

``kv_decode_attention`` is the ONE entry point the model layer calls:
it normalizes layouts (query prescale + head-dim word padding, cache
tile padding), routes to the Pallas kernel / interpret twin / jnp
oracle, and wraps the whole thing in a ``custom_vmap`` whose batching
rule FLATTENS the mapped axis into the slot axis — so the scheduler's
vmapped tick (and any deeper vmap nesting) still dispatches ONE
slot-batched kernel launch with per-slot plane-DMA elision instead of
falling apart into per-slot launches.

Backend contract (mirrors ``kernels.bitserial``):
    "pallas"     compiled TPU kernel
    "interpret"  same kernel, interpreter mode (CI / CPU parity)
    "ref"        jnp oracle (`kv_decode_attention_ref`)
    None         auto: "pallas" on TPU, else "ref"
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.kv_attention.kernel import kv_attention_slots_pallas
from repro.kernels.kv_attention.ref import kv_decode_attention_ref
from repro.kernels.tuning import tuned_tile

TILE_CHOICES = (128, 64, 32, 16, 8)

#: tuning-cache kernel family for the bucketed seq-tile knob
TUNE_KERNEL = "kv_attention"

#: kernel-trace counter keyed by (bits, backend) — tests assert the
#: scheduler's vmapped tick retraces nothing per slot
TRACE_COUNTS: dict = {}


def _count_trace(bits: int, backend: str) -> None:
    key = (bits, backend)
    TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1


def _pick_tile_t(t: int):
    """Largest tile from TILE_CHOICES dividing t, else pad t up to the
    smallest choice's multiple."""
    for c in TILE_CHOICES:
        if t >= c and t % c == 0:
            return c, 0
    c = TILE_CHOICES[-1]
    return c, (-t) % c


def resolve_tile_t(t: int, bits: int):
    """``(tile_t, pad_t)`` for a cache seq-dim of ``t`` rows: the tuning
    cache's winner when one is present (padding up to it when it doesn't
    divide ``t`` — the tuned tile is also the pad granularity), else the
    default ``_pick_tile_t`` walk. Cache miss reproduces today's choice
    exactly."""
    tuned = tuned_tile(TUNE_KERNEL, n=t, bits=bits)
    if tuned:
        return tuned, (-t) % tuned
    return _pick_tile_t(t)


def _dispatch_kernel(q, k_planes, k_scale, k_zero, v_planes, v_scale,
                     v_zero, lens, kv_b, *, bits, softcap, backend,
                     tile_t=0):
    """Layout-normalize and launch the Pallas kernel (compiled or
    interpret). q: (S, M, hq, dh); cache operands in state layout."""
    slots, m, hq, dh = q.shape
    hkv = k_planes.shape[3]
    dw = k_planes.shape[-1]
    dh_w = dw * 32
    g = hq // hkv

    qp = q.astype(jnp.float32) * (dh ** -0.5)
    qp = qp.reshape(slots, m, hkv, g, dh).transpose(0, 2, 1, 3, 4)
    qp = qp.reshape(slots, hkv, m * g, dh)
    if dh_w > dh:
        qp = jnp.pad(qp, ((0, 0),) * 3 + ((0, dh_w - dh),))

    t = k_planes.shape[2]
    if tile_t:
        pad_t = (-t) % tile_t
    else:
        tile_t, pad_t = _pick_tile_t(t)
    if pad_t:
        def pad_seq(x, axis):
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, pad_t)
            return jnp.pad(x, widths)
        k_planes = pad_seq(k_planes, 2)
        v_planes = pad_seq(v_planes, 2)
        k_scale = pad_seq(k_scale, 1)
        k_zero = pad_seq(k_zero, 1)
        v_scale = pad_seq(v_scale, 1)
        v_zero = pad_seq(v_zero, 1)

    out = kv_attention_slots_pallas(
        qp, k_planes, k_scale[..., 0], k_zero[..., 0], v_planes,
        v_scale[..., 0], v_zero[..., 0], lens.reshape(-1), kv_b,
        bits=bits, tile_t=tile_t, m_rows=m, softcap=softcap,
        interpret=(backend == "interpret"))
    out = out[..., :dh].reshape(slots, hkv, m, g, dh)
    out = out.transpose(0, 2, 1, 3, 4).reshape(slots, m, hq, dh)
    return jnp.where((kv_b > 0)[:, None, None, None], out, 0.0)


@functools.partial(jax.jit, static_argnames=("bits", "softcap", "backend",
                                             "tile_t"))
def _dispatch(q, k_planes, k_scale, k_zero, v_planes, v_scale, v_zero,
              lens, kv_b, *, bits, softcap, backend, tile_t=0):
    _count_trace(bits, backend)
    if backend == "ref":
        return kv_decode_attention_ref(
            q.astype(jnp.float32), k_planes, k_scale, k_zero, v_planes,
            v_scale, v_zero, lens, kv_b, bits=bits,
            logit_softcap=softcap)
    return _dispatch_kernel(q, k_planes, k_scale, k_zero, v_planes,
                            v_scale, v_zero, lens, kv_b, bits=bits,
                            softcap=softcap, backend=backend,
                            tile_t=tile_t)


@functools.lru_cache(maxsize=None)
def _kv_batchable(bits: int, softcap: float, backend: str, tile_t: int = 0):
    """One custom_vmap per (bits, softcap, backend, tile_t): any vmap
    depth flattens onto the slot axis and re-enters the SAME object —
    one kernel launch regardless of nesting."""

    @jax.custom_batching.custom_vmap
    def fn(q, k_planes, k_scale, k_zero, v_planes, v_scale, v_zero,
           lens, kv_b):
        return _dispatch(q, k_planes, k_scale, k_zero, v_planes,
                         v_scale, v_zero, lens, kv_b, bits=bits,
                         softcap=softcap, backend=backend, tile_t=tile_t)

    @fn.def_vmap
    def _vmap_rule(axis_size, in_batched, q, k_planes, k_scale, k_zero,
                   v_planes, v_scale, v_zero, lens, kv_b):
        args = [q, k_planes, k_scale, k_zero, v_planes, v_scale,
                v_zero, lens, kv_b]
        full = []
        for a, batched in zip(args, in_batched):
            if not batched:
                a = jnp.broadcast_to(a[None], (axis_size,) + a.shape)
            full.append(a)
        inner = full[0].shape[1]
        flat = [a.reshape((axis_size * a.shape[1],) + a.shape[2:])
                for a in full]
        y = fn(*flat)
        return y.reshape((axis_size, inner) + y.shape[1:]), True

    return fn


def kv_decode_attention(q, k_planes, k_scale, k_zero, v_planes, v_scale,
                        v_zero, lens, kv_b, *, bits: int,
                        logit_softcap: float = 0.0,
                        backend: Optional[str] = None) -> jax.Array:
    """Slot-batched plane-read decode attention.

    q: (S, M, hq, dh); k/v_planes: (S, bits, T, hkv, dw) int32 (the
    ``pack_rows`` cache layout); k/v scale/zero: (S, T, hkv, 1) f32;
    lens: (S, M) int32 per-row causal lengths; kv_b: (S,) int32 read
    precisions — slot s reads exactly kv_b[s] planes per cache tile
    (0 = idle: no fetches, zero output). Returns (S, M, hq, dh) f32.
    """
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    if k_planes.shape[1] != bits:
        raise ValueError(
            f"plane stack carries {k_planes.shape[1]} planes, bits={bits}")
    tile_t = 0
    if backend != "ref":
        # resolved ONCE here (host code), threaded static; shape[-3] is
        # the seq dim whether or not a vmap has eaten the slot axis
        tile_t, _ = resolve_tile_t(int(k_planes.shape[-3]), bits)
    fn = _kv_batchable(bits, float(logit_softcap), backend, tile_t)
    return fn(q, k_planes, k_scale, k_zero, v_planes, v_scale, v_zero,
              jnp.asarray(lens, jnp.int32), jnp.asarray(kv_b, jnp.int32))
