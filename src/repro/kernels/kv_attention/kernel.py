"""Slot-batched bit-serial decode-attention Pallas kernel.

Grid ``(slots, kv_tiles, bits)``, planes innermost. The KV cache is the
bitplane overlay (``core/bitplane.pack_rows`` layout: per-(position,
head) rows packed along the head dim), and the per-slot read precision
``kv_b_sel`` rides scalar prefetch: the plane index_map CLAMPS the
plane coordinate at ``kv_b_sel - 1`` and pins idle slots to block 0, so
Pallas's revisiting-block elision skips the HBM->VMEM copy for every
plane past the selected precision — slot ``s`` fetches exactly
``n_tiles * kv_b_sel[s]`` cache plane blocks (per K/V stream), the same
mechanism ``bitserial_matmul_slots_pallas`` applies to weight planes.

Per tile the kernel accumulates the bit-serial partial sums

    s_acc  += 2^(B-1-j) * (q @ k_plane_j^T)        (scores closed form)
    vv_acc += 2^(B-1-j) * v_plane_j                (values closed form)

and at the last plane applies the midpoint/zero/scale correction and
folds the tile into an online-softmax (flash) running state — one pass
over the cache, no (T,) score buffer.

``kv_plane_fetches`` walks the REAL index_map in grid order and counts
distinct consecutive blocks — the modeled HBM traffic the benchmarks
and property tests pin (`tests/test_traffic_properties.py`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitplane import PACK

NEG_INF = -1e30

_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kv_plane_block(b, s, i, j):
    """Block coords for one slot's (bits, T, hkv, dw) plane stack.

    Busy slots clamp the plane coordinate at ``b - 1`` (planes past the
    selected precision revisit the last fetched block — no new DMA);
    idle slots pin every coordinate to block 0.
    """
    active = b > 0
    jc = jnp.maximum(jnp.minimum(j, b - 1), 0)
    return (jnp.where(active, s, 0), jnp.where(active, jc, 0),
            jnp.where(active, i, 0), 0, 0)


def _unpack_block(words: jax.Array) -> jax.Array:
    """(tile_t, hkv, dw) int32 -> (hkv, tile_t, dw*32) f32 in {0, 1}."""
    t, hkv, dw = words.shape
    w = jnp.transpose(words, (1, 0, 2))
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, PACK), 3)
    bits = (w[..., None] >> shifts) & 1
    return bits.reshape(hkv, t, dw * PACK).astype(jnp.float32)


def _kv_kernel(kv_b_ref, lens_ref, q_ref, kp_ref, ks_ref, kz_ref, vp_ref,
               vs_ref, vz_ref, out_ref, s_acc, vv_acc, m_run, l_run,
               o_acc, *, bits, tile_t, m_rows, group, softcap):
    s = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    n_tiles = pl.num_programs(1)
    b_sel = kv_b_ref[s]
    active = b_sel > 0

    @pl.when(active & (i == 0) & (j == 0))
    def _init_flash():
        m_run[...] = jnp.full_like(m_run[...], NEG_INF)
        l_run[...] = jnp.zeros_like(l_run[...])
        o_acc[...] = jnp.zeros_like(o_acc[...])

    @pl.when(active & (j == 0))
    def _init_tile():
        s_acc[...] = jnp.zeros_like(s_acc[...])
        vv_acc[...] = jnp.zeros_like(vv_acc[...])

    @pl.when(j < b_sel)
    def _accumulate():
        w = 2.0 ** (bits - 1 - j)
        kb = _unpack_block(kp_ref[0, 0])            # (hkv, tile_t, dh_w)
        qv = q_ref[0]                               # (hkv, Mg, dh_w)
        contrib = jax.lax.dot_general(
            qv, kb, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)     # (hkv, Mg, tile_t)
        s_acc[...] += contrib * w
        vv_acc[...] += _unpack_block(vp_ref[0, 0]) * w

    @pl.when(active & (j == bits - 1))
    def _fold_tile():
        mid = (jnp.exp2((bits - b_sel).astype(jnp.float32)) - 1.0) * 0.5
        ks = ks_ref[0].T                            # (hkv, tile_t)
        kz = kz_ref[0].T
        vs = vs_ref[0].T
        vz = vz_ref[0].T
        qv = q_ref[0]
        sum_q = jnp.sum(qv, axis=-1)                # (hkv, Mg)
        scores = (s_acc[...] +
                  (mid - kz)[:, None, :] * sum_q[:, :, None]) * \
            ks[:, None, :]                          # (hkv, Mg, tile_t)
        if softcap and softcap > 0.0:
            scores = softcap * jnp.tanh(scores / softcap)
        mg = sum_q.shape[-1]
        col = i * tile_t + jax.lax.broadcasted_iota(
            jnp.int32, (mg, tile_t), 1)
        row_len = jnp.repeat(
            jnp.stack([lens_ref[s * m_rows + mm]
                       for mm in range(m_rows)]), group)
        valid = col < row_len[:, None]              # (Mg, tile_t)
        scores = jnp.where(valid[None], scores, NEG_INF)
        vvals = (vv_acc[...] + mid - vz[:, :, None]) * vs[:, :, None]
        m_new = jnp.maximum(m_run[...],
                            jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_run[...] - m_new)
        p = jnp.where(valid[None], jnp.exp(scores - m_new), 0.0)
        l_run[...] = l_run[...] * alpha + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        o_acc[...] = o_acc[...] * alpha + jax.lax.dot_general(
            p, vvals, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_run[...] = m_new

        @pl.when(i == n_tiles - 1)
        def _write():
            out_ref[0] = o_acc[...] / l_run[...]


@functools.partial(jax.jit, static_argnames=("bits", "tile_t", "m_rows",
                                             "softcap", "interpret"))
def kv_attention_slots_pallas(q, k_planes, k_scale, k_zero, v_planes,
                              v_scale, v_zero, lens, kv_b, *, bits: int,
                              tile_t: int, m_rows: int,
                              softcap: float = 0.0,
                              interpret: bool = False) -> jax.Array:
    """Slot-batched bit-serial decode attention over plane-stacked KV.

    q: (S, hkv, M*g, dh_w) f32, PRESCALED by dh^-0.5 and zero-padded to
    the word width dh_w = dw*32 (row r = m*g + gg: query head gg of
    group h for token row m). k/v_planes: (S, bits, T, hkv, dw) int32;
    k/v scale/zero: (S, T, hkv) f32; lens: (S*M,) int32 flattened
    per-row causal lengths; kv_b: (S,) int32 read precisions. Returns
    (S, hkv, M*g, dh_w) f32 — idle slots' blocks are unwritten (callers
    mask on ``kv_b > 0``).
    """
    slots, hkv, mg, dh_w = q.shape
    t = k_planes.shape[2]
    dw = k_planes.shape[-1]
    group = mg // m_rows
    grid = (slots, t // tile_t, bits)

    def q_map(s, i, j, b_ref, l_ref):
        return (s, 0, 0, 0)

    def plane_map(s, i, j, b_ref, l_ref):
        return _kv_plane_block(b_ref[s], s, i, j)

    def sz_map(s, i, j, b_ref, l_ref):
        active = b_ref[s] > 0
        return (jnp.where(active, s, 0), jnp.where(active, i, 0), 0)

    def out_map(s, i, j, b_ref, l_ref):
        return (s, 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hkv, mg, dh_w), q_map),
            pl.BlockSpec((1, 1, tile_t, hkv, dw), plane_map),
            pl.BlockSpec((1, tile_t, hkv), sz_map),
            pl.BlockSpec((1, tile_t, hkv), sz_map),
            pl.BlockSpec((1, 1, tile_t, hkv, dw), plane_map),
            pl.BlockSpec((1, tile_t, hkv), sz_map),
            pl.BlockSpec((1, tile_t, hkv), sz_map),
        ],
        out_specs=pl.BlockSpec((1, hkv, mg, dh_w), out_map),
        scratch_shapes=[
            pltpu.VMEM((hkv, mg, tile_t), jnp.float32),
            pltpu.VMEM((hkv, tile_t, dh_w), jnp.float32),
            pltpu.VMEM((hkv, mg, 1), jnp.float32),
            pltpu.VMEM((hkv, mg, 1), jnp.float32),
            pltpu.VMEM((hkv, mg, dh_w), jnp.float32),
        ],
    )
    kernel = functools.partial(_kv_kernel, bits=bits, tile_t=tile_t,
                               m_rows=m_rows, group=group,
                               softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, hkv, mg, dh_w),
                                       jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",) * 3),
        interpret=interpret,
    )(jnp.asarray(kv_b, jnp.int32), jnp.asarray(lens, jnp.int32), q,
      k_planes, k_scale, k_zero, v_planes, v_scale, v_zero)


def kv_plane_fetches(kv_b, n_tiles: int, bits: int) -> int:
    """Modeled HBM plane-block traffic of ONE cache stream (K or V).

    Walks the real plane index_map in grid order — (slot, tile, plane),
    plane innermost — counting consecutive-distinct blocks, exactly the
    copies Pallas's revisiting-block elision leaves live. For
    ``n_tiles >= 2`` this equals the closed form

        n_tiles * sum(kv_b) + n_idle_runs

    (idle runs pin ONE block; a busy slot's first block carries its own
    slot coordinate, so — unlike the weight kernels' shared-operand
    pins — it never collides with the idle pin).
    """
    fetches = 0
    prev = None
    for s, b in enumerate(int(x) for x in kv_b):
        for i in range(n_tiles):
            for j in range(bits):
                blk = tuple(int(v) for v in _kv_plane_block(b, s, i, j))
                if blk != prev:
                    fetches += 1
                    prev = blk
    return fetches
