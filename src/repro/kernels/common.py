"""Shared kernel-dispatch utilities (used by the per-kernel ``ops.py``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_overlay_n(planes: jax.Array, scale: jax.Array, zero: jax.Array,
                  tile: int):
    """Pad a bit-plane overlay's N dim up to a multiple of ``tile``.

    The pad columns carry zero planes AND zero scale, so every padded
    output column is exactly 0 and callers slice them off — the contract
    that lets an explicitly requested kernel backend run on untileable N
    instead of silently falling back to the oracle.

    planes: (bits, K/32, N) int32; scale/zero: (1, N) f32. No-op when N
    already tiles.
    """
    n = planes.shape[-1]
    pad = (-n) % tile
    if pad == 0:
        return planes, scale, zero
    planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    scale = jnp.pad(scale, ((0, 0), (0, pad)))
    zero = jnp.pad(zero, ((0, 0), (0, pad)))
    return planes, scale, zero


def count_jaxpr_primitives(jaxpr, name: str | None = None) -> int:
    """Count primitive eqns in a jaxpr, recursing into sub-jaxprs (pjit
    bodies, scans, custom calls).

    ``name=None`` counts every eqn; otherwise only eqns of that
    primitive (e.g. ``"dot_general"``). This is how the repo's op-count
    invariants are asserted — e.g. the fused decision planner issuing
    exactly ONE estimator GEMM regardless of unit count
    (tests/test_kernels.py, benchmarks/estimator_overhead.py).
    """
    total = 0
    for eqn in jaxpr.eqns:
        if name is None or eqn.primitive.name == name:
            total += 1
        for v in eqn.params.values():
            # sub-jaxprs hide both as direct params (pjit/scan) and
            # inside tuples/lists (lax.cond/switch 'branches')
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for item in vs:
                inner = getattr(item, "jaxpr", None)
                if inner is not None:
                    total += count_jaxpr_primitives(inner, name)
    return total
