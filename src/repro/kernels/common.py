"""Shared kernel-dispatch utilities (used by the per-kernel ``ops.py``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_overlay_n(planes: jax.Array, scale: jax.Array, zero: jax.Array,
                  tile: int):
    """Pad a bit-plane overlay's N dim up to a multiple of ``tile``.

    The pad columns carry zero planes AND zero scale, so every padded
    output column is exactly 0 and callers slice them off — the contract
    that lets an explicitly requested kernel backend run on untileable N
    instead of silently falling back to the oracle.

    planes: (..., K/32, N) int32 — (bits, K/32, N) for plain overlays,
    (E, bits, K/32, N) for stacked MoE overlays; scale/zero: (..., N)
    f32. Only the trailing N axis pads. No-op when N already tiles.
    """
    n = planes.shape[-1]
    pad = (-n) % tile
    if pad == 0:
        return planes, scale, zero

    def pad_last(a):
        return jnp.pad(a, ((0, 0),) * (a.ndim - 1) + ((0, pad),))

    return pad_last(planes), pad_last(scale), pad_last(zero)


def count_jaxpr_primitives(jaxpr, name: str | None = None) -> int:
    """Count primitive eqns in a jaxpr, recursing into sub-jaxprs (pjit
    bodies, scans, custom calls).

    ``name=None`` counts every eqn; otherwise only eqns of that
    primitive (e.g. ``"dot_general"``). This is how the repo's op-count
    invariants are asserted — e.g. the fused decision planner issuing
    exactly ONE estimator GEMM regardless of unit count
    (tests/test_kernels.py, benchmarks/estimator_overhead.py).
    """
    total = 0
    for eqn in jaxpr.eqns:
        if name is None or eqn.primitive.name == name:
            total += 1
        for v in eqn.params.values():
            # sub-jaxprs hide both as direct params (pjit/scan) and
            # inside tuples/lists (lax.cond/switch 'branches')
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for item in vs:
                inner = getattr(item, "jaxpr", None)
                if inner is not None:
                    total += count_jaxpr_primitives(inner, name)
    return total


def max_eqn_aval_elems(jaxpr) -> int:
    """Largest intermediate array (in elements) a jaxpr ever binds,
    recursing into sub-jaxprs like :func:`count_jaxpr_primitives`.

    This is the shape-capture half of the repo's memory invariants: the
    grouped MoE path asserts NO equation output on the prefill/decode
    trace reaches the dense ``(M, E, K, N)`` per-row weight stack —
    peak MoE stage bytes stay independent of the row count M
    (tests/test_moe_grouped.py), while the legacy dense path demonstrably
    does bind one (proving the capture sees through the trace).
    """
    peak = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape is not None:
                size = 1
                for d in shape:
                    size *= int(d)
                peak = max(peak, size)
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for item in vs:
                inner = getattr(item, "jaxpr", None)
                if inner is not None:
                    peak = max(peak, max_eqn_aval_elems(inner))
    return peak
