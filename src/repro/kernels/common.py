"""Shared kernel-dispatch utilities (used by the per-kernel ``ops.py``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_overlay_n(planes: jax.Array, scale: jax.Array, zero: jax.Array,
                  tile: int):
    """Pad a bit-plane overlay's N dim up to a multiple of ``tile``.

    The pad columns carry zero planes AND zero scale, so every padded
    output column is exactly 0 and callers slice them off — the contract
    that lets an explicitly requested kernel backend run on untileable N
    instead of silently falling back to the oracle.

    planes: (bits, K/32, N) int32; scale/zero: (1, N) f32. No-op when N
    already tiles.
    """
    n = planes.shape[-1]
    pad = (-n) % tile
    if pad == 0:
        return planes, scale, zero
    planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    scale = jnp.pad(scale, ((0, 0), (0, pad)))
    zero = jnp.pad(zero, ((0, 0), (0, pad)))
    return planes, scale, zero
