"""Pure-jnp oracle for the static-precision dequant matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitplane import unpack_plane


def dequant_matmul_ref(x, planes, scale, zero, *, bits_active: int,
                       bits_parent: int):
    """x (M,K) @ W_b (K,N) for static b = bits_active."""
    k = x.shape[-1]
    w = jnp.zeros((k, planes.shape[-1]), jnp.float32)
    for j in range(bits_active):
        w = w + unpack_plane(planes[j]) * (2.0 ** (bits_parent - 1 - j))
    mid = (2.0 ** (bits_parent - bits_active) - 1.0) * 0.5
    w = (w + mid - zero) * scale
    return jax.lax.dot(x.astype(jnp.float32), w,
                       preferred_element_type=jnp.float32)
