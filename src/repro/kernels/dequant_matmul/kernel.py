"""Static-precision dequant-fused matmul — the prefill kernel (Pallas TPU).

Prefill uses the highest available precision per layer (paper §6.1: "for the
prefill phase ... we use the highest available precision"), so the bit count
is *static* here. The kernel is a standard 3-level tiled matmul
(grid = (M_tiles, N_tiles, K_tiles)) that dequantizes ``b`` bit-planes
tile-by-tile in VMEM and feeds the MXU — the b-bit weights never exist in HBM.

The midpoint/zero correction is distributive over K tiles:
``y += (mid - zero) * sum_k(x_tile)`` accumulates to the same closed form as
core/bitplane.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed upstream (TPUCompilerParams -> CompilerParams); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

PACK = 32


def _unpack(words: jax.Array) -> jax.Array:
    """(KW, TN) int32 -> (KW*32, TN) f32 in {0,1}."""
    kw, tn = words.shape
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    bits = (words[:, None, :] >> shifts[None, :, None]) & 1
    return bits.reshape(kw * PACK, tn).astype(jnp.float32)


def _kernel(x_ref, plane_ref, scale_ref, zero_ref, out_ref, acc_ref,
            *, bits_active: int, bits_parent: int, k_tiles: int):
    kt = pl.program_id(2)

    @pl.when(kt == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dequantize this (K_tile, N_tile) weight tile from its bit-planes
    w = jnp.zeros((plane_ref.shape[1] * PACK, plane_ref.shape[2]),
                  jnp.float32)
    for j in range(bits_active):
        w = w + _unpack(plane_ref[j]) * (2.0 ** (bits_parent - 1 - j))
    x = x_ref[...]
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    # distributive midpoint/zero correction for this K tile
    mid = (2.0 ** (bits_parent - bits_active) - 1.0) * 0.5
    sx = jnp.sum(x, axis=-1, keepdims=True)
    acc_ref[...] += (mid - zero_ref[...]) * sx

    @pl.when(kt == k_tiles - 1)
    def _finalize():
        out_ref[...] = acc_ref[...] * scale_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "bits_active", "bits_parent", "tile_m", "tile_n", "tile_k", "interpret"))
def dequant_matmul_pallas(
    x: jax.Array,           # (M, K) float32
    planes: jax.Array,      # (bits_parent, K/32, N) int32 (only first
                            #  bits_active planes are read)
    scale: jax.Array,       # (1, N)
    zero: jax.Array,        # (1, N)
    *,
    bits_active: int,
    bits_parent: int,
    tile_m: int = 256,
    tile_n: int = 256,
    tile_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    _, kw, n = planes.shape
    assert kw * PACK == k
    assert m % tile_m == 0 and n % tile_n == 0 and k % tile_k == 0
    grid = (m // tile_m, n // tile_n, k // tile_k)

    return pl.pallas_call(
        functools.partial(
            _kernel, bits_active=bits_active, bits_parent=bits_parent,
            k_tiles=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kt: (i, kt)),
            pl.BlockSpec((bits_active, tile_k // PACK, tile_n),
                         lambda i, j, kt: (0, kt, j)),
            pl.BlockSpec((1, tile_n), lambda i, j, kt: (0, j)),
            pl.BlockSpec((1, tile_n), lambda i, j, kt: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kt: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, planes, scale, zero)
