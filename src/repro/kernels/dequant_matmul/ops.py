"""jit'd public wrapper for the static-precision dequant matmul (prefill).

Backend contract: an **explicit** ``backend="pallas"|"interpret"`` always
runs the requested kernel — an untileable N is padded up to the tile (zero
scale on the pad, output sliced back); untileable M/K raise (padding the
reduction dim would silently inflate the tile budget). Auto mode
(``backend=None``) picks pallas on TPU when the shape tiles and otherwise
falls back to the jnp oracle, logging the fallback once per process.
"""
from __future__ import annotations

import functools
import logging
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

if TYPE_CHECKING:                    # annotation-only: a module-level import
    from repro.core.bitplane import QuantizedLinear   # would cycle through
                                                      # repro.core/__init__
from repro.kernels.common import pad_overlay_n
from repro.kernels.dequant_matmul.kernel import dequant_matmul_pallas
from repro.kernels.dequant_matmul.ref import dequant_matmul_ref

TILE_M, TILE_N, TILE_K = 256, 256, 512

_log = logging.getLogger(__name__)
_fallback_logged = False


def _tiles_ok(m, n, k, tm, tn, tk):
    return m % tm == 0 and n % tn == 0 and k % tk == 0


def _log_fallback_once(m, n, k) -> None:
    global _fallback_logged
    if not _fallback_logged:
        _log.warning(
            "dequant_matmul auto backend: shape (m=%d, n=%d, k=%d) does not "
            "tile (%d, %d, %d); falling back to the jnp oracle (logged once "
            "per process)", m, n, k, TILE_M, TILE_N, TILE_K)
        _fallback_logged = True


@functools.partial(jax.jit, static_argnames=("bits_active", "bits_parent",
                                              "backend"))
def _dispatch(x, planes, scale, zero, *, bits_active, bits_parent, backend):
    m, k = x.shape
    n = planes.shape[-1]
    if backend == "ref":
        return dequant_matmul_ref(
            x, planes, scale, zero,
            bits_active=bits_active, bits_parent=bits_parent)
    assert _tiles_ok(m, n, k, TILE_M, TILE_N, TILE_K), \
        (x.shape, planes.shape, "caller pads N / rejects M,K")
    return dequant_matmul_pallas(
        x, planes, scale, zero, bits_active=bits_active,
        bits_parent=bits_parent, interpret=(backend == "interpret"))


def dequant_matmul(
    x: jax.Array,
    ql: QuantizedLinear,
    bits_active: int,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """Prefill matmul at static precision ``bits_active``; returns float32."""
    lead = x.shape[:-1]
    xm = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    kp = ql.planes.shape[1] * 32
    if kp != xm.shape[-1]:
        xm = jnp.pad(xm, ((0, 0), (0, kp - xm.shape[-1])))
    m, k = xm.shape
    n = ql.planes.shape[-1]
    planes, scale, zero = ql.planes, ql.scale[None, :], ql.zero[None, :]
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
        if backend == "pallas" and not _tiles_ok(m, n, k, TILE_M, TILE_N,
                                                 TILE_K):
            _log_fallback_once(m, n, k)
            backend = "ref"
    elif backend in ("pallas", "interpret"):
        if m % TILE_M or k % TILE_K:
            raise ValueError(
                f"dequant_matmul backend={backend!r} needs M % {TILE_M} == 0"
                f" and K % {TILE_K} == 0, got (m={m}, k={k}); use "
                f"backend=None to allow the oracle fallback")
        planes, scale, zero = pad_overlay_n(planes, scale, zero, TILE_N)
    elif backend != "ref":
        raise ValueError(f"unknown backend {backend!r}; expected "
                         f"'pallas', 'interpret', or 'ref'")
    y = _dispatch(xm, planes, scale, zero,
                  bits_active=bits_active, bits_parent=ql.bits,
                  backend=backend)
    return y[..., :n].reshape(lead + (n,))
