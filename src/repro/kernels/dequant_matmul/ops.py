"""jit'd public wrapper for the static-precision dequant matmul (prefill)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import QuantizedLinear
from repro.kernels.dequant_matmul.kernel import dequant_matmul_pallas
from repro.kernels.dequant_matmul.ref import dequant_matmul_ref


def _tiles_ok(m, n, k, tm, tn, tk):
    return m % tm == 0 and n % tn == 0 and k % tk == 0


@functools.partial(jax.jit, static_argnames=("bits_active", "bits_parent",
                                              "backend"))
def _dispatch(x, planes, scale, zero, *, bits_active, bits_parent, backend):
    m, k = x.shape
    n = planes.shape[-1]
    if backend == "ref" or not _tiles_ok(m, n, k, 256, 256, 512):
        return dequant_matmul_ref(
            x, planes, scale, zero,
            bits_active=bits_active, bits_parent=bits_parent)
    return dequant_matmul_pallas(
        x, planes, scale, zero, bits_active=bits_active,
        bits_parent=bits_parent, interpret=(backend == "interpret"))


def dequant_matmul(
    x: jax.Array,
    ql: QuantizedLinear,
    bits_active: int,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """Prefill matmul at static precision ``bits_active``; returns float32."""
    if backend is None:
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    lead = x.shape[:-1]
    xm = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    kp = ql.planes.shape[1] * 32
    if kp != xm.shape[-1]:
        xm = jnp.pad(xm, ((0, 0), (0, kp - xm.shape[-1])))
    y = _dispatch(xm, ql.planes, ql.scale[None, :], ql.zero[None, :],
                  bits_active=bits_active, bits_parent=ql.bits,
                  backend=backend)
    return y.reshape(lead + (y.shape[-1],))
