from repro.kernels.dequant_matmul.kernel import dequant_matmul_pallas
from repro.kernels.dequant_matmul.ops import dequant_matmul
from repro.kernels.dequant_matmul.ref import dequant_matmul_ref

__all__ = ["dequant_matmul", "dequant_matmul_pallas", "dequant_matmul_ref"]
