"""Pure-jnp oracle for the bit-serial dynamic-precision matmul.

This is the closed form from ``core/bitplane.py`` — every plane is unpacked
and the precision enters as a mask, so the math is bit-exact with the kernel
while making no tiling/DMA assumptions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitplane import PACK, unpack_plane


def bitserial_matmul_ref(
    x: jax.Array,        # (M, K) float32
    planes: jax.Array,   # (bits, K/32, N) int32
    scale: jax.Array,    # (1, N) float32
    zero: jax.Array,     # (1, N) float32
    b_sel: jax.Array,    # (1,) int32
    *,
    bits: int,
) -> jax.Array:
    b = b_sel[0]
    acc = jnp.zeros((x.shape[0], planes.shape[-1]), jnp.float32)
    for j in range(planes.shape[0]):
        w = unpack_plane(planes[j])
        acc = acc + jnp.where(j < b, 1.0, 0.0) * (
            jax.lax.dot(x.astype(jnp.float32), w,
                        preferred_element_type=jnp.float32)
            * (2.0 ** (bits - 1 - j)))
    sx = jnp.sum(x.astype(jnp.float32), axis=-1, keepdims=True)
    mid = (jnp.exp2((bits - b).astype(jnp.float32)) - 1.0) * 0.5
    return (acc + (mid - zero) * sx) * scale


def bitserial_matmul_slots_ref(
    x: jax.Array,        # (S, M, K) float32 — per-slot activations
    planes: jax.Array,   # (bits, K/32, N) int32 — shared overlay
    scale: jax.Array,    # (1, N) float32
    zero: jax.Array,     # (1, N) float32
    b_sel: jax.Array,    # (S,) int32 — per-slot precision; 0 = idle
    *,
    bits: int,
) -> jax.Array:
    """Oracle for the batched-slot kernel: the single-request closed form
    vmapped over slots, with idle slots (``b_sel == 0``) defined as zeros —
    the same contract the Pallas dispatch enforces by masking."""
    y = jax.vmap(
        lambda xs, bs: bitserial_matmul_ref(xs, planes, scale, zero, bs,
                                            bits=bits))(x, b_sel[:, None])
    return jnp.where((b_sel > 0)[:, None, None], y, 0.0)


def bitserial_matmul_grouped_ref(
    x: jax.Array,          # (G, C, K) float32 — capacity-padded groups
    planes: jax.Array,     # (E, bits, K/32, N) int32 — stacked overlay
    scale: jax.Array,      # (E, N) float32
    zero: jax.Array,       # (E, N) float32
    expert_of: jax.Array,  # (G,) int32
    b_sel: jax.Array,      # (G,) int32 — per-group precision; 0 = idle
    counts: jax.Array,     # (G,) int32 — assigned tokens; 0 = empty
    *,
    bits: int,
) -> jax.Array:
    """Oracle for the grouped MoE expert kernel: the single-request
    closed form vmapped over groups, each gathering its OWN expert's
    plane stack, with idle groups (no assigned tokens, or 0 bits)
    defined as zeros — the same contract the Pallas dispatch enforces by
    masking. The vmapped gather materializes (G, bits, K/32, N) packed
    words — oracle semantics only; the kernel streams one plane block at
    a time and never gathers.
    """
    def one(xg, e, b, c):
        y = bitserial_matmul_ref(xg, planes[e], scale[e][None],
                                 zero[e][None], b[None], bits=bits)
        return jnp.where((b > 0) & (c > 0), y, 0.0)

    return jax.vmap(one)(x, expert_of, b_sel, counts)
