"""jit'd public wrapper for the bit-serial dynamic-precision matmul.

Handles padding to kernel tile requirements, dtype normalization, and backend
dispatch: on TPU the Pallas kernel runs natively; elsewhere (this CPU
container) the default is the jnp oracle (identical math), with
``interpret=True`` available to execute the actual kernel body for tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bitplane import QuantizedLinear
from repro.kernels.bitserial.kernel import bitserial_matmul_pallas
from repro.kernels.bitserial.ref import bitserial_matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_tile_n(n: int) -> int:
    for t in (256, 128):
        if n % t == 0:
            return t
    return 0


@functools.partial(jax.jit, static_argnames=("bits", "backend"))
def _dispatch(x, planes, scale, zero, b_sel, *, bits: int, backend: str):
    if backend == "ref":
        return bitserial_matmul_ref(x, planes, scale, zero, b_sel, bits=bits)
    tile_n = _pick_tile_n(planes.shape[-1])
    if tile_n == 0:
        return bitserial_matmul_ref(x, planes, scale, zero, b_sel, bits=bits)
    return bitserial_matmul_pallas(
        x, planes, scale, zero, b_sel, bits=bits, tile_n=tile_n,
        interpret=(backend == "interpret"))


def bitserial_matmul(
    x: jax.Array,
    ql: QuantizedLinear,
    b_sel: jax.Array,
    *,
    backend: Optional[str] = None,   # None -> auto; "pallas"|"interpret"|"ref"
) -> jax.Array:
    """``x @ W_{b_sel}`` for a bit-plane overlay; returns float32.

    x: (..., K); b_sel: scalar int32 (runtime precision, 1..ql.bits).
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "ref"
    lead = x.shape[:-1]
    xm = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    kp = ql.planes.shape[1] * 32
    if kp != xm.shape[-1]:
        xm = jnp.pad(xm, ((0, 0), (0, kp - xm.shape[-1])))
    y = _dispatch(
        xm, ql.planes, ql.scale[None, :], ql.zero[None, :],
        jnp.asarray(b_sel, jnp.int32).reshape((1,)),
        bits=ql.bits, backend=backend)
    return y.reshape(lead + (y.shape[-1],))
