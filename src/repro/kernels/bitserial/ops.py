"""jit'd public wrapper for the bit-serial dynamic-precision matmul.

Handles padding to kernel tile requirements, dtype normalization, and backend
dispatch: on TPU the Pallas kernel runs natively; elsewhere (this CPU
container) the default is the jnp oracle (identical math), with
``interpret=True`` available to execute the actual kernel body for tests.

Backend contract: an **explicit** ``backend="pallas"|"interpret"`` always
runs the requested kernel — untileable N is padded up to the tile (and the
output sliced back); it never silently reroutes to the oracle. Auto mode
(``backend=None``) picks pallas on TPU and the oracle elsewhere.

Batched dispatch (the continuous-batching scheduler): ``bitserial_matmul``
is wrapped in :func:`jax.custom_batching.custom_vmap`, so when the
scheduler vmaps the decode tick over slots, the mapped call does NOT get
generically lifted (which would make every slot pay for the most expensive
slot's planes). Instead the batching rule collapses the mapped axis into
the slot axis of the batched kernel — per-slot ``b_sel`` rides in as a
scalar-prefetch vector, planes ≥ b_sel[s] cost zero HBM traffic per slot,
and ``b_sel[s] == 0`` (idle slot) skips compute entirely and returns
zeros. ``TRACE_COUNTS`` counts Python traces of each dispatch entry point
(the no-retrace-across-b_sel guarantee is testable).
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, Dict, Optional

import jax
import jax.numpy as jnp

if TYPE_CHECKING:                    # annotation-only: a module-level import
    from repro.core.bitplane import (QuantizedLinear,  # would cycle through
                                     QuantizedStacked)  # repro.core/__init__
from repro.kernels.bitserial.kernel import (bitserial_matmul_grouped_pallas,
                                            bitserial_matmul_pallas,
                                            bitserial_matmul_slots_pallas)
from repro.kernels.bitserial.ref import (bitserial_matmul_grouped_ref,
                                         bitserial_matmul_ref,
                                         bitserial_matmul_slots_ref)
from repro.kernels.common import pad_overlay_n
from repro.kernels.tuning import tuned_tile

TILE_CHOICES = (256, 128)

#: tuning-cache kernel family for all three dispatch shapes
#: (plain / slots / grouped share the same tile_n semantics)
TUNE_KERNEL = "bitserial"

# Python-trace counters per dispatch entry point ("single" / "slots"):
# increments happen at trace time only, so a counter that stays flat across
# calls with different b_sel values proves the compiled kernel is reused.
TRACE_COUNTS: Dict[str, int] = {}


def _count_trace(key: str) -> None:
    TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_tile_n(n: int) -> int:
    for t in TILE_CHOICES:
        if n % t == 0:
            return t
    return 0


def resolve_tile_n(n: int, bits: int) -> int:
    """Tile for an N-dim of ``n``: the tuning cache's winner when it
    divides ``n``, else the first default choice that does, else 0
    (caller must pad — see :func:`pad_tile_n`). Cache miss reproduces
    today's ``_pick_tile_n`` exactly, so dispatch without a cache is
    unchanged."""
    tuned = tuned_tile(TUNE_KERNEL, n=n, bits=bits)
    if tuned and n % tuned == 0:
        return tuned
    return _pick_tile_n(n)


def pad_tile_n(n: int, bits: int) -> int:
    """Padding granularity for untileable N under an explicit kernel
    backend: the tuned tile when one is cached (the satellite fix — a
    tuned non-default tile must never trip the default-tile pad
    assumption), else the smallest default choice."""
    tuned = tuned_tile(TUNE_KERNEL, n=n, bits=bits)
    return tuned if tuned else min(TILE_CHOICES)


@functools.partial(jax.jit, static_argnames=("bits", "backend", "tile_n"))
def _dispatch(x, planes, scale, zero, b_sel, *, bits: int, backend: str,
              tile_n: int = 0):
    _count_trace("single")
    if backend == "ref":
        y = bitserial_matmul_ref(x, planes, scale, zero, b_sel, bits=bits)
    else:
        tile_n = tile_n or _pick_tile_n(planes.shape[-1])
        assert tile_n, (planes.shape, "caller pads N for explicit backends")
        y = bitserial_matmul_pallas(
            x, planes, scale, zero, b_sel, bits=bits, tile_n=tile_n,
            interpret=(backend == "interpret"))
    # b_sel == 0 (idle: an inactive applier outside the slot vmap) has the
    # same contract here as in the slot-batched path: output is zeros, not
    # the oracle's midpoint-correction residue
    return jnp.where(b_sel[0] > 0, y, 0.0)


@functools.partial(jax.jit, static_argnames=("bits", "backend", "tile_n"))
def _dispatch_slots(x, planes, scale, zero, b_sel, *, bits: int,
                    backend: str, tile_n: int = 0):
    """Slot-batched dispatch: x (S, M, K), b_sel (S,); idle slots -> 0."""
    _count_trace("slots")
    if backend == "ref":
        return bitserial_matmul_slots_ref(x, planes, scale, zero, b_sel,
                                          bits=bits)
    tile_n = tile_n or _pick_tile_n(planes.shape[-1])
    assert tile_n, (planes.shape, "caller pads N for explicit backends")
    y = bitserial_matmul_slots_pallas(
        x, planes, scale, zero, b_sel, bits=bits, tile_n=tile_n,
        interpret=(backend == "interpret"))
    # idle slots skip writeback in the kernel — define their output as 0
    return jnp.where((b_sel > 0)[:, None, None], y, 0.0)


@functools.partial(jax.jit, static_argnames=("bits", "backend", "tile_n"))
def _dispatch_grouped(x, planes, scale, zero, expert_of, b_sel, counts, *,
                      bits: int, backend: str, tile_n: int = 0):
    """Grouped MoE dispatch: x (G, C, K); idle/empty groups -> zeros."""
    _count_trace("grouped")
    if backend == "ref":
        return bitserial_matmul_grouped_ref(
            x, planes, scale, zero, expert_of, b_sel, counts, bits=bits)
    tile_n = tile_n or _pick_tile_n(planes.shape[-1])
    assert tile_n, (planes.shape, "caller pads N for explicit backends")
    y = bitserial_matmul_grouped_pallas(
        x, planes, scale, zero, expert_of, b_sel, counts, bits=bits,
        tile_n=tile_n, interpret=(backend == "interpret"))
    # idle groups skip writeback in the kernel — define their output as 0
    return jnp.where(((b_sel > 0) & (counts > 0))[:, None, None], y, 0.0)


@functools.lru_cache(maxsize=None)
def _grouped_batchable(bits: int, backend: str, tile_n: int = 0):
    """custom_vmap'd GROUPED core: vmapping an already group-batched call
    flattens the new axis into the existing group axis instead of generic
    Pallas lifting. This is how MoE prefill collapses: the rows-mode
    per-row vmap lands every row's E expert groups on the group axis
    (G = M·E with each row's own b_sel), and the scheduler's slot vmap
    on top folds again to ONE (S·M·E)-group launch — the expert_of table
    tiles, per-group b_sel/counts ride the scalar prefetch, and planes
    stay the shared (never-gathered) stacked overlay."""

    @jax.custom_batching.custom_vmap
    def fn(x, planes, scale, zero, expert_of, b_sel, counts):
        return _dispatch_grouped(x, planes, scale, zero, expert_of, b_sel,
                                 counts, bits=bits, backend=backend,
                                 tile_n=tile_n)

    @fn.def_vmap
    def _vmap_rule(axis_size, in_batched, x, planes, scale, zero,
                   expert_of, b_sel, counts):
        x_b, planes_b, scale_b, zero_b, e_b, b_b, c_b = in_batched
        if planes_b or scale_b or zero_b or e_b:
            # batched overlay/assignment-table: not the serving layout —
            # generic mapping
            axes = tuple(0 if b else None for b in in_batched)
            y = jax.vmap(
                functools.partial(_dispatch_grouped, bits=bits,
                                  backend=backend, tile_n=tile_n),
                in_axes=axes)(x, planes, scale, zero, expert_of, b_sel,
                              counts)
            return y, True
        if not x_b:
            x = jnp.broadcast_to(x[None], (axis_size,) + x.shape)
        if not b_b:
            b_sel = jnp.broadcast_to(b_sel[None], (axis_size,) + b_sel.shape)
        if not c_b:
            counts = jnp.broadcast_to(counts[None],
                                      (axis_size,) + counts.shape)
        r, g, c, k = x.shape
        y = fn(x.reshape(r * g, c, k), planes, scale, zero,
               jnp.tile(expert_of, r), b_sel.reshape(r * g),
               counts.reshape(r * g))
        return y.reshape(r, g, c, y.shape[-1]), True

    return fn


@functools.lru_cache(maxsize=None)
def _slots_batchable(bits: int, backend: str, tile_n: int = 0):
    """custom_vmap'd SLOT-batched core: vmapping an already slot-batched
    call flattens the new axis into the existing slot axis instead of
    generic Pallas lifting. This is how the speculative VERIFY launch
    gets its (S, k) batch: the rows-mode applier's per-row vmap lands k
    rows on the slot axis, and the scheduler's slot vmap on top folds to
    ONE (S·k)-slot launch — per-row b_sel prefetch, plane-DMA elision
    and all. The rule calls the same custom_vmap object recursively, so
    any vmap depth composes down to a single kernel launch."""

    @jax.custom_batching.custom_vmap
    def fn(x, planes, scale, zero, b_sel):
        return _dispatch_slots(x, planes, scale, zero, b_sel, bits=bits,
                               backend=backend, tile_n=tile_n)

    @fn.def_vmap
    def _vmap_rule(axis_size, in_batched, x, planes, scale, zero, b_sel):
        x_b, planes_b, scale_b, zero_b, b_b = in_batched
        if planes_b or scale_b or zero_b:
            # batched overlay: not the serving layout — generic mapping
            axes = tuple(0 if b else None for b in in_batched)
            y = jax.vmap(
                functools.partial(_dispatch_slots, bits=bits,
                                  backend=backend, tile_n=tile_n),
                in_axes=axes)(x, planes, scale, zero, b_sel)
            return y, True
        if not x_b:
            x = jnp.broadcast_to(x[None], (axis_size,) + x.shape)
        if not b_b:
            b_sel = jnp.broadcast_to(b_sel[None], (axis_size,) + b_sel.shape)
        s2, s1, m, k = x.shape
        y = fn(x.reshape(s2 * s1, m, k), planes, scale, zero,
               b_sel.reshape(s2 * s1))
        return y.reshape(s2, s1, m, y.shape[-1]), True

    return fn


@functools.lru_cache(maxsize=None)
def _batchable(bits: int, backend: str, tile_n: int = 0):
    """custom_vmap'd core: unmapped calls run the single-request path;
    a mapped call (the scheduler's slot axis) collapses into the batched
    kernel with per-slot DMA elision instead of generic Pallas batching.

    Cached per (bits, backend) so repeated traces reuse ONE custom_vmap
    object (a fresh one per call would defeat jit caching)."""

    @jax.custom_batching.custom_vmap
    def fn(x, planes, scale, zero, b_sel):
        return _dispatch(x, planes, scale, zero, b_sel, bits=bits,
                         backend=backend, tile_n=tile_n)

    @fn.def_vmap
    def _vmap_rule(axis_size, in_batched, x, planes, scale, zero, b_sel):
        x_b, planes_b, scale_b, zero_b, b_b = in_batched
        if planes_b or scale_b or zero_b:
            # the overlay itself is batched (not the serving layout):
            # generic per-element mapping, exactly what plain vmap did
            axes = tuple(0 if b else None for b in in_batched)
            y = jax.vmap(
                functools.partial(_dispatch, bits=bits, backend=backend,
                                  tile_n=tile_n),
                in_axes=axes)(x, planes, scale, zero, b_sel)
            return y, True
        if not x_b:
            x = jnp.broadcast_to(x[None], (axis_size,) + x.shape)
        if not b_b:
            b_sel = jnp.broadcast_to(b_sel[None], (axis_size,) + b_sel.shape)
        # route through the slot-batched custom_vmap wrapper so a FURTHER
        # vmap (scheduler slots over speculative verify rows) flattens
        # into the slot axis instead of generically batching the kernel
        y = _slots_batchable(bits, backend, tile_n)(x, planes, scale, zero,
                                                    b_sel[:, 0])
        return y, True

    return fn


def bitserial_matmul(
    x: jax.Array,
    ql: QuantizedLinear,
    b_sel: jax.Array,
    *,
    backend: Optional[str] = None,   # None -> auto; "pallas"|"interpret"|"ref"
) -> jax.Array:
    """``x @ W_{b_sel}`` for a bit-plane overlay; returns float32.

    x: (..., K); b_sel: scalar int32 (runtime precision, 1..ql.bits; under
    the scheduler's slot vmap it is per-slot, and 0 marks an idle slot
    whose output is zeros and whose planes are never fetched).
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "ref"
    elif backend not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}; expected "
                         f"'pallas', 'interpret', or 'ref'")
    lead = x.shape[:-1]
    xm = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    kp = ql.planes.shape[1] * 32
    if kp != xm.shape[-1]:
        xm = jnp.pad(xm, ((0, 0), (0, kp - xm.shape[-1])))
    n = ql.planes.shape[-1]
    planes, scale, zero = ql.planes, ql.scale[None, :], ql.zero[None, :]
    tile_n = 0
    if backend != "ref":
        # resolved ONCE here (host code, outside jit) and threaded as a
        # static key — a tuning-cache change lands on the next call
        tile_n = resolve_tile_n(n, ql.bits)
        if tile_n == 0:
            # explicit kernel backend on untileable N: pad to the tile
            # actually dispatched (tuned when cached, smallest default
            # otherwise) — never a stale hardcoded granularity
            tile_n = pad_tile_n(n, ql.bits)
            planes, scale, zero = pad_overlay_n(planes, scale, zero,
                                                tile_n)
    y = _batchable(ql.bits, backend, tile_n)(
        xm, planes, scale, zero,
        jnp.asarray(b_sel, jnp.int32).reshape((1,)))
    y = y[..., :n]
    return y.reshape(lead + (y.shape[-1],))


def bitserial_matmul_grouped(
    x: jax.Array,
    qs: QuantizedStacked,
    expert_of: jax.Array,
    b_sel: jax.Array,
    counts: jax.Array,
    *,
    backend: Optional[str] = None,   # None -> auto; "pallas"|"interpret"|"ref"
) -> jax.Array:
    """Grouped/ragged ``x[g] @ W_{b_sel[g]}`` over a stacked MoE overlay.

    x: (G, C, K) — G router groups of C capacity rows each (zero-padded;
    zero rows contribute exactly zero to the closed form, so capacity
    padding is free); expert_of/b_sel/counts: (G,) — the router's
    token→expert assignment table, scalar-prefetched by the kernel.
    Returns (G, C, N) float32. Groups with ``b_sel == 0`` (precision
    gated off) or ``counts == 0`` (no assigned tokens) fetch no planes
    and return zeros.

    Under ``jax.vmap`` (prefill rows, scheduler slots) the mapped axis
    collapses into the group axis — see :func:`_grouped_batchable`.
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "ref"
    elif backend not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown backend {backend!r}; expected "
                         f"'pallas', 'interpret', or 'ref'")
    g, c, _ = x.shape
    xm = x.astype(jnp.float32)
    kp = qs.planes.shape[2] * 32
    if kp != xm.shape[-1]:
        xm = jnp.pad(xm, ((0, 0), (0, 0), (0, kp - xm.shape[-1])))
    n = qs.planes.shape[-1]
    planes, scale, zero = qs.planes, qs.scale, qs.zero
    tile_n = 0
    if backend != "ref":
        tile_n = resolve_tile_n(n, qs.bits)
        if tile_n == 0:
            # explicit kernel backend on untileable N: pad to the tile
            # actually dispatched (tuned when cached, else smallest default)
            tile_n = pad_tile_n(n, qs.bits)
            planes, scale, zero = pad_overlay_n(planes, scale, zero,
                                                tile_n)
    y = _grouped_batchable(qs.bits, backend, tile_n)(
        xm, planes, scale, zero,
        jnp.asarray(expert_of, jnp.int32).reshape((g,)),
        jnp.asarray(b_sel, jnp.int32).reshape((g,)),
        jnp.asarray(counts, jnp.int32).reshape((g,)))
    return y[..., :n]
