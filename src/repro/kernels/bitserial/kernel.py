"""Dynamic-precision bit-serial matmul — the DP-LLM decode kernel (Pallas TPU).

Computes ``y = x @ W_b`` where ``W_b`` is the b-bit prefix of a bit-plane
overlay (core/bitplane.py) and ``b`` is a **runtime scalar** chosen by the
precision selector. TPU-native mechanism (DESIGN.md §2.1):

* grid = (N_tiles, B) with the plane index minor → planes stream through VMEM
  one at a time per output tile;
* the plane operand's ``index_map`` clamps the plane index to
  ``min(plane, b_sel-1)``: Pallas elides the HBM→VMEM copy when consecutive
  grid steps name the same block, so planes ≥ b_sel cost **zero HBM traffic**
  — the paper's "read fewer weight bits" on TPU;
* ``pl.when(plane < b_sel)`` skips the MXU work of masked planes;
* each plane step unpacks int32 words → {0,1} via VPU shift/mask and issues
  one MXU matmul, accumulating 2^(B-1-j)-weighted partials in VMEM scratch;
* the final plane step applies the closed-form midpoint/zero correction and
  per-channel scale.

Validated against ``ref.py`` in interpret mode (tests/test_kernels.py); on a
real TPU the same code lowers through Mosaic (no interpret flag).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed upstream (TPUCompilerParams -> CompilerParams); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

PACK = 32
DEFAULT_TILE_N = 256


def _unpack(words: jax.Array) -> jax.Array:
    """(KW, TN) int32 -> (KW*32, TN) f32 in {0,1} (VPU shift/mask)."""
    kw, tn = words.shape
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    bits = (words[:, None, :] >> shifts[None, :, None]) & 1
    return bits.reshape(kw * PACK, tn).astype(jnp.float32)


def _kernel(b_sel_ref, x_ref, plane_ref, scale_ref, zero_ref, out_ref,
            acc_ref, *, bits: int):
    plane = pl.program_id(1)             # minor grid dim: plane index
    b_sel = b_sel_ref[0]

    @pl.when(plane == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(plane < b_sel)
    def _accumulate():
        w = _unpack(plane_ref[0])        # (K, TILE_N) in {0,1}
        contrib = jax.lax.dot(
            x_ref[...], w, preferred_element_type=jnp.float32)
        acc_ref[...] += contrib * (2.0 ** (bits - 1 - plane))

    @pl.when(plane == bits - 1)
    def _finalize():
        sx = jnp.sum(x_ref[...], axis=-1, keepdims=True)      # (M, 1)
        mid = (jnp.exp2((bits - b_sel).astype(jnp.float32)) - 1.0) * 0.5
        corr = (mid - zero_ref[...]) * sx                      # (M, TILE_N)
        out_ref[...] = (acc_ref[...] + corr) * scale_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bits", "tile_n", "interpret"))
def bitserial_matmul_pallas(
    x: jax.Array,            # (M, K) float32
    planes: jax.Array,       # (bits, K/32, N) int32
    scale: jax.Array,        # (1, N) float32
    zero: jax.Array,         # (1, N) float32
    b_sel: jax.Array,        # (1,) int32 — runtime-selected precision
    *,
    bits: int,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """y[M, N] = x @ W_{b_sel}; HBM plane traffic ∝ b_sel."""
    m, k = x.shape
    _, kw, n = planes.shape
    assert kw * PACK == k, (kw, k)
    assert n % tile_n == 0, (n, tile_n)

    grid = (n // tile_n, bits)

    def x_map(i, j, sref):
        del i, j, sref
        return (0, 0)

    def plane_map(i, j, sref):
        # Clamp: steps past b_sel re-name the previous block -> no new DMA.
        return (jnp.minimum(j, sref[0] - 1), 0, i)

    def nvec_map(i, j, sref):
        del j, sref
        return (0, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), x_map),
            pl.BlockSpec((1, kw, tile_n), plane_map),
            pl.BlockSpec((1, tile_n), nvec_map),
            pl.BlockSpec((1, tile_n), nvec_map),
        ],
        out_specs=pl.BlockSpec((m, tile_n), nvec_map),
        scratch_shapes=[pltpu.VMEM((m, tile_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(b_sel, x, planes, scale, zero)
