"""Dynamic-precision bit-serial matmul — the DP-LLM decode kernel (Pallas TPU).

Computes ``y = x @ W_b`` where ``W_b`` is the b-bit prefix of a bit-plane
overlay (core/bitplane.py) and ``b`` is a **runtime scalar** chosen by the
precision selector. TPU-native mechanism (DESIGN.md §2.1):

* grid = (N_tiles, B) with the plane index minor → planes stream through VMEM
  one at a time per output tile;
* the plane operand's ``index_map`` clamps the plane index to
  ``min(plane, b_sel-1)``: Pallas elides the HBM→VMEM copy when consecutive
  grid steps name the same block, so planes ≥ b_sel cost **zero HBM traffic**
  — the paper's "read fewer weight bits" on TPU;
* ``pl.when(plane < b_sel)`` skips the MXU work of masked planes;
* each plane step unpacks int32 words → {0,1} via VPU shift/mask and issues
  one MXU matmul, accumulating 2^(B-1-j)-weighted partials in VMEM scratch;
* the final plane step applies the closed-form midpoint/zero correction and
  per-channel scale.

Validated against ``ref.py`` in interpret mode (tests/test_kernels.py); on a
real TPU the same code lowers through Mosaic (no interpret flag).

Batched-slot variant (continuous batching): the scheduler vmaps the decode
tick over S slots, each with its OWN runtime precision. Generic Pallas
batching would lift the single-request kernel into grid (N_tiles, B) with a
batched operand — every slot then pays for the most expensive slot's planes.
``bitserial_matmul_slots_pallas`` instead runs grid = (S, N_tiles, B) with a
scalar-prefetched (S,) ``b_sel`` vector:

* the plane ``index_map`` clamps the plane index **per slot** to
  ``min(plane, b_sel[s]-1)`` — slot s's plane steps ≥ b_sel[s] re-name the
  previous block, so per-slot HBM plane traffic is ∝ b_sel[s];
* ``b_sel[s] == 0`` marks an **idle** slot: its index_map pins to block
  (0, 0, 0) (at most one fetch per idle run) and the kernel body skips
  init, MXU work, and writeback entirely — the dispatch layer defines idle
  output as zeros;
* :func:`plane_block_fetches` is the host-side model of this contract: it
  walks the grid in iteration order through the *actual* index_map and
  counts consecutive-distinct block names (exactly the copies Pallas
  cannot elide), making "blocks fetched ∝ Σ b_sel" a testable invariant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed upstream (TPUCompilerParams -> CompilerParams); support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

PACK = 32
DEFAULT_TILE_N = 256


def _unpack(words: jax.Array) -> jax.Array:
    """(KW, TN) int32 -> (KW*32, TN) f32 in {0,1} (VPU shift/mask)."""
    kw, tn = words.shape
    shifts = jnp.arange(PACK, dtype=jnp.int32)
    bits = (words[:, None, :] >> shifts[None, :, None]) & 1
    return bits.reshape(kw * PACK, tn).astype(jnp.float32)


def _kernel(b_sel_ref, x_ref, plane_ref, scale_ref, zero_ref, out_ref,
            acc_ref, *, bits: int):
    plane = pl.program_id(1)             # minor grid dim: plane index
    b_sel = b_sel_ref[0]

    @pl.when(plane == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(plane < b_sel)
    def _accumulate():
        w = _unpack(plane_ref[0])        # (K, TILE_N) in {0,1}
        contrib = jax.lax.dot(
            x_ref[...], w, preferred_element_type=jnp.float32)
        acc_ref[...] += contrib * (2.0 ** (bits - 1 - plane))

    @pl.when(plane == bits - 1)
    def _finalize():
        sx = jnp.sum(x_ref[...], axis=-1, keepdims=True)      # (M, 1)
        mid = (jnp.exp2((bits - b_sel).astype(jnp.float32)) - 1.0) * 0.5
        corr = (mid - zero_ref[...]) * sx                      # (M, TILE_N)
        out_ref[...] = (acc_ref[...] + corr) * scale_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bits", "tile_n", "interpret"))
def bitserial_matmul_pallas(
    x: jax.Array,            # (M, K) float32
    planes: jax.Array,       # (bits, K/32, N) int32
    scale: jax.Array,        # (1, N) float32
    zero: jax.Array,         # (1, N) float32
    b_sel: jax.Array,        # (1,) int32 — runtime-selected precision
    *,
    bits: int,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """y[M, N] = x @ W_{b_sel}; HBM plane traffic ∝ b_sel."""
    m, k = x.shape
    _, kw, n = planes.shape
    assert kw * PACK == k, (kw, k)
    assert n % tile_n == 0, (n, tile_n)

    grid = (n // tile_n, bits)

    def x_map(i, j, sref):
        del i, j, sref
        return (0, 0)

    def plane_map(i, j, sref):
        # Clamp: steps past b_sel re-name the previous block -> no new DMA.
        # The lower clamp keeps b_sel = 0 (idle, zeros contract enforced by
        # the ops.py dispatch) from naming an out-of-range block.
        return (jnp.maximum(jnp.minimum(j, sref[0] - 1), 0), 0, i)

    def nvec_map(i, j, sref):
        del j, sref
        return (0, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), x_map),
            pl.BlockSpec((1, kw, tile_n), plane_map),
            pl.BlockSpec((1, tile_n), nvec_map),
            pl.BlockSpec((1, tile_n), nvec_map),
        ],
        out_specs=pl.BlockSpec((m, tile_n), nvec_map),
        scratch_shapes=[pltpu.VMEM((m, tile_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(b_sel, x, planes, scale, zero)


# ---------------------------------------------------------------------------
# Batched-slot kernel: grid (slots, n_tiles, bits), per-slot DMA elision
# ---------------------------------------------------------------------------
def _slot_plane_block(b, i, j):
    """Plane-block index named by a slot with precision ``b`` at (tile i,
    plane j) — THE elision contract, shared by the kernel's index_map and
    the host-side traffic model :func:`plane_block_fetches`.

    Busy slot (b > 0): ``(min(j, b-1), 0, i)`` — planes ≥ b re-name the
    previous block (zero HBM traffic). Idle slot (b == 0): pinned to
    ``(0, 0, 0)`` so an idle run costs at most one plane-block fetch.
    """
    active = b > 0
    jc = jnp.maximum(jnp.minimum(j, b - 1), 0)
    return (jnp.where(active, jc, 0), 0, jnp.where(active, i, 0))


def _slot_kernel(b_sel_ref, x_ref, plane_ref, scale_ref, zero_ref, out_ref,
                 acc_ref, *, bits: int):
    s = pl.program_id(0)
    plane = pl.program_id(2)             # minor grid dim: plane index
    b_sel = b_sel_ref[s]
    active = b_sel > 0

    @pl.when(active & (plane == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(plane < b_sel)              # implies active (b_sel > plane >= 0)
    def _accumulate():
        w = _unpack(plane_ref[0])        # (K, TILE_N) in {0,1}
        contrib = jax.lax.dot(
            x_ref[0], w, preferred_element_type=jnp.float32)
        acc_ref[...] += contrib * (2.0 ** (bits - 1 - plane))

    @pl.when(active & (plane == bits - 1))
    def _finalize():
        sx = jnp.sum(x_ref[0], axis=-1, keepdims=True)         # (M, 1)
        mid = (jnp.exp2((bits - b_sel).astype(jnp.float32)) - 1.0) * 0.5
        corr = (mid - zero_ref[...]) * sx                      # (M, TILE_N)
        out_ref[0] = (acc_ref[...] + corr) * scale_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bits", "tile_n", "interpret"))
def bitserial_matmul_slots_pallas(
    x: jax.Array,            # (S, M, K) float32 — per-slot activations
    planes: jax.Array,       # (bits, K/32, N) int32 — shared overlay
    scale: jax.Array,        # (1, N) float32
    zero: jax.Array,         # (1, N) float32
    b_sel: jax.Array,        # (S,) int32 — per-slot precision; 0 = idle
    *,
    bits: int,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """y[S, M, N] = x[s] @ W_{b_sel[s]}; plane traffic ∝ Σ_s b_sel[s].

    Idle slots (``b_sel[s] == 0``) skip init/MXU/writeback — their output
    blocks are UNDEFINED; callers must mask them (ops.py defines them as
    zeros). The plane operand is shared across slots; its index_map
    (:func:`_slot_plane_block`) gives per-slot DMA elision.
    """
    s, m, k = x.shape
    _, kw, n = planes.shape
    assert kw * PACK == k, (kw, k)
    assert n % tile_n == 0, (n, tile_n)
    assert b_sel.shape == (s,), (b_sel.shape, s)

    grid = (s, n // tile_n, bits)

    def x_map(si, i, j, bref):
        del i, j, bref
        return (si, 0, 0)

    def plane_map(si, i, j, bref):
        return _slot_plane_block(bref[si], i, j)

    def nvec_map(si, i, j, bref):
        del si, j, bref
        return (0, i)

    def out_map(si, i, j, bref):
        del j, bref
        return (si, 0, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, k), x_map),
            pl.BlockSpec((1, kw, tile_n), plane_map),
            pl.BlockSpec((1, tile_n), nvec_map),
            pl.BlockSpec((1, tile_n), nvec_map),
        ],
        out_specs=pl.BlockSpec((1, m, tile_n), out_map),
        scratch_shapes=[pltpu.VMEM((m, tile_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_slot_kernel, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(b_sel, x, planes, scale, zero)


# ---------------------------------------------------------------------------
# Grouped MoE expert kernel: grid (group, n_tiles, bits), per-expert elision
# ---------------------------------------------------------------------------
def _group_plane_block(e, b, c, i, j):
    """Plane-block index named by expert group ``(e, b, c)`` at (tile i,
    plane j) — THE grouped elision contract, shared by the kernel's
    index_map and the host-side traffic model
    :func:`expert_plane_fetches`.

    A group is one (expert, token-group) cell of the router's dispatch:
    ``e`` names whose stacked planes it reads, ``b`` its runtime
    precision, ``c`` how many tokens the router actually assigned. Busy
    group (``b > 0 and c > 0``): ``(e, min(j, b-1), 0, i)`` — planes ≥ b
    re-name the previous block (zero HBM traffic), exactly the slot
    kernel's clamp lifted onto the expert axis. Idle group (no tokens,
    or gated to 0 bits): pinned to ``(0, 0, 0, 0)`` so an idle run costs
    at most one plane-block fetch — empty experts are free.
    """
    busy = (b > 0) & (c > 0)
    jc = jnp.maximum(jnp.minimum(j, b - 1), 0)
    return (jnp.where(busy, e, 0), jnp.where(busy, jc, 0), 0,
            jnp.where(busy, i, 0))


def _grouped_kernel(expert_ref, b_sel_ref, count_ref, x_ref, plane_ref,
                    scale_ref, zero_ref, out_ref, acc_ref, *, bits: int):
    g = pl.program_id(0)
    plane = pl.program_id(2)             # minor grid dim: plane index
    b_sel = b_sel_ref[g]
    busy = (b_sel > 0) & (count_ref[g] > 0)

    @pl.when(busy & (plane == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(busy & (plane < b_sel))
    def _accumulate():
        w = _unpack(plane_ref[0, 0])     # (K, TILE_N) in {0,1}
        contrib = jax.lax.dot(
            x_ref[0], w, preferred_element_type=jnp.float32)
        acc_ref[...] += contrib * (2.0 ** (bits - 1 - plane))

    @pl.when(busy & (plane == bits - 1))
    def _finalize():
        sx = jnp.sum(x_ref[0], axis=-1, keepdims=True)         # (C, 1)
        mid = (jnp.exp2((bits - b_sel).astype(jnp.float32)) - 1.0) * 0.5
        corr = (mid - zero_ref[...]) * sx                      # (C, TILE_N)
        out_ref[0] = (acc_ref[...] + corr) * scale_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bits", "tile_n", "interpret"))
def bitserial_matmul_grouped_pallas(
    x: jax.Array,            # (G, C, K) float32 — capacity-padded groups
    planes: jax.Array,       # (E, bits, K/32, N) int32 — stacked overlay
    scale: jax.Array,        # (E, N) float32
    zero: jax.Array,         # (E, N) float32
    expert_of: jax.Array,    # (G,) int32 — which expert each group reads
    b_sel: jax.Array,        # (G,) int32 — per-group precision; 0 = idle
    counts: jax.Array,       # (G,) int32 — assigned tokens; 0 = empty
    *,
    bits: int,
    tile_n: int = DEFAULT_TILE_N,
    interpret: bool = False,
) -> jax.Array:
    """y[g] = x[g] @ W_{b_sel[g]} of expert ``expert_of[g]``; plane HBM
    traffic follows ``Σ_g n_tiles · b_sel[g]`` over busy groups.

    The router's token→expert assignment arrives as scalar-prefetched
    tables (``expert_of`` / ``b_sel`` / ``counts``), so the plane
    index_map (:func:`_group_plane_block`) clamps per GROUP: group g
    fetches exactly ``b_sel[g]`` plane blocks per tile of ITS expert's
    stack, and groups with no assigned tokens (or gated to 0 bits) pin
    to one block and skip init/MXU/writeback — their output blocks are
    UNDEFINED; the ops.py dispatch defines them as zeros.
    """
    g, c, k = x.shape
    e, _, kw, n = planes.shape
    assert kw * PACK == k, (kw, k)
    assert n % tile_n == 0, (n, tile_n)
    assert expert_of.shape == b_sel.shape == counts.shape == (g,), \
        (expert_of.shape, b_sel.shape, counts.shape, g)

    grid = (g, n // tile_n, bits)

    def x_map(gi, i, j, eref, bref, cref):
        del i, j, eref, bref, cref
        return (gi, 0, 0)

    def plane_map(gi, i, j, eref, bref, cref):
        return _group_plane_block(eref[gi], bref[gi], cref[gi], i, j)

    def evec_map(gi, i, j, eref, bref, cref):
        # scale/zero ride the same busy/idle pinning as the planes so an
        # idle run re-names one (tiny) block instead of gathering E rows
        del j
        busy = (bref[gi] > 0) & (cref[gi] > 0)
        return (jnp.where(busy, eref[gi], 0), jnp.where(busy, i, 0))

    def out_map(gi, i, j, eref, bref, cref):
        del j, eref, bref, cref
        return (gi, 0, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, k), x_map),
            pl.BlockSpec((1, 1, kw, tile_n), plane_map),
            pl.BlockSpec((1, tile_n), evec_map),
            pl.BlockSpec((1, tile_n), evec_map),
        ],
        out_specs=pl.BlockSpec((1, c, tile_n), out_map),
        scratch_shapes=[pltpu.VMEM((c, tile_n), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_grouped_kernel, bits=bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, c, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(expert_of, b_sel, counts, x, planes, scale, zero)


def plane_block_fetches(b_sel, n_tiles: int, bits: int) -> int:
    """Host-side model of the slot kernel's plane HBM traffic.

    Walks grid (S, n_tiles, bits) in iteration order (plane minor) through
    the kernel's actual ``index_map`` (:func:`_slot_plane_block`) and counts
    the steps whose named block differs from the previous step's — exactly
    the HBM→VMEM copies Pallas cannot elide. For ``n_tiles >= 2`` and busy
    precisions >= 1 this equals ``n_tiles * sum(b_sel)`` plus one fetch when
    the batch ends in an idle run (tests/test_kernels.py asserts the closed
    form) — i.e. blocks fetched ∝ Σ b_sel, not S * bits.
    """
    fetches, prev = 0, None
    for b in np.asarray(b_sel, dtype=np.int64):
        for i in range(n_tiles):
            for j in range(bits):
                blk = tuple(int(v) for v in
                            _slot_plane_block(jnp.int32(b), i, j))
                if blk != prev:
                    fetches += 1
                    prev = blk
    return fetches


def expert_plane_fetches(expert_of, b_sel, counts, n_tiles: int,
                         bits: int) -> int:
    """Host-side model of the grouped kernel's plane HBM traffic.

    Walks grid (G, n_tiles, bits) in iteration order (plane minor)
    through the kernel's actual ``index_map``
    (:func:`_group_plane_block`) and counts the steps whose named block
    differs from the previous step's — exactly the HBM→VMEM copies
    Pallas cannot elide. For ``n_tiles >= 2`` this equals the closed
    form::

        Σ_{busy g} n_tiles · b_sel[g]
          + (number of idle runs)
          - #{busy g : expert_of[g] == 0 and group g-1 is idle}

    where busy means ``b_sel[g] > 0 and counts[g] > 0`` (the last term:
    a busy expert-0 group's first block (0,0,0,0) coincides with the
    idle pin). tests/test_traffic_properties.py asserts the closed form
    over randomized assignment tables — blocks fetched ∝ Σ b_sel over
    busy groups, never G·bits.
    """
    fetches, prev = 0, None
    es = np.asarray(expert_of, dtype=np.int64)
    bs = np.asarray(b_sel, dtype=np.int64)
    cs = np.asarray(counts, dtype=np.int64)
    for e, b, c in zip(es, bs, cs):
        for i in range(n_tiles):
            for j in range(bits):
                blk = tuple(int(v) for v in _group_plane_block(
                    jnp.int32(e), jnp.int32(b), jnp.int32(c), i, j))
                if blk != prev:
                    fetches += 1
                    prev = blk
    return fetches
