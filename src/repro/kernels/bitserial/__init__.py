from repro.kernels.bitserial.kernel import (bitserial_matmul_grouped_pallas,
                                            bitserial_matmul_pallas,
                                            bitserial_matmul_slots_pallas,
                                            expert_plane_fetches,
                                            plane_block_fetches)
from repro.kernels.bitserial.ops import (TRACE_COUNTS, bitserial_matmul,
                                         bitserial_matmul_grouped)
from repro.kernels.bitserial.ref import (bitserial_matmul_grouped_ref,
                                         bitserial_matmul_ref,
                                         bitserial_matmul_slots_ref)

__all__ = [
    "bitserial_matmul",
    "bitserial_matmul_grouped",
    "bitserial_matmul_grouped_pallas",
    "bitserial_matmul_grouped_ref",
    "bitserial_matmul_pallas",
    "bitserial_matmul_ref",
    "bitserial_matmul_slots_pallas",
    "bitserial_matmul_slots_ref",
    "expert_plane_fetches",
    "plane_block_fetches",
    "TRACE_COUNTS",
]
