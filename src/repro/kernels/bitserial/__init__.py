from repro.kernels.bitserial.kernel import (bitserial_matmul_pallas,
                                            bitserial_matmul_slots_pallas,
                                            plane_block_fetches)
from repro.kernels.bitserial.ops import TRACE_COUNTS, bitserial_matmul
from repro.kernels.bitserial.ref import (bitserial_matmul_ref,
                                         bitserial_matmul_slots_ref)

__all__ = [
    "bitserial_matmul",
    "bitserial_matmul_pallas",
    "bitserial_matmul_ref",
    "bitserial_matmul_slots_pallas",
    "bitserial_matmul_slots_ref",
    "plane_block_fetches",
    "TRACE_COUNTS",
]
