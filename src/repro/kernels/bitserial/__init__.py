from repro.kernels.bitserial.kernel import bitserial_matmul_pallas
from repro.kernels.bitserial.ops import bitserial_matmul
from repro.kernels.bitserial.ref import bitserial_matmul_ref

__all__ = ["bitserial_matmul", "bitserial_matmul_pallas", "bitserial_matmul_ref"]
