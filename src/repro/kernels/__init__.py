"""Pallas TPU kernels for the DP-LLM hot paths.

- ``bitserial``     : dynamic-precision decode matmul (scalar-prefetch
                      predicated bit-plane DMA) — the paper's core mechanism.
- ``jl_estimator``  : fused relative-error estimation + threshold compare for
                      an async layer group.
- ``dequant_matmul``: static-precision prefill matmul with in-VMEM dequant.

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper with backend dispatch) and ``ref.py`` (pure-jnp oracle).
"""
from repro.kernels.bitserial import bitserial_matmul
from repro.kernels.dequant_matmul import dequant_matmul
from repro.kernels.jl_estimator import jl_estimate

__all__ = ["bitserial_matmul", "dequant_matmul", "jl_estimate"]
