"""Regression: the built_model cache key must cover EVERY build argument.

The key once omitted ``steps`` — two callers asking for differently
trained checkpoints (same targets/budget/split) silently shared one
pickle and one in-process memo entry, so whichever ran first poisoned
the other's results. The builders are stubbed out so this exercises only
the caching layer.
"""
import pytest

import benchmarks.common as common


@pytest.fixture
def patched(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "ART_DIR", str(tmp_path))
    monkeypatch.setattr(common, "_MEMO", {})
    monkeypatch.setattr(common, "trained_bench_lm",
                        lambda steps=300, force=False:
                        ("cfg", {"steps": steps}, 0.0))
    monkeypatch.setattr(common, "calibration_batches",
                        lambda cfg, **kw: [])
    calls = []

    def fake_build(cfg, params, batches, **kw):
        calls.append(kw)
        return {"build_id": len(calls), "params_steps": params["steps"]}

    monkeypatch.setattr(common, "build_multiscale_model", fake_build)
    return calls


def test_built_model_key_covers_steps(patched):
    _, p300, m300 = common.built_model((3.5,), steps=300)
    _, p50, m50 = common.built_model((3.5,), steps=50)
    assert len(patched) == 2                      # distinct builds ran
    assert m300 is not m50
    assert (p300["steps"], p50["steps"]) == (300, 50)
    # models carry the right checkpoint's weights
    assert m300["params_steps"] == 300 and m50["params_steps"] == 50


def test_built_model_memo_and_pickle_reuse(patched, tmp_path):
    out1 = common.built_model((3.5,), steps=300)
    out2 = common.built_model((3.5,), steps=300)
    assert len(patched) == 1                      # in-process memo hit
    assert out2 is out1
    common._MEMO.clear()                          # simulate a new process
    out3 = common.built_model((3.5,), steps=300)
    assert len(patched) == 1                      # pickle cache hit
    assert out3[2]["build_id"] == out1[2]["build_id"]


def test_built_model_key_still_covers_the_rest(patched):
    common.built_model((3.5,), steps=300)
    common.built_model((3.5, 4.5), steps=300)     # targets
    common.built_model((3.5,), budget=6.0, steps=300)
    common.built_model((3.5,), calib_split="eval", steps=300)
    common.built_model((3.5,), tag="x", steps=300)
    assert len(patched) == 5
