"""Decide/apply pipeline: bundle layout, planner bit-identity, async seed.

The fused planner must be a pure re-packaging of the inline per-unit
selector: same decisions, one launch. The engine's pipelining must seed
tick 0 with sync (same-tick) decisions and feed tick t's activations
into tick t+1's decisions — both verified here against the legacy
inline path as an independent reference implementation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import ServingEngine, make_decode_state

MODES = ("dynamic", "static:llm_mq", "max", "exact")


@pytest.fixture(scope="module")
def engine(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    return ServingEngine(cfg, params, model)


def _rand_acts(bundle, m=1, seed=0):
    """Random estimator rows honoring the capture contract: zero beyond
    each unit's true width (the applier zero-pads to K_max)."""
    rng = np.random.default_rng(seed)
    raw = rng.normal(size=(bundle.n_units, m, bundle.k_pad))
    raw *= (np.arange(bundle.k_pad)[None, None, :] <
            bundle.k_actual[:, None, None])
    return jnp.asarray(raw.astype(np.float32))


def _inline_reference(engine, mode, acts, t):
    """The legacy per-unit selector run over the same captured rows —
    the independent reference the fused planner must match bit-for-bit
    (shared harness: ``PrecisionPlanner.inline_reference``)."""
    base_mode, static_bits, serve_params = engine._mode_env(mode)
    bits = engine.planner(mode).inline_reference(
        acts, t, serve_params, engine.artifacts.table,
        mode=base_mode, static_bits=static_bits)
    return np.asarray(bits, np.int32)


def test_decision_bundle_layout(engine):
    """Row table, sizes, paddings, and the g_row elision chain."""
    from repro.core.adaptation import KIND_JL

    bundle = engine.artifacts.decision
    n_w = bundle.n_weight_units
    assert n_w == len(engine.artifacts.est)
    assert bundle.n_units == n_w + len(bundle.kv_rows)
    for i, p in enumerate(bundle.paths):
        assert bundle.row_of[p] == i
    # sizes reproduce the legacy per-record weights exactly
    for i, p in enumerate(bundle.paths[:n_w]):
        ov = engine.overlays[p]
        if ov.planes.ndim == 4:
            e, _, _, n = ov.planes.shape
            want = float(e * ov.k * n)
        else:
            want = float(ov.k * ov.planes.shape[-1])
        assert bundle.sizes[i] == want, p
    assert bundle.k_pad % 128 == 0
    assert np.all(bundle.k_actual <= bundle.k_pad)
    # KV pseudo-rows: zero-size clones of their value projection, one
    # per attention layer, appended after all weight rows
    for r, s in zip(bundle.kv_rows, bundle.kv_src):
        assert bundle.paths[r].endswith(".attn.kv") and r >= n_w
        assert bundle.paths[s].endswith(".attn.wv") and s < n_w
        assert bundle.sizes[r] == 0.0
        assert bundle.max_bits[r] == min(int(bundle.max_bits[s]), 8)
        for name in ("l", "h", "kind", "threshold", "g_row", "k_actual"):
            np.testing.assert_array_equal(getattr(bundle, name)[r],
                                          getattr(bundle, name)[s])
    # g_row: JL entries own a distinct packed row; others repeat the
    # previous unit's row (the kernel's DMA-elision contract). KV rows
    # sit outside the chain — they re-name their source's rows.
    prev = np.zeros((bundle.l.shape[1],), np.int64)
    seen = set()
    for u in range(n_w):
        for t in range(bundle.l.shape[1]):
            r = int(bundle.g_row[u, t])
            if bundle.kind[u, t] == KIND_JL:
                assert r not in seen and 1 <= r < bundle.g.shape[0]
                seen.add(r)
            else:
                assert r == prev[t]
        prev = bundle.g_row[u]
    assert len(seen) == bundle.g.shape[0] - 1      # row 0 = zero dummy
    assert not np.asarray(bundle.g[0]).any()


@pytest.mark.parametrize("mode", MODES)
def test_planner_bit_identity_all_modes(engine, mode):
    """The fused planner == the legacy inline selector, bit for bit, on
    identical inputs — every mode, every target."""
    planner = engine.planner(mode)
    bundle = engine.artifacts.decision
    for t in range(len(engine.artifacts.targets)):
        for seed in (0, 1):
            acts = _rand_acts(bundle, seed=seed + 10 * t)
            fused = np.asarray(planner.plan(acts, t))
            ref = _inline_reference(engine, mode, acts, t)
            np.testing.assert_array_equal(fused, ref, err_msg=(mode, t))
        # idle gate zeroes everything regardless of mode
        gated = planner.plan(_rand_acts(bundle), t, active=False)
        np.testing.assert_array_equal(np.asarray(gated), 0)


def test_planner_effective_bits_matches_applier_weights(engine):
    bundle = engine.artifacts.decision
    planner = engine.planner("dynamic")
    bits = planner.plan(_rand_acts(bundle), 0)
    eff = float(planner.effective_bits(bits))
    want = float(np.sum(np.asarray(bits) * bundle.sizes) /
                 np.sum(bundle.sizes))
    np.testing.assert_allclose(eff, want, rtol=1e-6)
    assert 0.0 < eff <= 8.0


def test_first_async_tick_uses_sync_decisions(engine, tiny_bundle):
    """Tick 0 of a pipelined query runs with inline (same-tick, sync)
    decisions — generate()'s first reported bits on a 1-token prompt
    must equal the standalone inline tick's effective bits."""
    cfg, _, _, batches = tiny_bundle
    prompt = batches[0][0][:1, :1]
    t_idx = jnp.int32(engine.artifacts.target_index(3.5))
    tick = jax.jit(engine.build_tick("dynamic"))
    state = make_decode_state(cfg, 1, engine.kv_bucket,
                              dtype=jnp.float32)
    _, _, eb_sync = tick(state, jnp.asarray(prompt), t_idx)
    _, ebits = engine.generate(prompt, 3, 3.5)
    np.testing.assert_allclose(ebits[0], float(eb_sync), atol=1e-5)


def test_pipelined_tick_uses_previous_tick_activations(engine,
                                                       tiny_bundle):
    """The async wiring: tick 1's applied bits must be what the LEGACY
    per-unit selector derives from tick 0's captured activations (the
    one-tick-stale pipeline), not from tick 1's own inputs."""
    from repro.core.dynamic_linear import DynamicLinearApplier
    from repro.models import decode_step

    cfg, _, _, batches = tiny_bundle
    prompt = batches[0][0][:1, :2]
    target = 3.5
    t_idx = jnp.int32(engine.artifacts.target_index(target))
    bundle = engine.artifacts.decision
    base_mode, static_bits, serve_params = engine._mode_env("dynamic")

    # tick 0 by hand: inline decisions + capture (what the boot tick does)
    state = make_decode_state(cfg, 1, engine.kv_bucket, dtype=jnp.float32)
    lin0 = DynamicLinearApplier(
        engine.artifacts.table, serve_params, target_idx=t_idx,
        mode=base_mode, use_async=engine.use_async, bundle=bundle,
        capture=True)
    decode_step(cfg, engine.raw, state, jnp.asarray(prompt[:, :1]),
                lin=lin0)
    acts0 = np.asarray(lin0.planner_inputs())

    # legacy selector over tick-0 activations -> expected tick-1 bits
    bits1 = _inline_reference(engine, "dynamic", jnp.asarray(acts0),
                              t_idx)
    eb1_ref = float(np.sum(bits1 * bundle.sizes) / np.sum(bundle.sizes))

    # the engine's pipelined run: with p=2, the first reported entry is
    # tick 1 (the tick that produced the first generated token)
    _, ebits = engine.generate(prompt, 1, target)
    np.testing.assert_allclose(ebits[0], eb1_ref, atol=1e-5)


def test_scheduler_carries_slot_decision_matrix(engine, tiny_bundle):
    """The scheduler's (S, U) decision carry exists, is gated to zero on
    never-admitted slots, and survives a full run."""
    from repro.serving import LatencyModel, QoSPlanner, Request, \
        SlotScheduler

    cfg, _, model, _ = tiny_bundle
    qos = QoSPlanner(sorted(model.adaptations),
                     LatencyModel(bytes_per_bit=1e9), chips=1)
    sched = SlotScheduler(engine, qos, slots=3, max_prompt=8, max_new=3,
                          chunk=4)
    n_units = engine.artifacts.decision.n_units
    assert sched._bits.shape == (3, n_units)
    assert not np.asarray(sched._bits[2]).any()        # never admitted
    rng = np.random.default_rng(9)
    req = Request(rid=0,
                  prompt=rng.integers(0, cfg.vocab_size,
                                      (3,)).astype(np.int32),
                  max_new=3, tpot_budget_s=6e-3)
    done = sched.run([req])
    assert len(done) == 1 and done[0].tokens.shape == (6,)
    assert sched._bits.shape == (3, n_units)
