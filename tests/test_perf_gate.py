"""Unit tests for the CI perf gate (tools/perf_gate.py).

The gate guards step-function serve-path regressions; these pin its
decision boundary (exactly -20% passes, anything past it fails), the
missing-key / new-metric pass-through that lets metrics land before
their baselines, and the direction handling for lower-is-better metrics.
"""
import json

import pytest

from tools.perf_gate import METRICS, check, main


BASE = {"decode_tokens_per_s": 100.0, "ttft_s": 0.050,
        "spec_tokens_per_s": 200.0, "moe_tokens_per_s": 1000.0}


def test_tracked_metrics_cover_serve_path():
    assert METRICS == {"decode_tokens_per_s": +1, "ttft_s": -1,
                       "spec_tokens_per_s": +1, "moe_tokens_per_s": +1,
                       "kv_tokens_per_s": +1, "p50_ttft_s": -1,
                       "p99_ttft_s": -1, "goodput_tokens_per_s": +1}


def test_regression_boundary_exact_tolerance_passes():
    """ratio == 1 - tolerance is OK; one hair past it fails."""
    new = dict(BASE, decode_tokens_per_s=80.0)        # exactly -20%
    assert check(new, BASE, 0.20) == []
    new["decode_tokens_per_s"] = 79.9
    assert check(new, BASE, 0.20) == ["decode_tokens_per_s"]


def test_lower_is_better_direction():
    """ttft regressions are INCREASES: the ratio inverts."""
    assert check(dict(BASE, ttft_s=0.0625), BASE, 0.20) == []   # b/n = .8
    assert check(dict(BASE, ttft_s=0.0630), BASE, 0.20) == ["ttft_s"]
    # improvements never fail, in either direction
    assert check(dict(BASE, ttft_s=0.001,
                      decode_tokens_per_s=500.0), BASE, 0.20) == []


def test_missing_key_skipped_both_ways():
    """A metric absent from EITHER file is skipped — new metrics land
    before their baselines, old baselines outlive retired metrics."""
    new = dict(BASE)
    del new["spec_tokens_per_s"]                     # retired from new
    assert check(new, BASE, 0.20) == []
    base = dict(BASE)
    del base["moe_tokens_per_s"]                     # not yet in baseline
    assert check(dict(BASE, moe_tokens_per_s=1.0), base, 0.20) == []


def test_nonpositive_baseline_skipped_and_zero_new_fails():
    assert check(dict(BASE, decode_tokens_per_s=1.0),
                 dict(BASE, decode_tokens_per_s=0.0), 0.20) == []
    # a lower-is-better metric collapsing to 0 new is a hard fail
    assert check(dict(BASE, ttft_s=0.0), BASE, 0.20) == ["ttft_s"]


def test_multiple_failures_reported_together():
    new = dict(BASE, decode_tokens_per_s=10.0, moe_tokens_per_s=10.0)
    assert check(new, BASE, 0.20) == ["decode_tokens_per_s",
                                      "moe_tokens_per_s"]


@pytest.mark.parametrize("wreck,code", [({}, 0),
                                        ({"ttft_s": 9.0}, 1)])
def test_main_exit_codes(tmp_path, monkeypatch, wreck, code):
    newp, basep = tmp_path / "new.json", tmp_path / "base.json"
    basep.write_text(json.dumps(BASE))
    newp.write_text(json.dumps(dict(BASE, **wreck)))
    monkeypatch.setattr("sys.argv",
                        ["perf_gate", str(newp), "--baseline", str(basep)])
    assert main() == code
