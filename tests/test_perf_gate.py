"""Unit tests for the CI perf gate (tools/perf_gate.py).

The gate guards step-function serve-path regressions; these pin its
decision boundary (exactly -20% passes, anything past it fails), the
missing-key / new-metric pass-through that lets metrics land before
their baselines, the direction handling for lower-is-better metrics,
and the per-platform artifact rules (auto-selection of
``BENCH_serve.<platform>.json`` and skip-with-notice on mismatch).
"""
import json

import pytest

from tools.perf_gate import METRICS, check, main, resolve_baseline


BASE = {"decode_tokens_per_s": 100.0, "ttft_s": 0.050,
        "spec_tokens_per_s": 200.0, "moe_tokens_per_s": 1000.0}


def test_tracked_metrics_cover_serve_path():
    assert METRICS == {"decode_tokens_per_s": +1, "ttft_s": -1,
                       "spec_tokens_per_s": +1, "moe_tokens_per_s": +1,
                       "kv_tokens_per_s": +1, "p50_ttft_s": -1,
                       "p99_ttft_s": -1, "goodput_tokens_per_s": +1}


def test_wall_s_never_tracked():
    """wall_s (total run wall clock) is machine noise, not a serve
    metric — it must stay out of the gate."""
    assert "wall_s" not in METRICS


def test_regression_boundary_exact_tolerance_passes():
    """ratio == 1 - tolerance is OK; one hair past it fails."""
    new = dict(BASE, decode_tokens_per_s=80.0)        # exactly -20%
    assert check(new, BASE, 0.20)[0] == []
    new["decode_tokens_per_s"] = 79.9
    assert check(new, BASE, 0.20)[0] == ["decode_tokens_per_s"]


def test_lower_is_better_direction():
    """ttft regressions are INCREASES: the ratio inverts."""
    assert check(dict(BASE, ttft_s=0.0625), BASE, 0.20)[0] == []  # b/n=.8
    assert check(dict(BASE, ttft_s=0.0630), BASE, 0.20)[0] == ["ttft_s"]
    # improvements never fail, in either direction
    assert check(dict(BASE, ttft_s=0.001,
                      decode_tokens_per_s=500.0), BASE, 0.20)[0] == []


def test_missing_key_skipped_both_ways():
    """A metric absent from EITHER file is skipped — new metrics land
    before their baselines, old baselines outlive retired metrics."""
    new = dict(BASE)
    del new["spec_tokens_per_s"]                     # retired from new
    assert check(new, BASE, 0.20)[0] == []
    base = dict(BASE)
    del base["moe_tokens_per_s"]                     # not yet in baseline
    assert check(dict(BASE, moe_tokens_per_s=1.0), base, 0.20)[0] == []


def test_compared_keys_reported():
    """check() reports exactly the metrics present (and positive) in
    BOTH blobs — the gate's comparison surface is auditable."""
    _, compared = check(dict(BASE), BASE, 0.20)
    assert compared == ["decode_tokens_per_s", "ttft_s",
                        "spec_tokens_per_s", "moe_tokens_per_s"]
    new = dict(BASE)
    del new["ttft_s"]
    _, compared = check(new, BASE, 0.20)
    assert "ttft_s" not in compared


def test_nonpositive_baseline_skipped_and_zero_new_fails():
    assert check(dict(BASE, decode_tokens_per_s=1.0),
                 dict(BASE, decode_tokens_per_s=0.0), 0.20)[0] == []
    # a lower-is-better metric collapsing to 0 new is a hard fail
    assert check(dict(BASE, ttft_s=0.0), BASE, 0.20)[0] == ["ttft_s"]


def test_multiple_failures_reported_together():
    new = dict(BASE, decode_tokens_per_s=10.0, moe_tokens_per_s=10.0)
    assert check(new, BASE, 0.20)[0] == ["decode_tokens_per_s",
                                         "moe_tokens_per_s"]


@pytest.mark.parametrize("wreck,code", [({}, 0),
                                        ({"ttft_s": 9.0}, 1)])
def test_main_exit_codes(tmp_path, monkeypatch, wreck, code):
    newp, basep = tmp_path / "new.json", tmp_path / "base.json"
    basep.write_text(json.dumps(BASE))
    newp.write_text(json.dumps(dict(BASE, **wreck)))
    monkeypatch.setattr("sys.argv",
                        ["perf_gate", str(newp), "--baseline", str(basep)])
    assert main() == code


# ---------------------------------------------------------------------------
# Per-platform artifact selection + mismatch skip
# ---------------------------------------------------------------------------
def test_resolve_baseline_prefers_platform_sibling(tmp_path):
    base = tmp_path / "BENCH_serve.json"
    sib = tmp_path / "BENCH_serve.tpu.json"
    base.write_text("{}")
    sib.write_text("{}")
    meas = {"platform": "tpu", "suite": "measured"}
    assert resolve_baseline(meas, str(base), None) == str(sib)
    # no sibling on disk -> falls back to the plain baseline
    got = resolve_baseline({"platform": "gpu", "suite": "measured"},
                           str(base), None)
    assert got == str(base)
    # explicit --artifact always wins
    assert resolve_baseline(meas, str(base), "X.json") == "X.json"
    # platform-less blob keeps the legacy baseline path
    assert resolve_baseline({"suite": "measured"}, str(base), None) == \
        str(base)
    # a run.py ("serve") blob must NEVER auto-upgrade onto a measured
    # sibling: same metric names, different fixtures and magnitudes
    assert resolve_baseline({"platform": "tpu", "suite": "serve"},
                            str(base), None) == str(base)
    assert resolve_baseline({"platform": "tpu"}, str(base), None) == \
        str(base)


def test_platform_mismatch_skips_with_notice(tmp_path, monkeypatch,
                                             capsys):
    """A committed artifact from another platform must SKIP (exit 0),
    never fail — even when every metric would regress."""
    newp = tmp_path / "new.json"
    artp = tmp_path / "BENCH_serve.tpu.json"
    newp.write_text(json.dumps(dict(BASE, platform="cpu",
                                    decode_tokens_per_s=1.0)))
    artp.write_text(json.dumps(dict(BASE, platform="tpu")))
    monkeypatch.setattr("sys.argv",
                        ["perf_gate", str(newp),
                         "--artifact", str(artp)])
    assert main() == 0
    assert "SKIPPED" in capsys.readouterr().out


def test_matching_platform_gates_normally(tmp_path, monkeypatch):
    newp = tmp_path / "new.json"
    artp = tmp_path / "BENCH_serve.cpu.json"
    artp.write_text(json.dumps(dict(BASE, platform="cpu")))
    newp.write_text(json.dumps(dict(BASE, platform="cpu",
                                    decode_tokens_per_s=1.0)))
    monkeypatch.setattr("sys.argv",
                        ["perf_gate", str(newp),
                         "--artifact", str(artp)])
    assert main() == 1
    newp.write_text(json.dumps(dict(BASE, platform="cpu")))
    assert main() == 0


def test_auto_selection_end_to_end(tmp_path, monkeypatch, capsys):
    """--baseline pointing at the legacy artifact auto-upgrades to the
    platform sibling when the new blob is a measured-suite blob that
    names its platform."""
    base = tmp_path / "BENCH_serve.json"
    sib = tmp_path / "BENCH_serve.cpu.json"
    newp = tmp_path / "new.json"
    base.write_text(json.dumps(dict(BASE, decode_tokens_per_s=1e9)))
    sib.write_text(json.dumps(dict(BASE, platform="cpu",
                                   suite="measured")))
    newp.write_text(json.dumps(dict(BASE, platform="cpu",
                                    suite="measured")))
    monkeypatch.setattr("sys.argv",
                        ["perf_gate", str(newp),
                         "--baseline", str(base)])
    # gating against the plain baseline would fail (1e9 baseline);
    # the cpu sibling passes — proof the sibling was selected
    assert main() == 0
    assert "BENCH_serve.cpu.json" in capsys.readouterr().out


def test_suite_mismatch_skips_with_notice(tmp_path, monkeypatch, capsys):
    """Explicitly pointing a serve blob at a measured artifact (or vice
    versa) skips — the metric names collide but the fixtures differ."""
    newp = tmp_path / "new.json"
    artp = tmp_path / "BENCH_serve.cpu.json"
    newp.write_text(json.dumps(dict(BASE, platform="cpu", suite="serve",
                                    decode_tokens_per_s=1.0)))
    artp.write_text(json.dumps(dict(BASE, platform="cpu",
                                    suite="measured")))
    monkeypatch.setattr("sys.argv",
                        ["perf_gate", str(newp),
                         "--artifact", str(artp)])
    assert main() == 0
    assert "SKIPPED" in capsys.readouterr().out
