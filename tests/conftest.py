import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too: benchmarks/ and tools/ are plain (namespace) packages
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))

try:                                    # prefer the real property tester
    import hypothesis                   # noqa: F401
except ImportError:                     # hermetic fallback (same API subset)
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def tiny_bundle():
    """One shared DP-LLM build on tiny-dense (expensive: ~1 min)."""
    from repro.configs import get_config
    from repro.core import build_multiscale_model
    from repro.models import init_model_params

    cfg = get_config("tiny-dense")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [
        (rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32),
         rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32))
        for _ in range(2)
    ]
    # three targets: the no-retrace acceptance check needs one compiled
    # decode step to serve >= 3 targets via the traced target index
    model = build_multiscale_model(
        cfg, params, batches, targets=[3.5, 4.0, 4.5], finetune_epochs=1,
        baselines=("llm_mq", "hawq_v2"))
    return cfg, params, model, batches


@pytest.fixture(scope="session")
def tiny_moe_bundle():
    """One shared DP-LLM build on tiny-moe (expensive: ~1.5 min) — the
    grouped-vs-dense MoE parity matrix's engine fixture. Two targets and
    one baseline keep the build time bounded; the MoE layer's expert
    stacks (w_gate/w_up/w_down) become QuantizedStacked units."""
    from repro.configs import get_config
    from repro.core import build_multiscale_model
    from repro.models import init_model_params

    cfg = get_config("tiny-moe")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [
        (rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32),
         rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32))
        for _ in range(2)
    ]
    model = build_multiscale_model(
        cfg, params, batches, targets=[3.5, 4.5], finetune_epochs=1,
        baselines=("llm_mq",))
    return cfg, params, model, batches
