"""Property-based coverage of the traffic closed forms and the bit-plane
round trip (hypothesis, or the seeded deterministic stub in hermetic envs).

The two fetch counters walk the kernels' REAL index_maps in grid order —
these tests pin the documented closed forms against that walk over
randomized precision/assignment tables, so the benchmarks' analytic
traffic models can never drift from what the kernels actually fetch.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bitplane import materialize, quantize_linear
from repro.kernels.bitserial import expert_plane_fetches, plane_block_fetches
from repro.kernels.kv_attention import kv_plane_fetches


def _table(seed: int, g: int, n_experts: int, bits: int):
    rng = np.random.default_rng(seed)
    expert_of = rng.integers(0, n_experts, size=g)
    b_sel = rng.integers(0, bits + 1, size=g)
    counts = rng.integers(0, 4, size=g)
    return expert_of.tolist(), b_sel.tolist(), counts.tolist()


def _idle_runs(busy):
    runs, prev_idle = 0, False
    for f in busy:
        if not f and not prev_idle:
            runs += 1
        prev_idle = not f
    return runs


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 10), st.integers(2, 4),
       st.integers(1, 8))
def test_expert_plane_fetches_closed_form(seed, g, n_tiles, bits):
    """For n_tiles >= 2 the grouped walk equals
    sum_busy(n_tiles * b_sel) + n_idle_runs
    - #{busy g: expert 0, preceded by an idle group}
    (a busy expert-0 group's first block IS the idle pin (0,0,0,0))."""
    expert_of, b_sel, counts = _table(seed, g, 4, bits)
    walked = expert_plane_fetches(expert_of, b_sel, counts, n_tiles, bits)
    busy = [(b > 0) and (c > 0) for b, c in zip(b_sel, counts)]
    total = sum(n_tiles * b for b, f in zip(b_sel, busy) if f)
    collide = sum(1 for i in range(1, g)
                  if busy[i] and expert_of[i] == 0 and not busy[i - 1])
    assert walked == total + _idle_runs(busy) - collide, \
        (expert_of, b_sel, counts, n_tiles, bits, walked)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 12), st.integers(2, 4),
       st.integers(1, 8))
def test_plane_block_fetches_closed_form(seed, s, n_tiles, bits):
    """For n_tiles >= 2 the slot walk equals
    n_tiles * sum(b_sel) + n_idle_runs
    - #{busy slots preceded by an idle slot}
    (every busy slot's first block (0,0,0) IS the idle pin)."""
    rng = np.random.default_rng(seed)
    b_list = rng.integers(0, bits + 1, size=s).tolist()
    walked = plane_block_fetches(b_list, n_tiles, bits)
    busy = [b > 0 for b in b_list]
    total = n_tiles * sum(b_list)
    collide = sum(1 for i in range(1, s) if busy[i] and not busy[i - 1])
    assert walked == total + _idle_runs(busy) - collide, \
        (b_list, n_tiles, bits, walked)


def test_fetch_counters_degenerate_tables():
    """All-idle tables pin ONE block ever; all-busy tables are the pure
    product form with no idle terms."""
    assert plane_block_fetches([0, 0, 0], 3, 6) == 1
    assert plane_block_fetches([2, 3], 3, 6) == 3 * 5
    assert expert_plane_fetches([1, 2, 3], [0, 0, 0], [1, 1, 1], 3, 6) == 1
    assert expert_plane_fetches([1, 2], [2, 3], [1, 1], 3, 6) == 3 * 5


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 12), st.integers(2, 4),
       st.integers(1, 8))
def test_kv_plane_fetches_closed_form(seed, s, n_tiles, bits):
    """For n_tiles >= 2 the KV-attention walk equals
    n_tiles * sum(kv_b) + n_idle_runs
    with NO collide term: the plane block id carries the slot
    coordinate, so a busy slot's first block never aliases the idle
    pin (unlike the shared-operand weight kernels)."""
    rng = np.random.default_rng(seed)
    b_list = rng.integers(0, bits + 1, size=s).tolist()
    walked = kv_plane_fetches(b_list, n_tiles, bits)
    busy = [b > 0 for b in b_list]
    assert walked == n_tiles * sum(b_list) + _idle_runs(busy), \
        (b_list, n_tiles, bits, walked)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 10), st.integers(2, 4),
       st.integers(2, 8))
def test_kv_plane_fetches_idle_free_and_linear(seed, s, n_tiles, bits):
    """The two read-precision properties the planner relies on: an idle
    slot adds no plane traffic beyond its (amortized) pin, and for a
    FIXED busy pattern traffic is exactly linear in sum(kv_b) with
    slope n_tiles."""
    rng = np.random.default_rng(seed)
    b_list = rng.integers(1, bits + 1, size=s).tolist()   # all busy
    base = kv_plane_fetches(b_list, n_tiles, bits)
    # appending idle slots adds exactly ONE pinned fetch, total
    assert kv_plane_fetches(b_list + [0, 0], n_tiles, bits) == base + 1
    # raising one slot's read precision by d adds n_tiles * d fetches
    i = int(rng.integers(0, s))
    if b_list[i] < bits:
        bumped = list(b_list)
        bumped[i] += 1
        assert kv_plane_fetches(bumped, n_tiles, bits) == base + n_tiles
    # a full-stack read costs n_tiles * bits per slot — never more
    assert kv_plane_fetches([bits] * s, n_tiles, bits) == \
        n_tiles * bits * s


def test_kv_plane_fetches_degenerate_tables():
    assert kv_plane_fetches([0, 0, 0], 3, 8) == 1         # one pin total
    assert kv_plane_fetches([8, 0, 3], 2, 8) == 2 * 11 + 1
    assert kv_plane_fetches([1, 1, 0, 2], 4, 8) == 4 * 4 + 1


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 8))
def test_bitplane_round_trip_bounds_and_monotonicity(seed, bits):
    """Quantize -> materialize honors the closed-form truncation bounds:
    the B-bit reconstruction is within scale/2 of the weight, every b-bit
    truncation is within scale * (2^(B-b) - 1) / 2 of the B-bit one, and
    mean |error| never grows as b rises (more planes, less error)."""
    rng = np.random.default_rng(seed)
    w = np.asarray(rng.normal(size=(32, 16)) * rng.uniform(0.01, 2.0),
                   np.float32)
    ql = quantize_linear(w, bits=bits)
    scale = np.asarray(ql.scale)[None, :]
    w_full = np.asarray(materialize(ql, bits))[:w.shape[0]]
    assert np.all(np.abs(w - w_full) <= np.abs(scale) * 0.5 + 1e-5)

    maes = []
    for b in range(1, bits + 1):
        w_b = np.asarray(materialize(ql, b))[:w.shape[0]]
        bound = np.abs(scale) * (2.0 ** (bits - b) - 1.0) * 0.5
        assert np.all(np.abs(w_b - w_full) <= bound + 1e-4), b
        maes.append(float(np.mean(np.abs(w_b - w))))
    for lo, hi in zip(maes, maes[1:]):
        assert hi <= lo + 1e-6, maes
