"""Grouped MoE expert kernel: parity matrix + no-materialization capture.

The grouped bit-serial kernel must be a pure APPLY change: identical
outputs to the dense materialize-and-einsum MoE path at every level —
kernel vs oracle vs per-group dense loop, layer forward, per-row prefill,
and the serving engine across all modes and async/sync — while never
binding the dense ``(E, K, N)`` / per-row ``(M, E, K, N)`` expert stacks
the legacy path materializes (asserted by walking the traced jaxpr), and
with plane-block traffic following ``expert_plane_fetches``'s walked
index_map.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitplane import materialize_stacked, quantize_stacked
from repro.kernels.bitserial import (TRACE_COUNTS, bitserial_matmul_grouped,
                                     bitserial_matmul_grouped_ref,
                                     expert_plane_fetches)
from repro.kernels.common import max_eqn_aval_elems
from repro.models.moe import moe_decode_forward, moe_decode_rows, moe_forward
from repro.serving import ServingEngine

E, D, F, BITS = 4, 32, 48, 6


def _stacks(seed=0, e=E, d=D, f=F, bits=BITS):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s) * 0.2, jnp.float32)
    return {
        "m.w_gate": quantize_stacked(mk(e, d, f), bits=bits),
        "m.w_up": quantize_stacked(mk(e, d, f), bits=bits),
        "m.w_down": quantize_stacked(mk(e, f, d), bits=bits),
    }, mk(d, e)


def _dense_loop(x, qs, expert_of, b_sel, counts):
    """Per-group materialize + matmul — the grouped kernel's dense oracle."""
    out = []
    for g in range(x.shape[0]):
        e, b, c = int(expert_of[g]), int(b_sel[g]), int(counts[g])
        if b > 0 and c > 0:
            out.append(x[g] @ materialize_stacked(qs, b)[e])
        else:
            out.append(jnp.zeros((x.shape[1], qs.planes.shape[-1])))
    return jnp.stack(out)


# ---------------------------------------------------------------------------
# Kernel level: grouped vs oracle vs dense, elision routings, vmap fold
# ---------------------------------------------------------------------------
TABLES = {
    "mixed": ([0, 1, 1, 3, 2, 0], [4, 0, 2, 6, 1, 3], [3, 2, 0, 5, 1, 2]),
    "empty-experts": ([0, 1, 2, 3], [6, 6, 6, 6], [4, 0, 0, 2]),
    "all-one-expert": ([2, 2, 2, 2], [3, 5, 1, 6], [2, 2, 2, 2]),
    "all-idle": ([0, 1, 2, 3], [0, 0, 0, 0], [1, 1, 1, 1]),
}


@pytest.mark.parametrize("table", sorted(TABLES))
def test_grouped_kernel_parity(table):
    """ref == interpret == per-group dense loop on every routing shape,
    including zero-count experts, idle (0-bit) groups, and every group
    landing on one expert."""
    qs, _ = _stacks()
    expert_of, b_sel, counts = (jnp.asarray(v, jnp.int32)
                                for v in TABLES[table])
    g = expert_of.shape[0]
    x = jnp.asarray(np.random.default_rng(1).normal(size=(g, 3, D)),
                    jnp.float32)
    qsk = qs["m.w_gate"]
    dense = _dense_loop(x, qsk, expert_of, b_sel, counts)
    y_ref = bitserial_matmul_grouped(x, qsk, expert_of, b_sel, counts,
                                     backend="ref")
    np.testing.assert_allclose(y_ref, dense, rtol=1e-4, atol=1e-4)
    y_int = bitserial_matmul_grouped(x, qsk, expert_of, b_sel, counts,
                                     backend="interpret")
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)
    idle = (b_sel == 0) | (counts == 0)
    if bool(jnp.any(idle)):
        assert bool(jnp.all(y_int[np.asarray(idle)] == 0.0))


def test_grouped_kernel_tileable_n():
    """Untileable N pads through pad_overlay_n (asserted above with
    N=48); a tileable N=128 stack runs the kernel unpadded."""
    qs = quantize_stacked(
        jnp.asarray(np.random.default_rng(2).normal(size=(E, D, 128)) * 0.2,
                    jnp.float32), bits=BITS)
    expert_of = jnp.asarray([1, 3, 0], jnp.int32)
    b_sel = jnp.asarray([2, 6, 0], jnp.int32)
    counts = jnp.asarray([2, 1, 4], jnp.int32)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(3, 2, D)),
                    jnp.float32)
    y_int = bitserial_matmul_grouped(x, qs, expert_of, b_sel, counts,
                                     backend="interpret")
    y_ref = bitserial_matmul_grouped(x, qs, expert_of, b_sel, counts,
                                     backend="ref")
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)


def test_grouped_custom_vmap_fold_no_retrace():
    """A vmapped grouped matmul folds the batch axis into the group axis
    (ONE launch), reuses the cached trace across calls, and matches the
    unbatched call row for row."""
    qs, _ = _stacks(seed=4)
    qsk = qs["m.w_up"]
    expert_of = jnp.asarray([0, 1, 2, 3], jnp.int32)
    counts = jnp.asarray([2, 1, 0, 3], jnp.int32)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(4, 2, D)),
                    jnp.float32)
    xb = jnp.stack([x, x * 0.5, x * 2.0])
    bb = jnp.asarray([[4, 0, 2, 6], [1, 1, 1, 1], [6, 6, 6, 6]], jnp.int32)
    cb = jnp.broadcast_to(counts, (3, 4))

    fn = jax.jit(lambda xs, bs, cs: jax.vmap(
        lambda xi, bi, ci: bitserial_matmul_grouped(
            xi, qsk, expert_of, bi, ci, backend="ref"))(xs, bs, cs))
    yb = fn(xb, bb, cb)
    before = dict(TRACE_COUNTS)
    yb2 = fn(xb * 1.5, bb, cb)                    # same shapes: no retrace
    assert dict(TRACE_COUNTS) == before
    assert yb.shape == (3, 4, 2, F)
    for r in range(3):
        y1 = bitserial_matmul_grouped(xb[r], qsk, expert_of, bb[r], cb[r],
                                      backend="ref")
        np.testing.assert_allclose(yb[r], y1, rtol=1e-5, atol=1e-5)
    del yb2


def test_expert_plane_fetches_walks_index_map():
    """Hand-walked cases: busy groups fetch n_tiles * b_sel blocks, idle
    runs pin ONE block, and a busy expert-0 group following an idle run
    reuses the idle pin's (0, 0, 0, 0) first block."""
    # all busy, 2 tiles: straight sum
    assert expert_plane_fetches([0, 1], [3, 2], [1, 1], 2, BITS) == 10
    # idle group pins one block between two busy experts (non-zero ids)
    assert expert_plane_fetches([1, 2, 3], [2, 0, 2], [1, 1, 1], 2,
                                BITS) == 9
    # busy expert 0 right after an idle run: first block already resident
    assert expert_plane_fetches([1, 3, 0], [2, 0, 2], [1, 1, 1], 2,
                                BITS) == 8
    # zero-count groups elide exactly like 0-bit groups
    assert expert_plane_fetches([1, 2], [4, 4], [1, 0], 2, BITS) == \
        expert_plane_fetches([1, 2], [4, 0], [1, 1], 2, BITS)
    # all idle: the pinned block is fetched once, ever
    assert expert_plane_fetches([0, 1, 2], [0, 0, 0], [1, 1, 1], 4,
                                BITS) == 1


# ---------------------------------------------------------------------------
# Layer level: moe_forward / moe_decode_rows grouped vs dense
# ---------------------------------------------------------------------------
class _Lin:
    def __init__(self, ovs, router, bits, grouped, backend="ref"):
        self._ovs, self._router = ovs, router
        self._bits, self._grouped = bits, grouped
        self.backend = backend

    def __call__(self, path, x, **kw):
        return jnp.einsum("...k,kn->...n", x, self._router)

    def weights(self, path, x, **kw):
        b = self._bits if jnp.ndim(self._bits) == 0 else self._bits[0]
        return materialize_stacked(self._ovs[path], b)

    def weights_rows(self, path, x, **kw):
        if jnp.ndim(self._bits) == 0:
            return materialize_stacked(self._ovs[path], self._bits)
        return jax.vmap(
            lambda b: materialize_stacked(self._ovs[path], b))(self._bits)

    def grouped_weights(self, path, x, **kw):
        return (self._ovs[path], self._bits) if self._grouped else None


@pytest.mark.parametrize("kind", ["swiglu", "relu2"])
def test_moe_forward_grouped_vs_dense(kind):
    ovs, router = _stacks(seed=6)
    x = jnp.asarray(np.random.default_rng(7).normal(size=(2, 8, D)),
                    jnp.float32)
    for bits in (BITS, 3, 1):
        args = (kind, ovs, router, x, bits)
        yd, auxd = _fwd(*args, grouped=False)
        yg, auxg = _fwd(*args, grouped=True)
        np.testing.assert_allclose(yg, yd, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(auxg, auxd, rtol=1e-6)


def _fwd(kind, ovs, router, x, bits, *, grouped):
    return moe_forward(kind, _Lin(ovs, router, jnp.int32(bits), grouped),
                       {}, "m", x, num_experts=E, top_k=2, group_size=8)


def test_moe_decode_rows_grouped_vs_dense_per_row_bits():
    """The per-row prefill path: heterogeneous (M,) bits vectors apply
    identically through the grouped kernel and the vmapped dense stack."""
    ovs, router = _stacks(seed=8)
    b, m = 2, 6
    x = jnp.asarray(np.random.default_rng(9).normal(size=(b, m, D)),
                    jnp.float32)
    bits_m = jnp.asarray([6, 3, 6, 1, 4, 6], jnp.int32)
    yd, _ = moe_decode_rows("swiglu", _Lin(ovs, router, bits_m, False), {},
                            "m", x, num_experts=E, top_k=2)
    yg, _ = moe_decode_rows("swiglu", _Lin(ovs, router, bits_m, True), {},
                            "m", x, num_experts=E, top_k=2)
    np.testing.assert_allclose(yg, yd, rtol=1e-4, atol=1e-4)


def test_moe_rows_no_dense_stack_in_trace():
    """Shape capture: the grouped prefill trace never binds the per-row
    ``(M, E, K, N)`` weight stack (on the kernel dispatch, whose
    pallas_call stays one opaque eqn like the TPU lowering), while the
    dense path demonstrably does — the capture sees through the trace."""
    ovs, router = _stacks(seed=10)
    b, m = 2, 8
    stack_elems = m * max(ov.planes.shape[0] * ov.k * ov.planes.shape[-1]
                          for ov in ovs.values())

    def run(grouped, backend, mm):
        xm = jnp.zeros((b, mm, D), jnp.float32)
        bits_m = jnp.full((mm,), BITS, jnp.int32)
        jaxpr = jax.make_jaxpr(lambda a: moe_decode_rows(
            "swiglu", _Lin(ovs, router, bits_m, grouped, backend), {},
            "m", a, num_experts=E, top_k=2))(xm).jaxpr
        return max_eqn_aval_elems(jaxpr)

    assert run(True, "interpret", m) < stack_elems
    assert run(False, "ref", m) >= stack_elems        # positive control
    # grouped peak is activations only: exactly linear in M
    assert run(True, "interpret", 2 * m) == 2 * run(True, "interpret", m)


# ---------------------------------------------------------------------------
# Engine level: grouped vs dense serving across modes / async / chunking
# ---------------------------------------------------------------------------
PREFILL_CHUNK = 8


@pytest.fixture(scope="module")
def moe_engines(tiny_moe_bundle):
    """(grouped, dense) engines: identical but for the MoE apply path."""
    cfg, params, model, _ = tiny_moe_bundle
    grouped = ServingEngine(cfg, params, model,
                            prefill_chunk=PREFILL_CHUNK)
    dense = ServingEngine(cfg, params, model, use_grouped=False,
                          prefill_chunk=PREFILL_CHUNK)
    return grouped, dense


@pytest.mark.parametrize("mode", ["dynamic", "static:llm_mq", "max",
                                  "exact"])
def test_engine_grouped_vs_dense_all_modes(moe_engines, tiny_moe_bundle,
                                           mode):
    """Same tokens AND same per-token effective bits in every mode, for a
    short prompt (one prefill launch) and a long prompt straddling
    prefill chunks (carried decision vector across the boundary)."""
    _, _, _, batches = tiny_moe_bundle
    grouped, dense = moe_engines
    for p in (4, PREFILL_CHUNK + 3):
        prompt = batches[0][0][:1, :p]
        out_d, eb_d = dense.generate(prompt, 5, 3.5, mode=mode)
        out_g, eb_g = grouped.generate(prompt, 5, 3.5, mode=mode)
        assert np.array_equal(out_d, out_g), (mode, p)
        np.testing.assert_allclose(eb_g, eb_d, atol=1e-5)
    toks = batches[0][0][:1, :16]
    nll_d, eb_d = dense.teacher_forced_nll(toks, 3.5, mode=mode)
    nll_g, eb_g = grouped.teacher_forced_nll(toks, 3.5, mode=mode)
    assert abs(nll_d - nll_g) < 1e-4, mode
    np.testing.assert_allclose(eb_g, eb_d, atol=1e-5)


def test_engine_grouped_vs_dense_sync(tiny_moe_bundle):
    """use_async=False: inline same-tick decisions, grouped == dense."""
    cfg, params, model, batches = tiny_moe_bundle
    grouped = ServingEngine(cfg, params, model, use_async=False,
                            prefill_chunk=PREFILL_CHUNK)
    dense = ServingEngine(cfg, params, model, use_async=False,
                          use_grouped=False, prefill_chunk=PREFILL_CHUNK)
    prompt = batches[0][0][:1, :PREFILL_CHUNK + 2]
    out_d, eb_d = dense.generate(prompt, 4, 4.5)
    out_g, eb_g = grouped.generate(prompt, 4, 4.5)
    assert np.array_equal(out_d, out_g)
    np.testing.assert_allclose(eb_g, eb_d, atol=1e-5)


def test_engine_kernel_trace_accounting(moe_engines, tiny_moe_bundle):
    """The grouped dispatch traces once per (bits, backend) the engine
    serves — more targets and prompts reuse the cached custom_vmap fold
    (the kernel-level complement of engine.trace_counts)."""
    _, _, _, batches = tiny_moe_bundle
    grouped, _ = moe_engines
    prompt = batches[0][0][:1, :4]
    grouped.generate(prompt, 4, 3.5)                     # warm
    baseline = grouped.kernel_traces()
    assert baseline.get("grouped", 0) >= 1
    grouped.generate(prompt, 4, 4.5)                     # new target
    grouped.generate(batches[0][0][:1, :3], 4, 3.5)      # new prompt
    assert grouped.kernel_traces() == baseline


def test_engine_grouped_prefill_no_dense_stack(tiny_moe_bundle):
    """Acceptance shape-capture at the ENGINE level: the grouped
    prefill launch never binds a per-row (M, E, K, N) expert stack;
    the dense engine's launch binds one (positive control)."""
    cfg, params, model, _ = tiny_moe_bundle
    from repro.serving import make_prefill_state
    rows = PREFILL_CHUNK
    stacked = [ov for ov in model.overlays.values()
               if ov.planes.ndim == 4]
    assert stacked, "tiny-moe must quantize expert stacks"
    stack_elems = rows * max(ov.planes.shape[0] * ov.k * ov.planes.shape[-1]
                             for ov in stacked)

    def peak(**engine_kw):
        eng = ServingEngine(cfg, params, model,
                            prefill_chunk=rows, **engine_kw)
        run = eng.build_prefill_rows("dynamic", rows, carried=False)
        state = make_prefill_state(cfg, 1, rows, rows)
        toks = jnp.zeros((1, rows), jnp.int32)
        jaxpr = jax.make_jaxpr(
            lambda st, tk: run(st, tk, jnp.int32(0),
                               jnp.int32(rows)))(state, toks).jaxpr
        return max_eqn_aval_elems(jaxpr)

    assert peak(backend="interpret") < stack_elems
    assert peak(use_grouped=False) >= stack_elems
