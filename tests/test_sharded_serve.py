"""Mesh-native serve path: sharded scheduler parity with single-device.

The sharded decode tick must be a *pure placement* change: on a 2×4
('data' × 'model') host mesh the slot scheduler admits the same requests,
decodes bit-identical tokens with identical per-step effective bits, and
reuses one compiled chunk across heterogeneous targets — exactly like the
single-device path. Runs in a subprocess so the forced 8-device host
platform never leaks into the main process (see launch/dryrun.py).
"""
import subprocess
import sys
import textwrap

_N_DEV = 8

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import sys; sys.path.insert(0, "src")
import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_multiscale_model
from repro.models import init_model_params
from repro.serving import (LatencyModel, QoSPlanner, Request,
                           ServingEngine, SlotScheduler)

assert len(jax.devices()) == %d
mesh = jax.make_mesh((2, 4), ("data", "model"))

cfg = get_config("tiny-dense")
params = init_model_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batches = [
    (rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32),
     rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32))
    for _ in range(2)]
model = build_multiscale_model(cfg, params, batches,
                               targets=[3.5, 4.0, 4.5],
                               finetune_epochs=1, baselines=())


def planner(engine):
    # bytes_per_bit spreads these budgets across all three targets
    return QoSPlanner(sorted(model.adaptations),
                      LatencyModel(bytes_per_bit=1e9), chips=1)


def requests(seed_off):
    r = np.random.default_rng(42 + seed_off)
    # admission-time utilization runs 0, .25, .5, .75 on 4 slots; with
    # tpot ~= (1.22*bits + 0.2)ms these budgets plan 4.5, 4.0, 3.5, 3.5
    budgets = [6e-3, 7e-3, 9.5e-3, 1e-3, 6e-3]
    return [Request(rid=seed_off * 10 + i,
                    prompt=r.integers(0, cfg.vocab_size,
                                      (3 + i %% 4,)).astype(np.int32),
                    max_new=4 + i %% 3, tpot_budget_s=b)
            for i, b in enumerate(budgets)]


def serve(engine, wave):
    sched = SlotScheduler(engine, planner(engine), slots=4, max_prompt=8,
                          max_new=6, chunk=4)
    done = {r.rid: r for r in sched.run(requests(wave))}
    return sched, done


single = ServingEngine(cfg, params, model)
sharded = ServingEngine(cfg, params, model, mesh=mesh)

# the sharded engine's serve arrays actually live on the mesh
kinds = {str(v.sharding.spec)
         for v in sharded.raw.values()} | \
        {str(ov.planes.sharding.spec) for ov in sharded.overlays.values()}
assert any("model" in k for k in kinds), kinds

_, done_s = serve(single, 0)
sched_m, done_m = serve(sharded, 0)

# scheduler output parity: bit-identical tokens, identical targets/bits
assert set(done_s) == set(done_m)
targets = {r.target for r in done_m.values()}
assert len(targets) == 3, targets          # genuinely heterogeneous batch
for rid, rs in done_s.items():
    rm = done_m[rid]
    assert rs.target == rm.target, (rid, rs.target, rm.target)
    assert np.array_equal(rs.tokens, rm.tokens), rid
    np.testing.assert_allclose(rs.effective_bits, rm.effective_bits,
                               atol=1e-5)

# no retrace across targets / admission churn on the mesh: a second wave
# of different prompts+budgets reuses the one compiled sharded chunk
baseline = dict(sharded.trace_counts)
sched_m.run(requests(1))
assert sharded.trace_counts == baseline, (baseline,
                                          sharded.trace_counts)

# the batched kernel's b_sel prefetch vector carries the slot axis:
# slots -> 'data' when divisible (per-DP-group precisions), else replicated
from repro.distributed.sharding import slot_prefetch_spec
assert "data" in str(slot_prefetch_spec(mesh, 4)), \
    slot_prefetch_spec(mesh, 4)
assert str(slot_prefetch_spec(mesh, 3)) == "PartitionSpec(None,)", \
    slot_prefetch_spec(mesh, 3)

# fused-scan host-sync invariant holds on the mesh too
n0 = sharded.host_syncs
out_m, bits_m = sharded.generate(
    np.asarray([[5, 7, 11]], np.int32), 6, 4.0)
assert sharded.host_syncs - n0 == 2, sharded.host_syncs
out_s, bits_s = single.generate(
    np.asarray([[5, 7, 11]], np.int32), 6, 4.0)
assert np.array_equal(out_m, out_s)
np.testing.assert_allclose(bits_m, bits_s, atol=1e-5)

# --- prefill/decode disaggregation across mesh slices (PR 5) -------------
# The schedulers above ran prefill-at-admission (engines default to
# prefill_chunk=16): the parity checks already prove the cross-slice
# KV handoff is bit-identical to the single-device path. Pin the
# contract pieces explicitly:
from repro.distributed.sharding import prefill_spec
# the prefill slice leaves 'data' (the decode slot axis) out of every
# KV leaf — the block changes placement once, at the insert handoff
for k, v in sched_m._pf_state.items():
    spec = prefill_spec(mesh, k, v.shape)
    assert "data" not in str(spec), (k, spec)
# admission actually ran the two-stage path on the mesh: prefill
# launches + ONE insert per admitted request, no legacy boot admits
assert sharded.call_counts.get("slot_insert", 0) >= 10  # 2 waves x 5
assert sharded.call_counts.get("slot_prefill", 0) >= 10
assert ("slot_admit", "dynamic") not in sharded.trace_counts
# the insert step (prefill specs in -> slot specs out) compiled ONCE
assert sharded.trace_counts.get(("slot_insert", "dynamic")) == 1
# a long prompt spanning multiple prefill chunks on the mesh matches
# the single-device engine bit for bit (multi-launch carried prefill)
long_prompt = np.arange(1, 20, dtype=np.int32)[None, :]
out_m, bits_m = sharded.generate(long_prompt, 5, 4.0)
out_s, bits_s = single.generate(long_prompt, 5, 4.0)
assert np.array_equal(out_m, out_s)
np.testing.assert_allclose(bits_m, bits_s, atol=1e-5)
assert sharded.call_counts.get("prefill", 0) >= 2   # ceil(19/16) + warm

# --- speculative decode on the mesh (PR 6) -------------------------------
# verify rows ride the kernel's slot axis: the (slots, k) verify batch
# shards slots -> 'data' when divisible and NEVER shards the window axis
from repro.distributed.sharding import verify_batch_spec
assert "data" in str(verify_batch_spec(mesh, 4, 3)), \
    verify_batch_spec(mesh, 4, 3)
assert str(verify_batch_spec(mesh, 3, 2)) == "PartitionSpec(None, None)", \
    verify_batch_spec(mesh, 3, 2)

# spec_k scheduler on the mesh == plain single-device scheduler: same
# tokens, same per-step bits, per-slot accept/reject under the 'data'
# sharding (variable accepted lengths across slots in one chunk)
sched_k = SlotScheduler(sharded, planner(sharded), slots=4, max_prompt=8,
                        max_new=6, chunk=4, spec_k=2)
done_k = {r.rid: r for r in sched_k.run(requests(0))}
assert set(done_k) == set(done_s)
for rid, rs in done_s.items():
    rk = done_k[rid]
    assert rs.target == rk.target, (rid, rs.target, rk.target)
    assert np.array_equal(rs.tokens, rk.tokens), rid
    np.testing.assert_allclose(rs.effective_bits, rk.effective_bits,
                               atol=1e-5)
assert sched_k.spec_windows > 0

# spec generate parity on the mesh with the O(1) host-sync invariant
out_b, bits_b = sharded.generate(
    np.asarray([[5, 7, 11]], np.int32), 6, 4.0)
n0 = sharded.host_syncs
out_k, bits_k = sharded.generate(
    np.asarray([[5, 7, 11]], np.int32), 6, 4.0, spec_k=2)
assert sharded.host_syncs - n0 == 2, sharded.host_syncs
assert np.array_equal(out_k, out_b)
np.testing.assert_allclose(bits_k, bits_b, atol=1e-5)

# --- dynamic-precision KV cache on the mesh (PR 8) -----------------------
# plane stacks keep the plane axis UNSHARDED everywhere (reads slice a
# plane prefix; splitting it would turn the prefix read into a gather),
# heads follow the dense KV_HEADS rule, slots follow 'data'
from repro.distributed.sharding import (decode_state_spec, prefill_spec,
                                        slot_state_spec)
from repro.serving import make_decode_state
ov_state = make_decode_state(cfg, 2, 32, kv_format="overlay")
for k, v in ov_state.items():
    if not k.endswith("_planes"):
        continue
    dspec = decode_state_spec(mesh, k, v.shape)
    assert dspec[1] is None, (k, dspec)            # plane axis whole
    pspec = prefill_spec(mesh, k, v.shape)
    assert pspec[1] is None and "data" not in str(pspec), (k, pspec)
    sspec = slot_state_spec(mesh, k, (4,) + v.shape)
    assert sspec[2] is None, (k, sspec)            # plane axis whole
    assert sspec[0] in ("data", None), (k, sspec)

# overlay engine on the mesh == overlay engine on one device: full-stack
# (kv_dynamic=False) plane reads are bit-identical across placements,
# including a prompt straddling the prefill chunk (KV handoff on planes)
ov_single = ServingEngine(cfg, params, model, kv_overlay=True,
                          kv_dynamic=False)
ov_sharded = ServingEngine(cfg, params, model, mesh=mesh, kv_overlay=True,
                           kv_dynamic=False)
for prompt in [np.asarray([[5, 7, 11]], np.int32),
               np.arange(1, 20, dtype=np.int32)[None, :]]:
    out_s, bits_s = ov_single.generate(prompt, 4, 4.0)
    out_m, bits_m = ov_sharded.generate(prompt, 4, 4.0)
    assert np.array_equal(out_s, out_m)
    np.testing.assert_allclose(bits_s, bits_m, atol=1e-5)

# planner-assigned KV read bits on the mesh: the dynamic-KV engine runs
# with the O(1) host-sync invariant intact and the KV rows riding the
# one fused planner launch (bundle grew past the weight rows)
ov_dyn = ServingEngine(cfg, params, model, mesh=mesh, kv_overlay=True)
assert ov_dyn.artifacts.decision.weight_units < \
    ov_dyn.artifacts.decision.n_units
n0 = ov_dyn.host_syncs
out_d, bits_d = ov_dyn.generate(np.asarray([[5, 7, 11]], np.int32), 5, 4.0)
assert ov_dyn.host_syncs - n0 == 2, ov_dyn.host_syncs
assert out_d.shape == (1, 8) and np.all(np.isfinite(bits_d))

# --- paged bitplane-KV pool on the mesh (PR 9) ---------------------------
# the shared plane pool REPLICATES its page axis over 'data' (any slot's
# table may point at any page) while heads keep the KV_HEADS rule and
# the plane axis stays whole; page tables ride the slot axis like any
# per-slot vector
from repro.distributed.sharding import page_table_spec, paged_pool_spec
pspec = paged_pool_spec(mesh, "pool.0.k_planes", (9, 8, 4, 2, 1))
assert pspec[0] is None and pspec[1] is None, pspec    # pages + planes
sspec = paged_pool_spec(mesh, "pool.0.k_scale", (9, 4, 2, 1))
assert sspec[0] is None, sspec
assert "data" in str(page_table_spec(mesh, (4, 4))), \
    page_table_spec(mesh, (4, 4))
assert str(page_table_spec(mesh, (3, 4))) == \
    "PartitionSpec(None, None)", page_table_spec(mesh, (3, 4))

# paged scheduler on the mesh == bucketed scheduler on the mesh: the
# page indirection is a pure placement/layout change even under GSPMD —
# bit-identical tokens, per-step bits, and admitted targets
def serve_kv(paged):
    kw = dict(slots=4, max_prompt=8, max_new=6, chunk=4)
    if paged:
        kw.update(paged=True, page_len=4)
    sched = SlotScheduler(ov_dyn, planner(ov_dyn), **kw)
    return {r.rid: r for r in sched.run(requests(0))}

done_b = serve_kv(False)
done_p = serve_kv(True)
assert set(done_b) == set(done_p)
for rid, rb in done_b.items():
    rp = done_p[rid]
    assert rb.target == rp.target, (rid, rb.target, rp.target)
    assert np.array_equal(rb.tokens, rp.tokens), rid
    np.testing.assert_allclose(rb.effective_bits, rp.effective_bits,
                               atol=1e-5)
print("sharded-serve-ok")
""" % (_N_DEV, _N_DEV)


def test_sharded_scheduler_parity_and_no_retrace():
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_BODY)],
                       capture_output=True, text=True, cwd=".",
                       timeout=1500)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "sharded-serve-ok" in r.stdout


_MOE_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import sys; sys.path.insert(0, "src")
import jax
import numpy as np

from repro.configs import get_config
from repro.core import build_multiscale_model
from repro.models import init_model_params
from repro.serving import ServingEngine

assert len(jax.devices()) == %d
mesh = jax.make_mesh((2, 4), ("data", "model"))

cfg = get_config("tiny-moe")
params = init_model_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batches = [
    (rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32),
     rng.integers(0, cfg.vocab_size, (2, 48)).astype(np.int32))
    for _ in range(2)]
model = build_multiscale_model(cfg, params, batches, targets=[3.5, 4.5],
                               finetune_epochs=1, baselines=())

single = ServingEngine(cfg, params, model)
sharded = ServingEngine(cfg, params, model, mesh=mesh)

# --- expert parallelism survives the grouped kernel (PR 7) ---------------
# the expert stacks (E, B, kw, N) land on the mesh with E -> 'model':
# tiny-moe's E=8 divides 'model'=4, so each model-group holds 2 experts
stacked = [ov for ov in sharded.overlays.values() if ov.planes.ndim == 4]
assert stacked, "tiny-moe build produced no stacked expert overlays"
assert all("model" in str(ov.planes.sharding.spec) for ov in stacked), \\
    {str(ov.planes.sharding.spec) for ov in stacked}

# the grouped kernel's flat G axis follows the SAME rule (EXPERTS):
# expert-major groups shard over 'model' when divisible, else replicate
from repro.distributed.sharding import expert_group_spec
assert "model" in str(expert_group_spec(mesh, (8, 4, 32))), \\
    expert_group_spec(mesh, (8, 4, 32))
assert "model" in str(expert_group_spec(mesh, (8,))), \\
    expert_group_spec(mesh, (8,))
assert str(expert_group_spec(mesh, (6, 4, 32))) == \\
    "PartitionSpec(None, None, None)", expert_group_spec(mesh, (6, 4, 32))

# EP parity: the mesh placement changes nothing — bit-identical tokens
# and per-step effective bits vs the single-device grouped engine, for
# both the dynamic controller and the fixed-max mode, with a prompt
# straddling the default prefill chunk (16) to cross the KV handoff
from repro.kernels.bitserial import TRACE_COUNTS
for prompt, mode, target in [
        (np.asarray([[5, 7, 11, 13]], np.int32), "dynamic", 3.5),
        (np.arange(1, 20, dtype=np.int32)[None, :], "max", 4.5)]:
    out_s, bits_s = single.generate(prompt, 4, target, mode=mode)
    out_m, bits_m = sharded.generate(prompt, 4, target, mode=mode)
    assert np.array_equal(out_s, out_m), (mode, out_s, out_m)
    np.testing.assert_allclose(bits_s, bits_m, atol=1e-5)

# both engines actually took the grouped dispatch (never the dense
# (M, E, K, N) materialization) on this process's kernel trace counter
assert TRACE_COUNTS.get("grouped", 0) > 0, dict(TRACE_COUNTS)
print("sharded-moe-ok")
""" % (_N_DEV, _N_DEV)


def test_sharded_moe_expert_parallel_grouped_parity():
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_MOE_BODY)],
                       capture_output=True, text=True, cwd=".",
                       timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "sharded-moe-ok" in r.stdout
