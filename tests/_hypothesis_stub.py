"""Minimal deterministic stand-in for the ``hypothesis`` API surface used
by this test suite (``given`` / ``settings`` / ``strategies.integers`` /
``strategies.floats``).

The real dependency is declared in requirements.txt and is preferred when
installed; this fallback keeps the property tests *running* (boundary
values + seeded uniform draws per example) in hermetic environments where
it is not. Wired up by tests/conftest.py before test collection.
"""
from __future__ import annotations

import random
import zlib

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng, edge: (
        min_value if edge == 0 else max_value if edge == 1
        else rng.randint(min_value, max_value)))


def _floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng, edge: (
        min_value if edge == 0 else max_value if edge == 1
        else rng.uniform(min_value, max_value)))


class strategies:
    integers = staticmethod(_integers)
    floats = staticmethod(_floats)


def given(*strats: _Strategy):
    def deco(fn):
        # nullary wrapper; deliberately NOT functools.wraps — pytest must
        # see a no-argument signature, not the wrapped (fixture-like) one
        def wrapper():
            n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(n):
                edge = i if i < 2 else -1   # first two: boundary examples
                fn(*[s.draw(rng, edge) for s in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
