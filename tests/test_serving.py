"""Serving engine + QoS: dynamic decode, effective bits, percentiles."""
import numpy as np
import pytest

from repro.serving import (LatencyModel, QoSPlanner, QueryBitTracker,
                           ServingEngine)


@pytest.fixture(scope="module")
def engine(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    return ServingEngine(cfg, params, model)


def test_dynamic_effective_bits_near_target(engine, tiny_bundle):
    _, _, model, batches = tiny_bundle
    toks = batches[0][0][:1, :32]
    _, ebits = engine.teacher_forced_nll(toks, 3.5)
    assert 3.0 <= np.mean(ebits) <= 4.6
    # per-step decisions actually vary (the paper's core observation)
    assert len(set(np.round(ebits, 3))) > 3


def test_static_vs_dynamic_both_run(engine, tiny_bundle):
    _, _, model, batches = tiny_bundle
    toks = batches[0][0][:1, :16]
    nll_d, _ = engine.teacher_forced_nll(toks, 3.5)
    nll_s, eb_s = engine.teacher_forced_nll(toks, 3.5, mode="static:llm_mq")
    assert np.isfinite(nll_d) and np.isfinite(nll_s)
    assert np.allclose(np.std(eb_s), 0.0)    # static never varies


def test_exact_estimator_mode(engine, tiny_bundle):
    _, _, model, batches = tiny_bundle
    toks = batches[0][0][:1, :16]
    nll_e, _ = engine.teacher_forced_nll(toks, 3.5, mode="exact")
    assert np.isfinite(nll_e)


def test_generate_shapes(engine, tiny_bundle):
    cfg, _, _, batches = tiny_bundle
    out, ebits = engine.generate(batches[0][0][:1, :4], 5, 3.5)
    assert out.shape == (1, 9)
    assert len(ebits) == 5
    assert np.all(out < cfg.vocab_size)


def test_overlay_memory_budget(engine, tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    # overlays truncated to Phase-1 max bits: <= budget/8 bytes per param
    from repro.models import linear_units
    unit_params = sum(int(np.prod(params[u.path].shape))
                      for u in linear_units(cfg))
    budget_bytes = unit_params * model.memory_budget_bits / 8
    # packed int32 padding allows some slack
    assert engine.overlay_bytes() <= budget_bytes * 1.3


def test_qos_planner_monotone():
    lat = LatencyModel(bytes_per_bit=1e9)
    pl = QoSPlanner([3.0, 4.0, 5.0, 6.0], lat, chips=1)
    p_loose = pl.plan(1.0)
    p_tight = pl.plan(3e-3)
    assert p_loose >= p_tight
    assert pl.plan(1e-9) == 3.0      # infeasible -> min precision


def test_query_bit_tracker_percentiles():
    tr = QueryBitTracker()
    rng = np.random.default_rng(0)
    for _ in range(200):
        tr.record_query(rng.normal(3.5, 0.05, size=50))
    s = tr.summary()
    assert 0 <= s["p90_increase"] < 0.1
    assert s["p99_increase"] >= s["p90_increase"]
