"""Serving engine + QoS: dynamic decode, effective bits, percentiles."""
import numpy as np
import pytest

from repro.serving import (LatencyModel, QoSPlanner, QueryBitTracker,
                           ServingEngine)


@pytest.fixture(scope="module")
def engine(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    return ServingEngine(cfg, params, model)


def test_dynamic_effective_bits_near_target(engine, tiny_bundle):
    _, _, model, batches = tiny_bundle
    toks = batches[0][0][:1, :32]
    _, ebits = engine.teacher_forced_nll(toks, 3.5)
    assert 3.0 <= np.mean(ebits) <= 4.6
    # per-step decisions actually vary (the paper's core observation)
    assert len(set(np.round(ebits, 3))) > 3


def test_static_vs_dynamic_both_run(engine, tiny_bundle):
    _, _, model, batches = tiny_bundle
    toks = batches[0][0][:1, :16]
    nll_d, _ = engine.teacher_forced_nll(toks, 3.5)
    nll_s, eb_s = engine.teacher_forced_nll(toks, 3.5, mode="static:llm_mq")
    assert np.isfinite(nll_d) and np.isfinite(nll_s)
    assert np.allclose(np.std(eb_s), 0.0)    # static never varies


def test_exact_estimator_mode(engine, tiny_bundle):
    _, _, model, batches = tiny_bundle
    toks = batches[0][0][:1, :16]
    nll_e, _ = engine.teacher_forced_nll(toks, 3.5, mode="exact")
    assert np.isfinite(nll_e)


def test_generate_shapes(engine, tiny_bundle):
    cfg, _, _, batches = tiny_bundle
    out, ebits = engine.generate(batches[0][0][:1, :4], 5, 3.5)
    assert out.shape == (1, 9)
    assert len(ebits) == 5
    assert np.all(out < cfg.vocab_size)


def test_overlay_memory_budget(engine, tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    # overlays truncated to Phase-1 max bits: <= budget/8 bytes per param
    from repro.models import linear_units
    unit_params = sum(int(np.prod(params[u.path].shape))
                      for u in linear_units(cfg))
    budget_bytes = unit_params * model.memory_budget_bits / 8
    # packed int32 padding allows some slack
    assert engine.overlay_bytes() <= budget_bytes * 1.3


def test_qos_planner_monotone():
    lat = LatencyModel(bytes_per_bit=1e9)
    pl = QoSPlanner([3.0, 4.0, 5.0, 6.0], lat, chips=1)
    p_loose = pl.plan(1.0)
    p_tight = pl.plan(3e-3)
    assert p_loose >= p_tight
    assert pl.plan(1e-9) == 3.0      # infeasible -> min precision


def test_query_bit_tracker_empty_and_zero_mean():
    """Empty / degenerate trackers report cleanly — no NaN, no numpy
    RuntimeWarning, no crash."""
    import warnings

    tr = QueryBitTracker()
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # any warning -> failure
        assert tr.summary() == {}
        assert tr.percentile_increase(99) == 0.0
        tr.record_query([])                      # empty query is a no-op
        assert tr.summary() == {}
        tr.record_query([0.0, 0.0])              # zero-mean: defined as 0
        assert tr.percentile_increase(99) == 0.0
        s = tr.summary()
    assert s["mean"] == 0.0 and np.isfinite(s["p99_increase"])


def test_query_bit_tracker_percentiles():
    tr = QueryBitTracker()
    rng = np.random.default_rng(0)
    for _ in range(200):
        tr.record_query(rng.normal(3.5, 0.05, size=50))
    s = tr.summary()
    assert 0 <= s["p90_increase"] < 0.1
    assert s["p99_increase"] >= s["p90_increase"]


# ---------------------------------------------------------------------------
# Fused-scan decode: parity, no-retrace, O(1) host syncs
# ---------------------------------------------------------------------------
def test_scan_decode_matches_stepwise(engine, tiny_bundle):
    """Fused chunked-scan generate == token-by-token loop over get_step:
    identical tokens AND identical per-step effective bits, where
    ``ebits[i]`` is the bits of the tick that PRODUCED generated token i
    (the first generated token comes out of the last prompt-consuming
    tick)."""
    import jax.numpy as jnp
    from repro.serving import make_decode_state

    cfg, _, _, batches = tiny_bundle
    prompt = batches[0][0][:1, :4]
    max_new = 6
    out, ebits = engine.generate(prompt, max_new, 3.5)

    step = engine.get_step(3.5)
    state = make_decode_state(cfg, 1, prompt.shape[1] + max_new + 1,
                              dtype=jnp.float32)
    toks = jnp.asarray(prompt)
    for t in range(prompt.shape[1]):
        logits, state, eb_last = step(state, toks[:, t:t + 1])
    cur = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1)
    ref_toks, ref_ebits = [], []
    for _ in range(max_new):
        # eb_last belongs to the tick that produced ``cur``
        ref_toks.append(int(cur[0, 0]))
        ref_ebits.append(float(eb_last))
        logits, state, eb_last = step(state, cur)
        cur = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1)
    assert list(out[0, prompt.shape[1]:]) == ref_toks
    np.testing.assert_allclose(ebits, ref_ebits, atol=1e-5)


def test_generate_bits_align_with_teacher_forcing(engine, tiny_bundle):
    """Feeding generate()'s own output back through teacher forcing drives
    the exact same tick stream, so the per-token bits must line up: token
    p+i was produced by tick p-1+i. A one-tick-late slice would miss the
    first generated token's bits and report the final, discarded tick."""
    _, _, _, batches = tiny_bundle
    prompt = batches[0][0][:1, :4]
    p, max_new = prompt.shape[1], 6
    out, gen_ebits = engine.generate(prompt, max_new, 3.5)
    _, tf_ebits = engine.teacher_forced_nll(out, 3.5)
    np.testing.assert_allclose(
        gen_ebits, tf_ebits[p - 1:p - 1 + max_new], atol=1e-5)


def test_no_retrace_across_targets(engine, tiny_bundle):
    """One compiled decode step serves >= 3 targets: switching the target
    index never triggers a retrace of the fused chunk or the tick."""
    _, _, model, batches = tiny_bundle
    targets = sorted(model.adaptations)
    assert len(targets) >= 3
    prompt = batches[0][0][:1, :4]
    engine.generate(prompt, 5, targets[0])          # warm both chunk
    engine.teacher_forced_nll(batches[0][0][:1, :12], targets[0])  # variants
    baseline = dict(engine.trace_counts)
    for t in targets:
        engine.generate(prompt, 5, t)
        engine.teacher_forced_nll(batches[0][0][:1, :12], t)
    assert engine.trace_counts == baseline, (baseline, engine.trace_counts)


def test_generate_host_syncs_constant(engine, tiny_bundle, monkeypatch):
    """O(1) device->host transfer points per query, independent of length.

    Measured, not self-reported: count actual np.asarray conversions of
    device arrays during the call (the engine's own ``host_syncs`` counter
    is asserted against the same invariant as a consistency check)."""
    import jax

    _, _, _, batches = tiny_bundle
    prompt = batches[0][0][:1, :4]
    real_asarray = np.asarray
    measured = {"n": 0}

    def counting_asarray(a, *args, **kw):
        if isinstance(a, jax.Array):
            measured["n"] += 1
        return real_asarray(a, *args, **kw)

    monkeypatch.setattr(np, "asarray", counting_asarray)

    def syncs_for(max_new):
        measured["n"] = 0
        before = engine.host_syncs
        engine.generate(prompt, max_new, 3.5)
        return measured["n"], engine.host_syncs - before

    short, long = syncs_for(4), syncs_for(16)
    assert short == long, (short, long)       # independent of query length
    assert long[1] <= 2

    measured["n"] = 0
    before = engine.host_syncs
    engine.teacher_forced_nll(batches[0][0][:1, :24], 3.5)
    n24 = measured["n"]
    measured["n"] = 0
    engine.teacher_forced_nll(batches[0][0][:1, :12], 3.5)
    assert measured["n"] == n24               # ditto for teacher forcing
    assert engine.host_syncs - before == 2
