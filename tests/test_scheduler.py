"""Slot-based continuous batching: QoS admission, retirement, parity."""
import numpy as np
import pytest

from repro.serving import (LatencyModel, QoSPlanner, QueryBitTracker,
                           Request, ServingEngine, SlotScheduler)


@pytest.fixture(scope="module")
def engine(tiny_bundle):
    cfg, params, model, _ = tiny_bundle
    return ServingEngine(cfg, params, model)


def _planner(model):
    # bytes_per_bit chosen so the target axis actually splits budgets:
    # tpot(3.5)≈4.5ms, tpot(4.0)≈5.1ms, tpot(4.5)≈5.7ms
    return QoSPlanner(sorted(model.adaptations),
                      LatencyModel(bytes_per_bit=1e9), chips=1)


def test_scheduler_mixed_budgets(engine, tiny_bundle):
    cfg, _, model, batches = tiny_bundle
    tracker = QueryBitTracker()
    sched = SlotScheduler(engine, _planner(model), slots=2, max_prompt=8,
                          max_new=6, chunk=4, tracker=tracker)
    rng = np.random.default_rng(1)
    budgets = [6e-3, 5.2e-3, 4.6e-3, 1e-3, 6e-3]
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (3 + i % 4,)).astype(np.int32),
                    max_new=4 + i % 3, tpot_budget_s=b)
            for i, b in enumerate(budgets)]
    done = sched.run(reqs)

    # every request completes and every slot retires
    assert len(done) == len(reqs)
    assert all(s.request is None for s in sched._slots)

    by_rid = {r.rid: r for r in done}
    # per-request target assignment follows the budget (tight -> lower)
    assert by_rid[0].target == 4.5        # loose budget, empty slots
    assert by_rid[2].target == 3.5
    assert by_rid[3].target == 3.5        # infeasible -> min precision
    # mid budget: 4.0 on empty slots, 3.5 under load — never the max
    assert by_rid[1].target in (3.5, 4.0)
    # completions carry prompt + max_new tokens and per-step eff bits
    for r in done:
        p = len(np.asarray(r.prompt).reshape(-1))
        assert r.tokens.shape == (p + r.max_new,)
        assert np.array_equal(r.tokens[:p], np.asarray(r.prompt))
        assert r.effective_bits.shape == (r.max_new,)
        assert np.all((2.0 <= r.effective_bits)
                      & (r.effective_bits <= 6.0))
    # the tracker saw one entry per request
    assert len(tracker.per_query_bits) == len(reqs)


def test_scheduler_matches_engine_generate(engine, tiny_bundle):
    """A slot decoding next to others with different targets produces the
    same tokens and effective bits as a solo engine.generate run."""
    cfg, _, model, _ = tiny_bundle
    sched = SlotScheduler(engine, _planner(model), slots=3, max_prompt=8,
                          max_new=5, chunk=4)
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (4,)).astype(np.int32),
                    max_new=5, tpot_budget_s=b)
            for i, b in enumerate([6e-3, 4.6e-3, 1e-3])]
    done = {r.rid: r for r in sched.run(reqs)}
    targets = {r.target for r in done.values()}
    assert len(targets) >= 2               # genuinely heterogeneous batch
    for r in done.values():
        out, ebits = engine.generate(r.prompt[None, :], r.max_new, r.target)
        assert np.array_equal(out[0], r.tokens)
        np.testing.assert_allclose(ebits, r.effective_bits, atol=1e-5)


def test_scheduler_idle_slots_inert_and_bits_aligned(engine, tiny_bundle):
    """One request surrounded by permanently idle slots: the idle slots
    run at b_sel = 0 (zero plane traffic in the batched kernel) and must
    be completely inert — the busy slot decodes exactly like a solo
    engine.generate run. Its effective bits line up with teacher-forcing
    the generated sequence: bits[i] is the tick that PRODUCED token i
    (engine-vs-scheduler parity for the corrected alignment)."""
    cfg, _, model, _ = tiny_bundle
    sched = SlotScheduler(engine, _planner(model), slots=4, max_prompt=8,
                          max_new=5, chunk=4)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    req = Request(rid=0, prompt=prompt, max_new=5, tpot_budget_s=6e-3)
    done = sched.run([req])[0]
    assert sum(1 for s in sched._slots if s.request is not None) == 0

    out, ebits = engine.generate(prompt[None, :], 5, done.target)
    assert np.array_equal(out[0], done.tokens)
    np.testing.assert_allclose(ebits, done.effective_bits, atol=1e-5)

    p = len(prompt)
    _, tf_ebits = engine.teacher_forced_nll(done.tokens[None, :],
                                            done.target)
    np.testing.assert_allclose(done.effective_bits,
                               tf_ebits[p - 1:p - 1 + 5], atol=1e-5)


def test_scheduler_no_retrace_after_warmup(engine, tiny_bundle):
    """Admission/retirement churn reuses the one compiled chunk."""
    cfg, _, model, _ = tiny_bundle
    sched = SlotScheduler(engine, _planner(model), slots=2, max_prompt=8,
                          max_new=4, chunk=4)
    rng = np.random.default_rng(3)

    def batch(n, seed_off):
        return [Request(rid=seed_off * 10 + i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            (3 + i % 3,)).astype(np.int32),
                        max_new=3 + i % 2,
                        tpot_budget_s=float(rng.uniform(1e-3, 6e-3)))
                for i in range(n)]

    sched.run(batch(2, 1))                 # warm the compile
    baseline = dict(engine.trace_counts)
    sched.run(batch(3, 2))                 # new shapes of work, same chunk
    assert engine.trace_counts == baseline
