"""Scan-over-layers path ≡ per-layer loop path (forward + decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, forward, init_decode_state,
                          init_model_params)
from repro.models.stacked import (decode_step_stacked, forward_stacked,
                                  group_size, stack_decode_state,
                                  stack_params)

FAMS = ["tiny-dense", "tiny-sqrelu", "tiny-moe", "tiny-ssm", "tiny-hybrid"]


@pytest.mark.parametrize("name", FAMS)
def test_forward_equivalence(name):
    cfg = get_config(name)
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    glob, stacked = stack_params(cfg, params)
    l1, _ = forward(cfg, params, toks, moe_capacity_factor=8.0)
    l2, _ = forward_stacked(cfg, glob, stacked, toks, remat=False,
                            moe_capacity_factor=8.0)
    np.testing.assert_allclose(l1, l2, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("name", FAMS)
def test_decode_equivalence(name):
    cfg = get_config(name)
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0,
                              cfg.vocab_size)
    glob, stacked = stack_params(cfg, params)
    st = init_decode_state(cfg, 2, 8, dtype=jnp.float32)
    cache = stack_decode_state(cfg, st)
    pos = jnp.int32(0)
    for t in range(3):
        lg1, st = decode_step(cfg, params, st, toks[:, t:t + 1])
        lg2, cache, pos, _ = decode_step_stacked(
            cfg, glob, stacked, cache, pos, toks[:, t:t + 1])
        np.testing.assert_allclose(lg1, lg2, rtol=3e-4, atol=3e-4)


def test_group_sizes():
    assert group_size(get_config("llama3-8b")) == 1
    assert group_size(get_config("jamba-1.5-large-398b")) == 8
    assert group_size(get_config("tiny-hybrid")) == 4


def test_remat_matches_no_remat():
    cfg = get_config("tiny-dense")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                              cfg.vocab_size)
    glob, stacked = stack_params(cfg, params)
    l1, _ = forward_stacked(cfg, glob, stacked, toks, remat=False)
    l2, _ = forward_stacked(cfg, glob, stacked, toks, remat=True)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)
