"""Distributed runtime: sharding rules, compression, GPipe, elastic.

Multi-device behaviour runs in subprocesses with
``--xla_force_host_platform_device_count`` (the main process must keep the
single real device — see launch/dryrun.py for why).
"""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               SimulatedFailure,
                                               StragglerMitigator,
                                               run_with_restarts)


def _run_multidev(code: str, n_dev: int = 8):
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_dev}"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """)
    r = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                       capture_output=True, text=True, cwd=".",
                       timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


# ---------------------------------------------------------------------------
# Sharding rules (single-process: rules are pure functions of shapes)
# ---------------------------------------------------------------------------
def test_resolve_spec_divisibility_fallback():
    code = """
        from repro.distributed import resolve_spec, TRAIN_RULES
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        # kv_heads=3 not divisible by model=2 -> replicated
        s = resolve_spec((8, 3), ("embed", "kv_heads"), mesh, TRAIN_RULES)
        assert s == P(("pod", "data"), None), s
        # moe expert tensor: experts get EP, ffn must NOT reuse 'model'
        s = resolve_spec((4, 8, 6), ("experts", "embed", "ffn"), mesh,
                         TRAIN_RULES)
        assert s[0] == "model" and s[2] is None, s
        print("ok")
    """
    assert "ok" in _run_multidev(code)


def test_kv_cache_spec_long_context_spill():
    code = """
        from repro.distributed import kv_cache_spec
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        # batch=1 long context: seq takes every axis
        s = kv_cache_spec(mesh, 1, 64, 3)
        assert s[0] is None and s[1] == ("model", "pod", "data"), s
        print("ok")
    """
    assert "ok" in _run_multidev(code)


def test_compressed_allreduce_accuracy_and_feedback():
    code = """
        from jax.experimental.shard_map import shard_map
        from repro.distributed import (compressed_allreduce_shard,
                                       residual_shape)
        n = 8
        g = jax.random.normal(jax.random.PRNGKey(0), (n, 3000))
        res = jnp.zeros((n,) + residual_shape(3000, n))
        mesh = Mesh(np.array(jax.devices()), ("data",))
        fn = shard_map(
            lambda gg, rr: compressed_allreduce_shard(
                gg[0], rr[0], axis="data"),
            mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P(), P("data")), check_rep=False)
        mean_c, res1 = fn(g, res)
        true = jnp.mean(g, axis=0)
        rel = float(jnp.max(jnp.abs(mean_c - true)) /
                    jnp.max(jnp.abs(true)))
        assert rel < 0.02, rel
        # error feedback: running the same grads again corrects the bias
        mean2, _ = fn(g, res1.reshape(n, -1))
        err1 = float(jnp.mean(jnp.abs(mean_c - true)))
        both = 0.5 * (mean_c + mean2)
        err2 = float(jnp.mean(jnp.abs(both - true)))
        assert err2 < err1, (err1, err2)
        print("ok")
    """
    assert "ok" in _run_multidev(code)


def test_gpipe_matches_sequential():
    code = """
        from repro.distributed.pipeline_par import gpipe_forward
        S = 4                      # stages = fake pods
        mesh = Mesh(np.array(jax.devices()[:S]), ("pod",))
        d = 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 2, 3, d))

        def stage_fn(w, h, stage):
            return jnp.tanh(h @ w["w"])

        out = gpipe_forward(stage_fn, {"w": ws}, x, mesh, axis="pod")
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("ok")
    """
    assert "ok" in _run_multidev(code, n_dev=4)


def test_elastic_cross_mesh_restore():
    code = """
        import tempfile
        from repro.checkpoint import Checkpointer
        from repro.distributed import best_mesh, param_shardings
        devs = jax.devices()
        m8 = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
        x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                           NamedSharding(m8, P("data", "model")))
        with tempfile.TemporaryDirectory() as td:
            ck = Checkpointer(td, async_save=False)
            ck.save(5, {"x": x})
            # restore onto a SMALLER mesh (node loss) with new sharding
            m4 = best_mesh(4, model_parallel=2)
            sh = NamedSharding(m4, P("data", "model"))
            tree, step = ck.restore({"x": x}, shardings={"x": sh})
            assert step == 5
            np.testing.assert_allclose(np.asarray(tree["x"]),
                                       np.arange(32.0).reshape(8, 4))
            assert tree["x"].sharding.mesh.devices.size == 4
        print("ok")
    """
    assert "ok" in _run_multidev(code)


# ---------------------------------------------------------------------------
# Fault tolerance control logic (pure python)
# ---------------------------------------------------------------------------
def test_heartbeat_detects_dead_worker():
    t = {"now": 0.0}
    hb = HeartbeatMonitor(num_workers=3, timeout_s=10,
                          clock=lambda: t["now"])
    for w in range(3):
        hb.beat(w)
    t["now"] = 5.0
    hb.beat(0); hb.beat(1)
    assert hb.healthy()
    t["now"] = 12.0
    assert hb.dead_workers() == [2]


def test_straggler_flags_slow_steps():
    sm = StragglerMitigator(threshold=2.0)
    flags = [sm.observe(i, d) for i, d in
             enumerate([1.0, 1.1, 0.9, 5.0, 1.0])]
    assert flags == [False, False, False, True, False]
    assert sm.events[0]["step"] == 3


def test_run_with_restarts_resumes():
    calls = []
    checkpointed = [0]

    def restore():
        return checkpointed[0]

    def train(start):
        for s in range(start, 10):
            calls.append(s)
            if s % 3 == 0:
                checkpointed[0] = s    # "checkpoint" every 3 steps
            if s == 4 and calls.count(4) == 1:
                raise SimulatedFailure("boom")
        return 10

    assert run_with_restarts(train, restore_fn=restore,
                             max_restarts=2) == 10
    assert calls.count(4) == 2      # replayed from checkpoint at 3


def test_run_with_restarts_gives_up():
    def train(start):
        raise SimulatedFailure("always")

    with pytest.raises(RuntimeError):
        run_with_restarts(train, restore_fn=lambda: 0, max_restarts=1)
