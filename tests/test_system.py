"""End-to-end behaviour of the paper's system.

The full story on one tiny model: quantize once -> build the adaptation set
(Phases 1-3 + estimators) -> serve with per-step dynamic layer-wise
precision -> behaviour matches the paper's claims in-kind:
 - effective bitwidth tracks the target precision,
 - the dynamic path is at least as good as uniform static at equal bits,
 - the exact-error selector upper-bounds the approximate one (Table 3),
 - fault-injected training resumes losslessly from checkpoints.
"""
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.serving import ServingEngine


def test_end_to_end_adaptation_set(tiny_bundle):
    cfg, params, model, batches = tiny_bundle
    assert set(model.adaptations) == {3.5, 4.0, 4.5}
    # one overlay per linear unit, shared across all targets (memory story)
    from repro.models import linear_units
    assert set(model.overlays) == {u.path for u in linear_units(cfg)}


def test_dynamic_beats_or_matches_uniform(tiny_bundle):
    """At the same effective bits, dynamic layer-wise >= uniform static.

    On an UNTRAINED tiny model perplexity gaps are small; assert the
    ordering within a tolerance rather than a strict win (the trained-model
    benchmark in benchmarks/perplexity_tradeoff.py shows the real gap).
    """
    cfg, params, model, batches = tiny_bundle
    eng = ServingEngine(cfg, params, model)
    toks = batches[0][0][:1, :24]
    nll_dyn, eb = eng.teacher_forced_nll(toks, 3.5)
    from repro.core import uniform_allocation
    from repro.models import linear_units
    units = linear_units(cfg)
    model.static_tables["uniform4"] = {
        3.5: {u.path: 4 for u in units}}
    nll_u4, _ = eng.teacher_forced_nll(toks, 3.5, mode="static:uniform4")
    # dynamic@~3.5 effective bits should be within noise of uniform 4-bit
    assert nll_dyn < nll_u4 + 0.5, (nll_dyn, nll_u4)


def test_exact_selector_upper_bounds_approx(tiny_bundle):
    cfg, params, model, batches = tiny_bundle
    eng = ServingEngine(cfg, params, model)
    toks = batches[0][0][:1, :24]
    nll_apx, _ = eng.teacher_forced_nll(toks, 3.5)
    nll_ext, _ = eng.teacher_forced_nll(toks, 3.5, mode="exact")
    # Table 3: approx within a small margin of exact
    assert nll_apx <= nll_ext + 0.25, (nll_apx, nll_ext)


def test_train_restart_resumes_identically():
    """Fault tolerance: a run with an injected failure + restart produces
    the same final loss as an uninterrupted run (same data stream)."""
    from repro.launch.train import train
    with tempfile.TemporaryDirectory() as td:
        _, losses_clean = train("tiny-dense", steps=8, seq_len=32,
                                global_batch=4, ckpt_dir=None,
                                log=lambda *a, **k: None)
        _, losses_failed = train("tiny-dense", steps=8, seq_len=32,
                                 global_batch=4,
                                 ckpt_dir=os.path.join(td, "ck"),
                                 save_every=2, fail_at_step=5,
                                 log=lambda *a, **k: None)
    assert np.isfinite(losses_clean[-1])
    # the restarted run replays steps >= the restored checkpoint; final
    # losses agree because data + init are deterministic
    assert abs(losses_clean[-1] - losses_failed[-1]) < 0.3


def test_training_reduces_loss():
    from repro.launch.train import train
    _, losses = train("tiny-dense", steps=30, seq_len=64, global_batch=4,
                      lr=3e-3, log=lambda *a, **k: None)
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
