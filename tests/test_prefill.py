"""Prefill/decode disaggregation: bit-identity, launch counts, handoff.

The batched prefill stage must be a pure LAUNCH-SHAPE change: identical
tokens and identical per-token effective bits to the legacy tick-by-tick
path (the engines differ only in ``prefill_chunk``), while issuing
O(prompt_len / prefill_chunk) launches instead of O(prompt_len). The
prefill→decode handoff (``serving/kv_cache``) is exercised at the
scheduler level: prefill-at-admission + KV insert must reproduce the
legacy spun-boot scheduler bit for bit.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (LatencyModel, QoSPlanner, Request, ServingEngine,
                           SlotScheduler, handoff_state, insert_slot_state,
                           make_decode_state, make_prefill_state,
                           n_prefill_chunks, prefill_len, reset_state,
                           stage_bytes, state_bytes)

PREFILL_CHUNK = 8


@pytest.fixture(scope="module")
def engines(tiny_bundle):
    """(staged, legacy) engine pair: identical but for the prefill stage."""
    cfg, params, model, _ = tiny_bundle
    staged = ServingEngine(cfg, params, model,
                           prefill_chunk=PREFILL_CHUNK)
    legacy = ServingEngine(cfg, params, model, prefill_chunk=0)
    return staged, legacy


def _planner(model):
    return QoSPlanner(sorted(model.adaptations),
                      LatencyModel(bytes_per_bit=1e9), chips=1)


# ---------------------------------------------------------------------------
# Bit-identity: prefill stage vs legacy tick-by-tick, all 4 modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["dynamic", "static:llm_mq", "max",
                                  "exact"])
def test_prefill_bit_identity_all_modes(engines, tiny_bundle, mode):
    """Same tokens AND same per-token effective bits in every mode —
    short prompt (one bucketed launch) and long prompt (multi-chunk
    prefill with a carried decision vector across chunk boundaries)."""
    _, _, _, batches = tiny_bundle
    staged, legacy = engines
    for p in (4, 2 * PREFILL_CHUNK + 3):
        prompt = batches[0][0][:1, :p]
        out_l, eb_l = legacy.generate(prompt, 6, 3.5, mode=mode)
        out_s, eb_s = staged.generate(prompt, 6, 3.5, mode=mode)
        assert np.array_equal(out_l, out_s), (mode, p)
        np.testing.assert_allclose(eb_s, eb_l, atol=1e-5)
    toks = batches[0][0][:1, :24]
    nll_l, eb_l = legacy.teacher_forced_nll(toks, 3.5, mode=mode)
    nll_s, eb_s = staged.teacher_forced_nll(toks, 3.5, mode=mode)
    assert abs(nll_l - nll_s) < 1e-5
    np.testing.assert_allclose(eb_s, eb_l, atol=1e-5)


def test_prefill_bit_identity_sync_engine(tiny_bundle):
    """use_async=False: per-row same-tick decisions, no carry."""
    cfg, params, model, batches = tiny_bundle
    staged = ServingEngine(cfg, params, model, use_async=False,
                           prefill_chunk=PREFILL_CHUNK)
    legacy = ServingEngine(cfg, params, model, use_async=False,
                           prefill_chunk=0)
    prompt = batches[0][0][:1, :PREFILL_CHUNK + 3]
    out_l, eb_l = legacy.generate(prompt, 5, 4.0)
    out_s, eb_s = staged.generate(prompt, 5, 4.0)
    assert np.array_equal(out_l, out_s)
    np.testing.assert_allclose(eb_s, eb_l, atol=1e-5)


# ---------------------------------------------------------------------------
# Long-prompt edges
# ---------------------------------------------------------------------------
def test_prompt_longer_than_decode_chunk(tiny_bundle):
    """prompt_len > decode_chunk: the prefill stage covers what used to
    span multiple teacher-forced decode chunks."""
    cfg, params, model, batches = tiny_bundle
    staged = ServingEngine(cfg, params, model, decode_chunk=4,
                           prefill_chunk=PREFILL_CHUNK)
    legacy = ServingEngine(cfg, params, model, decode_chunk=4,
                           prefill_chunk=0)
    prompt = batches[0][0][:1, :11]        # 11 > decode_chunk = 4
    out_l, eb_l = legacy.generate(prompt, 5, 3.5)
    out_s, eb_s = staged.generate(prompt, 5, 3.5)
    assert np.array_equal(out_l, out_s)
    np.testing.assert_allclose(eb_s, eb_l, atol=1e-5)


def test_prompt_straddles_kv_bucket(tiny_bundle):
    """Bucketed KV allocation: prompts on both sides of a kv_bucket
    boundary (and a bucketed prefill tail crossing it) stay bit-identical
    and the cache is always long enough for the padded prefill."""
    cfg, params, model, batches = tiny_bundle
    staged = ServingEngine(cfg, params, model, kv_bucket=16,
                           prefill_chunk=PREFILL_CHUNK)
    legacy = ServingEngine(cfg, params, model, kv_bucket=16,
                           prefill_chunk=0)
    for p in (14, 15, 17):                 # around the 16-token bucket
        prompt = batches[0][0][:1, :p]
        out_l, eb_l = legacy.generate(prompt, 4, 4.0)
        out_s, eb_s = staged.generate(prompt, 4, 4.0)
        assert np.array_equal(out_l, out_s), p
        np.testing.assert_allclose(eb_s, eb_l, atol=1e-5)


def test_single_token_prompt(engines, tiny_bundle):
    """p=1: the prefill launch IS the boot tick (one bucketed row)."""
    _, _, _, batches = tiny_bundle
    staged, legacy = engines
    prompt = batches[0][0][:1, :1]
    out_l, eb_l = legacy.generate(prompt, 5, 4.5)
    out_s, eb_s = staged.generate(prompt, 5, 4.5)
    assert np.array_equal(out_l, out_s)
    np.testing.assert_allclose(eb_s, eb_l, atol=1e-5)


# ---------------------------------------------------------------------------
# Launch counts: O(prompt_len / prefill_chunk), measured not modeled
# ---------------------------------------------------------------------------
def test_prefill_launch_counts(engines, tiny_bundle):
    staged, legacy = engines
    _, _, _, batches = tiny_bundle
    c = staged.decode_chunk
    for p, max_new in ((4, 6), (PREFILL_CHUNK, 6),
                       (2 * PREFILL_CHUNK + 3, 6)):
        prompt = batches[0][0][:1, :p]
        staged.call_counts.clear()
        staged.generate(prompt, max_new, 3.5)
        want_pf = n_prefill_chunks(p, PREFILL_CHUNK)
        want_dec = -(-max_new // c)
        assert staged.call_counts.get("prefill", 0) == want_pf, \
            (p, staged.call_counts)
        assert staged.call_counts.get("chunk", 0) == want_dec
        assert "boot" not in staged.call_counts
        # legacy: the boot tick + one chunk per decode_chunk ticks over
        # the WHOLE stream — prompt launches scale with prompt length
        legacy.call_counts.clear()
        legacy.generate(prompt, max_new, 3.5)
        want_legacy = 1 + -(-(p + max_new - 1) // c)
        got_legacy = legacy.call_counts.get("boot", 0) + \
            legacy.call_counts.get("chunk", 0)
        assert got_legacy == want_legacy, (p, legacy.call_counts)
    # teacher forcing is pure prefill: zero decode chunks
    staged.call_counts.clear()
    staged.teacher_forced_nll(batches[0][0][:1, :24], 3.5)
    assert staged.call_counts.get("prefill", 0) == \
        n_prefill_chunks(23, PREFILL_CHUNK)
    assert "chunk" not in staged.call_counts


def test_prefill_host_syncs_constant(engines, tiny_bundle):
    """The O(1) host-sync invariant survives disaggregation."""
    staged, _ = engines
    _, _, _, batches = tiny_bundle
    before = staged.host_syncs
    staged.generate(batches[0][0][:1, :PREFILL_CHUNK + 2], 8, 3.5)
    assert staged.host_syncs - before == 2


def test_prefill_no_retrace_across_targets(engines, tiny_bundle):
    """The prefill launches are compiled once per mode — switching
    targets and prompt lengths reuses them (lengths share the bucketed
    (b, C) shape; only n_valid changes, and it is traced)."""
    staged, _ = engines
    _, _, model, batches = tiny_bundle
    targets = sorted(model.adaptations)
    staged.generate(batches[0][0][:1, :5], 4, targets[0])      # warm
    staged.generate(batches[0][0][:1, :PREFILL_CHUNK + 2], 4, targets[0])
    baseline = dict(staged.trace_counts)
    for t in targets:
        for p in (3, 6, PREFILL_CHUNK + 1):
            staged.generate(batches[0][0][:1, :p], 4, t)
    assert staged.trace_counts == baseline, (baseline,
                                             staged.trace_counts)


# ---------------------------------------------------------------------------
# Scheduler: prefill admission + KV handoff into slots
# ---------------------------------------------------------------------------
def _requests(cfg, seed=2, budgets=(6e-3, 5.2e-3, 4.6e-3, 1e-3, 6e-3)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (3 + i % 4,)).astype(np.int32),
                    max_new=4 + i % 3, tpot_budget_s=b)
            for i, b in enumerate(budgets)]


def test_scheduler_prefill_matches_legacy_admission(engines, tiny_bundle):
    """Prefill-at-admission + insert handoff == legacy spun-boot
    scheduler: identical targets, tokens, and per-token bits; the first
    generated token is emitted at admission (TTFT recorded)."""
    cfg, _, model, _ = tiny_bundle
    staged, legacy = engines
    s_staged = SlotScheduler(staged, _planner(model), slots=2,
                             max_prompt=8, max_new=6, chunk=4)
    s_legacy = SlotScheduler(legacy, _planner(model), slots=2,
                             max_prompt=8, max_new=6, chunk=4)
    done_s = {r.rid: r for r in s_staged.run(_requests(cfg))}
    done_l = {r.rid: r for r in s_legacy.run(_requests(cfg))}
    assert set(done_s) == set(done_l)
    for rid, rl in done_l.items():
        rs = done_s[rid]
        assert rs.target == rl.target
        assert np.array_equal(rs.tokens, rl.tokens), rid
        np.testing.assert_allclose(rs.effective_bits, rl.effective_bits,
                                   atol=1e-5)
        assert rs.ttft_s is not None and rs.ttft_s > 0
    # admission issued ceil(p/C) prefill launches + ONE insert each
    assert staged.call_counts.get("slot_insert", 0) == len(done_s)
    assert staged.call_counts.get("slot_prefill", 0) == sum(
        n_prefill_chunks(len(r.prompt), PREFILL_CHUNK)
        for r in done_s.values())


def test_scheduler_prefill_sync_engine(tiny_bundle):
    """Sync engine: each prefill-admitted slot decodes exactly like a
    solo tick-by-tick sync engine run at its admitted target. (Direct
    staged-vs-legacy scheduler runs can admit at different targets —
    admission-time utilization evolves differently when prompts stop
    consuming chunk ticks — so the solo engine is the parity oracle.)"""
    cfg, params, model, _ = tiny_bundle
    staged = ServingEngine(cfg, params, model, use_async=False,
                           prefill_chunk=PREFILL_CHUNK)
    legacy = ServingEngine(cfg, params, model, use_async=False,
                           prefill_chunk=0)
    s_staged = SlotScheduler(staged, _planner(model), slots=2,
                             max_prompt=8, max_new=6, chunk=4)
    done_s = {r.rid: r for r in s_staged.run(_requests(cfg, seed=4))}
    for rid, r in done_s.items():
        out, ebits = legacy.generate(r.prompt[None, :], r.max_new,
                                     r.target)
        assert np.array_equal(out[0], r.tokens), rid
        np.testing.assert_allclose(ebits, r.effective_bits, atol=1e-5)


def test_scheduler_prefill_no_retrace(engines, tiny_bundle):
    """Admission churn with varying prompt lengths reuses the compiled
    prefill/insert/chunk steps."""
    cfg, _, model, _ = tiny_bundle
    staged, _ = engines
    sched = SlotScheduler(staged, _planner(model), slots=2, max_prompt=8,
                          max_new=6, chunk=4)
    sched.run(_requests(cfg, seed=5))                # warm
    baseline = dict(staged.trace_counts)
    sched.run(_requests(cfg, seed=6))
    assert staged.trace_counts == baseline


# ---------------------------------------------------------------------------
# M-row decode cells: every model family, raw params
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["tiny-dense", "tiny-sqrelu", "tiny-moe",
                                  "tiny-ssm", "tiny-hybrid", "tiny-encdec"])
def test_decode_step_rows_match_sequential(name):
    """decode_step with (b, M) token rows == M sequential single-token
    ticks: logits per row, KV/SSM state, and position all line up —
    for attention, squared-ReLU, MoE (per-row dispatch), SSM (gated
    recurrence), hybrid interleave, and enc-dec cells."""
    import jax

    from repro.configs import get_config
    from repro.models import decode_step, init_decode_state, \
        init_model_params

    cfg = get_config(name)
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    m = 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, m), 0,
                              cfg.vocab_size)
    st_ref = init_decode_state(cfg, 2, 8, dtype=jnp.float32)
    ref = []
    for t in range(m):
        lg, st_ref = decode_step(cfg, params, st_ref, toks[:, t:t + 1])
        ref.append(lg[:, 0])
    st = init_decode_state(cfg, 2, 8, dtype=jnp.float32)
    logits, st = decode_step(cfg, params, st, toks)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(jnp.stack(ref, axis=1)),
                               rtol=1e-4, atol=1e-4)
    assert int(st["pos"]) == int(st_ref["pos"]) == m
    for k in st_ref:
        np.testing.assert_allclose(np.asarray(st[k]),
                                   np.asarray(st_ref[k]),
                                   rtol=1e-4, atol=1e-4, err_msg=k)


def test_decode_step_rows_pad_gating():
    """Pad rows (>= n_valid) advance nothing the sequential path would
    not have: pos stops at n_valid, SSM conv/recurrent state equals the
    valid prefix's, and KV rows past the prompt are scratch the decode
    stage overwrites before attending."""
    import jax

    from repro.configs import get_config
    from repro.models import decode_step, init_decode_state, \
        init_model_params

    cfg = get_config("tiny-hybrid")
    params = init_model_params(cfg, jax.random.PRNGKey(0))
    nv, m = 3, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, m), 0,
                              cfg.vocab_size)
    st_ref = init_decode_state(cfg, 1, 8, dtype=jnp.float32)
    for t in range(nv):
        lg_ref, st_ref = decode_step(cfg, params, st_ref,
                                     toks[:, t:t + 1])
    st = init_decode_state(cfg, 1, 8, dtype=jnp.float32)
    logits, st = decode_step(cfg, params, st, toks,
                             n_valid=jnp.int32(nv))
    assert int(st["pos"]) == nv
    np.testing.assert_allclose(np.asarray(logits[:, nv - 1]),
                               np.asarray(lg_ref[:, 0]),
                               rtol=1e-4, atol=1e-4)
    for k in st_ref:
        if k.startswith("ssm."):
            np.testing.assert_allclose(np.asarray(st[k]),
                                       np.asarray(st_ref[k]),
                                       rtol=1e-4, atol=1e-4, err_msg=k)
        elif k.startswith("kv.") and st[k].ndim == 4:
            np.testing.assert_allclose(np.asarray(st[k][:, :nv]),
                                       np.asarray(st_ref[k][:, :nv]),
                                       rtol=1e-4, atol=1e-4, err_msg=k)


# ---------------------------------------------------------------------------
# KV handoff contract (serving/kv_cache)
# ---------------------------------------------------------------------------
def test_prefill_len_bucketing():
    assert prefill_len(1, 8) == 8
    assert prefill_len(8, 8) == 8
    assert prefill_len(9, 8) == 16
    assert n_prefill_chunks(17, 8) == 3
    with pytest.raises(ValueError):
        prefill_len(4, 0)


def test_insert_slot_state_offsets():
    """KV block lands at the given offset of the slot's cache; SSM/pos
    leaves transfer wholesale; other slots untouched."""
    from repro.configs import get_config
    cfg = get_config("tiny-dense")
    src = make_prefill_state(cfg, 1, 8, 8, dtype=jnp.float32)
    src = {k: (jnp.arange(v.size, dtype=v.dtype).reshape(v.shape)
               if v.ndim else jnp.int32(5)) for k, v in src.items()}
    proto = make_decode_state(cfg, 1, 20, dtype=jnp.float32)
    dst = {k: jnp.zeros((3,) + v.shape, v.dtype) for k, v in proto.items()}
    out = insert_slot_state(dst, src, 1, offset=2)
    for k, v in src.items():
        if k == "pos":
            assert int(out[k][1]) == 5 + 2
            assert int(out[k][0]) == 0
        elif k.startswith("kv."):
            got = np.asarray(out[k][1, 0])
            np.testing.assert_array_equal(got[2:10], np.asarray(v[0]))
            assert np.all(got[:2] == 0) and np.all(got[10:] == 0)
            assert np.all(np.asarray(out[k][0]) == 0)   # other slots
        else:
            np.testing.assert_array_equal(np.asarray(out[k][1]),
                                          np.asarray(v))


def test_insert_slot_state_clips_long_bucket():
    """A prefill bucket longer than the slot cache inserts only the
    window that fits (pad rows past the prompt are disposable)."""
    from repro.configs import get_config
    cfg = get_config("tiny-dense")
    src = make_prefill_state(cfg, 1, 16, 16, dtype=jnp.float32)  # len 16
    src = {k: jnp.ones_like(v) for k, v in src.items()}
    proto = make_decode_state(cfg, 1, 10, dtype=jnp.float32)     # len 10
    dst = {k: jnp.zeros((2,) + v.shape, v.dtype) for k, v in proto.items()}
    out = insert_slot_state(dst, src, 0, offset=0)
    for k in src:
        if k.startswith("kv."):
            assert np.all(np.asarray(out[k][0, 0]) == 1.0)
            assert out[k].shape[2] == 10


def test_reset_state_donates_buffers():
    """reset_state zeroes through ONE jitted call whose argument is
    DONATED — on accelerator backends XLA reuses the incoming HBM pages
    for the zero fill (CPU ignores donation but honors the contract),
    so slot retirement stops allocating a fresh pytree per query."""
    import jax

    from repro.configs import get_config
    from repro.serving import kv_cache

    cfg = get_config("tiny-dense")
    state = make_decode_state(cfg, 1, 16, dtype=jnp.float32)
    state = {k: v + 1.0 if v.dtype == jnp.float32 else v
             for k, v in state.items()}
    kv_key = next(k for k in state if k.startswith("kv."))
    shape = state[kv_key].shape
    donated = jax.tree.leaves(jax.tree.map(
        lambda i: i.donated,
        kv_cache._zero_state.lower(state).args_info))
    assert donated and all(donated)
    out = reset_state(state)
    assert float(jnp.sum(out[kv_key])) == 0.0
    assert out[kv_key].shape == shape
    # recycling the same shapes reuses the one compiled zero fill
    n = kv_cache._zero_state._cache_size()
    reset_state(out)
    assert kv_cache._zero_state._cache_size() == n


def test_stage_bytes_accounting():
    from repro.configs import get_config
    cfg = get_config("tiny-dense")
    state = make_prefill_state(cfg, 1, 8, 8)
    rep = stage_bytes(state)
    assert rep["total"] == state_bytes(state)
    assert rep["kv"] > 0
    assert rep["total"] == rep["kv"] + rep["ssm"] + rep["xkv"] + \
        rep["other"]


def test_handoff_state_identity():
    """Single-mesh path: the handoff is an identity transfer — the SAME
    arrays come back untouched."""
    state = {"kv.0.k": jnp.ones((1, 4, 2, 8)), "pos": jnp.int32(3)}
    out = handoff_state(state)
    assert out["kv.0.k"] is state["kv.0.k"]
    assert out["pos"] is state["pos"]


# ---------------------------------------------------------------------------
# QoS: TTFT admission term
# ---------------------------------------------------------------------------
def test_qos_ttft_model_monotone():
    lat = LatencyModel(bytes_per_bit=1e9)
    assert lat.ttft(4.0, 64, 16) == pytest.approx(4 * lat.tpot(4.0))
    assert lat.ttft(4.0, 64, 16) < lat.ttft(4.0, 64, 8)
    assert lat.ttft(4.0, 64, 1) == pytest.approx(64 * lat.tpot(4.0))
    assert lat.ttft(3.0, 64, 16) < lat.ttft(5.0, 64, 16)


def test_qos_ttft_guards_long_prompts():
    """A long prompt with a tight TTFT budget admits at a lower
    precision than TPOT alone would pick; chunked prefill restores it."""
    lat = LatencyModel(bytes_per_bit=1e9)
    pl = QoSPlanner([3.0, 4.0, 5.0], lat, chips=1)
    tpot_only = pl.plan(8e-3)
    assert tpot_only == 5.0
    # tick-by-tick prefill of a 64-token prompt blows an 80ms TTFT
    # budget at 5 bits (64 * 6.3ms); only 3.0 fits
    tight = pl.plan(8e-3, prompt_len=64, ttft_budget_s=0.27,
                    prefill_chunk=None)
    assert tight == 3.0
    # the batched prefill stage (chunk 16 -> 4 launches) restores 5.0
    staged = pl.plan(8e-3, prompt_len=64, ttft_budget_s=0.27,
                     prefill_chunk=16)
    assert staged == 5.0
    # no TTFT budget -> TPOT-only admission (back-compat)
    assert pl.plan(8e-3, prompt_len=64) == tpot_only
    # a TTFT budget without a prompt length is a loud error, not a
    # silently skipped guard
    with pytest.raises(ValueError):
        pl.plan(8e-3, ttft_budget_s=0.1)
