"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes, dtypes, and precisions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bitplane import materialize, quantize_linear
from repro.kernels.bitserial import bitserial_matmul
from repro.kernels.dequant_matmul import dequant_matmul
from repro.kernels.jl_estimator import jl_estimate


@pytest.mark.parametrize("k,n,m", [(64, 128, 1), (128, 256, 8),
                                   (96, 128, 3), (256, 512, 16)])
@pytest.mark.parametrize("bits,b_sel", [(6, 3), (6, 6), (8, 4), (4, 2)])
def test_bitserial_interpret_vs_ref(k, n, m, bits, b_sel):
    w = jax.random.normal(jax.random.PRNGKey(k + n), (k, n)) * 0.2
    ql = quantize_linear(w, bits=bits)
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    y_ref = bitserial_matmul(x, ql, b_sel, backend="ref")
    y_int = bitserial_matmul(x, ql, b_sel, backend="interpret")
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_ref, x @ materialize(ql, b_sel),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitserial_dtypes(dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128)).astype(dtype)
    y_ref = bitserial_matmul(x, ql, 4, backend="ref")
    y_int = bitserial_matmul(x, ql, 4, backend="interpret")
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-4, atol=1e-3)


def test_bitserial_traffic_skips_planes():
    """The clamped index_map means planes >= b_sel are never re-fetched:
    consecutive grid steps past b_sel name the same block index."""
    from repro.kernels.bitserial.kernel import bitserial_matmul_pallas
    # behavioural proxy testable on CPU: results identical whether the
    # overlay physically stores 6 planes or is truncated to b_sel planes
    from repro.core.bitplane import truncate_overlay
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 128)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64))
    full = bitserial_matmul(x, ql, 3, backend="interpret")
    trunc = bitserial_matmul(x, truncate_overlay(ql, 3), 3, backend="ref")
    np.testing.assert_allclose(full, trunc, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("l,kproj,k,m", [(4, 16, 96, 1), (2, 64, 128, 8)])
def test_jl_estimator_interpret_vs_ref(l, kproj, k, m):
    g = jax.random.normal(jax.random.PRNGKey(0), (l, kproj, k))
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    t = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (l,))) * 5
    e1, s1 = jl_estimate(x, g, t, backend="ref")
    e2, s2 = jl_estimate(x, g, t, backend="interpret")
    np.testing.assert_allclose(e1, e2, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_jl_concentration(seed):
    """JL lemma: ||Ax|| concentrates around ||x|| for k=64 (property)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (1, 512))
    a = jax.random.normal(k2, (1, 64, 512)) / np.sqrt(64)
    est, _ = jl_estimate(x, a, jnp.zeros((1,)), backend="ref")
    true = float(jnp.linalg.norm(x))
    assert abs(float(est[0]) - true) / true < 0.5   # loose 1-sample bound


@pytest.mark.parametrize("bits_active", [3, 6])
def test_dequant_matmul_interpret_vs_ref(bits_active):
    k, n, m = 512, 256, 256
    w = jax.random.normal(jax.random.PRNGKey(7), (k, n)) * 0.1
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(8), (m, k))
    y_ref = dequant_matmul(x, ql, bits_active, backend="ref")
    y_int = dequant_matmul(x, ql, bits_active, backend="interpret")
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(y_ref, x @ materialize(ql, bits_active),
                               rtol=2e-4, atol=2e-3)


def test_dequant_matmul_small_shapes_fall_back():
    # non-tileable shapes silently use the oracle (dispatch correctness)
    w = jax.random.normal(jax.random.PRNGKey(9), (96, 40)) * 0.1
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(10), (3, 96))
    y = dequant_matmul(x, ql, 4, backend="interpret")
    np.testing.assert_allclose(y, x @ materialize(ql, 4), rtol=2e-4,
                               atol=2e-3)
