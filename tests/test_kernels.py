"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes, dtypes, and precisions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bitplane import materialize, quantize_linear
from repro.kernels.bitserial import bitserial_matmul
from repro.kernels.dequant_matmul import dequant_matmul
from repro.kernels.jl_estimator import jl_estimate


@pytest.mark.parametrize("k,n,m", [(64, 128, 1), (128, 256, 8),
                                   (96, 128, 3), (256, 512, 16)])
@pytest.mark.parametrize("bits,b_sel", [(6, 3), (6, 6), (8, 4), (4, 2)])
def test_bitserial_interpret_vs_ref(k, n, m, bits, b_sel):
    w = jax.random.normal(jax.random.PRNGKey(k + n), (k, n)) * 0.2
    ql = quantize_linear(w, bits=bits)
    x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
    y_ref = bitserial_matmul(x, ql, b_sel, backend="ref")
    y_int = bitserial_matmul(x, ql, b_sel, backend="interpret")
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_ref, x @ materialize(ql, b_sel),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitserial_dtypes(dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128)).astype(dtype)
    y_ref = bitserial_matmul(x, ql, 4, backend="ref")
    y_int = bitserial_matmul(x, ql, 4, backend="interpret")
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-4, atol=1e-3)


def test_unknown_backend_rejected():
    """A typo'd backend raises up front instead of silently reaching the
    dispatch un-padded / un-validated."""
    w = jax.random.normal(jax.random.PRNGKey(30), (64, 128)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(31), (2, 64))
    with pytest.raises(ValueError, match="unknown backend"):
        bitserial_matmul(x, ql, 3, backend="Interpret")
    with pytest.raises(ValueError, match="unknown backend"):
        dequant_matmul(x, ql, 3, backend="cuda")


def test_bitserial_b_sel_zero_is_zeros_unbatched():
    """b_sel = 0 (an inactive applier outside the slot vmap) follows the
    same idle contract as the batched path: zeros, not the oracle's
    midpoint-correction residue."""
    w = jax.random.normal(jax.random.PRNGKey(21), (64, 128)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(22), (2, 64))
    for backend in ("ref", "interpret"):
        np.testing.assert_array_equal(
            np.asarray(bitserial_matmul(x, ql, 0, backend=backend)), 0.0)


def test_bitserial_traffic_skips_planes():
    """The clamped index_map means planes >= b_sel are never re-fetched:
    consecutive grid steps past b_sel name the same block index."""
    from repro.kernels.bitserial.kernel import bitserial_matmul_pallas
    # behavioural proxy testable on CPU: results identical whether the
    # overlay physically stores 6 planes or is truncated to b_sel planes
    from repro.core.bitplane import truncate_overlay
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 128)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64))
    full = bitserial_matmul(x, ql, 3, backend="interpret")
    trunc = bitserial_matmul(x, truncate_overlay(ql, 3), 3, backend="ref")
    np.testing.assert_allclose(full, trunc, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("l,kproj,k,m", [(4, 16, 96, 1), (2, 64, 128, 8)])
def test_jl_estimator_interpret_vs_ref(l, kproj, k, m):
    g = jax.random.normal(jax.random.PRNGKey(0), (l, kproj, k))
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    t = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (l,))) * 5
    e1, s1 = jl_estimate(x, g, t, backend="ref")
    e2, s2 = jl_estimate(x, g, t, backend="interpret")
    np.testing.assert_allclose(e1, e2, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_jl_estimate_multi_row_is_row_max():
    """The documented batch contract: a multi-row input yields the
    conservative row-max estimate per layer — NOT row 0's estimate (the
    silent-truncation failure mode), and NOT any other single row's."""
    l, kproj, k, m = 3, 8, 64, 5
    g = jax.random.normal(jax.random.PRNGKey(5), (l, kproj, k))
    x = jax.random.normal(jax.random.PRNGKey(6), (m, k)) * \
        jnp.arange(1, m + 1, dtype=jnp.float32)[:, None]   # rows differ
    thr = jnp.zeros((l,))
    for backend in ("ref", "interpret"):
        err, _ = jl_estimate(x, g, thr, backend=backend)
        per_row = jnp.stack(
            [jl_estimate(x[i:i + 1], g, thr, backend=backend)[0]
             for i in range(m)])                            # (m, l)
        np.testing.assert_allclose(err, jnp.max(per_row, axis=0),
                                   rtol=1e-6)
        # the scaled rows make row 0 strictly smaller: err must not be it
        assert np.all(np.asarray(err) > np.asarray(per_row[0]) * 1.5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_jl_concentration(seed):
    """JL lemma: ||Ax|| concentrates around ||x|| for k=64 (property)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (1, 512))
    a = jax.random.normal(k2, (1, 64, 512)) / np.sqrt(64)
    est, _ = jl_estimate(x, a, jnp.zeros((1,)), backend="ref")
    true = float(jnp.linalg.norm(x))
    assert abs(float(est[0]) - true) / true < 0.5   # loose 1-sample bound


@pytest.mark.parametrize("bits_active", [3, 6])
def test_dequant_matmul_interpret_vs_ref(bits_active):
    k, n, m = 512, 256, 256
    w = jax.random.normal(jax.random.PRNGKey(7), (k, n)) * 0.1
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(8), (m, k))
    y_ref = dequant_matmul(x, ql, bits_active, backend="ref")
    y_int = dequant_matmul(x, ql, bits_active, backend="interpret")
    np.testing.assert_allclose(y_int, y_ref, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(y_ref, x @ materialize(ql, bits_active),
                               rtol=2e-4, atol=2e-3)


def test_dequant_small_shapes_auto_falls_back_to_oracle():
    # auto mode on non-tileable shapes uses the oracle (and logs once)
    w = jax.random.normal(jax.random.PRNGKey(9), (96, 40)) * 0.1
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(10), (3, 96))
    y = dequant_matmul(x, ql, 4)
    np.testing.assert_allclose(y, x @ materialize(ql, 4), rtol=2e-4,
                               atol=2e-3)


def test_dequant_explicit_backend_pads_n():
    """backend="interpret" is honored on untileable N: the wrapper pads N
    to the tile and slices back instead of silently rerouting to the
    oracle."""
    w = jax.random.normal(jax.random.PRNGKey(9), (512, 40)) * 0.1
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(10), (256, 512))
    y = dequant_matmul(x, ql, 4, backend="interpret")
    assert y.shape == (256, 40)
    np.testing.assert_allclose(y, x @ materialize(ql, 4), rtol=2e-4,
                               atol=2e-3)


def test_dequant_explicit_backend_rejects_untileable_mk():
    w = jax.random.normal(jax.random.PRNGKey(9), (512, 256)) * 0.1
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(10), (3, 512))
    with pytest.raises(ValueError, match="backend='interpret'"):
        dequant_matmul(x, ql, 4, backend="interpret")


def test_bitserial_explicit_backend_pads_n():
    """Explicit kernel backends never silently fall back: untileable N is
    padded to the tile (zero-scale pad columns) and sliced back."""
    w = jax.random.normal(jax.random.PRNGKey(11), (64, 40)) * 0.2
    ql = quantize_linear(w, bits=6)
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 64))
    y_int = bitserial_matmul(x, ql, 3, backend="interpret")
    assert y_int.shape == (2, 40)
    np.testing.assert_allclose(y_int, bitserial_matmul(x, ql, 3,
                                                       backend="ref"),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_int, x @ materialize(ql, 3),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Batched-slot kernel: per-slot DMA elision over heterogeneous precisions
# ---------------------------------------------------------------------------
def _slot_setup(k=64, n=256, bits=6, slots=5, m=2, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.2
    ql = quantize_linear(w, bits=bits)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (slots, m, k)).astype(jnp.float32)
    return ql, x


@pytest.mark.parametrize("b_sel", [[3, 0, 6, 1, 0], [2, 2, 2, 2, 2],
                                   [0, 0, 0, 0, 0], [6, 5, 4, 3, 1]])
def test_slot_kernel_interpret_vs_vmapped_ref(b_sel):
    """The batched kernel is bit-level-equivalent to the vmapped oracle
    across heterogeneous per-slot precisions, including idle (b_sel = 0)
    slots (defined as zero output) and all-idle batches."""
    from repro.kernels.bitserial import (bitserial_matmul_slots_pallas,
                                         bitserial_matmul_slots_ref)
    ql, x = _slot_setup()
    bvec = jnp.asarray(b_sel, jnp.int32)
    scale, zero = ql.scale[None, :], ql.zero[None, :]
    y_ref = bitserial_matmul_slots_ref(x, ql.planes, scale, zero, bvec,
                                       bits=ql.bits)
    y_int = bitserial_matmul_slots_pallas(x, ql.planes, scale, zero, bvec,
                                          bits=ql.bits, tile_n=128,
                                          interpret=True)
    y_int = jnp.where((bvec > 0)[:, None, None], y_int, 0.0)
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-5)
    for s, b in enumerate(b_sel):
        if b == 0:
            np.testing.assert_array_equal(np.asarray(y_ref[s]), 0.0)
        else:
            np.testing.assert_allclose(
                y_ref[s], x[s] @ materialize(ql, b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_vmapped_bitserial_dispatches_to_slot_batch(backend):
    """jax.vmap over (x, b_sel) — the scheduler's slot axis — routes
    through the custom_vmap rule into the slot-batched path instead of
    generically lifting the single-request kernel."""
    from repro.kernels.bitserial import TRACE_COUNTS, \
        bitserial_matmul_slots_ref
    ql, x = _slot_setup()
    bvec = jnp.asarray([3, 0, 6, 1, 2], jnp.int32)
    before = TRACE_COUNTS.get("slots", 0)
    y = jax.vmap(lambda xs, bs: bitserial_matmul(xs, ql, bs,
                                                 backend=backend))(x, bvec)
    assert TRACE_COUNTS.get("slots", 0) > before   # slot path, not generic
    y_ref = bitserial_matmul_slots_ref(
        x, ql.planes, ql.scale[None, :], ql.zero[None, :], bvec,
        bits=ql.bits)
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


def test_slot_dispatch_no_retrace_across_b_sel():
    """Different b_sel vectors (same shapes) reuse ONE compiled slot
    dispatch — precision churn in the scheduler never retraces."""
    from repro.kernels.bitserial import TRACE_COUNTS
    ql, x = _slot_setup(seed=20)
    fn = lambda xs, bs: bitserial_matmul(xs, ql, bs, backend="ref")
    jax.vmap(fn)(x, jnp.asarray([1, 2, 3, 4, 5], jnp.int32))   # warm
    before = dict(TRACE_COUNTS)
    for bvec in ([5, 4, 3, 2, 1], [0, 0, 6, 0, 1], [6, 6, 6, 6, 6]):
        jax.vmap(fn)(x, jnp.asarray(bvec, jnp.int32))
    assert TRACE_COUNTS == before, (before, TRACE_COUNTS)


def test_slot_plane_traffic_proportional_to_bits():
    """The elision contract, asserted: walking the grid through the
    kernel's actual plane index_map counts n_tiles * sum(b_sel) fetches
    (+1 when the batch ends idle) — NOT slots * n_tiles * bits. Idle slots
    pin to one block, so an idle run costs at most one fetch."""
    from repro.kernels.bitserial import plane_block_fetches
    bits, n_tiles = 6, 4
    for b_sel in ([3, 0, 6, 1, 0], [1, 1, 1, 1], [6, 6], [2, 0, 0, 4]):
        got = plane_block_fetches(b_sel, n_tiles, bits)
        want = n_tiles * sum(b_sel) + (1 if b_sel[-1] == 0 else 0)
        assert got == want, (b_sel, got, want)
        naive = len(b_sel) * n_tiles * bits
        assert got <= naive
        if any(b < bits for b in b_sel):
            assert got < naive
    # all-idle batch: the whole grid names one pinned block
    assert plane_block_fetches([0, 0, 0], n_tiles, bits) == 1
    # adding one bit to one busy slot costs exactly n_tiles more fetches
    base = plane_block_fetches([3, 2, 4], n_tiles, bits)
    assert plane_block_fetches([3, 3, 4], n_tiles, bits) == base + n_tiles


# ---------------------------------------------------------------------------
# Fused decision planner: one launch resolves every unit's precision
# ---------------------------------------------------------------------------
def _plan_setup(u=6, t=3, m=2, k=128, kproj=16, seed=0):
    """Synthetic decision tables in the DecisionBundle layout, with the
    g_row DMA-elision chain (non-JL entries repeat the previous row)."""
    rng = np.random.default_rng(seed)
    tables = {
        "l": jnp.asarray(rng.integers(2, 4, (u, t)), jnp.int32),
        "h": jnp.asarray(rng.integers(5, 7, (u, t)), jnp.int32),
        "kind": jnp.asarray(rng.integers(0, 3, (u, t)), jnp.int32),
        "threshold": jnp.asarray(
            rng.uniform(0.1, 3.0, (u, t)).astype(np.float32)),
        "a": jnp.asarray(rng.uniform(0, 0.2, (u, t)).astype(np.float32)),
        "b": jnp.asarray(rng.uniform(0, 0.2, (u, t)).astype(np.float32)),
        "gamma": jnp.asarray(
            rng.uniform(0.5, 1.5, (u, t)).astype(np.float32)),
    }
    kinds = np.asarray(tables["kind"])
    g_rows = [np.zeros((kproj, k), np.float32)]
    g_row = np.zeros((u, t), np.int32)
    prev = np.zeros((t,), np.int32)
    for ui in range(u):
        for ti in range(t):
            if kinds[ui, ti] == 2:                       # KIND_JL
                g_row[ui, ti] = len(g_rows)
                g_rows.append(rng.normal(size=(kproj, k))
                              .astype(np.float32) / np.sqrt(kproj))
            else:
                g_row[ui, ti] = prev[ti]
        prev = g_row[ui]
    tables["g"] = jnp.asarray(np.stack(g_rows))
    tables["g_row"] = jnp.asarray(g_row)
    x = jnp.asarray(rng.normal(size=(u, m, k)).astype(np.float32))
    return tables, x, kinds, g_row


@pytest.mark.parametrize("t", [0, 1, 2])
def test_plan_bits_interpret_vs_ref(t):
    from repro.kernels.jl_estimator import plan_bits
    tables, x, _, _ = _plan_setup()
    b_ref = plan_bits(x, tables, t, backend="ref")
    b_int = plan_bits(x, tables, t, backend="interpret")
    assert b_ref.shape == (x.shape[0],)
    np.testing.assert_array_equal(np.asarray(b_ref), np.asarray(b_int))
    # pinned rows always take l; decisions land on l or h everywhere
    kinds = np.asarray(tables["kind"])[:, t]
    lo = np.asarray(tables["l"])[:, t]
    hi = np.asarray(tables["h"])[:, t]
    got = np.asarray(b_ref)
    assert np.all((got == lo) | (got == hi))
    assert np.all(got[kinds == 0] == lo[kinds == 0])


def test_plan_bits_idle_gate_zeros():
    from repro.kernels.jl_estimator import plan_bits
    tables, x, _, _ = _plan_setup()
    for backend in ("ref", "interpret"):
        bits = plan_bits(x, tables, 1, active=False, backend=backend)
        np.testing.assert_array_equal(np.asarray(bits), 0)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_plan_bits_vmapped_slots_parity_incl_idle(backend):
    """jax.vmap over (x, target, active) — the scheduler's slot axis —
    routes through the custom_vmap rule into the (S, U) slot planner and
    matches the per-slot loop exactly, idle slots gated to all-zero."""
    from repro.kernels.jl_estimator import TRACE_COUNTS, plan_bits
    tables, _, _, _ = _plan_setup()
    s, u, m, k = 4, 6, 2, 128
    rng = np.random.default_rng(7)
    xs = jnp.asarray(rng.normal(size=(s, u, m, k)).astype(np.float32))
    ts = jnp.asarray([0, 1, 2, 0], jnp.int32)
    act = jnp.asarray([True, True, False, True])
    before = TRACE_COUNTS.get("plan_slots", 0)
    bs = jax.vmap(lambda xv, tv, av: plan_bits(xv, tables, tv, av,
                                               backend=backend))(xs, ts, act)
    assert TRACE_COUNTS.get("plan_slots", 0) > before
    man = np.stack([np.asarray(plan_bits(xs[i], tables, ts[i], act[i],
                                         backend=backend))
                    for i in range(s)])
    np.testing.assert_array_equal(np.asarray(bs), man)
    np.testing.assert_array_equal(np.asarray(bs)[2], 0)   # idle slot


def test_plan_bits_no_retrace_across_targets_and_slots():
    """Different targets / active masks / slot b-vectors reuse ONE
    compiled planner dispatch — per-tick decision churn never retraces."""
    from repro.kernels.jl_estimator import TRACE_COUNTS, plan_bits
    tables, x, _, _ = _plan_setup()
    plan_bits(x, tables, 0, backend="ref")                    # warm
    s = 3
    xs = jnp.stack([x] * s)
    vf = jax.jit(jax.vmap(lambda xv, tv, av: plan_bits(
        xv, tables, tv, av, backend="ref")))
    vf(xs, jnp.asarray([0, 1, 2]), jnp.asarray([True, True, True]))  # warm
    before = dict(TRACE_COUNTS)
    for t in (0, 1, 2):
        plan_bits(x, tables, t, backend="ref")
        plan_bits(x, tables, t, active=False, backend="ref")
    vf(xs, jnp.asarray([2, 0, 1]), jnp.asarray([False, True, True]))
    assert TRACE_COUNTS == before, (before, TRACE_COUNTS)


def test_plan_bits_one_estimator_gemm_regardless_of_units():
    """THE op-count invariant of the decide/apply split: the fused
    planner issues exactly ONE estimator GEMM (dot_general) no matter
    how many units the model has — O(1) dispatched decision work on the
    decode critical path, vs O(U) for the inline path."""
    from repro.kernels.common import count_jaxpr_primitives
    from repro.kernels.jl_estimator import plan_bits

    for u in (4, 16):
        tables, x, _, _ = _plan_setup(u=u)
        jx = jax.make_jaxpr(
            lambda xv: plan_bits(xv, tables, 1, backend="ref"))(x)
        got = count_jaxpr_primitives(jx.jaxpr, "dot_general")
        assert got == 1, (u, got)


def test_planner_g_traffic_proportional_to_jl_units():
    """The planner-side DMA-elision contract: walking the grid through
    the scalar-prefetched g_row table fetches one block per JL unit
    (plus one leading dummy when the walk starts on a non-JL unit) —
    NOT one per unit."""
    from repro.kernels.jl_estimator import g_block_fetches
    tables, _, kinds, g_row = _plan_setup(u=8, seed=3)
    for t in range(kinds.shape[1]):
        n_jl = int((kinds[:, t] == 2).sum())
        lead = 1 if kinds[0, t] != 2 else 0
        got = g_block_fetches(g_row[:, t])
        assert got == n_jl + lead, (t, got, n_jl, lead)
        assert got <= kinds.shape[0]
    # slot-batched walk: consecutive slots chain through the same table
    two = np.stack([g_row[:, 0], g_row[:, 0]])
    assert g_block_fetches(two) <= 2 * g_block_fetches(g_row[:, 0])
